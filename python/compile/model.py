"""L2: JAX model zoo + training programs for the SRigL reproduction.

Each model config yields four AOT-exportable programs (flat positional
signatures; ordering is recorded in artifacts/manifest.json):

  train_step(*params, *momenta, *masks, x, y, lr)
      -> (*params', *momenta', loss)
      One masked SGD(+momentum, weight decay, optional label smoothing)
      step. Sparse params are multiplied by their mask in the forward and
      re-masked after the update so pruned weights stay exactly zero.

  dense_grad(*params, *masks, x, y) -> (*grads_for_sparse_params)
      Gradients w.r.t. the *effective* (masked) weights, dL/d(w .* m) — these
      are dense (non-zero at pruned positions) and drive the RigL/SRigL
      regrowth criterion (paper Section 3.1 step 1).

  eval_logits(*params, *masks, x) -> (logits,)
  loss_eval(*params, *masks, x, y) -> (loss,)

The topology (masks) lives in the rust L3 coordinator; masks enter here as
f32 tensors so the HLO stays static-shaped while connectivity evolves.

The MLP family's forward runs through the L1 Pallas ``masked_matmul``
kernel so kernel + model lower into a single HLO module; the CNN and
transformer families use jnp ops (the mask multiply lowers adjacent to
the matmul/conv, where XLA's compile-time fusion folds it into the op's
epilogue).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

from compile.kernels.masked_dense import masked_matmul


# --------------------------------------------------------------------------
# Parameter specs
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ParamSpec:
    """Metadata for one trainable tensor, mirrored into manifest.json."""

    name: str
    shape: tuple
    sparse: bool = False
    # Axis indexing neurons/filters (always 0 for our layouts); fan_in is the
    # dense fan-in per neuron = prod(shape[1:]) for sparse params.
    neuron_axis: int = 0
    init: str = "zeros"  # zeros | ones | he | normal:<sigma>

    @property
    def fan_in(self) -> int:
        out = 1
        for s in self.shape[1:]:
            out *= s
        return out

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "shape": list(self.shape),
            "dtype": "f32",
            "sparse": self.sparse,
            "neuron_axis": self.neuron_axis,
            "fan_in": self.fan_in,
            "init": self.init,
        }


@dataclasses.dataclass
class ModelSpec:
    """A fully-described model config: params, shapes, and forward fn."""

    name: str
    params: list  # [ParamSpec]
    batch: int
    x_shape: tuple  # without batch
    x_dtype: str  # "f32" | "i32"
    y_shape: tuple  # without batch; () for class label, (T,) for LM targets
    y_dtype: str
    num_classes: int
    forward: Callable  # forward(eff_params: dict, x) -> logits
    task: str  # "classify" | "lm"
    label_smoothing: float = 0.0
    momentum: float = 0.9
    weight_decay: float = 0.0

    @property
    def sparse_params(self):
        return [p for p in self.params if p.sparse]


# --------------------------------------------------------------------------
# Shared pieces
# --------------------------------------------------------------------------

def _cross_entropy(logits, y, num_classes, smoothing):
    """Mean softmax cross-entropy; logits (..., C), y integer (...)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, num_classes, dtype=logits.dtype)
    if smoothing > 0.0:
        onehot = onehot * (1.0 - smoothing) + smoothing / num_classes
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def apply_masks(params: dict, masks: dict) -> dict:
    """Effective parameters: sparse weights are elementwise-masked."""
    return {k: (v * masks[k] if k in masks else v) for k, v in params.items()}


def make_loss_fn(spec: ModelSpec):
    def loss_fn(eff: dict, x, y):
        logits = spec.forward(eff, x)
        if spec.task == "lm":
            c = logits.shape[-1]
            return _cross_entropy(logits, y, c, spec.label_smoothing)
        return _cross_entropy(logits, y, spec.num_classes, spec.label_smoothing)

    return loss_fn


# --------------------------------------------------------------------------
# MLP family — forward goes through the L1 Pallas masked kernel
# --------------------------------------------------------------------------

def build_mlp(name, dims, batch, num_classes, use_pallas=True,
              label_smoothing=0.0, weight_decay=5e-4):
    """dims = [in, h1, ..., out]; every weight matrix is sparse."""
    params = []
    for i in range(len(dims) - 1):
        params.append(ParamSpec(f"l{i}.w", (dims[i + 1], dims[i]), sparse=True, init="he"))
        params.append(ParamSpec(f"l{i}.b", (dims[i + 1],)))
    n_layers = len(dims) - 1

    def forward(eff, x):
        h = x
        for i in range(n_layers):
            w = eff[f"l{i}.w"]
            if use_pallas:
                # Kernel expects (w, m) separately; eff is already masked, so
                # pass an all-ones mask — the multiply is a no-op but routes
                # the matmul through the Pallas kernel schedule.
                h = masked_matmul(h, w, jnp.ones_like(w)) + eff[f"l{i}.b"][None, :]
            else:
                h = h @ w.T + eff[f"l{i}.b"][None, :]
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return h

    return ModelSpec(
        name=name, params=params, batch=batch,
        x_shape=(dims[0],), x_dtype="f32", y_shape=(), y_dtype="i32",
        num_classes=num_classes, forward=forward, task="classify",
        label_smoothing=label_smoothing, weight_decay=weight_decay,
    )


# --------------------------------------------------------------------------
# CNN family — proxy for ResNet-18/50, WRN-22 experiments
# --------------------------------------------------------------------------

def build_cnn(name, channels, batch, num_classes, image=16, in_ch=3,
              label_smoothing=0.1, weight_decay=1e-4):
    """Small conv net: [conv3x3 -> relu -> pool2]* -> GAP -> fc.

    channels = e.g. (16, 32, 64). Conv weights are sparse with constant
    fan-in per *filter* (fan-in = in*kh*kw), matching the paper's treatment
    of convolutions; the classifier fc is sparse too.
    """
    params = []
    prev = in_ch
    for i, c in enumerate(channels):
        params.append(ParamSpec(f"conv{i}.w", (c, prev, 3, 3), sparse=True, init="he"))
        params.append(ParamSpec(f"conv{i}.b", (c,)))
        prev = c
    params.append(ParamSpec("fc.w", (num_classes, prev), sparse=True, init="he"))
    params.append(ParamSpec("fc.b", (num_classes,)))
    n_conv = len(channels)

    def forward(eff, x):
        h = x  # (B, C, H, W)
        for i in range(n_conv):
            w = eff[f"conv{i}.w"]
            h = jax.lax.conv_general_dilated(
                h, w, window_strides=(1, 1), padding="SAME",
                dimension_numbers=("NCHW", "OIHW", "NCHW"))
            h = h + eff[f"conv{i}.b"][None, :, None, None]
            h = jax.nn.relu(h)
            if i < n_conv - 1:  # pool all but last stage
                h = jax.lax.reduce_window(
                    h, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
        h = jnp.mean(h, axis=(2, 3))  # global average pool -> (B, C)
        return h @ eff["fc.w"].T + eff["fc.b"][None, :]

    return ModelSpec(
        name=name, params=params, batch=batch,
        x_shape=(in_ch, image, image), x_dtype="f32", y_shape=(), y_dtype="i32",
        num_classes=num_classes, forward=forward, task="classify",
        label_smoothing=label_smoothing, weight_decay=weight_decay,
    )


# --------------------------------------------------------------------------
# Transformer family — ViT-proxy classifier & causal LM
# --------------------------------------------------------------------------

def _transformer_params(prefix, d, n_layers, sparse_out_proj=True):
    """Per-block params. Paper (App. D.3): MHA *input* projections stay
    dense; MHA output projection and both FF matrices are sparse."""
    ps = []
    for l in range(n_layers):
        b = f"{prefix}b{l}."
        ps += [
            ParamSpec(b + "ln1.g", (d,), init="ones"),
            ParamSpec(b + "ln1.b", (d,)),
            ParamSpec(b + "qkv.w", (3 * d, d), init="he"),  # dense per paper
            ParamSpec(b + "qkv.b", (3 * d,)),
            ParamSpec(b + "out.w", (d, d), sparse=sparse_out_proj, init="he"),
            ParamSpec(b + "out.b", (d,)),
            ParamSpec(b + "ln2.g", (d,), init="ones"),
            ParamSpec(b + "ln2.b", (d,)),
            ParamSpec(b + "ff1.w", (4 * d, d), sparse=True, init="he"),
            ParamSpec(b + "ff1.b", (4 * d,)),
            ParamSpec(b + "ff2.w", (d, 4 * d), sparse=True, init="he"),
            ParamSpec(b + "ff2.b", (d,)),
        ]
    return ps


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _block(eff, pfx, h, heads, causal):
    b, t, d = h.shape
    hd = d // heads
    x = _layernorm(h, eff[pfx + "ln1.g"], eff[pfx + "ln1.b"])
    qkv = x @ eff[pfx + "qkv.w"].T + eff[pfx + "qkv.b"]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads_view(z):
        return z.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads_view(q), heads_view(k), heads_view(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(hd)  # (B, H, T, T)
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        att = jnp.where(mask[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    z = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    h = h + z @ eff[pfx + "out.w"].T + eff[pfx + "out.b"]

    x = _layernorm(h, eff[pfx + "ln2.g"], eff[pfx + "ln2.b"])
    x = jax.nn.gelu(x @ eff[pfx + "ff1.w"].T + eff[pfx + "ff1.b"])
    h = h + x @ eff[pfx + "ff2.w"].T + eff[pfx + "ff2.b"]
    return h


def build_vit(name, d, n_layers, heads, seq, d_in, batch, num_classes,
              label_smoothing=0.11, weight_decay=0.03):
    """Encoder classifier on pre-tokenized inputs (B, T, d_in) — the
    ViT-B/16 proxy for Table 4 / Fig. 9. Patch projection sparse (the
    paper's best variant), MHA in-proj dense, mean-pool + dense head."""
    params = [
        ParamSpec("proj.w", (d, d_in), sparse=True, init="he"),
        ParamSpec("proj.b", (d,)),
        ParamSpec("pos", (seq, d), init="normal:0.02"),
    ]
    params += _transformer_params("", d, n_layers)
    params += [
        ParamSpec("head.w", (num_classes, d), init="he"),
        ParamSpec("head.b", (num_classes,)),
    ]

    def forward(eff, x):
        h = x @ eff["proj.w"].T + eff["proj.b"] + eff["pos"][None]
        for l in range(n_layers):
            h = _block(eff, f"b{l}.", h, heads, causal=False)
        h = jnp.mean(h, axis=1)
        return h @ eff["head.w"].T + eff["head.b"]

    return ModelSpec(
        name=name, params=params, batch=batch,
        x_shape=(seq, d_in), x_dtype="f32", y_shape=(), y_dtype="i32",
        num_classes=num_classes, forward=forward, task="classify",
        label_smoothing=label_smoothing, weight_decay=weight_decay,
    )


def build_lm(name, vocab, d, n_layers, heads, seq, batch,
             weight_decay=0.01):
    """Decoder-only causal LM — the end-to-end training driver model.

    Sparse FF + attention out-proj + lm head (the 'Sparse FF' setup the
    paper adopts for transformers); embeddings and positions dense.
    """
    params = [
        ParamSpec("embed", (vocab, d), init="normal:0.02"),
        ParamSpec("pos", (seq, d), init="normal:0.02"),
    ]
    params += _transformer_params("", d, n_layers)
    params += [
        ParamSpec("lnf.g", (d,), init="ones"),
        ParamSpec("lnf.b", (d,)),
        ParamSpec("lm_head.w", (vocab, d), sparse=True, init="he"),
    ]

    def forward(eff, x):
        h = jnp.take(eff["embed"], x, axis=0) + eff["pos"][None]
        for l in range(n_layers):
            h = _block(eff, f"b{l}.", h, heads, causal=True)
        h = _layernorm(h, eff["lnf.g"], eff["lnf.b"])
        return h @ eff["lm_head.w"].T  # (B, T, V)

    return ModelSpec(
        name=name, params=params, batch=batch,
        x_shape=(seq,), x_dtype="i32", y_shape=(seq,), y_dtype="i32",
        num_classes=vocab, forward=forward, task="lm",
        label_smoothing=0.0, weight_decay=weight_decay,
    )


# --------------------------------------------------------------------------
# Program builders (flat signatures for AOT export)
# --------------------------------------------------------------------------

def _pack(spec, flat):
    return {p.name: a for p, a in zip(spec.params, flat)}


def make_train_step(spec: ModelSpec):
    loss_fn = make_loss_fn(spec)
    names = [p.name for p in spec.params]
    sparse = [p.name for p in spec.sparse_params]
    mu, wd = spec.momentum, spec.weight_decay

    def train_step(*args):
        n = len(names)
        ns = len(sparse)
        params = _pack(spec, args[:n])
        momenta = _pack(spec, args[n:2 * n])
        masks = dict(zip(sparse, args[2 * n:2 * n + ns]))
        x, y, lr = args[2 * n + ns:2 * n + ns + 3]

        eff = apply_masks(params, masks)
        loss, grads = jax.value_and_grad(loss_fn)(eff, x, y)
        new_p, new_m = [], []
        for name in names:
            g = grads[name] + wd * params[name]
            v = mu * momenta[name] + g
            p = params[name] - lr * v
            if name in masks:
                p = p * masks[name]
                v = v * masks[name]
            new_p.append(p)
            new_m.append(v)
        return tuple(new_p) + tuple(new_m) + (loss,)

    return train_step


def make_dense_grad(spec: ModelSpec):
    loss_fn = make_loss_fn(spec)
    names = [p.name for p in spec.params]
    sparse = [p.name for p in spec.sparse_params]

    def dense_grad(*args):
        n, ns = len(names), len(sparse)
        params = _pack(spec, args[:n])
        masks = dict(zip(sparse, args[n:n + ns]))
        x, y = args[n + ns:n + ns + 2]
        eff = apply_masks(params, masks)
        grads = jax.grad(loss_fn)(eff, x, y)
        return tuple(grads[s] for s in sparse)

    return dense_grad


def make_eval_logits(spec: ModelSpec):
    names = [p.name for p in spec.params]
    sparse = [p.name for p in spec.sparse_params]

    def eval_logits(*args):
        n, ns = len(names), len(sparse)
        params = _pack(spec, args[:n])
        masks = dict(zip(sparse, args[n:n + ns]))
        x = args[n + ns]
        return (spec.forward(apply_masks(params, masks), x),)

    return eval_logits


def make_loss_eval(spec: ModelSpec):
    loss_fn = make_loss_fn(spec)
    names = [p.name for p in spec.params]
    sparse = [p.name for p in spec.sparse_params]

    def loss_eval(*args):
        n, ns = len(names), len(sparse)
        params = _pack(spec, args[:n])
        masks = dict(zip(sparse, args[n:n + ns]))
        x, y = args[n + ns:n + ns + 2]
        return (loss_fn(apply_masks(params, masks), x, y),)

    return loss_eval


# --------------------------------------------------------------------------
# Model registry — names referenced by rust configs & the Makefile
# --------------------------------------------------------------------------

def registry() -> dict:
    """name -> zero-arg builder. Sizes chosen to train in minutes on 1 CPU
    core while exercising the same code paths as the paper's models."""
    return {
        # tiny MLP: integration tests + quickstart
        "mlp_tiny": lambda: build_mlp("mlp_tiny", [32, 64, 64, 4], batch=32, num_classes=4),
        # MLP proxy used in several scaled experiments
        "mlp_proxy": lambda: build_mlp("mlp_proxy", [128, 256, 256, 128, 10], batch=64, num_classes=10),
        # CNN proxies: ResNet-18/CIFAR-10 (table2), ResNet-50/ImageNet (table1/3), WRN (table9)
        "cnn_proxy": lambda: build_cnn("cnn_proxy", (16, 32, 64), batch=32, num_classes=10),
        "cnn_wide": lambda: build_cnn("cnn_wide", (32, 64, 128), batch=32, num_classes=10),
        # ViT-B/16 proxy (table4 / fig9 / fig12)
        "vit_proxy": lambda: build_vit("vit_proxy", d=64, n_layers=2, heads=4, seq=16,
                                       d_in=48, batch=32, num_classes=10),
        # causal LMs for the end-to-end driver (example: train_lm_srigl)
        "lm_small": lambda: build_lm("lm_small", vocab=256, d=128, n_layers=2, heads=4,
                                     seq=64, batch=8),
        "lm_medium": lambda: build_lm("lm_medium", vocab=512, d=256, n_layers=4, heads=8,
                                      seq=128, batch=8),
    }


def param_count(spec: ModelSpec) -> int:
    return sum(math.prod(p.shape) for p in spec.params)
