"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

pytest (python/tests/test_kernels.py) asserts ``assert_allclose`` between
these references and the kernels over hypothesis-generated shapes/dtypes.
"""

from __future__ import annotations

import jax.numpy as jnp


def condensed_matmul_ref(x, w, idx):
    """out[b, n] = sum_k x[b, idx[n, k]] * w[n, k]  (Appendix F, Eq. 31)."""
    gathered = jnp.take(x, idx.astype(jnp.int32), axis=1)  # (B, N, K)
    return jnp.sum(gathered * w[None, :, :], axis=-1)


def condensed_to_dense(w, idx, d):
    """Expand a condensed (values, indices) pair to the dense (N, D) matrix.

    Rows of ``idx`` must not contain duplicate columns (the constant fan-in
    constraint guarantees this); with duplicates the dense expansion sums.
    """
    n, k = w.shape
    dense = jnp.zeros((n, d), dtype=w.dtype)
    rows = jnp.repeat(jnp.arange(n), k)
    return dense.at[rows, idx.reshape(-1)].add(w.reshape(-1))


def masked_matmul_ref(x, w, m):
    """out = x @ (w * m).T — masked dense linear forward."""
    return x @ (w * m).T
