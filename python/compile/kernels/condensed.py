"""L1 Pallas kernel: condensed constant-fan-in sparse matmul (paper Algorithm 1).

The condensed representation stores a sparse weight matrix W (n x d) with
exactly `k` non-zeros per row (neuron) as two dense (n x k) matrices:

  * ``w``   — the non-zero *values*,
  * ``idx`` — the *column indices* of those values in the dense W.

The forward pass of a linear layer then becomes (Appendix F, Eq. 31):

  out[b, n] = sum_k  x[b, idx[n, k]] * w[n, k]

i.e. a per-neuron gather followed by a multiply-accumulate. This is the
paper's compute hot-spot for accelerated inference (Fig. 4).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the kernel grid tiles the
*neuron* axis; each program holds one (TN x k) value/index tile plus the
(B x d) activation block in VMEM and performs the gather-MAC on the VPU.
The CUDA implementation the paper benchmarks assigns a thread block per
neuron group — the BlockSpec below expresses the same schedule as an
HBM->VMEM pipeline. ``interpret=True`` is mandatory on this testbed: real
TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _condensed_kernel(x_ref, w_ref, idx_ref, o_ref):
    """One grid step: all batch rows x one tile of neurons.

    x_ref:   (B, D)   activations (full block, reused across the grid)
    w_ref:   (TN, K)  condensed weight values for this neuron tile
    idx_ref: (TN, K)  column indices into D for this neuron tile
    o_ref:   (B, TN)  output tile
    """
    x = x_ref[...]
    w = w_ref[...]
    idx = idx_ref[...]
    # Gather: (B, TN, K) — x[b, idx[n, k]].
    gathered = jnp.take(x, idx, axis=1)
    o_ref[...] = jnp.sum(gathered * w[None, :, :], axis=-1)


def _pick_tile(n: int, max_tile: int = 128) -> int:
    """Largest divisor of ``n`` that is <= max_tile (VMEM sizing knob)."""
    t = min(n, max_tile)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("tile_n",))
def condensed_matmul(x, w, idx, *, tile_n: int | None = None):
    """Condensed constant-fan-in sparse matmul via a Pallas kernel.

    Args:
      x:   (B, D) float activations.
      w:   (N, K) float condensed weight values.
      idx: (N, K) int32 column indices, each row's entries in [0, D).
      tile_n: neuron-tile size; must divide N. Default: largest divisor <=128.

    Returns:
      (B, N) float outputs, equal to ``x @ dense(W).T``.
    """
    b, d = x.shape
    n, k = w.shape
    assert idx.shape == (n, k), (idx.shape, (n, k))
    tn = tile_n if tile_n is not None else _pick_tile(n)
    assert n % tn == 0, f"tile_n={tn} must divide n={n}"
    grid = (n // tn,)
    return pl.pallas_call(
        _condensed_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((tn, k), lambda i: (i, 0)),
            pl.BlockSpec((tn, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, tn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        interpret=True,
    )(x, w, idx.astype(jnp.int32))


def condensed_linear(x, w, idx, bias=None, *, tile_n: int | None = None):
    """Condensed linear layer: ``condensed_matmul`` plus optional bias."""
    out = condensed_matmul(x, w, idx, tile_n=tile_n)
    if bias is not None:
        out = out + bias[None, :]
    return out


@functools.partial(jax.jit, static_argnames=("tile_b", "tile_n"))
def condensed_matmul_batched(x, w, idx, *, tile_b: int | None = None,
                             tile_n: int | None = None):
    """Batched-inference variant: 2-D grid over (batch, neuron) tiles.

    The single-grid kernel above holds the whole (B, D) activation block
    resident, which stops scaling once B·D·4 bytes approaches VMEM (the
    paper's Fig. 4b / Fig. 21 batch-256/2048 regime). This variant tiles
    the batch axis too, bounding the resident block to (TB, D) and the
    gather temporary to TB·TN·K — the schedule a TPU would pipeline as a
    double-buffered HBM→VMEM stream over batch tiles.
    """
    b, d = x.shape
    n, k = w.shape
    assert idx.shape == (n, k)
    tb = tile_b if tile_b is not None else _pick_tile(b, 8)
    tn = tile_n if tile_n is not None else _pick_tile(n)
    assert b % tb == 0 and n % tn == 0, (b, tb, n, tn)
    grid = (b // tb, n // tn)
    return pl.pallas_call(
        _condensed_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((tn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        interpret=True,
    )(x, w, idx.astype(jnp.int32))


def vmem_bytes(b: int, d: int, n: int, k: int, tile_n: int | None = None,
               elem_bytes: int = 4) -> dict:
    """Estimate the per-program VMEM footprint of the kernel (DESIGN §Perf).

    Returns a dict with the resident bytes of each block plus the gather
    temporary; used by EXPERIMENTS.md §Perf to check the tile fits the
    ~16 MiB VMEM of a TPU core and to size ``tile_n``.
    """
    tn = tile_n if tile_n is not None else _pick_tile(n)
    x_bytes = b * d * elem_bytes
    w_bytes = tn * k * elem_bytes
    idx_bytes = tn * k * 4
    out_bytes = b * tn * elem_bytes
    gather_bytes = b * tn * k * elem_bytes
    total = x_bytes + w_bytes + idx_bytes + out_bytes + gather_bytes
    return {
        "tile_n": tn,
        "x": x_bytes,
        "w": w_bytes,
        "idx": idx_bytes,
        "out": out_bytes,
        "gather_tmp": gather_bytes,
        "total": total,
        "fits_16MiB": total <= 16 * 1024 * 1024,
        # 2 FLOPs (mul+add) per (4B value + 4B index) loaded once per tile;
        # x is amortized across the neuron grid.
        "arith_intensity_flops_per_byte": (2 * b * tn * k) / max(total, 1),
    }
