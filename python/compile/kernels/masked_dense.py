"""L1 Pallas kernel: masked dense matmul — the DST *training* forward.

During sparse-to-sparse training the topology changes every ΔT steps, so
the weights are kept dense-shaped with an explicit binary mask (the
standard masked-dense DST formulation RigL/SRigL use). The forward is

  out = x @ (w * m).T

This kernel tiles the output (neuron) axis like ``condensed.py`` so the
two share a schedule; it exists so the L2 training graph exercises a
Pallas kernel end-to-end (spec: L2 calls L1 and both lower into one HLO).
``interpret=True`` is mandatory on CPU PJRT (no Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masked_kernel(x_ref, w_ref, m_ref, o_ref):
    x = x_ref[...]           # (B, D)
    w = w_ref[...]           # (TN, D)
    m = m_ref[...]           # (TN, D)
    o_ref[...] = x @ (w * m).T


def _pick_tile(n: int, max_tile: int = 128) -> int:
    t = min(n, max_tile)
    while n % t != 0:
        t -= 1
    return t


def _masked_matmul_fwd_impl(x, w, m):
    b, d = x.shape
    n, d2 = w.shape
    assert d == d2 and m.shape == (n, d)
    tn = _pick_tile(n)
    grid = (n // tn,)
    return pl.pallas_call(
        _masked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((tn, d), lambda i: (i, 0)),
            pl.BlockSpec((tn, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, tn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        interpret=True,
    )(x, w, m)


@jax.custom_vjp
def masked_matmul(x, w, m):
    """``x @ (w*m).T`` with the neuron axis tiled by a Pallas kernel.

    x: (B, D), w: (N, D), m: (N, D) {0,1}-valued float mask. -> (B, N)

    Interpret-mode Pallas kernels are not reverse-mode differentiable, so
    the backward pass is expressed in plain jnp (it lowers into the same
    HLO module): dx = g @ (w*m); dw = (g.T @ x) * m. The mask is a
    topology constant owned by the L3 coordinator — its cotangent is zero.
    """
    return _masked_matmul_fwd_impl(x, w, m)


def _mm_fwd(x, w, m):
    return _masked_matmul_fwd_impl(x, w, m), (x, w, m)


def _mm_bwd(res, g):
    x, w, m = res
    wm = w * m
    dx = g @ wm
    dw = (g.T @ x) * m
    return dx, dw, jnp.zeros_like(m)


masked_matmul.defvjp(_mm_fwd, _mm_bwd)
