"""AOT compiler driver: lower every model program + condensed kernels to
HLO text and write artifacts/manifest.json for the rust runtime.

Interchange format is HLO *text*, not serialized protos: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.

Run once (``make artifacts``); python never appears on the request path.

Usage:
  python -m compile.aot [--out-dir ../artifacts] [--models a,b,c|all]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.condensed import (
    condensed_matmul,
    condensed_matmul_batched,
    vmem_bytes,
)

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}

# Default export set: everything the experiment harnesses reference.
DEFAULT_MODELS = [
    "mlp_tiny", "mlp_proxy", "cnn_proxy", "cnn_wide", "vit_proxy", "lm_small",
]

# Condensed-kernel standalone programs. The 768x3072 geometry is the exact
# ViT-B/16 FF layer benchmarked in Fig. 4 / Appendix I; k = round(d*(1-s)).
CONDENSED_GEOMS = {
    "cond_tiny": dict(batch=8, d=32, n=16, k=8),
    "cond_vitff_s90_b1": dict(batch=1, d=3072, n=768, k=307),
    "cond_vitff_s90_b32": dict(batch=32, d=3072, n=768, k=307),
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), DTYPES[dtype])


def _model_arg_specs(spec: M.ModelSpec):
    """Argument ShapeDtypeStructs in the canonical manifest order."""
    b = spec.batch
    p = [_spec(ps.shape, "f32") for ps in spec.params]
    m = [_spec(ps.shape, "f32") for ps in spec.sparse_params]
    x = _spec((b, *spec.x_shape), spec.x_dtype)
    y = _spec((b, *spec.y_shape), spec.y_dtype)
    lr = _spec((), "f32")
    return p, m, x, y, lr


def export_model(spec: M.ModelSpec, out_dir: str) -> dict:
    p, m, x, y, lr = _model_arg_specs(spec)
    programs = {
        "train_step": (M.make_train_step(spec), [*p, *p, *m, x, y, lr]),
        "dense_grad": (M.make_dense_grad(spec), [*p, *m, x, y]),
        "eval_logits": (M.make_eval_logits(spec), [*p, *m, x]),
        "loss_eval": (M.make_loss_eval(spec), [*p, *m, x, y]),
    }
    prog_entries = {}
    for pname, (fn, args) in programs.items():
        fname = f"{spec.name}.{pname}.hlo.txt"
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        prog_entries[pname] = fname
        print(f"  {fname}: {len(text)} chars")
    return {
        "batch": spec.batch,
        "task": spec.task,
        "num_classes": spec.num_classes,
        "x": {"shape": [spec.batch, *spec.x_shape], "dtype": spec.x_dtype},
        "y": {"shape": [spec.batch, *spec.y_shape], "dtype": spec.y_dtype},
        "params": [ps.to_json() for ps in spec.params],
        "hyper": {
            "momentum": spec.momentum,
            "weight_decay": spec.weight_decay,
            "label_smoothing": spec.label_smoothing,
        },
        "param_count": M.param_count(spec),
        "programs": prog_entries,
    }


def export_condensed(name: str, geom: dict, out_dir: str) -> dict:
    b, d, n, k = geom["batch"], geom["d"], geom["n"], geom["k"]

    # Batched workloads use the 2-D (batch, neuron) tiled kernel so the
    # resident activation block stays VMEM-sized (see condensed.py).
    kernel = condensed_matmul_batched if b > 8 else condensed_matmul

    def fn(x, w, idx):
        return (kernel(x, w, idx),)

    args = [_spec((b, d), "f32"), _spec((n, k), "f32"), _spec((n, k), "i32")]
    fname = f"{name}.hlo.txt"
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"  {fname}: {len(text)} chars")
    entry = dict(geom)
    entry["file"] = fname
    entry["vmem"] = vmem_bytes(b, d, n, k)
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS),
                    help="comma-separated model names, or 'all'")
    ap.add_argument("--out", default=None, help="(legacy, ignored)")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    reg = M.registry()
    names = list(reg) if args.models == "all" else args.models.split(",")

    manifest = {"version": 1, "models": {}, "condensed": {}}
    for name in names:
        spec = reg[name]()
        print(f"[aot] model {name} ({M.param_count(spec):,} params)")
        manifest["models"][name] = export_model(spec, out_dir)

    for cname, geom in CONDENSED_GEOMS.items():
        print(f"[aot] condensed {cname}")
        manifest["condensed"][cname] = export_condensed(cname, geom, out_dir)

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {mpath}")


if __name__ == "__main__":
    main()
