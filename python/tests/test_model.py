"""L2 model-zoo correctness: shapes, gradient semantics, training descent."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


def _init_params(spec, rng):
    out = {}
    for p in spec.params:
        if p.init == "zeros":
            a = np.zeros(p.shape, np.float32)
        elif p.init == "ones":
            a = np.ones(p.shape, np.float32)
        elif p.init == "he":
            a = rng.normal(size=p.shape).astype(np.float32) * np.sqrt(2.0 / max(p.fan_in, 1))
        elif p.init.startswith("normal:"):
            a = rng.normal(size=p.shape).astype(np.float32) * float(p.init.split(":")[1])
        else:
            raise ValueError(p.init)
        out[p.name] = jnp.asarray(a)
    return out


def _rand_masks(spec, rng, density=0.5):
    masks = {}
    for p in spec.sparse_params:
        m = (rng.uniform(size=p.shape) < density).astype(np.float32)
        masks[p.name] = jnp.asarray(m)
    return masks


def _rand_batch(spec, rng):
    b = spec.batch
    if spec.x_dtype == "f32":
        x = jnp.asarray(rng.normal(size=(b, *spec.x_shape)).astype(np.float32))
    else:
        x = jnp.asarray(rng.integers(0, spec.num_classes, size=(b, *spec.x_shape)).astype(np.int32))
    y = jnp.asarray(rng.integers(0, spec.num_classes, size=(b, *spec.y_shape)).astype(np.int32))
    return x, y


SMALL_MODELS = ["mlp_tiny", "cnn_proxy", "vit_proxy", "lm_small"]


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_forward_shapes(name):
    spec = M.registry()[name]()
    rng = np.random.default_rng(0)
    params = _init_params(spec, rng)
    masks = _rand_masks(spec, rng)
    x, _ = _rand_batch(spec, rng)
    logits = spec.forward(M.apply_masks(params, masks), x)
    if spec.task == "lm":
        assert logits.shape == (spec.batch, *spec.x_shape, spec.num_classes)
    else:
        assert logits.shape == (spec.batch, spec.num_classes)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("name", ["mlp_tiny", "cnn_proxy"])
def test_dense_grad_nonzero_at_pruned(name):
    """dense_grad is dL/d(w.*m): it must be non-zero at pruned positions —
    the signal RigL/SRigL regrowth needs (Section 3.1 step 1)."""
    spec = M.registry()[name]()
    rng = np.random.default_rng(1)
    params = _init_params(spec, rng)
    masks = _rand_masks(spec, rng, density=0.3)
    x, y = _rand_batch(spec, rng)
    fn = M.make_dense_grad(spec)
    flat = [params[p.name] for p in spec.params] + \
           [masks[p.name] for p in spec.sparse_params] + [x, y]
    grads = fn(*flat)
    for g, p in zip(grads, spec.sparse_params):
        m = np.asarray(masks[p.name])
        g = np.asarray(g)
        assert g.shape == p.shape
        pruned = g[m == 0]
        assert pruned.size > 0 and np.any(pruned != 0.0), \
            f"{p.name}: no gradient signal at pruned weights"


def test_train_step_masks_enforced_and_loss_finite():
    spec = M.registry()["mlp_tiny"]()
    rng = np.random.default_rng(2)
    params = _init_params(spec, rng)
    momenta = {p.name: jnp.zeros(p.shape, jnp.float32) for p in spec.params}
    masks = _rand_masks(spec, rng, density=0.2)
    # start masked
    for p in spec.sparse_params:
        params[p.name] = params[p.name] * masks[p.name]
    x, y = _rand_batch(spec, rng)
    fn = M.make_train_step(spec)
    n = len(spec.params)
    flat = [params[p.name] for p in spec.params] + \
           [momenta[p.name] for p in spec.params] + \
           [masks[p.name] for p in spec.sparse_params] + \
           [x, y, jnp.float32(0.1)]
    out = fn(*flat)
    new_params = out[:n]
    loss = out[-1]
    assert np.isfinite(float(loss))
    for p_new, p in zip(new_params, spec.params):
        if p.sparse:
            off = np.asarray(p_new) * (1 - np.asarray(masks[p.name]))
            assert np.max(np.abs(off)) == 0.0, f"{p.name}: pruned weights moved"


@pytest.mark.parametrize("name", ["mlp_tiny", "lm_small"])
def test_loss_decreases(name):
    """A few SGD steps on a fixed batch must reduce the loss."""
    spec = M.registry()[name]()
    rng = np.random.default_rng(3)
    params = _init_params(spec, rng)
    momenta = {p.name: jnp.zeros(p.shape, jnp.float32) for p in spec.params}
    masks = _rand_masks(spec, rng, density=0.5)
    for p in spec.sparse_params:
        params[p.name] = params[p.name] * masks[p.name]
    x, y = _rand_batch(spec, rng)
    step = jax.jit(M.make_train_step(spec))
    n = len(spec.params)
    flat = [params[p.name] for p in spec.params] + \
           [momenta[p.name] for p in spec.params] + \
           [masks[p.name] for p in spec.sparse_params] + \
           [x, y, jnp.float32(0.05)]
    losses = []
    for _ in range(8):
        out = step(*flat)
        losses.append(float(out[-1]))
        flat = list(out[:2 * n]) + flat[2 * n:]
    assert losses[-1] < losses[0], losses


def test_mlp_pallas_forward_equals_plain():
    """The Pallas-kerneled MLP must equal the plain-jnp formulation."""
    reg = M.registry()
    spec_k = reg["mlp_tiny"]()
    spec_p = M.build_mlp("mlp_plain", [32, 64, 64, 4], batch=32, num_classes=4,
                         use_pallas=False)
    rng = np.random.default_rng(4)
    params = _init_params(spec_k, rng)
    masks = _rand_masks(spec_k, rng)
    x, _ = _rand_batch(spec_k, rng)
    eff = M.apply_masks(params, masks)
    np.testing.assert_allclose(
        spec_k.forward(eff, x), spec_p.forward(eff, x), rtol=1e-4, atol=1e-5)


def test_numerical_gradient_mlp():
    """dense_grad vs central finite differences on a few coordinates."""
    spec = M.registry()["mlp_tiny"]()
    rng = np.random.default_rng(5)
    params = _init_params(spec, rng)
    masks = _rand_masks(spec, rng)
    x, y = _rand_batch(spec, rng)
    loss_fn = M.make_loss_fn(spec)

    def loss_of(eff):
        return float(loss_fn(eff, x, y))

    eff = {k: np.asarray(v).copy() for k, v in M.apply_masks(params, masks).items()}
    fn = M.make_dense_grad(spec)
    flat = [params[p.name] for p in spec.params] + \
           [masks[p.name] for p in spec.sparse_params] + [x, y]
    grads = dict(zip([p.name for p in spec.sparse_params], fn(*flat)))

    eps = 1e-3
    name = "l1.w"
    for (i, j) in [(0, 0), (3, 7), (10, 20)]:
        e = {k: jnp.asarray(v) for k, v in eff.items()}
        ep = dict(e); ep[name] = e[name].at[i, j].add(eps)
        em = dict(e); em[name] = e[name].at[i, j].add(-eps)
        num = (loss_of(ep) - loss_of(em)) / (2 * eps)
        ana = float(grads[name][i, j])
        assert abs(num - ana) < 5e-3 + 0.05 * abs(num), (i, j, num, ana)


def test_param_counts():
    reg = M.registry()
    assert M.param_count(reg["mlp_tiny"]()) == 6532
    assert M.param_count(reg["lm_medium"]()) > 3_000_000
    # every sparse param has neuron axis 0 and positive fan-in
    for name in SMALL_MODELS:
        for p in reg[name]().sparse_params:
            assert p.neuron_axis == 0 and p.fan_in >= 1
