"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes (spec requirement); assert_allclose against
ref.py is the core correctness signal for the AOT'd hot path.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.condensed import (
    condensed_matmul,
    condensed_matmul_batched,
    vmem_bytes,
    _pick_tile,
)
from compile.kernels.masked_dense import masked_matmul


def _rand_condensed(rng, b, d, n, k, dtype):
    x = rng.normal(size=(b, d)).astype(dtype)
    w = rng.normal(size=(n, k)).astype(dtype)
    idx = np.stack([rng.choice(d, size=k, replace=False) for _ in range(n)]).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(idx)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 8),
    d=st.integers(4, 48),
    n=st.integers(1, 32),
    data=st.data(),
)
def test_condensed_matches_ref_hypothesis(b, d, n, data):
    k = data.draw(st.integers(1, d))
    rng = np.random.default_rng(b * 1000 + d * 100 + n * 10 + k)
    x, w, idx = _rand_condensed(rng, b, d, n, k, np.float32)
    out = condensed_matmul(x, w, idx)
    np.testing.assert_allclose(out, ref.condensed_matmul_ref(x, w, idx),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,rtol", [(np.float32, 1e-5), (np.float64, 1e-12)])
def test_condensed_dtypes(dtype, rtol):
    if dtype == np.float64:
        jax.config.update("jax_enable_x64", True)
    try:
        rng = np.random.default_rng(7)
        x, w, idx = _rand_condensed(rng, 4, 32, 16, 8, dtype)
        out = condensed_matmul(x, w, idx)
        np.testing.assert_allclose(out, ref.condensed_matmul_ref(x, w, idx),
                                   rtol=rtol, atol=rtol)
    finally:
        jax.config.update("jax_enable_x64", False)


def test_condensed_equals_dense_matmul():
    """Condensed form == x @ dense(W).T — Appendix F equivalence."""
    rng = np.random.default_rng(3)
    b, d, n, k = 6, 40, 20, 10
    x, w, idx = _rand_condensed(rng, b, d, n, k, np.float32)
    dense = ref.condensed_to_dense(w, idx, d)
    np.testing.assert_allclose(
        condensed_matmul(x, w, idx), x @ dense.T, rtol=1e-4, atol=1e-5)


def test_condensed_tiling_invariance():
    """Output must not depend on the neuron tile size (pure schedule knob)."""
    rng = np.random.default_rng(11)
    b, d, n, k = 4, 32, 24, 6
    x, w, idx = _rand_condensed(rng, b, d, n, k, np.float32)
    base = condensed_matmul(x, w, idx, tile_n=24)
    for tn in (1, 2, 3, 4, 6, 8, 12):
        np.testing.assert_allclose(
            condensed_matmul(x, w, idx, tile_n=tn), base, rtol=1e-6)


def test_condensed_duplicate_indices_sum():
    """With repeated indices the kernel must sum contributions (gather does)."""
    x = jnp.ones((1, 4), jnp.float32)
    w = jnp.array([[2.0, 3.0]], jnp.float32)
    idx = jnp.array([[1, 1]], jnp.int32)
    np.testing.assert_allclose(condensed_matmul(x, w, idx), [[5.0]])


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 8), d=st.integers(2, 40), n=st.integers(1, 32),
       density=st.floats(0.05, 1.0))
def test_masked_matches_ref_hypothesis(b, d, n, density):
    rng = np.random.default_rng(b + d * 7 + n * 13)
    x = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    m = jnp.asarray((rng.uniform(size=(n, d)) < density).astype(np.float32))
    np.testing.assert_allclose(
        masked_matmul(x, w, m), ref.masked_matmul_ref(x, w, m),
        rtol=1e-4, atol=1e-5)


def test_masked_matmul_grad_matches_dense():
    """custom_vjp backward == autodiff through the plain jnp formulation."""
    rng = np.random.default_rng(5)
    b, d, n = 4, 16, 8
    x = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    m = jnp.asarray((rng.uniform(size=(n, d)) < 0.4).astype(np.float32))

    def loss_kernel(w_):
        return jnp.sum(jnp.tanh(masked_matmul(x, w_, m)))

    def loss_ref(w_):
        return jnp.sum(jnp.tanh(ref.masked_matmul_ref(x, w_, m)))

    gk = jax.grad(loss_kernel)(w)
    gr = jax.grad(loss_ref)(w)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)
    # pruned positions receive zero gradient through the kernel
    assert float(jnp.max(jnp.abs(gk * (1 - m)))) == 0.0


def test_masked_matmul_dx_grad():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(3, 10)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(5, 10)).astype(np.float32))
    m = jnp.asarray((rng.uniform(size=(5, 10)) < 0.5).astype(np.float32))
    gk = jax.grad(lambda x_: jnp.sum(masked_matmul(x_, w, m) ** 2))(x)
    gr = jax.grad(lambda x_: jnp.sum(ref.masked_matmul_ref(x_, w, m) ** 2))(x)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    bt=st.sampled_from([(4, 2), (8, 4), (6, 3), (8, 1)]),
    n=st.sampled_from([8, 12, 16]),
    data=st.data(),
)
def test_condensed_batched_matches_single_grid(bt, n, data):
    b, tb = bt
    d = data.draw(st.integers(8, 40))
    k = data.draw(st.integers(1, d))
    rng = np.random.default_rng(b * 100 + d * 10 + k)
    x, w, idx = _rand_condensed(rng, b, d, n, k, np.float32)
    single = condensed_matmul(x, w, idx)
    batched = condensed_matmul_batched(x, w, idx, tile_b=tb)
    np.testing.assert_allclose(batched, single, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(batched, ref.condensed_matmul_ref(x, w, idx),
                               rtol=1e-5, atol=1e-5)


def test_condensed_batched_tile_invariance():
    rng = np.random.default_rng(13)
    x, w, idx = _rand_condensed(rng, 8, 24, 12, 5, np.float32)
    base = condensed_matmul_batched(x, w, idx, tile_b=8, tile_n=12)
    for tb in (1, 2, 4):
        for tn in (2, 3, 6):
            got = condensed_matmul_batched(x, w, idx, tile_b=tb, tile_n=tn)
            np.testing.assert_allclose(got, base, rtol=1e-6)


def test_pick_tile_divides():
    for n in range(1, 300):
        t = _pick_tile(n)
        assert n % t == 0 and 1 <= t <= 128


def test_vmem_estimate_fig4_geometry_fits():
    """Fig. 4 layer (768x3072, 90% sparse) must fit a 16 MiB VMEM budget."""
    est = vmem_bytes(b=1, d=3072, n=768, k=307)
    assert est["fits_16MiB"], est
    assert est["tile_n"] >= 1 and 768 % est["tile_n"] == 0
