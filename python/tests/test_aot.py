"""AOT pipeline consistency: manifest vs registry, HLO artifacts well-formed.

These tests read artifacts/ if present (built by `make artifacts`); the
export itself is also exercised end-to-end on the tiny model in-process.
"""

import json
import os

import pytest

from compile import aot
from compile import model as M

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
MANIFEST = os.path.join(ART, "manifest.json")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first")


def test_export_roundtrip_tmpdir(tmp_path):
    spec = M.registry()["mlp_tiny"]()
    entry = aot.export_model(spec, str(tmp_path))
    assert set(entry["programs"]) == {"train_step", "dense_grad", "eval_logits", "loss_eval"}
    for fname in entry["programs"].values():
        text = (tmp_path / fname).read_text()
        assert text.startswith("HloModule"), fname
        assert "ENTRY" in text
    # canonical ordering: params, momenta, masks, x, y, lr
    n, ns = len(spec.params), len(spec.sparse_params)
    assert entry["param_count"] == M.param_count(spec)
    assert len(entry["params"]) == n and sum(p["sparse"] for p in entry["params"]) == ns


@needs_artifacts
def test_manifest_matches_registry():
    with open(MANIFEST) as f:
        man = json.load(f)
    reg = M.registry()
    for name, entry in man["models"].items():
        spec = reg[name]()
        assert entry["batch"] == spec.batch
        assert entry["param_count"] == M.param_count(spec)
        assert [p["name"] for p in entry["params"]] == [p.name for p in spec.params]
        for p_json, p in zip(entry["params"], spec.params):
            assert tuple(p_json["shape"]) == tuple(p.shape)
            assert p_json["sparse"] == p.sparse
            assert p_json["fan_in"] == p.fan_in
        for fname in entry["programs"].values():
            assert os.path.exists(os.path.join(ART, fname)), fname


@needs_artifacts
def test_condensed_entries_geometry():
    with open(MANIFEST) as f:
        man = json.load(f)
    assert "cond_vitff_s90_b1" in man["condensed"]
    g = man["condensed"]["cond_vitff_s90_b1"]
    # Fig. 4 geometry: ViT-B/16 final FF layer, 90% sparse
    assert (g["d"], g["n"], g["k"]) == (3072, 768, 307)
    assert g["vmem"]["fits_16MiB"]
    for entry in man["condensed"].values():
        assert os.path.exists(os.path.join(ART, entry["file"]))


@needs_artifacts
def test_hlo_text_parseable_headers():
    """Every artifact is HLO text with an ENTRY computation (the format the
    xla crate's from_text_file parser accepts — see DESIGN.md)."""
    with open(MANIFEST) as f:
        man = json.load(f)
    files = [f for e in man["models"].values() for f in e["programs"].values()]
    files += [e["file"] for e in man["condensed"].values()]
    for fname in files:
        with open(os.path.join(ART, fname)) as fh:
            head = fh.read(4096)
        assert head.startswith("HloModule"), fname
