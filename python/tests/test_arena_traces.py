"""Distribution oracle for the Rust arena trace generators.

An exact Python port of ``rust/src/arena/trace.rs`` (same xoshiro256**
stream, same per-event draw order), used to pre-verify the pinned
distribution assertions in ``rust/tests/arena.rs``: if a bound holds here
for the same seeds and parameters, it holds in Rust up to libm rounding —
the assertions use wide margins precisely so ULP differences in
``ln``/``powf`` cannot flip them. Digests are never compared
cross-language.

Runs under plain pytest (stdlib only — no numpy/jax needed).
"""

import math

MASK = (1 << 64) - 1

BURST_START_P = 1.0 / 32.0
BURST_LEN_MIN = 64
BURST_LEN_MAX = 128
BURST_SPEEDUP = 50.0
DIURNAL_TROUGH = 0.25
HEAVY_TAIL_ALPHA = 1.2


def _splitmix_stream(seed):
    sm = seed & MASK
    while True:
        sm = (sm + 0x9E3779B97F4A7C15) & MASK
        z = sm
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        yield z ^ (z >> 31)


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """xoshiro256** matching rust/src/util/rng.rs bit-for-bit."""

    def __init__(self, seed):
        sm = _splitmix_stream(seed)
        self.s = [next(sm) for _ in range(4)]

    def next_u64(self):
        s = self.s
        r = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return r

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        x = self.next_u64()
        m = x * n
        l = m & MASK
        if l < n:
            t = (-n) % (1 << 64) % n
            while l < t:
                x = self.next_u64()
                m = x * n
                l = m & MASK
        return m >> 64


def exp_gap_us(mean_us, rng):
    u = max(rng.uniform(), 1e-12)
    if mean_us <= 0.0:
        return 0.0
    return min(mean_us * -math.log(u), 10.0 * mean_us)


def pareto_rows(max_rows, rng):
    u = max(rng.uniform(), 1e-12)
    r = int(math.floor((1.0 / u) ** (1.0 / HEAVY_TAIL_ALPHA)))
    return min(max(r, 1), max_rows)


def generate(scenario, n, mean_gap_us, max_rows, pool, seed):
    """Port of Trace::generate; returns a list of (at_us, rows, payload)."""
    rng = Rng(seed)
    mean = max(mean_gap_us, 0.0)
    max_rows = max(max_rows, 1)
    pool = max(pool, 1)
    events = []
    t_us = 0.0
    burst_left = 0
    for i in range(n):
        if scenario in ("poisson", "heavytail", "adversarial"):
            gap = exp_gap_us(mean, rng)
        elif scenario == "bursty":
            if burst_left == 0 and rng.uniform() < BURST_START_P:
                burst_left = BURST_LEN_MIN + rng.below(BURST_LEN_MAX - BURST_LEN_MIN + 1)
            if burst_left > 0:
                burst_left -= 1
                gap = exp_gap_us(mean / BURST_SPEEDUP, rng)
            else:
                gap = exp_gap_us(mean, rng)
        elif scenario == "diurnal":
            x = i / (n - 1) if n > 1 else 0.5
            r = DIURNAL_TROUGH + (1.0 - DIURNAL_TROUGH) * math.sin(math.pi * x)
            gap = exp_gap_us(mean, rng) / r
        else:
            raise ValueError(scenario)
        t_us += gap
        rows = pareto_rows(max_rows, rng) if scenario == "heavytail" else 1 + rng.below(max_rows)
        payload = i if scenario == "adversarial" else rng.below(pool)
        # Rust f64::round rounds half away from zero; t_us >= 0 here
        events.append((int(math.floor(t_us + 0.5)), rows, payload))
    return events


def gaps(events):
    out, prev = [], 0
    for at, _, _ in events:
        out.append(float(max(at - prev, 0)))
        prev = at
    return out


def cv(xs):
    m = sum(xs) / len(xs)
    var = sum((x - m) ** 2 for x in xs) / (len(xs) - 1)
    return math.sqrt(var) / m


# The exact parameters rust/tests/arena.rs pins (SHAPE_* constants there).
N, GAP, ROWS, POOL = 2000, 100.0, 8, 32
SEEDS = (1, 2, 3)


def test_rng_port_matches_reference_vector():
    # xoshiro256** seeded via splitmix64(42): first draws are an
    # implementation invariant both sides share (checked in Rust by
    # rng.rs's own determinism tests; here it guards the Python port).
    r1, r2 = Rng(42), Rng(42)
    assert [r1.next_u64() for _ in range(4)] == [r2.next_u64() for _ in range(4)]
    assert Rng(1).next_u64() != Rng(2).next_u64()
    u = Rng(7).uniform()
    assert 0.0 <= u < 1.0


def test_poisson_cv_near_one():
    for seed in SEEDS:
        g = gaps(generate("poisson", N, GAP, ROWS, POOL, seed))
        assert 0.8 < cv(g) < 1.25, (seed, cv(g))


def test_bursty_is_overdispersed():
    for seed in SEEDS:
        g = gaps(generate("bursty", N, GAP, ROWS, POOL, seed))
        assert cv(g) > 1.8, (seed, cv(g))


def test_diurnal_middle_runs_hotter():
    for seed in SEEDS:
        g = gaps(generate("diurnal", N, GAP, ROWS, POOL, seed))
        third = len(g) // 3
        outer = g[:third] + g[-third:]
        middle = g[third : 2 * third]
        mid_mean = sum(middle) / len(middle)
        out_mean = sum(outer) / len(outer)
        assert mid_mean < 0.7 * out_mean, (seed, mid_mean, out_mean)


def test_heavytail_rows_mostly_one_with_monsters():
    for seed in SEEDS:
        ev = generate("heavytail", N, GAP, ROWS, POOL, seed)
        frac_one = sum(1 for _, r, _ in ev if r == 1) / len(ev)
        assert 0.45 < frac_one < 0.75, (seed, frac_one)
        assert any(r == ROWS for _, r, _ in ev), seed


def test_adversarial_payloads_unique():
    ev = generate("adversarial", N, GAP, ROWS, POOL, 1)
    payloads = [p for _, _, p in ev]
    assert len(set(payloads)) == len(payloads)
