//! # SRigL — Dynamic Sparse Training with Structured Sparsity
//!
//! A production-style reproduction of Lasby et al., ICLR 2024, as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: DST topology updaters
//!   ([`dst`]), the training-loop driver ([`train`]), the PJRT runtime
//!   that executes AOT-compiled JAX programs ([`runtime`]), the condensed
//!   sparse inference engine and online-inference server ([`inference`],
//!   bottoming out in the runtime-dispatched SIMD microkernels of
//!   [`kernels`])
//!   with its socket serving front-end ([`inference::frontend`] over the
//!   [`net`] wire protocol) and live metrics layer ([`obs`]: lock-light
//!   counters/histograms behind a plaintext `GET /metrics` endpoint),
//!   plus the analysis substrates the paper's evaluation needs
//!   ([`stats`], [`flops`]), one harness per paper table/figure
//!   ([`exp`]), and the traffic arena for head-to-head serving duels
//!   with a persisted perf trajectory ([`arena`]).
//! * **L2** — `python/compile/model.py`: JAX models (MLP/CNN/transformer)
//!   lowered once to HLO text (`make artifacts`).
//! * **L1** — `python/compile/kernels/`: Pallas kernels (the condensed
//!   constant-fan-in matmul of paper Algorithm 1, and the masked training
//!   matmul), called from L2 so they lower into the same HLO.
//!
//! Python never runs on the training or request path.

// Every unsafe operation must sit in an explicit `unsafe {}` block even
// inside `unsafe fn`, so each site is visible to `srigl lint`'s
// SAFETY-comment rule (docs/ANALYSIS.md).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod arena;
pub mod bench;
pub mod data;
pub mod dst;
pub mod exp;
pub mod flops;
pub mod inference;
pub mod kernels;
pub mod lint;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod sparsity;
pub mod stats;
pub mod tensor;
pub mod train;
pub mod util;
