//! Synchronous loopback client for the serving front-end: one request in
//! flight per connection, so responses always match the outstanding id.
//! Used by `examples/socket_serving.rs`, `benches/frontend.rs`, and the
//! socket integration tests.

use std::io::{self, BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::{read_response, write_request, RequestFrame, ResponseBody};

/// What the server said about one request.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    /// Row-major model output (`rows * out_width` f32s).
    Output(Vec<f32>),
    /// Backpressure: the bounded queue was full; retry after the backoff.
    Busy { retry_after_ms: u32 },
}

/// Blocking request/response client over one TCP connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?; // request/response pattern: don't Nagle-delay frames
        let writer = BufWriter::new(stream.try_clone()?);
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 1 })
    }

    /// Send `rows` rows of features and block for the server's answer.
    /// A [`ResponseBody::Error`] from the server surfaces as an
    /// `InvalidInput` io error (the connection stays usable).
    pub fn infer(&mut self, rows: usize, x: &[f32]) -> io::Result<Reply> {
        let id = self.next_id;
        self.next_id += 1;
        write_request(
            &mut self.writer,
            &RequestFrame { id, rows: rows as u32, payload: x.to_vec() },
        )?;
        self.writer.flush()?;
        let resp = read_response(&mut self.reader)?.ok_or_else(|| {
            io::Error::new(io::ErrorKind::ConnectionAborted, "server closed mid-request")
        })?;
        if resp.id != id {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("response id {} for request {id} (sync client)", resp.id),
            ));
        }
        match resp.body {
            ResponseBody::Output { data, .. } => Ok(Reply::Output(data)),
            ResponseBody::Busy { retry_after_ms } => Ok(Reply::Busy { retry_after_ms }),
            ResponseBody::Error(msg) => Err(io::Error::new(io::ErrorKind::InvalidInput, msg)),
        }
    }

    /// [`Client::infer`], sleeping out `Busy` backoffs up to `max_retries`
    /// times — the polite way to drive a backpressuring server.
    pub fn infer_retrying(
        &mut self,
        rows: usize,
        x: &[f32],
        max_retries: usize,
    ) -> io::Result<Vec<f32>> {
        for _ in 0..=max_retries {
            match self.infer(rows, x)? {
                Reply::Output(out) => return Ok(out),
                Reply::Busy { retry_after_ms } => {
                    std::thread::sleep(Duration::from_millis(retry_after_ms as u64));
                }
            }
        }
        Err(io::Error::new(io::ErrorKind::TimedOut, "server still busy after retries"))
    }
}
