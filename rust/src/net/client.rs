//! Synchronous loopback client for the serving front-end: one request in
//! flight per connection, so responses always match the outstanding id.
//! Used by `examples/socket_serving.rs`, `benches/frontend.rs`, and the
//! socket integration tests.

use std::io::{self, BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::{read_response, write_control, write_request, RequestFrame, ResponseBody, CONTROL_OP_RELOAD};

/// What the server said about one request.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    /// Row-major model output (`rows * out_width` f32s).
    Output(Vec<f32>),
    /// Backpressure: the bounded queue was full; retry after the backoff.
    Busy { retry_after_ms: u32 },
}

/// Blocking request/response client over one TCP connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?; // request/response pattern: don't Nagle-delay frames
        let writer = BufWriter::new(stream.try_clone()?);
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 1 })
    }

    /// Send `rows` rows of features and block for the server's answer.
    /// A [`ResponseBody::Error`] from the server surfaces as an
    /// `InvalidInput` io error (the connection stays usable).
    pub fn infer(&mut self, rows: usize, x: &[f32]) -> io::Result<Reply> {
        let id = self.next_id;
        self.next_id += 1;
        write_request(
            &mut self.writer,
            &RequestFrame { id, rows: rows as u32, payload: x.to_vec() },
        )?;
        self.writer.flush()?;
        let resp = read_response(&mut self.reader)?.ok_or_else(|| {
            io::Error::new(io::ErrorKind::ConnectionAborted, "server closed mid-request")
        })?;
        if resp.id != id {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("response id {} for request {id} (sync client)", resp.id),
            ));
        }
        match resp.body {
            ResponseBody::Output { data, .. } => Ok(Reply::Output(data)),
            ResponseBody::Busy { retry_after_ms } => Ok(Reply::Busy { retry_after_ms }),
            ResponseBody::Error(msg) => Err(io::Error::new(io::ErrorKind::InvalidInput, msg)),
            ResponseBody::Epoch(e) => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected Epoch({e}) answer to an inference request"),
            )),
        }
    }

    /// Ask the server to reload its stack and publish a new epoch
    /// (a [`CONTROL_OP_RELOAD`] control frame); blocks for the answer and
    /// returns the epoch now serving. Servers spawned without a reload
    /// source answer `Error`, which surfaces as `InvalidInput` (the
    /// connection stays usable).
    pub fn reload(&mut self) -> io::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        write_control(&mut self.writer, id, CONTROL_OP_RELOAD)?;
        self.writer.flush()?;
        let resp = read_response(&mut self.reader)?.ok_or_else(|| {
            io::Error::new(io::ErrorKind::ConnectionAborted, "server closed mid-request")
        })?;
        if resp.id != id {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("response id {} for control {id} (sync client)", resp.id),
            ));
        }
        match resp.body {
            ResponseBody::Epoch(e) => Ok(e),
            ResponseBody::Error(msg) => Err(io::Error::new(io::ErrorKind::InvalidInput, msg)),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected {other:?} answer to a reload control frame"),
            )),
        }
    }

    /// [`Client::infer`], sleeping out `Busy` backoffs — the polite way to
    /// drive a backpressuring server.
    ///
    /// Makes at most `1 + max_retries` attempts: the initial send plus up
    /// to `max_retries` retries, each preceded by a [`backoff_delay`]
    /// sleep (the server's hint clamped to
    /// [`BACKOFF_FLOOR_MS`]..=[`BACKOFF_CAP_MS`] plus deterministic
    /// jitter). No sleep follows the final failed attempt — the caller
    /// gets its `TimedOut` immediately.
    pub fn infer_retrying(
        &mut self,
        rows: usize,
        x: &[f32],
        max_retries: usize,
    ) -> io::Result<Vec<f32>> {
        // Jitter keyed off the request id about to be used: deterministic
        // for a given client/request sequence, decorrelated across clients
        // (each connection's ids advance with its own traffic).
        let jitter_seed = self.next_id;
        for attempt in 0..=max_retries {
            match self.infer(rows, x)? {
                Reply::Output(out) => return Ok(out),
                Reply::Busy { retry_after_ms } => {
                    if attempt < max_retries {
                        std::thread::sleep(backoff_delay(
                            retry_after_ms,
                            attempt as u32,
                            jitter_seed,
                        ));
                    }
                }
            }
        }
        Err(io::Error::new(io::ErrorKind::TimedOut, "server still busy after retries"))
    }
}

/// Smallest backoff a `Busy` hint can produce. A server that answers
/// `retry_after_ms == 0` used to busy-spin the client against the full
/// wire round-trip — re-flooding the very queue that just rejected it.
pub const BACKOFF_FLOOR_MS: u64 = 1;

/// Largest backoff a `Busy` hint can produce. A garbage or hostile hint
/// (`u32::MAX` is ~49.7 days) used to park the client unboundedly.
pub const BACKOFF_CAP_MS: u64 = 250;

/// The deterministic backoff schedule behind [`Client::infer_retrying`]:
/// the server's `retry_after_ms` hint clamped to
/// [`BACKOFF_FLOOR_MS`]..=[`BACKOFF_CAP_MS`], plus up to +50% jitter
/// derived (SplitMix64 finalizer) from `(seed, attempt)`. Deterministic so
/// tests and arena replays reproduce exactly; jittered so clients that
/// were rejected together don't retry in lockstep and re-flood the queue.
pub fn backoff_delay(retry_after_ms: u32, attempt: u32, seed: u64) -> Duration {
    let base_us = (retry_after_ms as u64).clamp(BACKOFF_FLOOR_MS, BACKOFF_CAP_MS) * 1000;
    // SplitMix64 finalizer over (seed, attempt): cheap, stateless, and
    // well-mixed — the same mixing the crate's Rng seeds with.
    let mut z = seed ^ (attempt as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    let jitter_us = z % (base_us / 2 + 1);
    Duration::from_micros(base_us + jitter_us)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_floors_zero_hint() {
        // retry_after_ms == 0 must never busy-spin: at least the 1 ms floor
        for attempt in 0..8 {
            for seed in [0u64, 1, 0xDEAD] {
                let d = backoff_delay(0, attempt, seed);
                assert!(d >= Duration::from_millis(BACKOFF_FLOOR_MS), "{d:?}");
                assert!(d <= Duration::from_micros(BACKOFF_FLOOR_MS * 1500), "jitter <= +50%");
            }
        }
    }

    #[test]
    fn backoff_caps_huge_hint() {
        // u32::MAX ms is ~49.7 days; the cap bounds it to <= 250ms * 1.5
        let d = backoff_delay(u32::MAX, 0, 7);
        assert!(d <= Duration::from_micros(BACKOFF_CAP_MS * 1500), "{d:?}");
        assert!(d >= Duration::from_millis(BACKOFF_CAP_MS), "base preserved under jitter");
    }

    #[test]
    fn backoff_bounds_hold_for_ordinary_hints() {
        for hint in [1u32, 2, 10, 100, 250] {
            for attempt in 0..4 {
                let d = backoff_delay(hint, attempt, 42);
                let base = Duration::from_millis(hint as u64);
                assert!(d >= base, "hint {hint} attempt {attempt}: {d:?} < base");
                assert!(d <= base * 3 / 2, "hint {hint} attempt {attempt}: {d:?} > 1.5x base");
            }
        }
    }

    #[test]
    fn backoff_is_deterministic_and_jitter_varies() {
        // same (hint, attempt, seed) -> same delay, reproducibly
        assert_eq!(backoff_delay(5, 2, 99), backoff_delay(5, 2, 99));
        // across attempts the jitter must actually move (no lockstep):
        // 8 attempts all colliding on one of 2501 jitter values won't happen
        let delays: Vec<Duration> = (0..8).map(|a| backoff_delay(5, a, 99)).collect();
        let distinct: std::collections::HashSet<_> = delays.iter().collect();
        assert!(distinct.len() > 1, "jitter never varied: {delays:?}");
        // and different seeds decorrelate concurrent clients
        let a: Vec<Duration> = (0..8).map(|at| backoff_delay(5, at, 1)).collect();
        let b: Vec<Duration> = (0..8).map(|at| backoff_delay(5, at, 2)).collect();
        assert_ne!(a, b, "seeds must decorrelate client schedules");
    }
}
