//! Wire protocol for the network serving front-end
//! ([`crate::inference::frontend`]) — a minimal length-prefixed binary
//! format over TCP (see `docs/WIRE.md` for the byte-level spec).
//!
//! All integers are little-endian; payloads are raw f32 bits.
//!
//! **Request** (client -> server):
//! ```text
//! u32 len      # bytes after this field (= 12 + 4*rows*d)
//! u64 id       # client-chosen, echoed in the response
//! u32 rows     # batch rows in this request
//! f32[rows*d]  # row-major features, d = model input width
//! ```
//!
//! **Control** (client -> server): the request framing with
//! `rows == u32::MAX` ([`CONTROL_SENTINEL`]) and a 1-byte opcode in place
//! of the payload (`len` is therefore exactly 13). Opcode 1
//! ([`CONTROL_OP_RELOAD`]) asks the server to reload its stack and publish
//! a new epoch; success is answered with status 3 (see docs/RELOAD.md).
//!
//! **Response** (server -> client):
//! ```text
//! u32 len      # bytes after this field
//! u64 id       # echoes the request id
//! u8  status   # 0 = Ok, 1 = Busy (backpressure), 2 = Error, 3 = Epoch
//! status 0:  u32 rows, f32[rows*out_width]
//! status 1:  u32 retry_after_ms
//! status 2:  utf-8 message (len - 9 bytes)
//! status 3:  u64 epoch   # control frame succeeded; stack now at this epoch
//! ```
//!
//! Responses carry the request id because a pipelined connection may be
//! answered out of submission order (cache hits and rejections are written
//! by the reader thread, computed results by whichever pool worker ran the
//! batch). The synchronous [`Client`] keeps one request in flight, so it
//! never observes reordering.

mod client;

pub use client::{backoff_delay, Client, Reply, BACKOFF_CAP_MS, BACKOFF_FLOOR_MS};

use std::io::{self, Read, Write};

/// Refuse frames above this size (64 MiB) so a corrupt or hostile length
/// prefix cannot OOM the server.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Response status byte.
pub const STATUS_OK: u8 = 0;
pub const STATUS_BUSY: u8 = 1;
pub const STATUS_ERROR: u8 = 2;
pub const STATUS_EPOCH: u8 = 3;

/// `rows` value reserved for control frames: no real request can carry
/// `u32::MAX` rows (the 64 MiB frame cap caps rows far lower), so the
/// sentinel cleanly retrofits control traffic onto the request framing.
pub const CONTROL_SENTINEL: u32 = u32::MAX;

/// Control opcode: reload the serving stack from its manifest source and
/// publish it as a new epoch (`serve-model --reload`; docs/RELOAD.md).
/// Answered with [`ResponseBody::Epoch`] on success.
pub const CONTROL_OP_RELOAD: u8 = 1;

/// One inference request: `rows` feature rows, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestFrame {
    pub id: u64,
    pub rows: u32,
    pub payload: Vec<f32>,
}

/// One parsed client frame: a normal inference request, or a control
/// frame (`rows == `[`CONTROL_SENTINEL`], 1-byte opcode body).
#[derive(Clone, Debug, PartialEq)]
pub enum Incoming {
    Request(RequestFrame),
    Control { id: u64, op: u8 },
}

/// One server response, tagged by the request id it answers.
#[derive(Clone, Debug, PartialEq)]
pub struct ResponseFrame {
    pub id: u64,
    pub body: ResponseBody,
}

#[derive(Clone, Debug, PartialEq)]
pub enum ResponseBody {
    /// Model output: `rows` rows of `out_width` f32s, row-major.
    Output { rows: u32, data: Vec<f32> },
    /// Bounded queue was full; retry after the given backoff.
    Busy { retry_after_ms: u32 },
    /// Malformed or unservable request (shape mismatch, oversized batch).
    Error(String),
    /// A control frame succeeded; the stack now serves at this epoch
    /// (answers [`CONTROL_OP_RELOAD`]). Failures answer `Error`.
    Epoch(u64),
}

/// FNV-1a over a byte slice — the result-cache key; the serving front-end
/// ([`crate::inference::frontend`]) hashes each request's row bytes with
/// this.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// FNV-1a over the raw bits of an f32 slice (no copy).
pub fn fnv1a_f32(xs: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> io::Result<bool> {
    // Distinguish clean EOF (no bytes at all) from a truncated frame.
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(false);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("truncated frame: {got}/{} header bytes", buf.len()),
                ));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

fn frame_len<R: Read>(r: &mut R) -> io::Result<Option<usize>> {
    let mut lenb = [0u8; 4];
    if !read_exact_or_eof(r, &mut lenb)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(lenb) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds cap {MAX_FRAME_BYTES}"),
        ));
    }
    Ok(Some(len))
}

fn f32s_from_le(bytes: &[u8]) -> io::Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("payload of {} bytes is not a whole number of f32s", bytes.len()),
        ));
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

fn extend_f32s_le(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Write one request frame (single `write_all` so a frame is never
/// interleaved with another writer on a shared stream).
pub fn write_request<W: Write>(w: &mut W, req: &RequestFrame) -> io::Result<()> {
    let len = 12 + req.payload.len() * 4;
    let mut buf = Vec::with_capacity(4 + len);
    buf.extend_from_slice(&(len as u32).to_le_bytes());
    buf.extend_from_slice(&req.id.to_le_bytes());
    buf.extend_from_slice(&req.rows.to_le_bytes());
    extend_f32s_le(&mut buf, &req.payload);
    w.write_all(&buf)
}

/// Write one control frame (the request framing with
/// `rows == `[`CONTROL_SENTINEL`] and a 1-byte opcode body).
pub fn write_control<W: Write>(w: &mut W, id: u64, op: u8) -> io::Result<()> {
    let mut buf = Vec::with_capacity(4 + 13);
    buf.extend_from_slice(&13u32.to_le_bytes());
    buf.extend_from_slice(&id.to_le_bytes());
    buf.extend_from_slice(&CONTROL_SENTINEL.to_le_bytes());
    buf.push(op);
    w.write_all(&buf)
}

/// Read one request or control frame; `Ok(None)` on clean EOF (client
/// hung up between frames). Shape validation (rows x d) is the server's
/// job — the wire layer only enforces framing; likewise an unknown
/// control opcode parses fine and the server answers `Error`.
pub fn read_request<R: Read>(r: &mut R) -> io::Result<Option<Incoming>> {
    let Some(len) = frame_len(r)? else {
        return Ok(None);
    };
    if len < 12 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("request frame of {len} bytes is shorter than its 12-byte header"),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    let id = u64::from_le_bytes(body[0..8].try_into().unwrap()); // lint:allow-unwrap infallible: 8-byte slice of a >=12-byte buffer
    let rows = u32::from_le_bytes(body[8..12].try_into().unwrap()); // lint:allow-unwrap infallible: fixed-width slice
    if rows == CONTROL_SENTINEL {
        if body.len() != 13 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("control frame of {} bytes (need exactly 13: header + opcode)", body.len()),
            ));
        }
        return Ok(Some(Incoming::Control { id, op: body[12] }));
    }
    let payload = f32s_from_le(&body[12..])?;
    Ok(Some(Incoming::Request(RequestFrame { id, rows, payload })))
}

/// Write one response frame (single `write_all`; see [`write_request`]).
pub fn write_response<W: Write>(w: &mut W, resp: &ResponseFrame) -> io::Result<()> {
    let body_len = match &resp.body {
        ResponseBody::Output { data, .. } => 13 + data.len() * 4,
        ResponseBody::Busy { .. } => 13,
        ResponseBody::Error(msg) => 9 + msg.len(),
        ResponseBody::Epoch(_) => 17,
    };
    let mut buf = Vec::with_capacity(4 + body_len);
    buf.extend_from_slice(&(body_len as u32).to_le_bytes());
    buf.extend_from_slice(&resp.id.to_le_bytes());
    match &resp.body {
        ResponseBody::Output { rows, data } => {
            buf.push(STATUS_OK);
            buf.extend_from_slice(&rows.to_le_bytes());
            extend_f32s_le(&mut buf, data);
        }
        ResponseBody::Busy { retry_after_ms } => {
            buf.push(STATUS_BUSY);
            buf.extend_from_slice(&retry_after_ms.to_le_bytes());
        }
        ResponseBody::Error(msg) => {
            buf.push(STATUS_ERROR);
            buf.extend_from_slice(msg.as_bytes());
        }
        ResponseBody::Epoch(epoch) => {
            buf.push(STATUS_EPOCH);
            buf.extend_from_slice(&epoch.to_le_bytes());
        }
    }
    w.write_all(&buf)
}

/// Read one response frame; `Ok(None)` on clean EOF (server closed).
pub fn read_response<R: Read>(r: &mut R) -> io::Result<Option<ResponseFrame>> {
    let Some(len) = frame_len(r)? else {
        return Ok(None);
    };
    if len < 9 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("response frame of {len} bytes is shorter than its 9-byte header"),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    let id = u64::from_le_bytes(body[0..8].try_into().unwrap()); // lint:allow-unwrap infallible: 8-byte slice of a >=9-byte buffer
    let status = body[8];
    let rest = &body[9..];
    let body = match status {
        STATUS_OK => {
            if rest.len() < 4 {
                return Err(io::Error::new(io::ErrorKind::InvalidData, "Ok frame missing rows"));
            }
            let rows = u32::from_le_bytes(rest[0..4].try_into().unwrap()); // lint:allow-unwrap infallible: length checked above
            ResponseBody::Output { rows, data: f32s_from_le(&rest[4..])? }
        }
        STATUS_BUSY => {
            if rest.len() != 4 {
                return Err(io::Error::new(io::ErrorKind::InvalidData, "Busy frame malformed"));
            }
            ResponseBody::Busy { retry_after_ms: u32::from_le_bytes(rest.try_into().unwrap()) } // lint:allow-unwrap infallible: length checked above
        }
        STATUS_ERROR => ResponseBody::Error(String::from_utf8_lossy(rest).into_owned()),
        STATUS_EPOCH => {
            if rest.len() != 8 {
                return Err(io::Error::new(io::ErrorKind::InvalidData, "Epoch frame malformed"));
            }
            ResponseBody::Epoch(u64::from_le_bytes(rest.try_into().unwrap())) // lint:allow-unwrap infallible: length checked above
        }
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unknown response status {other}"),
            ))
        }
    };
    Ok(Some(ResponseFrame { id, body }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn request_roundtrip() {
        let req = RequestFrame { id: 0xDEAD_BEEF_0042, rows: 2, payload: vec![1.5, -2.0, 0.0, 3.25] };
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        assert_eq!(buf.len(), 4 + 12 + 16);
        let got = read_request(&mut Cursor::new(&buf)).unwrap().unwrap();
        assert_eq!(got, Incoming::Request(req));
    }

    #[test]
    fn control_roundtrip_and_malformed_length() {
        let mut buf = Vec::new();
        write_control(&mut buf, 77, CONTROL_OP_RELOAD).unwrap();
        assert_eq!(buf.len(), 4 + 13);
        let got = read_request(&mut Cursor::new(&buf)).unwrap().unwrap();
        assert_eq!(got, Incoming::Control { id: 77, op: CONTROL_OP_RELOAD });
        // A sentinel-rows frame with payload bytes beyond the opcode is
        // malformed: no real request can carry u32::MAX rows.
        let mut bad = Vec::new();
        bad.extend_from_slice(&16u32.to_le_bytes());
        bad.extend_from_slice(&77u64.to_le_bytes());
        bad.extend_from_slice(&CONTROL_SENTINEL.to_le_bytes());
        bad.extend_from_slice(&[1, 2, 3, 4]);
        assert!(read_request(&mut Cursor::new(&bad)).is_err());
    }

    #[test]
    fn response_roundtrips_all_variants() {
        let frames = [
            ResponseFrame { id: 1, body: ResponseBody::Output { rows: 1, data: vec![9.0, -1.0] } },
            ResponseFrame { id: 2, body: ResponseBody::Busy { retry_after_ms: 7 } },
            ResponseFrame { id: 3, body: ResponseBody::Error("bad shape".into()) },
            ResponseFrame { id: 4, body: ResponseBody::Epoch(0x0123_4567_89AB_CDEF) },
        ];
        for f in &frames {
            let mut buf = Vec::new();
            write_response(&mut buf, f).unwrap();
            let got = read_response(&mut Cursor::new(&buf)).unwrap().unwrap();
            assert_eq!(&got, f);
        }
    }

    #[test]
    fn back_to_back_frames_and_clean_eof() {
        let mut buf = Vec::new();
        for id in 0..3u64 {
            write_request(&mut buf, &RequestFrame { id, rows: 1, payload: vec![id as f32] })
                .unwrap();
        }
        let mut cur = Cursor::new(&buf);
        for id in 0..3u64 {
            let got = match read_request(&mut cur).unwrap().unwrap() {
                Incoming::Request(req) => req,
                other => panic!("expected a request, got {other:?}"),
            };
            assert_eq!(got.id, id);
            assert_eq!(got.payload, vec![id as f32]);
        }
        assert!(read_request(&mut cur).unwrap().is_none(), "clean EOF after last frame");
    }

    #[test]
    fn truncated_frame_is_an_error_not_eof() {
        let mut buf = Vec::new();
        write_request(&mut buf, &RequestFrame { id: 5, rows: 1, payload: vec![1.0, 2.0] }).unwrap();
        for cut in [2, 6, buf.len() - 1] {
            let err = match read_request(&mut Cursor::new(&buf[..cut])) {
                Err(e) => e,
                Ok(f) => panic!("cut={cut} parsed {f:?}"),
            };
            assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "cut={cut}");
        }
    }

    #[test]
    fn oversized_and_undersized_frames_rejected() {
        let huge = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes();
        assert!(read_request(&mut Cursor::new(&huge[..])).is_err());
        // len < header size
        let mut tiny = Vec::new();
        tiny.extend_from_slice(&4u32.to_le_bytes());
        tiny.extend_from_slice(&[0u8; 4]);
        assert!(read_request(&mut Cursor::new(&tiny)).is_err());
        assert!(read_response(&mut Cursor::new(&tiny)).is_err());
    }

    #[test]
    fn ragged_payload_rejected() {
        // 13-byte request body: 12-byte header + 1 stray payload byte
        let mut buf = Vec::new();
        buf.extend_from_slice(&13u32.to_le_bytes());
        buf.extend_from_slice(&1u64.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.push(0xFF);
        assert!(read_request(&mut Cursor::new(&buf)).is_err());
    }

    #[test]
    fn fnv1a_known_vectors() {
        // Reference values for the 64-bit FNV-1a test vectors.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fnv1a_f32_matches_byte_hash() {
        let xs = [1.5f32, -0.25, 3.1415, f32::MIN_POSITIVE];
        let mut bytes = Vec::new();
        for x in &xs {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        assert_eq!(fnv1a_f32(&xs), fnv1a(&bytes));
        assert_ne!(fnv1a_f32(&xs), fnv1a_f32(&xs[..3]));
    }
}
