//! TokenSeq: pre-tokenized sequence classification — the ImageNet-patches
//! stand-in for the ViT-proxy (Table 4 / Fig. 9). Each class has a fixed
//! prototype token sequence; samples add per-token Gaussian noise and a
//! random cyclic shift (so attention, not just pooling, carries signal).

use super::{Batch, Dataset, XData};
use crate::util::rng::Rng;

pub struct TokenSeq {
    batch: usize,
    seq: usize,
    d: usize,
    classes: usize,
    noise: f32,
    /// (classes, seq, d) prototypes.
    proto: Vec<f32>,
}

impl TokenSeq {
    pub fn new(batch: usize, seq: usize, d: usize, classes: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x746f6b);
        let mut proto = vec![0f32; classes * seq * d];
        for v in proto.iter_mut() {
            *v = rng.normal_f32();
        }
        TokenSeq { batch, seq, d, classes, noise, proto }
    }
}

impl Dataset for TokenSeq {
    fn name(&self) -> &str {
        "tokenseq"
    }

    fn sample(&self, rng: &mut Rng) -> Batch {
        let (seq, d) = (self.seq, self.d);
        let mut x = vec![0f32; self.batch * seq * d];
        let mut y = vec![0i32; self.batch];
        for b in 0..self.batch {
            let c = rng.below(self.classes);
            y[b] = c as i32;
            let shift = rng.below(seq);
            for t in 0..seq {
                let src = (t + shift) % seq;
                for j in 0..d {
                    x[(b * seq + t) * d + j] = self.proto[(c * seq + src) * d + j]
                        + self.noise * rng.normal_f32();
                }
            }
        }
        Batch { x: XData::F32(x), y }
    }
}
