//! Synthetic dataset generators — the stand-ins for CIFAR-10 / ImageNet /
//! text corpora (see DESIGN.md §4 for the substitution rationale). All are
//! seeded, infinite streams with disjoint train/eval substreams.

pub mod gaussian;
pub mod markov_lm;
pub mod synthimg;
pub mod tokens;

pub use gaussian::GaussianMixture;
pub use markov_lm::MarkovLm;
pub use synthimg::SynthImg;
pub use tokens::TokenSeq;

use crate::util::rng::Rng;

/// Model inputs for one batch. `F32` for image/feature models, `I32` for
/// token models (the LM family).
#[derive(Clone, Debug)]
pub enum XData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl XData {
    pub fn as_f32(&self) -> &[f32] {
        match self {
            XData::F32(v) => v,
            _ => panic!("expected f32 batch"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            XData::I32(v) => v,
            _ => panic!("expected i32 batch"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Batch {
    /// Flat x of shape (batch, *x_shape).
    pub x: XData,
    /// Flat labels/targets of shape (batch, *y_shape).
    pub y: Vec<i32>,
}

/// An infinite, seeded synthetic data source.
pub trait Dataset: Send {
    fn name(&self) -> &str;
    /// Sample one batch using the provided stream rng.
    fn sample(&self, rng: &mut Rng) -> Batch;
}

/// Construct the dataset matching a manifest model entry.
pub fn for_model(entry: &crate::runtime::ModelEntry, seed: u64) -> Box<dyn Dataset> {
    let b = entry.batch;
    let xs = &entry.x.shape[1..];
    match entry.task.as_str() {
        "lm" => Box::new(MarkovLm::new(b, xs[0], entry.num_classes, 4, seed)),
        _ => match xs.len() {
            1 => Box::new(GaussianMixture::new(b, xs[0], entry.num_classes, 3.0, seed)),
            2 => Box::new(TokenSeq::new(b, xs[0], xs[1], entry.num_classes, 3.0, seed)),
            3 => Box::new(SynthImg::new(b, xs[0], xs[1], xs[2], entry.num_classes, 1.0, seed)),
            other => panic!("unsupported x rank {other}"),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_labels(b: &Batch, classes: usize) {
        assert!(b.y.iter().all(|&y| (y as usize) < classes));
    }

    #[test]
    fn gaussian_is_learnable_and_seeded() {
        let ds = GaussianMixture::new(16, 8, 4, 3.0, 7);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let b1 = ds.sample(&mut r1);
        let b2 = ds.sample(&mut r2);
        assert_eq!(b1.x.as_f32(), b2.x.as_f32(), "same seed must reproduce");
        assert_eq!(b1.y, b2.y);
        check_labels(&b1, 4);
        assert_eq!(b1.x.as_f32().len(), 16 * 8);
        // Same-class samples are closer to each other than cross-class
        // (separation 3 sigma): nearest-centroid classifies correctly most
        // of the time. Quick sanity: per-class mean distinct.
        let ds2 = GaussianMixture::new(256, 8, 2, 3.0, 9);
        let b = ds2.sample(&mut Rng::new(3));
        let x = b.x.as_f32();
        let mut means = [[0f64; 8]; 2];
        let mut counts = [0usize; 2];
        for i in 0..256 {
            let c = b.y[i] as usize;
            counts[c] += 1;
            for j in 0..8 {
                means[c][j] += x[i * 8 + j] as f64;
            }
        }
        let mut dist = 0.0;
        for j in 0..8 {
            let d = means[0][j] / counts[0].max(1) as f64 - means[1][j] / counts[1].max(1) as f64;
            dist += d * d;
        }
        assert!(dist.sqrt() > 1.0, "class means should separate, got {}", dist.sqrt());
    }

    #[test]
    fn synthimg_shapes() {
        let ds = SynthImg::new(4, 3, 16, 16, 10, 0.3, 0);
        let b = ds.sample(&mut Rng::new(0));
        assert_eq!(b.x.as_f32().len(), 4 * 3 * 16 * 16);
        assert_eq!(b.y.len(), 4);
        check_labels(&b, 10);
        let v: f32 = b.x.as_f32().iter().map(|v| v * v).sum();
        assert!(v.is_finite() && v > 0.0);
    }

    #[test]
    fn markov_lm_next_token_targets() {
        let ds = MarkovLm::new(2, 32, 64, 4, 5);
        let b = ds.sample(&mut Rng::new(0));
        let x = b.x.as_i32();
        assert_eq!(x.len(), 2 * 32);
        assert_eq!(b.y.len(), 2 * 32);
        // y is x shifted left within each sequence
        for s in 0..2 {
            for t in 0..31 {
                assert_eq!(b.y[s * 32 + t], x[s * 32 + t + 1]);
            }
        }
        assert!(x.iter().all(|&t| (t as usize) < 64));
    }

    #[test]
    fn markov_lm_is_predictable() {
        // With branching 4 over vocab 64 the conditional entropy is at
        // most log(4) << log(64): a trained LM can beat the unigram floor.
        let ds = MarkovLm::new(1, 256, 64, 4, 11);
        let b = ds.sample(&mut Rng::new(2));
        let x = b.x.as_i32();
        // successors per token should be a small set
        let mut succ: std::collections::HashMap<i32, std::collections::HashSet<i32>> =
            Default::default();
        for t in 0..255 {
            succ.entry(x[t]).or_default().insert(x[t + 1]);
        }
        let max_branch = succ.values().map(|s| s.len()).max().unwrap();
        assert!(max_branch <= 4, "branching {max_branch} > 4");
    }

    #[test]
    fn token_seq_shapes() {
        let ds = TokenSeq::new(8, 16, 48, 10, 0.5, 3);
        let b = ds.sample(&mut Rng::new(1));
        assert_eq!(b.x.as_f32().len(), 8 * 16 * 48);
        check_labels(&b, 10);
    }

    #[test]
    fn for_model_dispatch() {
        use crate::runtime::manifest::{Hyper, IoSpec, ModelEntry};
        let mk = |task: &str, xshape: Vec<usize>| ModelEntry {
            name: "t".into(),
            batch: 4,
            task: task.into(),
            num_classes: 10,
            x: IoSpec { shape: xshape, dtype: "f32".into() },
            y: IoSpec { shape: vec![4], dtype: "i32".into() },
            params: vec![],
            hyper: Hyper { momentum: 0.9, weight_decay: 0.0, label_smoothing: 0.0 },
            param_count: 0,
            programs: Default::default(),
        };
        assert_eq!(for_model(&mk("classify", vec![4, 8]), 0).name(), "gaussian");
        assert_eq!(for_model(&mk("classify", vec![4, 3, 8, 8]), 0).name(), "synthimg");
        assert_eq!(for_model(&mk("classify", vec![4, 6, 12]), 0).name(), "tokenseq");
        assert_eq!(for_model(&mk("lm", vec![4, 16]), 0).name(), "markov_lm");
    }
}
