//! Gaussian-mixture classification: class means at pairwise separation
//! `sep` (in noise-sigma units), unit isotropic noise. The MLP-family
//! workload; difficulty controlled by `sep` and dimension.

use super::{Batch, Dataset, XData};
use crate::util::rng::Rng;

pub struct GaussianMixture {
    batch: usize,
    d: usize,
    classes: usize,
    /// Flattened (classes, d) mean matrix, fixed at construction.
    means: Vec<f32>,
}

impl GaussianMixture {
    pub fn new(batch: usize, d: usize, classes: usize, sep: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x6d65616e73);
        let mut means = vec![0f32; classes * d];
        for c in 0..classes {
            // random direction scaled to norm `sep`
            let mut norm = 0f64;
            for j in 0..d {
                let v = rng.normal();
                means[c * d + j] = v as f32;
                norm += v * v;
            }
            let scale = (sep / norm.sqrt().max(1e-9)) as f32;
            for j in 0..d {
                means[c * d + j] *= scale;
            }
        }
        GaussianMixture { batch, d, classes, means }
    }
}

impl Dataset for GaussianMixture {
    fn name(&self) -> &str {
        "gaussian"
    }

    fn sample(&self, rng: &mut Rng) -> Batch {
        let mut x = vec![0f32; self.batch * self.d];
        let mut y = vec![0i32; self.batch];
        for b in 0..self.batch {
            let c = rng.below(self.classes);
            y[b] = c as i32;
            for j in 0..self.d {
                x[b * self.d + j] = self.means[c * self.d + j] + rng.normal_f32();
            }
        }
        Batch { x: XData::F32(x), y }
    }
}
