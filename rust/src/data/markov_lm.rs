//! MarkovLm: sequences from a random sparse Markov chain — the tiny-corpus
//! stand-in for LM training. Each token has `branching` possible
//! successors with random (renormalized) probabilities, so the optimal
//! cross-entropy is about log(branching) nats versus log(vocab) for an
//! untrained model: the loss curve has real headroom to descend.

use super::{Batch, Dataset, XData};
use crate::util::rng::Rng;

pub struct MarkovLm {
    batch: usize,
    seq: usize,
    vocab: usize,
    /// (vocab, branching) successor ids and cumulative probabilities.
    succ: Vec<u32>,
    cum: Vec<f32>,
    branching: usize,
}

impl MarkovLm {
    pub fn new(batch: usize, seq: usize, vocab: usize, branching: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x6d61726b);
        let branching = branching.clamp(1, vocab);
        let mut succ = vec![0u32; vocab * branching];
        let mut cum = vec![0f32; vocab * branching];
        for t in 0..vocab {
            let choices = rng.choose_k(vocab, branching);
            let mut probs: Vec<f32> = (0..branching).map(|_| rng.uniform_f32() + 0.1).collect();
            let total: f32 = probs.iter().sum();
            let mut acc = 0f32;
            for (i, c) in choices.into_iter().enumerate() {
                succ[t * branching + i] = c as u32;
                acc += probs[i] / total;
                cum[t * branching + i] = acc;
            }
            probs.clear();
        }
        MarkovLm { batch, seq, vocab, succ, cum, branching }
    }

    fn step(&self, tok: usize, rng: &mut Rng) -> usize {
        let u = rng.uniform_f32();
        let base = tok * self.branching;
        for i in 0..self.branching {
            if u <= self.cum[base + i] {
                return self.succ[base + i] as usize;
            }
        }
        self.succ[base + self.branching - 1] as usize
    }
}

impl Dataset for MarkovLm {
    fn name(&self) -> &str {
        "markov_lm"
    }

    fn sample(&self, rng: &mut Rng) -> Batch {
        let mut x = vec![0i32; self.batch * self.seq];
        let mut y = vec![0i32; self.batch * self.seq];
        for b in 0..self.batch {
            let mut tok = rng.below(self.vocab);
            for t in 0..self.seq {
                x[b * self.seq + t] = tok as i32;
                tok = self.step(tok, rng);
                y[b * self.seq + t] = tok as i32;
            }
        }
        Batch { x: XData::I32(x), y }
    }
}
