//! SynthImg: class-conditional spatial textures — the CIFAR-10/ImageNet
//! stand-in for the CNN experiments. Each class is a small set of 2-D
//! sinusoidal gratings (per-channel phase) + Gaussian pixel noise, so the
//! decision boundary is a *spatial frequency* pattern a conv net must
//! learn (pure per-pixel statistics do not separate the classes).

use super::{Batch, Dataset, XData};
use crate::util::rng::Rng;

pub struct SynthImg {
    batch: usize,
    ch: usize,
    h: usize,
    w: usize,
    classes: usize,
    noise: f32,
    /// Per class: (fx, fy, per-channel phase offsets).
    gratings: Vec<(f32, f32, Vec<f32>)>,
}

impl SynthImg {
    pub fn new(batch: usize, ch: usize, h: usize, w: usize, classes: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x696d67);
        let gratings = (0..classes)
            .map(|c| {
                // distinct integer frequency pair per class (stable across
                // noise draws); angle varies with class index.
                let fx = 1.0 + (c % 4) as f32;
                let fy = 1.0 + ((c / 4) % 4) as f32 + 0.5 * ((c % 2) as f32);
                let phases = (0..ch).map(|_| rng.uniform_f32() * std::f32::consts::TAU).collect();
                (fx, fy, phases)
            })
            .collect();
        SynthImg { batch, ch, h, w, classes, noise, gratings }
    }
}

impl Dataset for SynthImg {
    fn name(&self) -> &str {
        "synthimg"
    }

    fn sample(&self, rng: &mut Rng) -> Batch {
        let (ch, h, w) = (self.ch, self.h, self.w);
        let mut x = vec![0f32; self.batch * ch * h * w];
        let mut y = vec![0i32; self.batch];
        for b in 0..self.batch {
            let c = rng.below(self.classes);
            y[b] = c as i32;
            let (fx, fy, phases) = &self.gratings[c];
            // random translation: keeps the task shift-invariant
            let dx = rng.uniform_f32();
            let dy = rng.uniform_f32();
            for cc in 0..ch {
                let phase = phases[cc];
                for i in 0..h {
                    for j in 0..w {
                        let arg = std::f32::consts::TAU
                            * (fx * (i as f32 / h as f32 + dx) + fy * (j as f32 / w as f32 + dy))
                            + phase;
                        let idx = ((b * ch + cc) * h + i) * w + j;
                        x[idx] = arg.sin() + self.noise * rng.normal_f32();
                    }
                }
            }
        }
        Batch { x: XData::F32(x), y }
    }
}
