//! N:M block sparsity (paper §2): exactly N non-zeros in every contiguous
//! block of M weights along the fan-in axis. The constant fan-in
//! constraint SRigL learns is the special case M = full fan-in; this
//! module provides the general form for the SR-STE baseline and for
//! comparing representations.

use crate::sparsity::mask::Mask;
use crate::tensor::Tensor;

/// Top-N-of-M magnitude projection mask: for each neuron row and each
/// M-wide block, keep the N largest-|w| entries. Requires fan_in % m == 0.
pub fn nm_mask(w: &Tensor, n: usize, m: usize) -> Mask {
    let (rows, f) = w.neuron_view();
    assert!(m >= 1 && n >= 1 && n <= m, "bad N:M = {n}:{m}");
    assert_eq!(f % m, 0, "fan-in {f} not divisible by M={m}");
    let mut mask = Mask::from_tensor(Tensor::zeros(&w.shape));
    let mut idx: Vec<usize> = Vec::with_capacity(m);
    for r in 0..rows {
        for b in (0..f).step_by(m) {
            idx.clear();
            idx.extend(0..m);
            idx.sort_by(|&a, &c| {
                w.data[r * f + b + c]
                    .abs()
                    .partial_cmp(&w.data[r * f + b + a].abs())
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            for &j in idx.iter().take(n) {
                mask.t.data[r * f + b + j] = 1.0;
            }
        }
    }
    mask
}

/// Check the N:M invariant.
pub fn is_nm(mask: &Mask, n: usize, m: usize) -> bool {
    let f = mask.fan_in;
    if f % m != 0 {
        return false;
    }
    for r in 0..mask.neurons {
        for b in (0..f).step_by(m) {
            let cnt = (0..m).filter(|&j| mask.is_active(r, b + j)).count();
            if cnt != n {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn projection_keeps_largest() {
        let w = Tensor::from_vec(&[1, 8], vec![0.1, -0.9, 0.2, 0.05, 3.0, -0.1, 0.0, 2.0]);
        let m = nm_mask(&w, 2, 4);
        assert!(is_nm(&m, 2, 4));
        // block 0: keep -0.9 and 0.2; block 1: keep 3.0 and 2.0
        assert_eq!(m.t.data, vec![0., 1., 1., 0., 1., 0., 0., 1.]);
    }

    #[test]
    fn invariant_detects_violation() {
        let mut rng = Rng::new(0);
        let w = Tensor::normal(&[6, 16], 1.0, &mut rng);
        let mut m = nm_mask(&w, 1, 4);
        assert!(is_nm(&m, 1, 4));
        assert_eq!(m.nnz(), 6 * 4);
        m.set(0, 0, true);
        m.set(0, 1, true);
        assert!(!is_nm(&m, 1, 4));
    }

    #[test]
    fn two_four_density_is_half() {
        let mut rng = Rng::new(1);
        let w = Tensor::normal(&[16, 64], 1.0, &mut rng);
        let m = nm_mask(&w, 2, 4);
        assert!((m.density() - 0.5).abs() < 1e-12);
        // 2:4 is exactly the Ampere-accelerable pattern (paper §2)
        assert!(is_nm(&m, 2, 4));
    }
}
