//! Compressed Sparse Row matrices — the *unstructured* baseline
//! representation the paper benchmarks the condensed format against
//! (Fig. 4 "unstructured (CSR)").

use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// len rows+1; row r occupies indices[indptr[r]..indptr[r+1]].
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    pub fn from_dense(t: &Tensor) -> Csr {
        let (rows, cols) = t.neuron_view();
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for r in 0..rows {
            for c in 0..cols {
                let v = t.data[r * cols + c];
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        Csr { rows, cols, indptr, indices, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn to_dense(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.rows, self.cols]);
        for r in 0..self.rows {
            for i in self.indptr[r] as usize..self.indptr[r + 1] as usize {
                out.data[r * self.cols + self.indices[i] as usize] += self.values[i];
            }
        }
        out
    }

    /// Storage bytes: values + indices + indptr.
    pub fn storage_bytes(&self) -> usize {
        self.values.len() * 4 + self.indices.len() * 4 + self.indptr.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::mask::Mask;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(0);
        let m = Mask::random_per_layer(&[20, 30], 111, &mut rng);
        let mut w = Tensor::normal(&[20, 30], 1.0, &mut rng);
        w.mul_assign(&m.t);
        let csr = Csr::from_dense(&w);
        assert_eq!(csr.nnz(), 111);
        assert_eq!(csr.to_dense().data, w.data);
    }

    #[test]
    fn empty_rows_ok() {
        let mut w = Tensor::zeros(&[3, 4]);
        w.data[1 * 4 + 2] = 5.0;
        let csr = Csr::from_dense(&w);
        assert_eq!(csr.indptr, vec![0, 0, 1, 1]);
        assert_eq!(csr.to_dense().data, w.data);
    }

    #[test]
    fn indices_sorted_within_rows() {
        let mut rng = Rng::new(1);
        let m = Mask::random_per_layer(&[10, 50], 200, &mut rng);
        let csr = Csr::from_dense(&m.t);
        for r in 0..csr.rows {
            let row = &csr.indices[csr.indptr[r] as usize..csr.indptr[r + 1] as usize];
            assert!(row.windows(2).all(|p| p[0] < p[1]));
        }
    }
}
