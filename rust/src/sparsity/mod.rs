//! Sparsity substrate: per-layer distributions (uniform/ERK), constant
//! fan-in mask algebra, and the condensed & CSR storage formats.

pub mod condensed;
pub mod csr;
pub mod distribution;
pub mod mask;
pub mod nm;
pub mod quantized;

pub use condensed::{Condensed, CondensedError, CondensedTiled, IdxVal};
pub use quantized::{IdxQ, QuantizedCondensed, MAX_QUANT_WIDTH};
pub use csr::Csr;
pub use distribution::{achieved_sparsity, fan_in_targets, layer_densities, Distribution, LayerShape};
pub use mask::Mask;
