//! Constant-fan-in mask algebra.
//!
//! A mask is stored as an f32 {0,1} `Tensor` (the exact representation the
//! AOT HLO multiplies into the weights), viewed as `(neurons, fan_in)` with
//! the neuron axis first. `Mask` wraps it with the structural queries and
//! invariant checks SRigL needs.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Mask {
    pub t: Tensor,
    pub neurons: usize,
    pub fan_in: usize,
}

impl Mask {
    pub fn from_tensor(t: Tensor) -> Mask {
        let (neurons, fan_in) = t.neuron_view();
        Mask { t, neurons, fan_in }
    }

    /// All-active mask (density 1).
    pub fn dense(shape: &[usize]) -> Mask {
        Mask::from_tensor(Tensor::ones(shape))
    }

    /// Random mask with exactly `k` active incoming weights per neuron —
    /// the constant fan-in initial topology (SRigL).
    pub fn random_constant_fan_in(shape: &[usize], k: usize, rng: &mut Rng) -> Mask {
        let mut m = Mask::from_tensor(Tensor::zeros(shape));
        assert!(k <= m.fan_in, "k={k} > fan_in={}", m.fan_in);
        for n in 0..m.neurons {
            for j in rng.choose_k(m.fan_in, k) {
                m.t.data[n * m.fan_in + j] = 1.0;
            }
        }
        m
    }

    /// Random mask with exactly `nnz` active weights anywhere in the layer —
    /// the constant-per-layer initial topology (RigL/SET baselines).
    pub fn random_per_layer(shape: &[usize], nnz: usize, rng: &mut Rng) -> Mask {
        let mut m = Mask::from_tensor(Tensor::zeros(shape));
        assert!(nnz <= m.t.numel());
        for j in rng.choose_k(m.t.numel(), nnz) {
            m.t.data[j] = 1.0;
        }
        m
    }

    #[inline]
    pub fn is_active(&self, neuron: usize, j: usize) -> bool {
        self.t.data[neuron * self.fan_in + j] != 0.0
    }

    #[inline]
    pub fn set(&mut self, neuron: usize, j: usize, on: bool) {
        self.t.data[neuron * self.fan_in + j] = if on { 1.0 } else { 0.0 };
    }

    pub fn nnz(&self) -> usize {
        self.t.count_nonzero()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / self.t.numel() as f64
    }

    /// Active incoming connections per neuron.
    pub fn fan_in_counts(&self) -> Vec<usize> {
        (0..self.neurons)
            .map(|n| {
                self.t.data[n * self.fan_in..(n + 1) * self.fan_in]
                    .iter()
                    .filter(|v| **v != 0.0)
                    .count()
            })
            .collect()
    }

    /// Neurons with at least one active weight.
    pub fn active_neurons(&self) -> usize {
        self.fan_in_counts().iter().filter(|&&c| c > 0).count()
    }

    /// True iff every *active* neuron has exactly `k` incoming weights —
    /// the constant fan-in invariant (ablated neurons are all-zero rows).
    pub fn is_constant_fan_in(&self, k: usize) -> bool {
        self.fan_in_counts().iter().all(|&c| c == 0 || c == k)
    }

    /// Variance of fan-in across active neurons (paper Fig. 12 metric).
    pub fn fan_in_variance(&self) -> f64 {
        let counts: Vec<f64> = self
            .fan_in_counts()
            .into_iter()
            .filter(|&c| c > 0)
            .map(|c| c as f64)
            .collect();
        if counts.len() < 2 {
            return 0.0;
        }
        let mean = counts.iter().sum::<f64>() / counts.len() as f64;
        counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / counts.len() as f64
    }

    /// Fraction of positions that are currently or were ever active, for
    /// ITOP tracking — callers fold this into an accumulator mask.
    pub fn or_into(&self, acc: &mut Tensor) {
        assert_eq!(acc.shape, self.t.shape);
        for (a, m) in acc.data.iter_mut().zip(&self.t.data) {
            if *m != 0.0 {
                *a = 1.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_fan_in_init() {
        let mut rng = Rng::new(0);
        let m = Mask::random_constant_fan_in(&[32, 64], 7, &mut rng);
        assert!(m.is_constant_fan_in(7));
        assert_eq!(m.nnz(), 32 * 7);
        assert_eq!(m.active_neurons(), 32);
        assert_eq!(m.fan_in_variance(), 0.0);
    }

    #[test]
    fn conv_shaped_mask() {
        let mut rng = Rng::new(1);
        let m = Mask::random_constant_fan_in(&[8, 4, 3, 3], 5, &mut rng);
        assert_eq!(m.fan_in, 36);
        assert!(m.is_constant_fan_in(5));
    }

    #[test]
    fn per_layer_init_count() {
        let mut rng = Rng::new(2);
        let m = Mask::random_per_layer(&[16, 32], 100, &mut rng);
        assert_eq!(m.nnz(), 100);
        // with overwhelming probability NOT constant fan-in
        assert!(!m.is_constant_fan_in(100 / 16) || m.fan_in_variance() == 0.0);
    }

    #[test]
    fn set_get_density() {
        let mut m = Mask::from_tensor(Tensor::zeros(&[2, 4]));
        m.set(0, 1, true);
        m.set(1, 3, true);
        assert!(m.is_active(0, 1) && m.is_active(1, 3) && !m.is_active(0, 0));
        assert_eq!(m.nnz(), 2);
        assert!((m.density() - 0.25).abs() < 1e-12);
        m.set(0, 1, false);
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn or_into_accumulates() {
        let mut rng = Rng::new(3);
        let mut acc = Tensor::zeros(&[8, 8]);
        let m1 = Mask::random_constant_fan_in(&[8, 8], 2, &mut rng);
        let m2 = Mask::random_constant_fan_in(&[8, 8], 2, &mut rng);
        m1.or_into(&mut acc);
        m2.or_into(&mut acc);
        let union = acc.count_nonzero();
        assert!(union >= m1.nnz().max(m2.nnz()));
        assert!(union <= m1.nnz() + m2.nnz());
    }
}
