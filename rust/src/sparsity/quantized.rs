//! The int8-quantized condensed representation (NNUE-style f32-train /
//! quantized-serve split).
//!
//! Same geometry as [`Condensed`] — n_active rows x constant fan-in k,
//! ascending in-row column indices, ablated rows dropped — but each
//! stored weight is a 4-byte interleaved [`IdxQ`] record (`u16` column
//! index + `i8` quantized weight + one zero pad byte) instead of the
//! 8-byte f32 [`crate::sparsity::IdxVal`]: half the weight traffic on
//! the memory-bandwidth-bound gather-MAC. Per active row a single f32
//! scale maps quantized integers back to weight space.
//!
//! **Quantization + calibration.** Per row: `s0 = max|w| / 127`,
//! `q_i = round(w_i / s0)` (symmetric, so `q_i` never hits -128 and
//! `|q_i| <= 127`). The stored scale is then *calibrated* against the
//! f32 oracle weights by least squares over the already-chosen integers:
//! `s = Σ w_i·q_i / Σ q_i²` — the unique minimizer of `Σ (w_i - s·q_i)²`
//! for fixed `q`, accumulated in f64 so construction is deterministic.
//! Each term `w_i·q_i` is non-negative (`q_i` has the sign of `w_i`), so
//! `s >= 0` always.
//!
//! **Accumulator range.** Constant fan-in makes the i32 accumulator
//! statically boundable from k alone: with `|q| <= 127` and activations
//! quantized to `|qx| <= 127`, `|acc| <= k·127² = 16129·k`. Since
//! construction enforces `d <= 65536` (u16 indices) and `k <= d`,
//! `|acc| <= ~1.06e9 < 2³¹` — overflow is impossible by construction,
//! no saturation logic needed. See docs/KERNELS.md.
//!
//! **Error budget.** Alongside the scale, construction records two
//! per-row diagnostics that bound the quantization error of any output
//! without reference to the original weights:
//! `resid_l1[r] = Σ |w_i - s·q_i|` and `qabs_l1[r] = Σ |s·q_i|`.
//! For an input row with `X = max|x|` (so the activation scale is
//! `sx = X/127` and `|x_j - sx·qx_j| <= sx/2`):
//!
//! ```text
//! |y_f32 - y_int8| = |Σ (w_i - s·q_i)·x + Σ s·q_i·(x - sx·qx)|
//!                 <= X·resid_l1[r] + (X/254)·qabs_l1[r]
//! ```
//!
//! [`QuantizedCondensed::row_error_bound`] evaluates exactly that;
//! `rust/tests/quant_equivalence.rs` pins every served output inside it.
//!
//! Construction returns the same typed [`CondensedError`] as the f32
//! forms (plus [`CondensedError::WidthTooLarge`] when `d` overflows the
//! u16 index).

use crate::sparsity::condensed::{Condensed, CondensedError};
use crate::sparsity::mask::Mask;
use crate::tensor::Tensor;

/// Largest input width a [`QuantizedCondensed`] layer can index: column
/// indices are stored as `u16`, so `d` must not exceed 2^16. (Also what
/// keeps the i32 accumulator bound `k·127² <= d·127²` under 2³¹.)
pub const MAX_QUANT_WIDTH: usize = 1 << 16;

/// Symmetric int8 range: quantized values live in `[-127, 127]` (the
/// -128 corner is never produced, keeping negation and the accumulator
/// bound symmetric).
pub const QMAX: i32 = 127;

/// One interleaved record of the quantized condensed layout: column
/// index (`u16`), quantized weight (`i8`), and one explicit zero pad
/// byte so the whole record is exactly one initialized 32-bit lane —
/// the AVX2 kernel loads 8 records as a single `__m256i` and decodes
/// index/weight with mask/shift ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(C)]
pub struct IdxQ {
    /// Column index into the input row.
    pub idx: u16,
    /// Quantized weight in `[-127, 127]`.
    pub q: i8,
    pad: u8,
}

// One record == one 32-bit lane (idx in bits 0..16, q in bits 16..24,
// zero pad in 24..32): the AVX2 decode depends on this exact layout.
const _: () = assert!(std::mem::size_of::<IdxQ>() == 4);
const _: () = assert!(std::mem::align_of::<IdxQ>() <= 4);

impl IdxQ {
    /// Build a record (the pad byte is always zero).
    pub fn new(idx: u16, q: i8) -> IdxQ {
        IdxQ { idx, q, pad: 0 }
    }
}

/// The int8 condensed layout: [`Condensed`] geometry, [`IdxQ`] records,
/// calibrated per-row scales, and the per-row error-budget terms.
/// Consumed by the integer kernels in [`crate::kernels::quant`]; the
/// same stored layout serves both the row-gather and the batch-tiled
/// drivers (tile width is a kernel property, not a storage one).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedCondensed {
    /// Number of columns of the dense matrix (layer input features).
    pub d: usize,
    /// Number of rows of the dense matrix (layer width incl. ablated).
    pub n_orig: usize,
    /// Constant fan-in.
    pub k: usize,
    /// Surviving neuron ids, ascending; len = n_active.
    pub active: Vec<u32>,
    /// (n_active x k) interleaved (index, int8 weight) records,
    /// row-major, indices ascending within each row.
    pub recs: Vec<IdxQ>,
    /// Per active row: calibrated dequantization scale (>= 0).
    pub scales: Vec<f32>,
    /// Per active row: `Σ |w_i - s·q_i|` — the weight-residual term of
    /// the error budget.
    pub resid_l1: Vec<f32>,
    /// Per active row: `Σ |s·q_i|` — the activation-rounding term of
    /// the error budget.
    pub qabs_l1: Vec<f32>,
}

impl QuantizedCondensed {
    /// Quantize and calibrate an f32 [`Condensed`] matrix. Errors with
    /// [`CondensedError::WidthTooLarge`] when the input width overflows
    /// the u16 column index.
    pub fn from_condensed(c: &Condensed) -> Result<QuantizedCondensed, CondensedError> {
        if c.d > MAX_QUANT_WIDTH {
            return Err(CondensedError::WidthTooLarge { d: c.d, limit: MAX_QUANT_WIDTH });
        }
        let na = c.n_active();
        let mut recs = Vec::with_capacity(na * c.k);
        let mut scales = Vec::with_capacity(na);
        let mut resid_l1 = Vec::with_capacity(na);
        let mut qabs_l1 = Vec::with_capacity(na);
        for r in 0..na {
            let vals = &c.values[r * c.k..(r + 1) * c.k];
            let idxs = &c.idx[r * c.k..(r + 1) * c.k];
            let amax = vals.iter().fold(0f32, |m, &v| m.max(v.abs()));
            let row0 = recs.len();
            if amax == 0.0 {
                // A row whose surviving weights are all exactly zero
                // (mask-active but value 0): scale 0, all-zero integers —
                // the forward reproduces `bias` exactly, like the oracle.
                for &j in idxs {
                    recs.push(IdxQ::new(j as u16, 0));
                }
                scales.push(0.0);
                resid_l1.push(0.0);
                qabs_l1.push(0.0);
                continue;
            }
            // Initial symmetric step, then integers (f64 so construction
            // rounds identically everywhere).
            let s0 = amax as f64 / QMAX as f64;
            let mut num = 0f64; // Σ w·q
            let mut den = 0i64; // Σ q²  (exact in integers)
            for (&v, &j) in vals.iter().zip(idxs) {
                let q = (v as f64 / s0).round().clamp(-(QMAX as f64), QMAX as f64) as i32;
                recs.push(IdxQ::new(j as u16, q as i8));
                num += v as f64 * q as f64;
                den += (q as i64) * (q as i64);
            }
            // Least-squares calibration of the scale for the chosen
            // integers; den > 0 because amax > 0 puts at least one
            // |q| = 127 in the row. Each w·q term is >= 0, so s >= 0.
            let s = (num / den as f64) as f32;
            let mut resid = 0f64;
            let mut qabs = 0f64;
            for (&v, rec) in vals.iter().zip(&recs[row0..]) {
                let deq = s as f64 * rec.q as f64;
                resid += (v as f64 - deq).abs();
                qabs += deq.abs();
            }
            scales.push(s);
            resid_l1.push(resid as f32);
            qabs_l1.push(qabs as f32);
        }
        Ok(QuantizedCondensed {
            d: c.d,
            n_orig: c.n_orig,
            k: c.k,
            active: c.active.clone(),
            recs,
            scales,
            resid_l1,
            qabs_l1,
        })
    }

    /// Build directly from a weight tensor and its constant-fan-in mask
    /// (same contract as [`Condensed::from_masked`], then quantize).
    pub fn from_masked(w: &Tensor, m: &Mask) -> Result<QuantizedCondensed, CondensedError> {
        QuantizedCondensed::from_condensed(&Condensed::from_masked(w, m)?)
    }

    /// Surviving-neuron count.
    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Storage bytes: 4-byte records + active list + the three per-row
    /// f32 side arrays (scale, resid_l1, qabs_l1). At any realistic k
    /// this is just under half the f32 condensed footprint.
    pub fn storage_bytes(&self) -> usize {
        self.recs.len() * std::mem::size_of::<IdxQ>()
            + self.active.len() * 4
            + (self.scales.len() + self.resid_l1.len() + self.qabs_l1.len()) * 4
    }

    /// Expand to the f32 [`Condensed`] matrix this quantization *round-
    /// trips to* — values `s·q`, the dequantized twin the error budget
    /// is measured against. Geometry (active list, indices, k) is
    /// preserved exactly.
    pub fn dequantize(&self) -> Condensed {
        let mut values = Vec::with_capacity(self.recs.len());
        let mut idx = Vec::with_capacity(self.recs.len());
        for r in 0..self.n_active() {
            let s = self.scales[r];
            for rec in &self.recs[r * self.k..(r + 1) * self.k] {
                idx.push(rec.idx as u32);
                values.push(s * rec.q as f32);
            }
        }
        Condensed {
            d: self.d,
            n_orig: self.n_orig,
            k: self.k,
            active: self.active.clone(),
            values,
            idx,
        }
    }

    /// The documented per-row error budget for one output element given
    /// the input row's max magnitude `x_absmax`:
    /// `X·resid_l1[r] + (X/254)·qabs_l1[r]` (see the module docs for the
    /// derivation). `r` indexes *active* rows. Pure f32 evaluation slop
    /// (the i32→f32 accumulator cast, the finalize multiply) is not
    /// included — callers asserting against it add a small relative
    /// cushion.
    pub fn row_error_bound(&self, r: usize, x_absmax: f32) -> f32 {
        x_absmax * (self.resid_l1[r] + self.qabs_l1[r] / (2.0 * QMAX as f32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_layer(n: usize, d: usize, k: usize, seed: u64) -> (Tensor, Mask) {
        let mut rng = Rng::new(seed);
        let m = Mask::random_constant_fan_in(&[n, d], k, &mut rng);
        let mut w = Tensor::normal(&[n, d], 1.0, &mut rng);
        w.mul_assign(&m.t);
        (w, m)
    }

    #[test]
    fn geometry_matches_f32_condensed() {
        let (w, m) = random_layer(16, 40, 7, 0);
        let c = Condensed::from_masked(&w, &m).unwrap();
        let q = QuantizedCondensed::from_masked(&w, &m).unwrap();
        assert_eq!((q.d, q.n_orig, q.k), (c.d, c.n_orig, c.k));
        assert_eq!(q.active, c.active);
        assert_eq!(q.recs.len(), c.idx.len());
        for (rec, &j) in q.recs.iter().zip(&c.idx) {
            assert_eq!(rec.idx as u32, j);
            assert!(rec.q >= -127, "symmetric range never produces -128");
        }
        assert_eq!(q.scales.len(), q.n_active());
        // every row actually uses the full int8 range (max |q| == 127)
        for r in 0..q.n_active() {
            let m = q.recs[r * q.k..(r + 1) * q.k].iter().map(|p| (p.q as i32).abs()).max();
            assert_eq!(m, Some(QMAX));
        }
    }

    #[test]
    fn calibrated_scale_is_least_squares_optimal() {
        let (w, m) = random_layer(12, 64, 9, 3);
        let c = Condensed::from_masked(&w, &m).unwrap();
        let q = QuantizedCondensed::from_condensed(&c).unwrap();
        for r in 0..q.n_active() {
            let vals = &c.values[r * c.k..(r + 1) * c.k];
            let qs: Vec<f64> =
                q.recs[r * q.k..(r + 1) * q.k].iter().map(|p| p.q as f64).collect();
            let sse = |s: f64| -> f64 {
                vals.iter().zip(&qs).map(|(&v, &qi)| (v as f64 - s * qi).powi(2)).sum()
            };
            let s = q.scales[r] as f64;
            let amax = vals.iter().fold(0f32, |a, &v| a.max(v.abs())) as f64;
            let s0 = amax / 127.0;
            // LSQ-calibrated never worse than the naive amax/127 step
            assert!(sse(s) <= sse(s0) * (1.0 + 1e-9), "row {r}: {} vs {}", sse(s), sse(s0));
            // and locally optimal (perturbing the scale does not help)
            for ds in [0.999, 1.001] {
                assert!(sse(s) <= sse(s * ds) * (1.0 + 1e-9), "row {r} not optimal");
            }
        }
    }

    #[test]
    fn residual_within_half_step_bound() {
        // |w - s0·q| <= s0/2 per weight by rounding; calibration only
        // shrinks the L2 residual, and the recorded L1 residual stays
        // within the naive half-step envelope with modest slack.
        let (w, m) = random_layer(20, 128, 17, 5);
        let c = Condensed::from_masked(&w, &m).unwrap();
        let q = QuantizedCondensed::from_condensed(&c).unwrap();
        for r in 0..q.n_active() {
            let vals = &c.values[r * c.k..(r + 1) * c.k];
            let amax = vals.iter().fold(0f32, |a, &v| a.max(v.abs()));
            let naive = q.k as f32 * amax / 254.0;
            assert!(
                q.resid_l1[r] <= naive * 2.0 + 1e-6,
                "row {r}: resid {} vs half-step envelope {}",
                q.resid_l1[r],
                naive
            );
            assert!(q.scales[r] >= 0.0, "calibrated scale must be non-negative");
        }
    }

    #[test]
    fn dequantized_twin_preserves_geometry_and_error() {
        let (w, m) = random_layer(14, 30, 5, 4);
        let c = Condensed::from_masked(&w, &m).unwrap();
        let q = QuantizedCondensed::from_condensed(&c).unwrap();
        let deq = q.dequantize();
        assert_eq!(deq.to_mask().t.data, m.t.data, "mask survives the round-trip");
        assert_eq!((deq.d, deq.n_orig, deq.k, &deq.active), (c.d, c.n_orig, c.k, &c.active));
        // per-row L1 gap of the round-tripped values == recorded resid_l1
        for r in 0..q.n_active() {
            let gap: f32 = c.values[r * c.k..(r + 1) * c.k]
                .iter()
                .zip(&deq.values[r * c.k..(r + 1) * c.k])
                .map(|(&a, &b)| (a - b).abs())
                .sum();
            assert!(
                (gap - q.resid_l1[r]).abs() <= 1e-4 * (1.0 + gap),
                "row {r}: {gap} vs {}",
                q.resid_l1[r]
            );
        }
    }

    #[test]
    fn rejects_width_over_u16_with_typed_error() {
        let c = Condensed {
            d: MAX_QUANT_WIDTH + 1,
            n_orig: 1,
            k: 1,
            active: vec![0],
            values: vec![1.0],
            idx: vec![MAX_QUANT_WIDTH as u32],
        };
        match QuantizedCondensed::from_condensed(&c) {
            Err(CondensedError::WidthTooLarge { d, limit }) => {
                assert_eq!((d, limit), (MAX_QUANT_WIDTH + 1, MAX_QUANT_WIDTH));
            }
            other => panic!("expected WidthTooLarge, got {other:?}"),
        }
        let e = QuantizedCondensed::from_condensed(&c).unwrap_err();
        assert!(e.to_string().contains("u16"), "{e}");
    }

    #[test]
    fn width_at_exact_limit_is_accepted() {
        let c = Condensed {
            d: MAX_QUANT_WIDTH,
            n_orig: 1,
            k: 1,
            active: vec![0],
            values: vec![0.5],
            idx: vec![(MAX_QUANT_WIDTH - 1) as u32],
        };
        let q = QuantizedCondensed::from_condensed(&c).unwrap();
        assert_eq!(q.recs[0].idx, (MAX_QUANT_WIDTH - 1) as u16);
        assert_eq!(q.recs[0].q, 127);
    }

    #[test]
    fn all_ablated_is_empty() {
        let w = Tensor::zeros(&[6, 10]);
        let m = Mask::from_tensor(Tensor::zeros(&[6, 10]));
        let q = QuantizedCondensed::from_masked(&w, &m).unwrap();
        assert_eq!(q.n_active(), 0);
        assert_eq!(q.k, 0);
        assert!(q.recs.is_empty() && q.scales.is_empty());
        assert_eq!(q.storage_bytes(), 0);
        assert_eq!(q.dequantize().to_dense().data, w.data);
    }

    #[test]
    fn zero_valued_active_row_gets_zero_scale() {
        // mask-active but value-zero weights: scale 0, q all 0, budget 0
        let c = Condensed {
            d: 8,
            n_orig: 2,
            k: 2,
            active: vec![0, 1],
            values: vec![0.0, 0.0, 1.0, -2.0],
            idx: vec![0, 3, 1, 5],
        };
        let q = QuantizedCondensed::from_condensed(&c).unwrap();
        assert_eq!(q.scales[0], 0.0);
        assert_eq!((q.recs[0].q, q.recs[1].q), (0, 0));
        assert_eq!(q.row_error_bound(0, 10.0), 0.0);
        assert!(q.scales[1] > 0.0 && q.row_error_bound(1, 1.0) > 0.0);
    }

    #[test]
    fn storage_roughly_halves_f32_condensed() {
        let (w, m) = random_layer(96, 512, 51, 6);
        let c = Condensed::from_masked(&w, &m).unwrap();
        let q = QuantizedCondensed::from_condensed(&c).unwrap();
        assert_eq!(q.storage_bytes(), q.recs.len() * 4 + q.n_active() * 16);
        assert!(
            q.storage_bytes() * 3 < c.storage_bytes() * 2,
            "quantized {} should be well under 2/3 of f32 {}",
            q.storage_bytes(),
            c.storage_bytes()
        );
    }
}
