//! The condensed representation (paper Algorithm 1 / Appendix F).
//!
//! A constant-fan-in sparse matrix W (n x d, exactly k non-zeros per
//! active row) compresses to two dense (n_active x k) arrays — values and
//! column indices — plus the list of surviving (non-ablated) neurons.
//! This exploits *both* structure levels SRigL learns: neuron ablation
//! (skip all-zero rows entirely) and constant fan-in (uniform row layout,
//! no indptr indirection like CSR).

use crate::sparsity::mask::Mask;
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct Condensed {
    /// Number of columns of the dense matrix (layer input features).
    pub d: usize,
    /// Number of rows of the dense matrix (layer width incl. ablated).
    pub n_orig: usize,
    /// Constant fan-in.
    pub k: usize,
    /// Surviving neuron ids, ascending; len = n_active.
    pub active: Vec<u32>,
    /// (n_active x k) non-zero values, row-major.
    pub values: Vec<f32>,
    /// (n_active x k) column indices, row-major, each row sorted ascending
    /// (improves input-gather locality on CPU).
    pub idx: Vec<u32>,
}

impl Condensed {
    /// Build from a weight tensor and its constant-fan-in mask. Rows with
    /// zero active weights (ablated neurons) are dropped. Panics if active
    /// rows disagree on fan-in (the invariant SRigL maintains).
    pub fn from_masked(w: &Tensor, m: &Mask) -> Condensed {
        assert_eq!(w.shape, m.t.shape);
        let (n, d) = (m.neurons, m.fan_in);
        let counts = m.fan_in_counts();
        let k = counts.iter().copied().find(|&c| c > 0).unwrap_or(0);
        let mut active = Vec::new();
        let mut values = Vec::new();
        let mut idx = Vec::new();
        for row in 0..n {
            let c = counts[row];
            if c == 0 {
                continue;
            }
            assert_eq!(c, k, "row {row}: fan-in {c} != constant {k}");
            active.push(row as u32);
            for j in 0..d {
                if m.is_active(row, j) {
                    idx.push(j as u32);
                    values.push(w.data[row * d + j]);
                }
            }
        }
        Condensed { d, n_orig: n, k, active, values, idx }
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Storage bytes: values (f32) + indices (u32) + active list (u32).
    pub fn storage_bytes(&self) -> usize {
        self.values.len() * 4 + self.idx.len() * 4 + self.active.len() * 4
    }

    /// Expand back to the dense (n_orig x d) matrix (tests / baselines).
    pub fn to_dense(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.n_orig, self.d]);
        for (r, &row) in self.active.iter().enumerate() {
            for c in 0..self.k {
                let j = self.idx[r * self.k + c] as usize;
                out.data[row as usize * self.d + j] += self.values[r * self.k + c];
            }
        }
        out
    }

    /// Reconstruct the mask this condensed matrix came from.
    pub fn to_mask(&self) -> Mask {
        let mut m = Mask::from_tensor(Tensor::zeros(&[self.n_orig, self.d]));
        for (r, &row) in self.active.iter().enumerate() {
            for c in 0..self.k {
                m.set(row as usize, self.idx[r * self.k + c] as usize, true);
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_layer(n: usize, d: usize, k: usize, seed: u64) -> (Tensor, Mask) {
        let mut rng = Rng::new(seed);
        let m = Mask::random_constant_fan_in(&[n, d], k, &mut rng);
        let mut w = Tensor::normal(&[n, d], 1.0, &mut rng);
        w.mul_assign(&m.t);
        (w, m)
    }

    #[test]
    fn roundtrip_dense() {
        let (w, m) = random_layer(16, 40, 7, 0);
        let c = Condensed::from_masked(&w, &m);
        assert_eq!(c.n_active(), 16);
        assert_eq!(c.k, 7);
        assert_eq!(c.to_dense().data, w.data);
    }

    #[test]
    fn roundtrip_mask() {
        let (w, m) = random_layer(8, 24, 3, 1);
        let c = Condensed::from_masked(&w, &m);
        assert_eq!(c.to_mask().t.data, m.t.data);
    }

    #[test]
    fn drops_ablated_rows() {
        let (mut w, mut m) = random_layer(10, 20, 4, 2);
        // ablate neurons 2 and 7
        for &row in &[2usize, 7] {
            for j in 0..20 {
                m.set(row, j, false);
                w.data[row * 20 + j] = 0.0;
            }
        }
        let c = Condensed::from_masked(&w, &m);
        assert_eq!(c.n_active(), 8);
        assert!(!c.active.contains(&2) && !c.active.contains(&7));
        assert_eq!(c.to_dense().data, w.data);
    }

    #[test]
    fn idx_rows_sorted() {
        let (w, m) = random_layer(12, 64, 9, 3);
        let c = Condensed::from_masked(&w, &m);
        for r in 0..c.n_active() {
            let row = &c.idx[r * c.k..(r + 1) * c.k];
            assert!(row.windows(2).all(|p| p[0] < p[1]), "{row:?}");
        }
    }

    #[test]
    #[should_panic(expected = "fan-in")]
    fn rejects_non_constant_fan_in() {
        let mut rng = Rng::new(4);
        let m = Mask::random_per_layer(&[8, 16], 30, &mut rng);
        // Likely non-constant; if by rare chance constant this test would
        // fail, so force it:
        let mut m = m;
        m.set(0, 0, true);
        m.set(0, 1, true);
        m.set(0, 2, true);
        m.set(1, 0, true);
        let mut m2 = Mask::from_tensor(Tensor::zeros(&[8, 16]));
        m2.set(0, 0, true);
        m2.set(0, 1, true);
        m2.set(1, 0, true); // row 1 has fan-in 1, row 0 has 2
        let w = Tensor::ones(&[8, 16]);
        let _ = Condensed::from_masked(&w, &m2);
    }

    #[test]
    fn storage_beats_dense_at_high_sparsity() {
        let (w, m) = random_layer(768, 3072, 307, 5); // Fig. 4 @ 90%
        let c = Condensed::from_masked(&w, &m);
        let dense_bytes = w.numel() * 4;
        assert!(c.storage_bytes() * 4 < dense_bytes, "condensed should be <25% of dense");
    }
}
