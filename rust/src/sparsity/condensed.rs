//! The condensed representation (paper Algorithm 1 / Appendix F).
//!
//! A constant-fan-in sparse matrix W (n x d, exactly k non-zeros per
//! active row) compresses to two dense (n_active x k) arrays — values and
//! column indices — plus the list of surviving (non-ablated) neurons.
//! This exploits *both* structure levels SRigL learns: neuron ablation
//! (skip all-zero rows entirely) and constant fan-in (uniform row layout,
//! no indptr indirection like CSR).
//!
//! Two storage layouts share the same geometry:
//!
//! * [`Condensed`] — separate `values` / `idx` arrays (two streams per
//!   row), the layout the scalar gather-MAC reads.
//! * [`CondensedTiled`] — one interleaved `(idx, value)` record array
//!   ([`IdxVal`]): a single sequential stream per row, which is what the
//!   batch-tiled broadcast-MAC kernel in [`crate::kernels::tiled`] wants
//!   (one cache stream for the weights, one for the transposed input
//!   tile). The two layouts convert losslessly in both directions —
//!   `prop_invariants` pins the round-trip.
//!
//! Construction returns a typed [`CondensedError`] instead of panicking:
//! a serving stack built from a bad manifest must fail fast with a
//! message, not take down a worker thread mid-request.

use crate::sparsity::mask::Mask;
use crate::tensor::Tensor;

/// Why a weight/mask pair cannot be condensed. Converts into
/// `anyhow::Error` through `std::error::Error` for the serving/manifest
/// paths.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CondensedError {
    /// Weight tensor and mask disagree on shape.
    ShapeMismatch { weights: Vec<usize>, mask: Vec<usize> },
    /// An active row's fan-in differs from the layer's constant fan-in —
    /// the invariant SRigL maintains and Algorithm 1 requires.
    FanInMismatch { row: usize, got: usize, expect: usize },
    /// The layer's input width exceeds what a compact representation can
    /// index (the quantized layout stores column indices as `u16`).
    WidthTooLarge { d: usize, limit: usize },
}

impl std::fmt::Display for CondensedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CondensedError::ShapeMismatch { weights, mask } => {
                write!(f, "weights {weights:?} and mask {mask:?} have different shapes")
            }
            CondensedError::FanInMismatch { row, got, expect } => write!(
                f,
                "row {row}: fan-in {got} != constant {expect} \
                 (constant fan-in per layer is the invariant SRigL maintains; \
                 this mask cannot be condensed)"
            ),
            CondensedError::WidthTooLarge { d, limit } => write!(
                f,
                "input width {d} exceeds the representation's index limit {limit} \
                 (the quantized condensed layout stores column indices as u16)"
            ),
        }
    }
}

impl std::error::Error for CondensedError {}

#[derive(Clone, Debug, PartialEq)]
pub struct Condensed {
    /// Number of columns of the dense matrix (layer input features).
    pub d: usize,
    /// Number of rows of the dense matrix (layer width incl. ablated).
    pub n_orig: usize,
    /// Constant fan-in.
    pub k: usize,
    /// Surviving neuron ids, ascending; len = n_active.
    pub active: Vec<u32>,
    /// (n_active x k) non-zero values, row-major.
    pub values: Vec<f32>,
    /// (n_active x k) column indices, row-major, each row sorted ascending
    /// (improves input-gather locality on CPU).
    pub idx: Vec<u32>,
}

impl Condensed {
    /// Build from a weight tensor and its constant-fan-in mask. Rows with
    /// zero active weights (ablated neurons) are dropped; an all-ablated
    /// mask yields an empty (k = 0) representation, which every consumer
    /// supports. Errors (typed, no panics) when the shapes disagree or
    /// active rows disagree on fan-in.
    pub fn from_masked(w: &Tensor, m: &Mask) -> Result<Condensed, CondensedError> {
        if w.shape != m.t.shape {
            return Err(CondensedError::ShapeMismatch {
                weights: w.shape.clone(),
                mask: m.t.shape.clone(),
            });
        }
        let (n, d) = (m.neurons, m.fan_in);
        let counts = m.fan_in_counts();
        let k = counts.iter().copied().find(|&c| c > 0).unwrap_or(0);
        let mut active = Vec::new();
        let mut values = Vec::new();
        let mut idx = Vec::new();
        for row in 0..n {
            let c = counts[row];
            if c == 0 {
                continue;
            }
            if c != k {
                return Err(CondensedError::FanInMismatch { row, got: c, expect: k });
            }
            active.push(row as u32);
            for j in 0..d {
                if m.is_active(row, j) {
                    idx.push(j as u32);
                    values.push(w.data[row * d + j]);
                }
            }
        }
        Ok(Condensed { d, n_orig: n, k, active, values, idx })
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Storage bytes: values (f32) + indices (u32) + active list (u32).
    pub fn storage_bytes(&self) -> usize {
        self.values.len() * 4 + self.idx.len() * 4 + self.active.len() * 4
    }

    /// Expand back to the dense (n_orig x d) matrix (tests / baselines).
    pub fn to_dense(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.n_orig, self.d]);
        for (r, &row) in self.active.iter().enumerate() {
            for c in 0..self.k {
                let j = self.idx[r * self.k + c] as usize;
                out.data[row as usize * self.d + j] += self.values[r * self.k + c];
            }
        }
        out
    }

    /// Reconstruct the mask this condensed matrix came from.
    pub fn to_mask(&self) -> Mask {
        let mut m = Mask::from_tensor(Tensor::zeros(&[self.n_orig, self.d]));
        for (r, &row) in self.active.iter().enumerate() {
            for c in 0..self.k {
                m.set(row as usize, self.idx[r * self.k + c] as usize, true);
            }
        }
        m
    }
}

// ---------------------------------------------------------------------------
// Batch-tiled layout
// ---------------------------------------------------------------------------

/// One interleaved weight record of the batch-tiled condensed layout:
/// the column index and the stored value side by side, so the tile
/// kernel's inner loop walks a single sequential stream.
#[derive(Clone, Copy, Debug, PartialEq)]
#[repr(C)]
pub struct IdxVal {
    pub idx: u32,
    pub v: f32,
}

/// The batch-tiled condensed layout: same geometry as [`Condensed`]
/// (n_active rows x constant fan-in k, ascending in-row indices, ablated
/// rows dropped) with the value/index streams interleaved per weight.
/// Consumed by the batch-tiled broadcast-MAC kernel
/// ([`crate::kernels::tiled`]); converts to/from [`Condensed`] without
/// loss. Tile width is a *kernel* property ([`crate::kernels::TILE`]),
/// not a storage one — the same stored layout serves any tile width.
#[derive(Clone, Debug, PartialEq)]
pub struct CondensedTiled {
    pub d: usize,
    pub n_orig: usize,
    pub k: usize,
    /// Surviving neuron ids, ascending; len = n_active.
    pub active: Vec<u32>,
    /// (n_active x k) interleaved (column index, value) records,
    /// row-major, indices ascending within each row.
    pub pairs: Vec<IdxVal>,
}

impl CondensedTiled {
    /// Interleave a [`Condensed`] matrix (lossless).
    pub fn from_condensed(c: &Condensed) -> CondensedTiled {
        let pairs = c
            .idx
            .iter()
            .zip(&c.values)
            .map(|(&idx, &v)| IdxVal { idx, v })
            .collect();
        CondensedTiled {
            d: c.d,
            n_orig: c.n_orig,
            k: c.k,
            active: c.active.clone(),
            pairs,
        }
    }

    /// Build directly from a weight tensor and its constant-fan-in mask
    /// (same contract as [`Condensed::from_masked`]).
    pub fn from_masked(w: &Tensor, m: &Mask) -> Result<CondensedTiled, CondensedError> {
        Ok(CondensedTiled::from_condensed(&Condensed::from_masked(w, m)?))
    }

    /// De-interleave back to the two-stream layout (lossless — the exact
    /// inverse of [`CondensedTiled::from_condensed`]).
    pub fn to_condensed(&self) -> Condensed {
        let mut values = Vec::with_capacity(self.pairs.len());
        let mut idx = Vec::with_capacity(self.pairs.len());
        for p in &self.pairs {
            idx.push(p.idx);
            values.push(p.v);
        }
        Condensed {
            d: self.d,
            n_orig: self.n_orig,
            k: self.k,
            active: self.active.clone(),
            values,
            idx,
        }
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Storage bytes: interleaved records (u32 + f32 each) + active list
    /// (u32) — byte-for-byte the same total as the two-stream layout.
    pub fn storage_bytes(&self) -> usize {
        self.pairs.len() * 8 + self.active.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_layer(n: usize, d: usize, k: usize, seed: u64) -> (Tensor, Mask) {
        let mut rng = Rng::new(seed);
        let m = Mask::random_constant_fan_in(&[n, d], k, &mut rng);
        let mut w = Tensor::normal(&[n, d], 1.0, &mut rng);
        w.mul_assign(&m.t);
        (w, m)
    }

    #[test]
    fn roundtrip_dense() {
        let (w, m) = random_layer(16, 40, 7, 0);
        let c = Condensed::from_masked(&w, &m).unwrap();
        assert_eq!(c.n_active(), 16);
        assert_eq!(c.k, 7);
        assert_eq!(c.to_dense().data, w.data);
    }

    #[test]
    fn roundtrip_mask() {
        let (w, m) = random_layer(8, 24, 3, 1);
        let c = Condensed::from_masked(&w, &m).unwrap();
        assert_eq!(c.to_mask().t.data, m.t.data);
    }

    #[test]
    fn drops_ablated_rows() {
        let (mut w, mut m) = random_layer(10, 20, 4, 2);
        // ablate neurons 2 and 7
        for &row in &[2usize, 7] {
            for j in 0..20 {
                m.set(row, j, false);
                w.data[row * 20 + j] = 0.0;
            }
        }
        let c = Condensed::from_masked(&w, &m).unwrap();
        assert_eq!(c.n_active(), 8);
        assert!(!c.active.contains(&2) && !c.active.contains(&7));
        assert_eq!(c.to_dense().data, w.data);
    }

    #[test]
    fn idx_rows_sorted() {
        let (w, m) = random_layer(12, 64, 9, 3);
        let c = Condensed::from_masked(&w, &m).unwrap();
        for r in 0..c.n_active() {
            let row = &c.idx[r * c.k..(r + 1) * c.k];
            assert!(row.windows(2).all(|p| p[0] < p[1]), "{row:?}");
        }
    }

    #[test]
    fn rejects_non_constant_fan_in_with_typed_error() {
        let mut m2 = Mask::from_tensor(Tensor::zeros(&[8, 16]));
        m2.set(0, 0, true);
        m2.set(0, 1, true);
        m2.set(1, 0, true); // row 1 has fan-in 1, row 0 has 2
        let w = Tensor::ones(&[8, 16]);
        match Condensed::from_masked(&w, &m2) {
            Err(CondensedError::FanInMismatch { row, got, expect }) => {
                assert_eq!((row, got, expect), (1, 1, 2));
            }
            other => panic!("expected FanInMismatch, got {other:?}"),
        }
        // the tiled constructor propagates the same error
        assert!(CondensedTiled::from_masked(&w, &m2).is_err());
        // display + anyhow conversion carry a readable message
        let e = Condensed::from_masked(&w, &m2).unwrap_err();
        assert!(e.to_string().contains("fan-in 1 != constant 2"), "{e}");
        let a: anyhow::Error = e.into();
        assert!(format!("{a}").contains("fan-in"));
    }

    #[test]
    fn rejects_shape_mismatch_with_typed_error() {
        let (_, m) = random_layer(8, 16, 3, 9);
        let w = Tensor::ones(&[8, 12]);
        match Condensed::from_masked(&w, &m) {
            Err(CondensedError::ShapeMismatch { weights, mask }) => {
                assert_eq!(weights, vec![8, 12]);
                assert_eq!(mask, vec![8, 16]);
            }
            other => panic!("expected ShapeMismatch, got {other:?}"),
        }
    }

    #[test]
    fn tiled_roundtrips_losslessly() {
        let (mut w, mut m) = random_layer(14, 30, 5, 4);
        // ablate a few rows so the active list is non-trivial
        for &row in &[0usize, 6, 13] {
            for j in 0..30 {
                m.set(row, j, false);
                w.data[row * 30 + j] = 0.0;
            }
        }
        let c = Condensed::from_masked(&w, &m).unwrap();
        let t = CondensedTiled::from_condensed(&c);
        assert_eq!(t.n_active(), c.n_active());
        assert_eq!(t.storage_bytes(), c.storage_bytes(), "interleaving is byte-neutral");
        assert_eq!(t.to_condensed(), c, "lossless round-trip");
        // direct construction agrees with the via-Condensed path
        assert_eq!(CondensedTiled::from_masked(&w, &m).unwrap(), t);
    }

    #[test]
    fn tiled_all_ablated_is_empty() {
        let w = Tensor::zeros(&[6, 10]);
        let m = Mask::from_tensor(Tensor::zeros(&[6, 10]));
        let t = CondensedTiled::from_masked(&w, &m).unwrap();
        assert_eq!(t.n_active(), 0);
        assert_eq!(t.k, 0);
        assert!(t.pairs.is_empty());
        assert_eq!(t.storage_bytes(), 0);
        assert_eq!(t.to_condensed().to_dense().data, w.data);
    }

    #[test]
    fn storage_beats_dense_at_high_sparsity() {
        let (w, m) = random_layer(768, 3072, 307, 5); // Fig. 4 @ 90%
        let c = Condensed::from_masked(&w, &m).unwrap();
        let dense_bytes = w.numel() * 4;
        assert!(c.storage_bytes() * 4 < dense_bytes, "condensed should be <25% of dense");
    }
}
