//! Per-layer sparsity distributions: uniform and Erdős–Rényi-Kernel (ERK).
//!
//! ERK (Mocanu et al. 2018; Evci et al. 2021) allocates density to layer
//! `l` proportionally to `(sum of dims) / (product of dims)`, i.e. small
//! layers stay denser. The paper uses ERK for all CNN experiments and
//! uniform for ViT (App. D.1/D.3). Constant fan-in requires per-layer
//! densities, which is exactly what these return — unlike N:M sparsity,
//! which is locked to uniform (paper §2).

/// Shape of one sparse layer for distribution purposes.
#[derive(Clone, Debug)]
pub struct LayerShape {
    pub name: String,
    /// Full tensor dims, neuron axis first: (n, in) or (out, in, kh, kw).
    pub dims: Vec<usize>,
}

impl LayerShape {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// ERK raw scale: (n_out + n_in + kh + kw) / (n_out * n_in * kh * kw).
    pub fn erk_scale(&self) -> f64 {
        let sum: usize = self.dims.iter().sum();
        sum as f64 / self.numel() as f64
    }

    pub fn neurons(&self) -> usize {
        self.dims[0]
    }

    pub fn fan_in(&self) -> usize {
        self.dims[1..].iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Distribution {
    Uniform,
    Erk,
}

impl std::str::FromStr for Distribution {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "uniform" => Ok(Distribution::Uniform),
            "erk" => Ok(Distribution::Erk),
            other => anyhow::bail!("unknown distribution {other:?} (uniform|erk)"),
        }
    }
}

/// Compute per-layer *densities* (1 - sparsity) for a global sparsity
/// target. Densities are capped at 1; ERK redistributes the excess via the
/// standard iterative raise of the global multiplier.
pub fn layer_densities(
    dist: Distribution,
    layers: &[LayerShape],
    global_sparsity: f64,
) -> Vec<f64> {
    assert!((0.0..1.0).contains(&global_sparsity), "sparsity in [0,1)");
    let density = 1.0 - global_sparsity;
    match dist {
        Distribution::Uniform => vec![density; layers.len()],
        Distribution::Erk => {
            let total: f64 = layers.iter().map(|l| l.numel() as f64).sum();
            let budget = density * total;
            // Layers pinned at density 1.0 (epsilon*scale >= 1).
            let mut dense_set = vec![false; layers.len()];
            loop {
                let mut free_weight = 0.0; // sum over free layers of numel*scale
                let mut dense_numel = 0.0;
                for (i, l) in layers.iter().enumerate() {
                    if dense_set[i] {
                        dense_numel += l.numel() as f64;
                    } else {
                        free_weight += l.numel() as f64 * l.erk_scale();
                    }
                }
                let remaining = budget - dense_numel;
                assert!(
                    remaining > 0.0,
                    "ERK budget exhausted by dense layers (sparsity too low for these shapes)"
                );
                let eps = remaining / free_weight;
                let mut changed = false;
                for (i, l) in layers.iter().enumerate() {
                    if !dense_set[i] && eps * l.erk_scale() >= 1.0 {
                        dense_set[i] = true;
                        changed = true;
                    }
                }
                if !changed {
                    return layers
                        .iter()
                        .enumerate()
                        .map(|(i, l)| if dense_set[i] { 1.0 } else { eps * l.erk_scale() })
                        .collect();
                }
            }
        }
    }
}

/// Constant fan-in per layer: k = round(density * fan_in), clamped to
/// [1, fan_in]. The minimum of 1 mirrors the paper's minimum-salient
/// clamp (App. E): a layer never loses all connectivity.
pub fn fan_in_targets(layers: &[LayerShape], densities: &[f64]) -> Vec<usize> {
    layers
        .iter()
        .zip(densities)
        .map(|(l, d)| ((d * l.fan_in() as f64).round() as usize).clamp(1, l.fan_in()))
        .collect()
}

/// Achieved global sparsity for given per-layer fan-ins (reporting).
pub fn achieved_sparsity(layers: &[LayerShape], ks: &[usize]) -> f64 {
    let total: usize = layers.iter().map(|l| l.numel()).sum();
    let nnz: usize = layers.iter().zip(ks).map(|(l, &k)| l.neurons() * k).sum();
    1.0 - nnz as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<LayerShape> {
        vec![
            LayerShape { name: "conv0".into(), dims: vec![16, 3, 3, 3] },
            LayerShape { name: "conv1".into(), dims: vec![32, 16, 3, 3] },
            LayerShape { name: "fc".into(), dims: vec![10, 64] },
        ]
    }

    #[test]
    fn uniform_is_flat() {
        let d = layer_densities(Distribution::Uniform, &shapes(), 0.9);
        assert!(d.iter().all(|&x| (x - 0.1).abs() < 1e-12));
    }

    #[test]
    fn erk_meets_global_budget() {
        let ls = shapes();
        for s in [0.5, 0.8, 0.9, 0.95] {
            let d = layer_densities(Distribution::Erk, &ls, s);
            let total: f64 = ls.iter().map(|l| l.numel() as f64).sum();
            let nnz: f64 = ls.iter().zip(&d).map(|(l, &di)| l.numel() as f64 * di).sum();
            let achieved = 1.0 - nnz / total;
            assert!((achieved - s).abs() < 1e-9, "s={s} achieved={achieved}");
            assert!(d.iter().all(|&x| x > 0.0 && x <= 1.0), "{d:?}");
        }
    }

    #[test]
    fn erk_favors_small_layers() {
        let ls = shapes();
        let d = layer_densities(Distribution::Erk, &ls, 0.9);
        // conv0 (432 weights) should be denser than conv1 (4608 weights)
        assert!(d[0] > d[1], "{d:?}");
    }

    #[test]
    fn erk_caps_at_one_high_density() {
        // At very low sparsity the tiny layer saturates to 1.0.
        let ls = vec![
            LayerShape { name: "tiny".into(), dims: vec![4, 4] },
            LayerShape { name: "big".into(), dims: vec![512, 512] },
        ];
        let d = layer_densities(Distribution::Erk, &ls, 0.5);
        assert!(d[0] <= 1.0 + 1e-12 && d[1] < 1.0);
    }

    #[test]
    fn fan_in_targets_clamped() {
        let ls = shapes();
        let ks = fan_in_targets(&ls, &[0.001, 0.5, 1.0]);
        assert_eq!(ks[0], 1); // clamped up
        assert_eq!(ks[1], 72); // 144 * 0.5
        assert_eq!(ks[2], 64); // full fan-in
        let s = achieved_sparsity(&ls, &ks);
        assert!(s > 0.0 && s < 1.0);
    }
}
