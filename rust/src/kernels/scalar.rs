//! The scalar microkernels — the 4-way-unrolled loops the inference
//! engine shipped with, kept byte-for-byte as the **executable reference
//! oracle** the SIMD kinds are ULP-pinned against (`docs/KERNELS.md`).
//!
//! Four accumulators break the FP add dependency chain so the hardware
//! can keep multiple multiply-adds in flight even without vector code
//! (§Perf iteration 1 of the original engine: 2-way safe -> 4-way
//! unchecked).

/// Dense dot product, 4 accumulators, fixed reduction order
/// `a0 + a1 + a2 + a3` (left to right, as the original engine summed).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// Gather-MAC over separate value/index streams (paper Algorithm 1 inner
/// loop), 4 accumulators, bounds-check-free.
///
/// # Safety
/// Every `idx[i] as usize` must be `< xb.len()` (validated once at layer
/// construction).
pub unsafe fn gather(vals: &[f32], idx: &[u32], xb: &[f32]) -> f32 {
    let mut acc = [0f32; 4];
    let mut vi = vals.chunks_exact(4);
    let mut ii = idx.chunks_exact(4);
    for (v4, i4) in (&mut vi).zip(&mut ii) {
        // SAFETY: fn contract — every `idx` element is `< xb.len()`.
        unsafe {
            acc[0] += v4[0] * *xb.get_unchecked(i4[0] as usize);
            acc[1] += v4[1] * *xb.get_unchecked(i4[1] as usize);
            acc[2] += v4[2] * *xb.get_unchecked(i4[2] as usize);
            acc[3] += v4[3] * *xb.get_unchecked(i4[3] as usize);
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (v, i) in vi.remainder().iter().zip(ii.remainder()) {
        // SAFETY: fn contract — every `idx` element is `< xb.len()`.
        s += v * unsafe { *xb.get_unchecked(*i as usize) };
    }
    s
}
