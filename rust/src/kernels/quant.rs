//! Integer forward drivers for the int8 quantized condensed layers
//! ([`crate::inference::QuantizedLayer`] /
//! [`crate::inference::QuantizedTiledLayer`]) — the NNUE-style serving
//! path: i8 weights, per-forward i8 activations, i32 accumulation, one
//! shared f32 finalize.
//!
//! **Activation quantization** happens per *input row*, never per tile
//! or per batch: `sx = max|x_row| / 127`, `qx_j = round(x_j * 127 /
//! max|x_row|)`, so a row's integers are a pure function of that row —
//! the quantized analogue of the batch-position-invariance rule every
//! f32 kernel obeys. The gather path stages the integers as `i32` (what
//! `vpgatherdd` reads); the tiled path stages the transposed tile as
//! `i8` (`d x TILE` **bytes** — 4x smaller than the f32 tile buffer,
//! which is where the bandwidth win at large batch comes from). Both
//! stagings hold the *same integers*, so the two paths agree exactly.
//!
//! **Exactness across kinds** — stronger than the f32 family's ULP
//! bound: i32 addition is associative and (by the constant-fan-in
//! accumulator bound `|acc| <= k·127² < 2³¹`, see
//! [`crate::sparsity::quantized`]) never overflows, so the scalar
//! oracle, the portable lanes, and the AVX2 intrinsics produce the
//! **identical accumulator**, and the single shared [`finalize`]
//! expression makes every quantized output bit-for-bit identical across
//! kernel kinds, batch positions, full-tile vs remainder, thread
//! counts, shard cuts, and engines. Tests pin all of it.
//!
//! Staging buffers are thread-local and grown once per thread, matching
//! [`super::tiled`]: serving-engine forwards (`threads == 1` on pool
//! workers and shard teams) are allocation-free after warmup.

use std::cell::RefCell;

use super::{par_single_row, KernelKind, Microkernel, TILE};
use crate::sparsity::quantized::{IdxQ, QMAX};
use crate::util::threadpool::par_rows_mut;

thread_local! {
    /// Per-thread i32 staging of one quantized input row (gather path).
    static XQ: RefCell<Vec<i32>> = const { RefCell::new(Vec::new()) };
    /// Per-thread i8 staging of one transposed, quantized input tile
    /// (`d * TILE` bytes — the batch values of feature `j` live at
    /// `xtq[j*TILE..]`, exactly the f32 tiled layout shrunk 4x).
    static XTQ: RefCell<Vec<i8>> = const { RefCell::new(Vec::new()) };
}

/// Quantize one value given the precomputed multiplier `inv = 127 /
/// max|x_row|`. Shared by both stagings so the gather and tiled paths
/// see the same integers. `round` (half away from zero) then clamp:
/// f32 rounding can push `x * inv` a hair past 127, never past 127.5.
#[inline]
fn qz(v: f32, inv: f32) -> i32 {
    (v * inv).round().clamp(-(QMAX as f32), QMAX as f32) as i32
}

/// Quantize one input row into the i32 staging buffer. Returns the
/// activation scale `sx = max|x| / 127`; an all-zero row gets scale 0
/// and all-zero integers (the forward then reproduces `bias` exactly).
pub fn quantize_row_i32(x: &[f32], xq: &mut [i32]) -> f32 {
    debug_assert_eq!(x.len(), xq.len());
    let amax = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if amax == 0.0 {
        xq.fill(0);
        return 0.0;
    }
    let inv = QMAX as f32 / amax;
    for (o, &v) in xq.iter_mut().zip(x) {
        *o = qz(v, inv);
    }
    amax / QMAX as f32
}

/// Transpose-and-quantize `TILE` input rows (`x.len() == TILE * d`) into
/// the i8 staging buffer, one activation scale per batch lane. Lane `l`
/// gets the same integers [`quantize_row_i32`] would give its row —
/// that identity is what keeps full-tile and remainder outputs
/// bit-for-bit equal.
pub fn quantize_tile_i8(x: &[f32], d: usize, xtq: &mut [i8], sx: &mut [f32; TILE]) {
    debug_assert_eq!(x.len(), TILE * d);
    debug_assert!(xtq.len() >= d * TILE);
    for l in 0..TILE {
        let xrow = &x[l * d..(l + 1) * d];
        let amax = xrow.iter().fold(0f32, |m, &v| m.max(v.abs()));
        if amax == 0.0 {
            sx[l] = 0.0;
            for j in 0..d {
                xtq[j * TILE + l] = 0;
            }
            continue;
        }
        let inv = QMAX as f32 / amax;
        for (j, &v) in xrow.iter().enumerate() {
            xtq[j * TILE + l] = qz(v, inv) as i8;
        }
        sx[l] = amax / QMAX as f32;
    }
}

/// The single shared dequantize epilogue: scale the exact i32
/// accumulator by the (weight x activation) scale product and add the
/// bias. Plain multiply-then-add (no FMA) in **every** kind — combined
/// with the exact integer accumulation this is what makes quantized
/// outputs bit-for-bit identical across kernel kinds and engines.
#[inline]
pub fn finalize(acc: i32, w_scale: f32, x_scale: f32, bias: f32) -> f32 {
    (acc as f32) * (w_scale * x_scale) + bias
}

/// Integer gather-MAC over one row's interleaved records: `Σ q_i *
/// xq[idx_i]`, exact in i32 for every kind (see module docs).
///
/// # Safety
/// Every `rec.idx as usize` must be `< xq.len()` (validated once at
/// layer construction); the Avx2 kind additionally requires detected
/// AVX2 (guaranteed by the [`Microkernel`] dispatch invariant).
#[inline]
pub unsafe fn row_mac(recs: &[IdxQ], xq: &[i32], kind: KernelKind) -> i32 {
    debug_assert!(recs.iter().all(|p| (p.idx as usize) < xq.len()));
    match kind {
        // SAFETY: each implementation carries this fn's exact contract,
        // forwarded verbatim; the Avx2 arm is only constructible when
        // AVX2 is runtime-detected (`KernelKind::available`).
        KernelKind::Scalar => unsafe { row_mac_scalar(recs, xq) },
        KernelKind::Portable => unsafe { row_mac_lanes(recs, xq) },
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx2 => unsafe { super::avx2::row_mac_q(recs, xq) },
        #[cfg(not(target_arch = "x86_64"))]
        KernelKind::Avx2 => unreachable!("avx2 is never selected on this architecture"),
    }
}

/// Scalar integer oracle: one accumulator, record order.
///
/// # Safety
/// Every `rec.idx as usize` must be `< xq.len()`.
unsafe fn row_mac_scalar(recs: &[IdxQ], xq: &[i32]) -> i32 {
    let mut acc = 0i32;
    for p in recs {
        // SAFETY: fn contract — every `rec.idx` is `< xq.len()`.
        acc += p.q as i32 * unsafe { *xq.get_unchecked(p.idx as usize) };
    }
    acc
}

/// Portable 8-lane integer MAC: fixed-width `[i32; 8]` partial sums the
/// autovectorizer can keep in one vector register; i32 addition is
/// associative, so the result equals the scalar oracle exactly.
///
/// # Safety
/// Every `rec.idx as usize` must be `< xq.len()`.
unsafe fn row_mac_lanes(recs: &[IdxQ], xq: &[i32]) -> i32 {
    let mut lanes = [0i32; 8];
    let mut it = recs.chunks_exact(8);
    for c in &mut it {
        for l in 0..8 {
            // SAFETY: fn contract — every `rec.idx` is `< xq.len()`.
            lanes[l] += c[l].q as i32 * unsafe { *xq.get_unchecked(c[l].idx as usize) };
        }
    }
    let mut acc: i32 = lanes.iter().sum();
    for p in it.remainder() {
        // SAFETY: fn contract — every `rec.idx` is `< xq.len()`.
        acc += p.q as i32 * unsafe { *xq.get_unchecked(p.idx as usize) };
    }
    acc
}

/// Tile-lane dispatch of the integer broadcast-MAC: for each record,
/// multiply its (broadcast) i8 weight into the 8 contiguous batch
/// values of its column and add into the i32 lane accumulators.
///
/// # Safety
/// Every `rec.idx as usize * TILE + TILE` must be `<= xtq.len()`; the
/// Avx2 kind additionally requires detected AVX2 (guaranteed by the
/// [`Microkernel`] dispatch invariant).
#[inline]
unsafe fn tile_mac_q(recs: &[IdxQ], xtq: &[i8], acc: &mut [i32; TILE], kind: KernelKind) {
    match kind {
        // SAFETY: each implementation carries this fn's exact contract,
        // forwarded verbatim; the Avx2 arm is only constructible when
        // AVX2 is runtime-detected (`KernelKind::available`).
        KernelKind::Scalar => unsafe { tile_mac_scalar(recs, xtq, acc) },
        KernelKind::Portable => unsafe { tile_mac_lanes(recs, xtq, acc) },
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx2 => unsafe { super::avx2::tile_mac_q(recs, xtq, acc) },
        #[cfg(not(target_arch = "x86_64"))]
        KernelKind::Avx2 => unreachable!("avx2 is never selected on this architecture"),
    }
}

/// Scalar integer tile oracle: record order, one pass over the lanes.
///
/// # Safety
/// Every `rec.idx as usize * TILE + TILE` must be `<= xtq.len()`.
unsafe fn tile_mac_scalar(recs: &[IdxQ], xtq: &[i8], acc: &mut [i32; TILE]) {
    for p in recs {
        let j = p.idx as usize * TILE;
        let q = p.q as i32;
        for l in 0..TILE {
            // SAFETY: fn contract — `idx * TILE + TILE <= xtq.len()`.
            acc[l] += q * unsafe { *xtq.get_unchecked(j + l) } as i32;
        }
    }
}

/// Portable integer tile lanes: record pairs into two accumulator sets
/// for instruction-level parallelism — integer adds, so the merged
/// result equals the scalar oracle exactly.
///
/// # Safety
/// Every `rec.idx as usize * TILE + TILE` must be `<= xtq.len()`.
unsafe fn tile_mac_lanes(recs: &[IdxQ], xtq: &[i8], acc: &mut [i32; TILE]) {
    let mut a1 = [0i32; TILE];
    let mut it = recs.chunks_exact(2);
    for p in &mut it {
        let j0 = p[0].idx as usize * TILE;
        let q0 = p[0].q as i32;
        for l in 0..TILE {
            // SAFETY: fn contract — `idx * TILE + TILE <= xtq.len()`.
            acc[l] += q0 * unsafe { *xtq.get_unchecked(j0 + l) } as i32;
        }
        let j1 = p[1].idx as usize * TILE;
        let q1 = p[1].q as i32;
        for l in 0..TILE {
            // SAFETY: fn contract — `idx * TILE + TILE <= xtq.len()`.
            a1[l] += q1 * unsafe { *xtq.get_unchecked(j1 + l) } as i32;
        }
    }
    if let [p] = it.remainder() {
        let j = p.idx as usize * TILE;
        let q = p.q as i32;
        for l in 0..TILE {
            // SAFETY: fn contract — `idx * TILE + TILE <= xtq.len()`.
            acc[l] += q * unsafe { *xtq.get_unchecked(j + l) } as i32;
        }
    }
    for l in 0..TILE {
        acc[l] += a1[l];
    }
}

/// Row-at-a-time quantized forward (the gather path): quantize each
/// input row once into the thread-local i32 staging, then integer
/// gather-MAC + [`finalize`] per output row. Layout contract matches
/// [`super::tiled::forward_tiled`]: `recs` is `(n_active x k)`
/// row-major, `scales`/`bias` are packed to active neurons, `out` is
/// `(batch x n_active)` row-major. The caller (layer construction)
/// validated `idx < d` for every record.
#[allow(clippy::too_many_arguments)] // mirrors forward_tiled's driver signature
pub fn forward_quant(
    recs: &[IdxQ],
    k: usize,
    n_active: usize,
    d: usize,
    scales: &[f32],
    bias: &[f32],
    x: &[f32],
    batch: usize,
    out: &mut [f32],
    threads: usize,
    mk: Microkernel,
) {
    debug_assert_eq!(recs.len(), n_active * k);
    debug_assert_eq!(scales.len(), n_active);
    debug_assert_eq!(bias.len(), n_active);
    debug_assert_eq!(x.len(), batch * d);
    debug_assert_eq!(out.len(), batch * n_active);
    if n_active == 0 || batch == 0 {
        return;
    }
    let kind = mk.kind();
    if batch == 1 {
        // quantize once on the caller, split output columns across
        // threads (the scoped workers only read the staged integers)
        XQ.with(|cell| {
            let mut buf = cell.borrow_mut();
            if buf.len() < d {
                buf.resize(d, 0);
            }
            let sx = quantize_row_i32(x, &mut buf[..d]);
            let xq: &[i32] = &buf[..d];
            par_single_row(out, threads, |start, chunk| {
                for (i, o) in chunk.iter_mut().enumerate() {
                    let r = start + i;
                    // SAFETY: idx < d == xq.len(), validated at layer
                    // construction; Avx2 only when detected (dispatch).
                    let acc = unsafe { row_mac(&recs[r * k..(r + 1) * k], xq, kind) };
                    *o = finalize(acc, scales[r], sx, bias[r]);
                }
            });
        });
    } else {
        par_rows_mut(out, n_active, threads, |b, orow| {
            XQ.with(|cell| {
                let mut buf = cell.borrow_mut();
                if buf.len() < d {
                    buf.resize(d, 0);
                }
                let sx = quantize_row_i32(&x[b * d..(b + 1) * d], &mut buf[..d]);
                let xq: &[i32] = &buf[..d];
                for (r, o) in orow.iter_mut().enumerate() {
                    // SAFETY: idx < d == xq.len(), validated at layer
                    // construction; Avx2 only when detected (dispatch).
                    let acc = unsafe { row_mac(&recs[r * k..(r + 1) * k], xq, kind) };
                    *o = finalize(acc, scales[r], sx, bias[r]);
                }
            });
        });
    }
}

/// Batch-tiled quantized forward: full tiles stage the transposed i8
/// integers once and broadcast-MAC every record across the 8 batch
/// lanes; the ragged remainder delegates to [`forward_quant`], whose
/// per-row quantization produces the *same integers* as the tile
/// staging — so remainder outputs are bit-for-bit identical to
/// full-tile outputs (batch-position invariance, enforced by tests).
/// Thread splits are tile-aligned, exactly like the f32 tiled driver.
#[allow(clippy::too_many_arguments)] // mirrors forward_tiled's driver signature
pub fn forward_quant_tiled(
    recs: &[IdxQ],
    k: usize,
    n_active: usize,
    d: usize,
    scales: &[f32],
    bias: &[f32],
    x: &[f32],
    batch: usize,
    out: &mut [f32],
    threads: usize,
    mk: Microkernel,
) {
    debug_assert_eq!(recs.len(), n_active * k);
    debug_assert_eq!(scales.len(), n_active);
    debug_assert_eq!(bias.len(), n_active);
    debug_assert_eq!(x.len(), batch * d);
    debug_assert_eq!(out.len(), batch * n_active);
    if n_active == 0 || batch == 0 {
        return;
    }
    let kind = mk.kind();
    let tiles = batch / TILE;
    let rem_start = tiles * TILE;
    if tiles > 0 {
        let tile_out = &mut out[..tiles * TILE * n_active];
        par_rows_mut(tile_out, TILE * n_active, threads, |t, orows| {
            XTQ.with(|cell| {
                let mut buf = cell.borrow_mut();
                if buf.len() < d * TILE {
                    buf.resize(d * TILE, 0);
                }
                let xtq = &mut buf[..d * TILE];
                let mut sx = [0f32; TILE];
                let t0 = t * TILE;
                quantize_tile_i8(&x[t0 * d..(t0 + TILE) * d], d, xtq, &mut sx);
                for r in 0..n_active {
                    let mut acc = [0i32; TILE];
                    // SAFETY: idx < d validated at layer construction, so
                    // idx*TILE + TILE <= d*TILE == xtq.len(); Avx2 only
                    // when detected (dispatch invariant).
                    unsafe { tile_mac_q(&recs[r * k..(r + 1) * k], xtq, &mut acc, kind) };
                    let (s, b) = (scales[r], bias[r]);
                    for l in 0..TILE {
                        orows[l * n_active + r] = finalize(acc[l], s, sx[l], b);
                    }
                }
            });
        });
    }
    if rem_start < batch {
        let rem = batch - rem_start;
        let out_rem = &mut out[rem_start * n_active..];
        forward_quant(
            recs,
            k,
            n_active,
            d,
            scales,
            bias,
            &x[rem_start * d..],
            rem,
            out_rem,
            threads,
            mk,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn available_kinds() -> Vec<KernelKind> {
        KernelKind::ALL.iter().copied().filter(|k| k.available()).collect()
    }

    fn rand_recs(n: usize, k: usize, d: usize, seed: u64) -> (Vec<IdxQ>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let recs = (0..n * k)
            .map(|_| IdxQ::new(rng.below(d) as u16, (rng.below(255) as i32 - 127) as i8))
            .collect();
        let scales = (0..n).map(|_| rng.uniform() as f32 * 0.02).collect();
        let bias = (0..n).map(|_| rng.normal_f32() * 0.01).collect();
        (recs, scales, bias)
    }

    #[test]
    fn quantize_row_is_symmetric_and_bounded() {
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..100).map(|_| rng.normal_f32()).collect();
        let mut xq = vec![0i32; 100];
        let sx = quantize_row_i32(&x, &mut xq);
        assert!(sx > 0.0);
        let amax = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
        assert!((sx - amax / 127.0).abs() <= f32::EPSILON * amax);
        for (&v, &q) in x.iter().zip(&xq) {
            assert!((-127..=127).contains(&q), "q out of range: {q}");
            assert!(
                (v - sx * q as f32).abs() <= sx * 0.501 + 1e-7,
                "dequantized gap beyond half a step: {v} vs {}",
                sx * q as f32
            );
        }
        // the extreme element saturates the range exactly
        assert_eq!(xq.iter().map(|q| q.abs()).max(), Some(127));
        // all-zero row: scale 0, all integers 0
        let zeros = vec![0f32; 16];
        let mut zq = vec![9i32; 16];
        assert_eq!(quantize_row_i32(&zeros, &mut zq), 0.0);
        assert!(zq.iter().all(|&q| q == 0));
    }

    #[test]
    fn row_mac_kinds_agree_exactly() {
        let (n, k, d) = (7, 29, 64);
        let (recs, _, _) = rand_recs(n, k, d, 8);
        let mut rng = Rng::new(9);
        let xq: Vec<i32> = (0..d).map(|_| rng.below(255) as i32 - 127).collect();
        for r in 0..n {
            let row = &recs[r * k..(r + 1) * k];
            // SAFETY: indices were drawn `< d == xq.len()`; only
            // available kinds are exercised.
            let want = unsafe { row_mac(row, &xq, KernelKind::Scalar) };
            for kind in available_kinds() {
                // SAFETY: as above.
                let got = unsafe { row_mac(row, &xq, kind) };
                assert_eq!(got, want, "{} row {r}", kind.name());
            }
        }
    }

    #[test]
    fn tile_mac_kinds_agree_exactly_and_match_row_mac() {
        let (n, k, d) = (5, 23, 48);
        let (recs, _, _) = rand_recs(n, k, d, 12);
        let mut rng = Rng::new(13);
        // a transposed tile and the equivalent per-lane i32 rows
        let xtq: Vec<i8> = (0..d * TILE).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let lanes: Vec<Vec<i32>> = (0..TILE)
            .map(|l| (0..d).map(|j| xtq[j * TILE + l] as i32).collect())
            .collect();
        for r in 0..n {
            let row = &recs[r * k..(r + 1) * k];
            let mut want = [0i32; TILE];
            // SAFETY: indices drawn `< d`, staging is `d * TILE` long.
            unsafe { tile_mac_q(row, &xtq, &mut want, KernelKind::Scalar) };
            for (l, lane) in lanes.iter().enumerate() {
                // SAFETY: as above, per-lane view has length d.
                let via_row = unsafe { row_mac(row, lane, KernelKind::Scalar) };
                assert_eq!(want[l], via_row, "tile lane {l} vs row mac");
            }
            for kind in available_kinds() {
                let mut got = [0i32; TILE];
                // SAFETY: as above; only available kinds.
                unsafe { tile_mac_q(row, &xtq, &mut got, kind) };
                assert_eq!(got, want, "{} row {r}", kind.name());
            }
        }
    }

    #[test]
    fn drivers_agree_bitwise_across_paths_and_kinds() {
        let (n, k, d) = (11, 9, 40);
        let (recs, scales, bias) = rand_recs(n, k, d, 21);
        for &batch in &[1usize, 3, 7, 8, 9, 17] {
            let mut rng = Rng::new(0x51 ^ batch as u64);
            let x: Vec<f32> = (0..batch * d).map(|_| rng.normal_f32()).collect();
            let mut want = vec![0f32; batch * n];
            forward_quant(
                &recs,
                k,
                n,
                d,
                &scales,
                &bias,
                &x,
                batch,
                &mut want,
                1,
                Microkernel::of(KernelKind::Scalar),
            );
            for kind in available_kinds() {
                for threads in [1usize, 4] {
                    let mk = Microkernel::of(kind);
                    let mut row_out = vec![0f32; batch * n];
                    forward_quant(&recs, k, n, d, &scales, &bias, &x, batch, &mut row_out, threads, mk);
                    let mut tiled_out = vec![0f32; batch * n];
                    forward_quant_tiled(
                        &recs, k, n, d, &scales, &bias, &x, batch, &mut tiled_out, threads, mk,
                    );
                    for i in 0..batch * n {
                        assert_eq!(
                            row_out[i].to_bits(),
                            want[i].to_bits(),
                            "{} t{threads} b{batch} idx {i}: row vs scalar oracle",
                            kind.name()
                        );
                        assert_eq!(
                            tiled_out[i].to_bits(),
                            want[i].to_bits(),
                            "{} t{threads} b{batch} idx {i}: tiled vs scalar oracle",
                            kind.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn zero_input_row_reproduces_bias_exactly() {
        let (n, k, d) = (6, 5, 24);
        let (recs, scales, bias) = rand_recs(n, k, d, 30);
        let x = vec![0f32; d];
        let mut out = vec![9f32; n];
        forward_quant(&recs, k, n, d, &scales, &bias, &x, 1, &mut out, 1, Microkernel::auto());
        for r in 0..n {
            assert_eq!(out[r].to_bits(), bias[r].to_bits(), "row {r}");
        }
    }

    #[test]
    fn empty_geometries_are_noops() {
        let mk = Microkernel::auto();
        forward_quant(&[], 0, 0, 10, &[], &[], &[0.5; 10], 1, &mut [], 4, mk);
        forward_quant_tiled(&[], 0, 0, 10, &[], &[], &[0.5; 10], 1, &mut [], 4, mk);
        // k == 0 with active rows: bias passthrough on both drivers
        let bias = vec![1.5f32, -2.0];
        let scales = vec![0f32; 2];
        let x = vec![0.25f32; 9 * 4];
        for driver in [forward_quant, forward_quant_tiled] {
            let mut out = vec![0f32; 2 * 9];
            driver(&[], 0, 2, 4, &scales, &bias, &x, 9, &mut out, 2, mk);
            for b in 0..9 {
                assert_eq!(out[b * 2], 1.5);
                assert_eq!(out[b * 2 + 1], -2.0);
            }
        }
    }
}
