//! The batch-tiled condensed forward — the driver behind
//! [`crate::inference::CondensedTiledLayer`].
//!
//! Motivation (paper Algorithm 1 on CPU): the dominant cost of the
//! condensed gather-MAC at batch > 1 is the *indexed load* — every
//! stored weight triggers one data-dependent read of the input row. The
//! row-at-a-time kernel pays that load once per (weight, batch item).
//! This driver instead walks the batch in tiles of [`TILE`] columns:
//!
//! 1. **Transpose** the tile's input rows into a `(d x TILE)` staging
//!    buffer, so the `TILE` batch values of input feature `j` become one
//!    contiguous 8-float vector at `xt[j*TILE..]`.
//! 2. For every interleaved `(idx, value)` record of an output row,
//!    issue **one** contiguous 8-wide load (no gather at all) and one
//!    broadcast-FMA across the batch columns — the indexed-load cost is
//!    amortized `TILE`-fold, and the loads vectorize on every ISA.
//!
//! The transpose staging buffer is thread-local and grown once per
//! thread, so on the serving hot path — persistent pool workers and
//! shard-team threads run their kernels with `threads == 1` — forwards
//! are allocation-free after warmup (the serving engines' standing
//! requirement). With intra-op `threads > 1` the engine already spawns
//! fresh scoped threads per forward (`par_rows_mut`, pre-existing for
//! every representation); those short-lived threads each grow a fresh
//! staging buffer, a cost that rides along with the spawn itself.
//!
//! **Batch-position invariance** (load-bearing — the serving front-end
//! packs concurrent requests into one forward and pins packed-vs-direct
//! results bit-for-bit): an output element must not care whether it
//! landed in a full tile or the ragged remainder. Both paths therefore
//! accumulate with the *identical* association — dual chains over the
//! fan-in (even records into `acc0`, odd into `acc1`, final
//! `(acc0 + acc1) + bias`) — and with the same rounding: when the AVX2
//! kind is selected the tile lanes use `vfmadd` and the remainder rows
//! use `f32::mul_add` (IEEE fused multiply-add, bit-identical to the
//! hardware instruction); the scalar/portable kinds use plain
//! multiply-then-add on both paths. Thread splits are tile-aligned, so
//! thread count never changes tile boundaries.

use std::cell::RefCell;

use super::{forward_rows, KernelKind, Microkernel, TILE};
use crate::sparsity::condensed::IdxVal;
use crate::util::threadpool::par_rows_mut;

thread_local! {
    /// Per-thread transpose staging buffer (`d * TILE` floats), grown on
    /// demand and reused across tiles — and, on long-lived threads
    /// (pool workers, shard teams, `threads == 1` callers), across
    /// forwards and requests. Scoped threads spawned for intra-op
    /// `threads > 1` grow their own and drop it at join (see module
    /// docs).
    static XT: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Forward `batch` rows of `x` (row-major, width `d`) through a
/// batch-tiled condensed layer: `pairs` is the `(n_active x k)` row-major
/// interleaved record array, `bias` is packed to active neurons, `out`
/// is `(batch x n_active)` row-major.
///
/// Full tiles run the transposed broadcast-MAC; the ragged remainder
/// (`batch % TILE` rows, or the whole batch when `batch < TILE`) runs
/// the row kernel with the same association (see module docs). Threads
/// split whole tiles, then remainder rows.
///
/// The caller (layer construction) validated `idx < d` for every record,
/// which is what lets both paths read the input without bounds checks.
pub fn forward_tiled(
    pairs: &[IdxVal],
    k: usize,
    n_active: usize,
    d: usize,
    bias: &[f32],
    x: &[f32],
    batch: usize,
    out: &mut [f32],
    threads: usize,
    mk: Microkernel,
) {
    debug_assert_eq!(pairs.len(), n_active * k);
    debug_assert_eq!(bias.len(), n_active);
    debug_assert_eq!(x.len(), batch * d);
    debug_assert_eq!(out.len(), batch * n_active);
    if n_active == 0 || batch == 0 {
        return;
    }
    let kind = mk.kind();
    let tiles = batch / TILE;
    let rem_start = tiles * TILE;
    if tiles > 0 {
        // one "row" per tile: TILE batch rows x n_active outputs,
        // contiguous in `out` — thread splits are tile-aligned by
        // construction, so tiling never depends on the thread count
        let tile_out = &mut out[..tiles * TILE * n_active];
        par_rows_mut(tile_out, TILE * n_active, threads, |t, orows| {
            XT.with(|cell| {
                let mut buf = cell.borrow_mut();
                if buf.len() < d * TILE {
                    buf.resize(d * TILE, 0.0);
                }
                let xt = &mut buf[..d * TILE];
                let t0 = t * TILE;
                for l in 0..TILE {
                    let xrow = &x[(t0 + l) * d..(t0 + l + 1) * d];
                    for (j, &v) in xrow.iter().enumerate() {
                        xt[j * TILE + l] = v;
                    }
                }
                for r in 0..n_active {
                    let mut acc0 = [0f32; TILE];
                    let mut acc1 = [0f32; TILE];
                    let row = &pairs[r * k..(r + 1) * k];
                    // SAFETY: idx < d validated at construction, xt holds
                    // d*TILE floats; AVX2 availability is guaranteed by
                    // the Microkernel dispatch invariant.
                    unsafe { tile_mac(row, xt, &mut acc0, &mut acc1, kind) };
                    let b = bias[r];
                    for l in 0..TILE {
                        orows[l * n_active + r] = (acc0[l] + acc1[l]) + b;
                    }
                }
            });
        });
    }
    if rem_start < batch {
        let rem = batch - rem_start;
        let out_rem = &mut out[rem_start * n_active..];
        forward_rows(&x[rem_start * d..], d, rem, out_rem, threads, |xb, r| {
            // SAFETY: idx < d == xb.len(), validated at construction.
            (unsafe { gather_pairs(&pairs[r * k..(r + 1) * k], xb, kind) }) + bias[r]
        });
    }
}

/// Row kernel over the interleaved layout — the ragged-remainder (and
/// batch-1) path, association-matched to the tile lanes.
///
/// # Safety
/// Every `record.idx as usize` must be `< xb.len()`.
pub unsafe fn gather_pairs(row: &[IdxVal], xb: &[f32], kind: KernelKind) -> f32 {
    match kind {
        // f32::mul_add is IEEE fusedMultiplyAdd — bit-identical to the
        // vfmadd lanes of the AVX2 tile path.
        // SAFETY: both implementations carry this fn's exact contract
        // (every `record.idx < xb.len()`), forwarded verbatim.
        KernelKind::Avx2 => unsafe { gather_pairs_fma(row, xb) },
        _ => unsafe { gather_pairs_muladd(row, xb) },
    }
}

/// Tile-lane dispatch between the AVX2 broadcast-FMA kernel and the
/// autovectorized multiply-add lanes.
///
/// # Safety
/// Every `record.idx as usize * TILE + TILE` must be `<= xt.len()`; the
/// Avx2 kind additionally requires AVX2+FMA (guaranteed by the
/// [`Microkernel`] dispatch invariant).
#[inline]
unsafe fn tile_mac(
    row: &[IdxVal],
    xt: &[f32],
    acc0: &mut [f32; TILE],
    acc1: &mut [f32; TILE],
    kind: KernelKind,
) {
    match kind {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: both implementations carry this fn's exact contract,
        // forwarded verbatim; Avx2 is only selectable when detected.
        KernelKind::Avx2 => unsafe { super::avx2::tile_mac(row, xt, acc0, acc1) },
        _ => unsafe { tile_mac_muladd(row, xt, acc0, acc1) },
    }
}

/// Multiply-then-add tile lanes (scalar and portable kinds — the fixed
/// 8-wide lane loop autovectorizes; there is nothing kind-specific left
/// to dispatch on once the loads are contiguous).
///
/// # Safety
/// Every `record.idx as usize * TILE + TILE` must be `<= xt.len()`.
unsafe fn tile_mac_muladd(
    row: &[IdxVal],
    xt: &[f32],
    acc0: &mut [f32; TILE],
    acc1: &mut [f32; TILE],
) {
    let mut it = row.chunks_exact(2);
    for p in &mut it {
        let j0 = p[0].idx as usize * TILE;
        let v0 = p[0].v;
        for l in 0..TILE {
            // SAFETY: fn contract — `idx * TILE + TILE <= xt.len()`.
            acc0[l] += v0 * unsafe { *xt.get_unchecked(j0 + l) };
        }
        let j1 = p[1].idx as usize * TILE;
        let v1 = p[1].v;
        for l in 0..TILE {
            // SAFETY: fn contract — `idx * TILE + TILE <= xt.len()`.
            acc1[l] += v1 * unsafe { *xt.get_unchecked(j1 + l) };
        }
    }
    if let [p] = it.remainder() {
        let j = p.idx as usize * TILE;
        for l in 0..TILE {
            // SAFETY: fn contract — `idx * TILE + TILE <= xt.len()`.
            acc0[l] += p.v * unsafe { *xt.get_unchecked(j + l) };
        }
    }
}

/// Multiply-then-add row kernel (scalar/portable association).
///
/// # Safety
/// Every `record.idx as usize` must be `< xb.len()`.
unsafe fn gather_pairs_muladd(row: &[IdxVal], xb: &[f32]) -> f32 {
    let (mut a0, mut a1) = (0f32, 0f32);
    let mut it = row.chunks_exact(2);
    for p in &mut it {
        // SAFETY: fn contract — every `record.idx` is `< xb.len()`.
        a0 += p[0].v * unsafe { *xb.get_unchecked(p[0].idx as usize) };
        a1 += p[1].v * unsafe { *xb.get_unchecked(p[1].idx as usize) };
    }
    if let [p] = it.remainder() {
        // SAFETY: fn contract — every `record.idx` is `< xb.len()`.
        a0 += p.v * unsafe { *xb.get_unchecked(p.idx as usize) };
    }
    a0 + a1
}

/// Fused multiply-add row kernel (AVX2 association).
///
/// # Safety
/// Every `record.idx as usize` must be `< xb.len()`.
unsafe fn gather_pairs_fma(row: &[IdxVal], xb: &[f32]) -> f32 {
    let (mut a0, mut a1) = (0f32, 0f32);
    let mut it = row.chunks_exact(2);
    for p in &mut it {
        // SAFETY: fn contract — every `record.idx` is `< xb.len()`.
        a0 = p[0].v.mul_add(unsafe { *xb.get_unchecked(p[0].idx as usize) }, a0);
        a1 = p[1].v.mul_add(unsafe { *xb.get_unchecked(p[1].idx as usize) }, a1);
    }
    if let [p] = it.remainder() {
        // SAFETY: fn contract — every `record.idx` is `< xb.len()`.
        a0 = p.v.mul_add(unsafe { *xb.get_unchecked(p.idx as usize) }, a0);
    }
    a0 + a1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_rows(n: usize, k: usize, d: usize, seed: u64) -> (Vec<IdxVal>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let pairs = (0..n * k)
            .map(|_| IdxVal { idx: rng.below(d) as u32, v: rng.normal_f32() })
            .collect();
        let bias = (0..n).map(|_| rng.normal_f32() * 0.01).collect();
        (pairs, bias)
    }

    fn naive(pairs: &[IdxVal], k: usize, n: usize, bias: &[f32], x: &[f32], d: usize, batch: usize) -> Vec<f32> {
        let mut out = vec![0f32; batch * n];
        for b in 0..batch {
            for r in 0..n {
                let mut acc = bias[r];
                for p in &pairs[r * k..(r + 1) * k] {
                    acc += p.v * x[b * d + p.idx as usize];
                }
                out[b * n + r] = acc;
            }
        }
        out
    }

    #[test]
    fn tiled_matches_naive_over_ragged_batches() {
        let (n, k, d) = (13, 9, 40);
        let (pairs, bias) = rand_rows(n, k, d, 5);
        for kind in KernelKind::ALL {
            if !kind.available() {
                continue;
            }
            let mk = Microkernel::of(kind);
            for &batch in &[1usize, 3, 7, 8, 9, 16, 23] {
                let mut rng = Rng::new(0xF0 ^ batch as u64);
                let x: Vec<f32> = (0..batch * d).map(|_| rng.normal_f32()).collect();
                let want = naive(&pairs, k, n, &bias, &x, d, batch);
                for threads in [1usize, 4] {
                    let mut out = vec![0f32; batch * n];
                    forward_tiled(&pairs, k, n, d, &bias, &x, batch, &mut out, threads, mk);
                    for (i, (g, w)) in out.iter().zip(&want).enumerate() {
                        assert!(
                            (g - w).abs() < 1e-4 * (1.0 + w.abs()),
                            "{} b{batch} t{threads} idx {i}: {g} vs {w}",
                            kind.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn batch_position_invariance_bitwise() {
        // the same input row must produce bit-identical outputs whether
        // it sits in a full tile, the ragged remainder, or a batch-1
        // forward — the serving front-end's packing depends on this
        let (n, k, d) = (11, 7, 32);
        let (pairs, bias) = rand_rows(n, k, d, 9);
        let mut rng = Rng::new(77);
        let xrow: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        for kind in KernelKind::ALL {
            if !kind.available() {
                continue;
            }
            let mk = Microkernel::of(kind);
            let mut solo = vec![0f32; n];
            forward_tiled(&pairs, k, n, d, &bias, &xrow, 1, &mut solo, 1, mk);
            for &batch in &[8usize, 9, 17] {
                for pos in [0usize, batch / 2, batch - 1] {
                    let mut x = vec![0f32; batch * d];
                    for b in 0..batch {
                        for j in 0..d {
                            x[b * d + j] = ((b * 31 + j) % 17) as f32 * 0.1 - 0.5;
                        }
                    }
                    x[pos * d..(pos + 1) * d].copy_from_slice(&xrow);
                    let mut out = vec![0f32; batch * n];
                    forward_tiled(&pairs, k, n, d, &bias, &x, batch, &mut out, 2, mk);
                    for r in 0..n {
                        assert_eq!(
                            out[pos * n + r].to_bits(),
                            solo[r].to_bits(),
                            "{} batch {batch} pos {pos} r {r}: packed vs solo must be bit-for-bit",
                            kind.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn empty_rows_and_zero_k() {
        // n_active == 0: nothing to write
        forward_tiled(&[], 0, 0, 10, &[], &[0.5; 10], 1, &mut [], 4, Microkernel::auto());
        // k == 0 with active rows: bias passthrough
        let bias = vec![1.5f32, -2.0];
        let mut out = vec![0f32; 2 * 9];
        let x = vec![0.25f32; 9 * 4];
        forward_tiled(&[], 0, 2, 4, &bias, &x, 9, &mut out, 2, Microkernel::auto());
        for b in 0..9 {
            assert_eq!(out[b * 2], 1.5);
            assert_eq!(out[b * 2 + 1], -2.0);
        }
    }
}
