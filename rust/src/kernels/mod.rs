//! The compute subsystem: every forward path in the crate bottoms out in
//! the microkernels defined here — one place for the gather-MAC inner
//! loops that used to be copy-pasted across the four `LinearKernel`
//! representations in [`crate::inference`].
//!
//! Three implementations of each inner loop, selected **once per process
//! at runtime** (stable Rust, no nightly `std::simd`):
//!
//! * [`KernelKind::Scalar`] — the 4-way-unrolled scalar loops the repo
//!   shipped with. Kept verbatim as the **executable reference oracle**:
//!   the SIMD kinds are pinned against it by a per-element ULP bound
//!   (see `docs/KERNELS.md` for the bound and its rationale).
//! * [`KernelKind::Portable`] — fixed-width `[f32; 8]` accumulator loops
//!   written so LLVM's autovectorizer can lower them to whatever vector
//!   ISA the target has. The default on non-x86 and on x86 without AVX2.
//! * [`KernelKind::Avx2`] — explicit `std::arch::x86_64` AVX2+FMA
//!   intrinsics (`vgatherdps` for the indexed loads, `vfmadd` for the
//!   MACs), selected via `is_x86_feature_detected!` so a generic build
//!   still dispatches to it on capable hosts.
//!
//! Selection is cached in a `OnceLock` ([`selected`]) and can be forced
//! with `SRIGL_KERNEL=scalar|portable|avx2` (an unavailable forced kind
//! falls back with a warning — forcing AVX2 on a CPU without it would be
//! undefined behaviour, so the override is validated, never trusted).
//! Layers carry a copyable [`Microkernel`] handle stamped at
//! construction; slicing a layer for tensor-parallel serving copies the
//! handle, so every shard of a model runs the same kind and the engine
//! conformance suite stays **bit-for-bit within a fixed selection**.
//!
//! Two invariants every kind must uphold (tests enforce both):
//!
//! 1. **Batch-position invariance** — an output element is a pure
//!    function of its row's weights and its own input row, independent of
//!    batch size, tile position, thread count, and shard cuts. The
//!    serving front-end packs concurrent requests into one forward and
//!    pins packed-vs-direct results bit-for-bit, so this is not optional.
//!    For the batch-tiled path this is why the ragged-remainder row
//!    kernel uses the exact same dual-chain association (and FMA parity)
//!    as the full-tile lanes — see [`tiled`].
//! 2. **Determinism within a kind** — no run-to-run or thread-count
//!    variation; every reduction has a fixed association.
//!
//! The int8 quantized kernel family ([`quant`]) rides the same
//! dispatch — scalar integer oracle / portable lanes / AVX2 integer
//! MACs per [`KernelKind`] — with a *stronger* agreement guarantee:
//! i32 accumulation is exact, so quantized outputs are bit-for-bit
//! identical across kinds, not merely ULP-close.

use std::sync::OnceLock;

use crate::util::threadpool::par_rows_mut;

pub mod scalar;

pub mod portable;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

pub mod tiled;

pub mod quant;

/// Batch-tile width of the tiled condensed kernel: one AVX2 vector of
/// f32, and the fixed width the portable path autovectorizes at. The
/// [`tiled`] driver transposes `TILE` input rows at a time so every
/// gathered column index becomes one contiguous `TILE`-wide load.
pub const TILE: usize = 8;

/// Which microkernel implementation a layer runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// 4-way unrolled scalar — the reference oracle.
    Scalar,
    /// `[f32; 8]` fixed-width, autovectorization-friendly.
    Portable,
    /// AVX2+FMA intrinsics (x86_64, runtime-detected).
    Avx2,
}

impl KernelKind {
    pub const ALL: [KernelKind; 3] = [KernelKind::Scalar, KernelKind::Portable, KernelKind::Avx2];

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Portable => "portable",
            KernelKind::Avx2 => "avx2",
        }
    }

    pub fn parse(s: &str) -> Option<KernelKind> {
        match s {
            "scalar" => Some(KernelKind::Scalar),
            "portable" => Some(KernelKind::Portable),
            "avx2" => Some(KernelKind::Avx2),
            _ => None,
        }
    }

    /// Whether this kind can execute on the running CPU. `Scalar` and
    /// `Portable` always can; `Avx2` requires runtime-detected AVX2+FMA.
    pub fn available(self) -> bool {
        match self {
            KernelKind::Scalar | KernelKind::Portable => true,
            KernelKind::Avx2 => avx2_available(),
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

static SELECTED: OnceLock<KernelKind> = OnceLock::new();

/// The process-wide kernel selection: `SRIGL_KERNEL` override when valid
/// and available, else AVX2+FMA when detected, else the portable path.
/// Computed once; every `Microkernel::auto()` layer shares it, which is
/// what keeps replicated/sharded/persistent execution bit-for-bit.
pub fn selected() -> KernelKind {
    *SELECTED.get_or_init(|| {
        if let Ok(v) = std::env::var("SRIGL_KERNEL") {
            match KernelKind::parse(&v) {
                Some(k) if k.available() => return k,
                Some(k) => crate::util::log::warn(
                    "kernels",
                    &format!(
                        "SRIGL_KERNEL={v}: {} not available on this CPU, auto-detecting instead",
                        k.name()
                    ),
                ),
                None => crate::util::log::warn(
                    "kernels",
                    &format!(
                        "SRIGL_KERNEL={v}: unknown kernel (scalar|portable|avx2), auto-detecting instead"
                    ),
                ),
            }
        }
        if KernelKind::Avx2.available() {
            KernelKind::Avx2
        } else {
            KernelKind::Portable
        }
    })
}

/// One-line selection banner for logs / `Engine::describe`, e.g.
/// `kernel=avx2 tile=8`.
pub fn describe_selection() -> String {
    format!("kernel={} tile={}", selected().name(), TILE)
}

/// A copyable handle to one microkernel implementation. Layers stamp one
/// at construction ([`Microkernel::auto`] — the process-wide selection)
/// and carry it through slicing, so a model and all of its shard slices
/// always run the same kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Microkernel {
    kind: KernelKind,
}

impl Microkernel {
    /// The process-wide runtime selection (see [`selected`]).
    pub fn auto() -> Microkernel {
        Microkernel { kind: selected() }
    }

    /// Force a specific kind — benches and the SIMD-vs-scalar ULP tests.
    /// Panics if the kind cannot execute on this CPU (forcing AVX2 where
    /// it is not detected would be undefined behaviour, not a slow path).
    pub fn of(kind: KernelKind) -> Microkernel {
        assert!(kind.available(), "kernel kind {} not available on this CPU", kind.name());
        Microkernel { kind }
    }

    pub fn kind(self) -> KernelKind {
        self.kind
    }

    /// Dense dot product — the dense/structured row kernel.
    #[inline]
    pub fn dot(self, a: &[f32], b: &[f32]) -> f32 {
        match self.kind {
            KernelKind::Scalar => scalar::dot(a, b),
            KernelKind::Portable => portable::dot(a, b),
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Avx2 is only constructible when AVX2+FMA are
            // runtime-detected (`KernelKind::available`).
            KernelKind::Avx2 => unsafe { avx2::dot(a, b) },
            #[cfg(not(target_arch = "x86_64"))]
            KernelKind::Avx2 => unreachable!("avx2 is never selected on this architecture"),
        }
    }

    /// Sparse gather-MAC over separate value/index streams — the
    /// condensed (Algorithm 1) and CSR row kernel.
    ///
    /// # Safety
    /// Every `idx[i] as usize` must be `< xb.len()`. Both layer types
    /// validate this once at construction so the hot loop can gather
    /// without per-element bounds checks.
    #[inline]
    pub unsafe fn gather(self, vals: &[f32], idx: &[u32], xb: &[f32]) -> f32 {
        debug_assert_eq!(vals.len(), idx.len());
        debug_assert!(idx.iter().all(|&j| (j as usize) < xb.len()));
        match self.kind {
            // SAFETY: this fn's contract (`idx` in bounds of `xb`) is
            // exactly each implementation's contract, forwarded verbatim;
            // the Avx2 arm is only constructible when AVX2+FMA are
            // runtime-detected (`KernelKind::available`).
            KernelKind::Scalar => unsafe { scalar::gather(vals, idx, xb) },
            KernelKind::Portable => unsafe { portable::gather(vals, idx, xb) },
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2 => unsafe { avx2::gather(vals, idx, xb) },
            #[cfg(not(target_arch = "x86_64"))]
            KernelKind::Avx2 => unreachable!("avx2 is never selected on this architecture"),
        }
    }
}

/// The shared threading split of every layer forward (the code that was
/// duplicated four times in `inference`): batch-1 splits the single
/// output row's columns across threads (the paper's online-inference
/// setting, Figs. 18-20); batched splits batch rows. `row(xb, r)`
/// computes output feature `r` of input row `xb`. `out` is
/// `(batch, out.len()/batch)` row-major.
pub fn forward_rows<K>(x: &[f32], d: usize, batch: usize, out: &mut [f32], threads: usize, row: K)
where
    K: Fn(&[f32], usize) -> f32 + Sync,
{
    if out.is_empty() {
        return;
    }
    debug_assert!(batch >= 1 && out.len() % batch == 0);
    debug_assert_eq!(x.len(), batch * d);
    if batch == 1 {
        par_single_row(out, threads, |start, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                *o = row(x, start + i);
            }
        });
    } else {
        let ow = out.len() / batch;
        par_rows_mut(out, ow, threads, |b, orow| {
            let xb = &x[b * d..(b + 1) * d];
            for (r, o) in orow.iter_mut().enumerate() {
                *o = row(xb, r);
            }
        });
    }
}

/// Split a single output row into per-thread contiguous chunks (batch-1
/// fast path; avoids the useless spawn when threads == 1).
pub(crate) fn par_single_row<F>(out: &mut [f32], threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync, // (start_col, chunk)
{
    let n = out.len();
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        f(0, out);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut start = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let f = &f;
            s.spawn(move || f(start, head));
            start += take;
            rest = tail;
        }
    });
}

/// Scatter one compact (active-neurons-only) output row back to a
/// zero-filled full-width region — the compact-form epilogue shared by
/// [`crate::inference::SparseModel::forward`] and
/// [`crate::inference::ShardedModel`]'s `shard_pass`.
#[inline]
pub fn scatter_row(compact: &[f32], active: &[u32], region: &mut [f32]) {
    debug_assert_eq!(compact.len(), active.len());
    region.fill(0.0);
    for (j, &r) in active.iter().enumerate() {
        region[r as usize] = compact[j];
    }
}

/// Distance between two f32 in units-in-the-last-place, measured on the
/// monotone integer mapping of IEEE-754 bit patterns (sign-aware, so a
/// near-zero sign flip reads as a large distance — pair this with an
/// absolute floor when comparing sums that can cancel; see
/// `docs/KERNELS.md`). `a == b` (including `+0 == -0`) is 0; any NaN is
/// `u64::MAX`.
pub fn ulp_diff(a: f32, b: f32) -> u64 {
    if a == b {
        return 0;
    }
    if a.is_nan() || b.is_nan() {
        return u64::MAX;
    }
    fn ord(x: f32) -> i64 {
        let bits = x.to_bits();
        if bits & 0x8000_0000 == 0 {
            bits as i64
        } else {
            -((bits & 0x7FFF_FFFF) as i64)
        }
    }
    (ord(a) - ord(b)).unsigned_abs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn available_kinds() -> Vec<KernelKind> {
        KernelKind::ALL.iter().copied().filter(|k| k.available()).collect()
    }

    #[test]
    fn dot_matches_naive_for_every_kind() {
        let mut rng = Rng::new(3);
        for len in [0usize, 1, 3, 4, 7, 8, 15, 16, 17, 64, 100] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            for kind in available_kinds() {
                let got = Microkernel::of(kind).dot(&a, &b);
                assert!(
                    (got - naive).abs() < 1e-4 * (1.0 + naive.abs()),
                    "{} len {len}: {got} vs {naive}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn gather_matches_naive_for_every_kind() {
        let mut rng = Rng::new(11);
        for len in [0usize, 1, 4, 7, 8, 9, 16, 33, 100] {
            let d = 64;
            let xb: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let vals: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
            let idx: Vec<u32> = (0..len).map(|_| rng.below(d) as u32).collect();
            let naive: f32 =
                vals.iter().zip(&idx).map(|(v, &j)| v * xb[j as usize]).sum();
            for kind in available_kinds() {
                // SAFETY: idx was drawn from `rng.below(d)`, so every
                // element is `< d == xb.len()`.
                let got = unsafe { Microkernel::of(kind).gather(&vals, &idx, &xb) };
                assert!(
                    (got - naive).abs() < 1e-4 * (1.0 + naive.abs()),
                    "{} len {len}: {got} vs {naive}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn forward_rows_covers_batched_and_single() {
        // row function writes a recognizable value per (b, r)
        let d = 4;
        for &(batch, ow, threads) in
            &[(1usize, 13usize, 1usize), (1, 13, 4), (5, 7, 1), (5, 7, 3), (8, 3, 8)]
        {
            let x: Vec<f32> = (0..batch * d).map(|i| i as f32).collect();
            let mut out = vec![-1.0f32; batch * ow];
            forward_rows(&x, d, batch, &mut out, threads, |xb, r| xb[0] * 100.0 + r as f32);
            for b in 0..batch {
                for r in 0..ow {
                    let want = x[b * d] * 100.0 + r as f32;
                    assert_eq!(out[b * ow + r], want, "b={b} r={r} threads={threads}");
                }
            }
        }
        // empty output is a no-op
        forward_rows(&[], 0, 1, &mut [], 4, |_, _| panic!("no rows"));
    }

    #[test]
    fn scatter_row_zero_fills_and_places() {
        let mut region = vec![9.0f32; 6];
        scatter_row(&[1.0, 2.0], &[1, 4], &mut region);
        assert_eq!(region, vec![0.0, 1.0, 0.0, 0.0, 2.0, 0.0]);
        scatter_row(&[], &[], &mut region[..0]);
    }

    #[test]
    fn ulp_diff_basics() {
        assert_eq!(ulp_diff(1.0, 1.0), 0);
        assert_eq!(ulp_diff(0.0, -0.0), 0);
        assert_eq!(ulp_diff(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
        assert_eq!(ulp_diff(-1.0, f32::from_bits((-1.0f32).to_bits() + 1)), 1);
        // sign-crossing distances are symmetric and additive through zero
        let tiny = f32::from_bits(5);
        assert_eq!(ulp_diff(tiny, -tiny), 10);
        assert_eq!(ulp_diff(f32::NAN, 1.0), u64::MAX);
        assert!(ulp_diff(1.0, 1.0000001) <= 2);
        assert!(ulp_diff(1.0, 2.0) > 1_000_000);
    }

    #[test]
    fn selection_is_stable_and_available() {
        let first = selected();
        assert_eq!(selected(), first, "OnceLock-cached");
        assert!(first.available());
        assert_eq!(Microkernel::auto().kind(), first);
        assert!(describe_selection().contains(first.name()));
        assert!(describe_selection().contains("tile=8"));
    }

    #[test]
    fn parse_and_names_roundtrip() {
        for kind in KernelKind::ALL {
            assert_eq!(KernelKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(KernelKind::parse("sse"), None);
    }

    #[test]
    #[cfg(target_arch = "x86_64")]
    fn avx2_availability_is_consistent() {
        // `of` must refuse what `available` refuses (panic-tested by hand:
        // we only assert the non-panicking side here)
        if KernelKind::Avx2.available() {
            assert_eq!(Microkernel::of(KernelKind::Avx2).kind(), KernelKind::Avx2);
        }
    }
}
