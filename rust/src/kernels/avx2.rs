//! AVX2+FMA microkernels (`std::arch::x86_64`, runtime-dispatched on
//! stable Rust). Every function is `#[target_feature(enable = "avx2")]
//! #[target_feature(enable = "fma")]` and therefore `unsafe` to call;
//! the only construction path that selects them —
//! [`super::KernelKind::available`] behind [`super::Microkernel`] —
//! requires `is_x86_feature_detected!("avx2") && ("fma")`, so the
//! features are guaranteed present at every call site.
//!
//! * [`dot`]    — two 8-lane FMA accumulators (16 floats/iteration).
//! * [`gather`] — `vgatherdps` indexed loads + FMA, the vectorized
//!   Algorithm-1 inner loop.
//! * [`tile_mac`] — the batch-tiled condensed hot loop: one contiguous
//!   8-wide load per stored weight (the [`super::tiled`] driver
//!   transposed the input tile so a column index *is* a contiguous
//!   vector), broadcast the weight, FMA across the 8 batch columns.
//!
//! Reduction orders are fixed; FMA fuses each multiply-add with a single
//! rounding, so results differ from the scalar oracle within the
//! documented ULP bound (`docs/KERNELS.md`), never across runs.

use std::arch::x86_64::*;

use super::TILE;
use crate::sparsity::condensed::IdxVal;
use crate::sparsity::quantized::IdxQ;

// The tile kernels identify one tile with one __m256.
const _: () = assert!(TILE == 8, "avx2 tile kernels assume an 8-wide tile");

/// Dense dot product.
///
/// # Safety
/// AVX2+FMA must be available (guaranteed by the dispatch path).
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: AVX2+FMA present per the fn contract; every load is kept in
    // bounds of both slices by the `i + 16 <= n` / `i + 8 <= n` guards
    // (n = min of the lengths), and the unchecked tail reads `i < n`.
    unsafe {
        let n = a.len().min(b.len());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            let a0 = _mm256_loadu_ps(a.as_ptr().add(i));
            let b0 = _mm256_loadu_ps(b.as_ptr().add(i));
            acc0 = _mm256_fmadd_ps(a0, b0, acc0);
            let a1 = _mm256_loadu_ps(a.as_ptr().add(i + 8));
            let b1 = _mm256_loadu_ps(b.as_ptr().add(i + 8));
            acc1 = _mm256_fmadd_ps(a1, b1, acc1);
            i += 16;
        }
        if i + 8 <= n {
            let a0 = _mm256_loadu_ps(a.as_ptr().add(i));
            let b0 = _mm256_loadu_ps(b.as_ptr().add(i));
            acc0 = _mm256_fmadd_ps(a0, b0, acc0);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            s = a.get_unchecked(i).mul_add(*b.get_unchecked(i), s);
            i += 1;
        }
        s
    }
}

/// Gather-MAC over separate value/index streams via `vgatherdps`.
///
/// # Safety
/// AVX2+FMA must be available, and every `idx[i] as usize < xb.len()`
/// (validated once at layer construction — the gather reads `xb[idx[i]]`
/// unchecked).
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
pub unsafe fn gather(vals: &[f32], idx: &[u32], xb: &[f32]) -> f32 {
    // SAFETY: AVX2+FMA present per the fn contract; stream loads stay in
    // bounds by the `i + 16/8 <= n` guards, and every gathered lane reads
    // `xb[idx[i]]` with `idx[i] < xb.len()` per the fn contract.
    unsafe {
        let n = vals.len().min(idx.len());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            let j0 = _mm256_loadu_si256(idx.as_ptr().add(i) as *const __m256i);
            let v0 = _mm256_loadu_ps(vals.as_ptr().add(i));
            let x0 = _mm256_i32gather_ps::<4>(xb.as_ptr(), j0);
            acc0 = _mm256_fmadd_ps(v0, x0, acc0);
            let j1 = _mm256_loadu_si256(idx.as_ptr().add(i + 8) as *const __m256i);
            let v1 = _mm256_loadu_ps(vals.as_ptr().add(i + 8));
            let x1 = _mm256_i32gather_ps::<4>(xb.as_ptr(), j1);
            acc1 = _mm256_fmadd_ps(v1, x1, acc1);
            i += 16;
        }
        if i + 8 <= n {
            let j0 = _mm256_loadu_si256(idx.as_ptr().add(i) as *const __m256i);
            let v0 = _mm256_loadu_ps(vals.as_ptr().add(i));
            let x0 = _mm256_i32gather_ps::<4>(xb.as_ptr(), j0);
            acc0 = _mm256_fmadd_ps(v0, x0, acc0);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            s = vals
                .get_unchecked(i)
                .mul_add(*xb.get_unchecked(*idx.get_unchecked(i) as usize), s);
            i += 1;
        }
        s
    }
}

/// The batch-tiled condensed hot loop: for each interleaved (idx, value)
/// record, load the contiguous 8 batch values of that column from the
/// transposed tile `xt`, broadcast the value, FMA into the lane
/// accumulators. Dual chains (`acc0` even records, `acc1` odd) — the
/// **same association** as the ragged-remainder row kernel
/// [`super::tiled`] uses with `f32::mul_add`, which is what keeps every
/// output element bit-identical whether it landed in a full tile or the
/// remainder (batch-position invariance).
///
/// # Safety
/// AVX2+FMA must be available, and `xt` must hold at least
/// `(max idx + 1) * TILE` floats.
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
pub unsafe fn tile_mac(row: &[IdxVal], xt: &[f32], acc0: &mut [f32; TILE], acc1: &mut [f32; TILE]) {
    // SAFETY: AVX2+FMA present per the fn contract; each 8-wide load at
    // `idx * TILE` is in bounds because `xt` holds `(max idx + 1) * TILE`
    // floats per the fn contract, and the accumulators are exactly TILE
    // (== 8) wide by their types.
    unsafe {
        let mut a0 = _mm256_loadu_ps(acc0.as_ptr());
        let mut a1 = _mm256_loadu_ps(acc1.as_ptr());
        let mut it = row.chunks_exact(2);
        for p in &mut it {
            let x0 = _mm256_loadu_ps(xt.as_ptr().add(p[0].idx as usize * TILE));
            a0 = _mm256_fmadd_ps(_mm256_set1_ps(p[0].v), x0, a0);
            let x1 = _mm256_loadu_ps(xt.as_ptr().add(p[1].idx as usize * TILE));
            a1 = _mm256_fmadd_ps(_mm256_set1_ps(p[1].v), x1, a1);
        }
        if let [p] = it.remainder() {
            let x0 = _mm256_loadu_ps(xt.as_ptr().add(p.idx as usize * TILE));
            a0 = _mm256_fmadd_ps(_mm256_set1_ps(p.v), x0, a0);
        }
        _mm256_storeu_ps(acc0.as_mut_ptr(), a0);
        _mm256_storeu_ps(acc1.as_mut_ptr(), a1);
    }
}

/// Integer gather-MAC over the 4-byte `(u16 idx, i8 q, zero pad)`
/// records of the quantized condensed layout. Eight records are one
/// `__m256i` load; per 32-bit lane the index is `lane & 0xFFFF` and the
/// weight is `(lane << 8) >> 24` (arithmetic shift sign-extends byte 2;
/// byte 3 is the struct's explicit zero pad). Indexed activation loads
/// via `vpgatherdd` from the i32 staging, products via `vpmaddwd`: both
/// operands are in `[-127, 127]`, so their low i16 halves hold the true
/// values — masking the activation's high half makes the madd's second
/// pair-product zero and the result the **exact** `q * x` per lane.
/// i32 adds are exact and associative (the constant-fan-in bound keeps
/// `|acc| < 2³¹`), so this returns bit-identically what the scalar
/// integer oracle returns — the quantized path's cross-kind agreement
/// is exact, not ULP-bounded.
///
/// # Safety
/// AVX2 must be available, and every `rec.idx as usize < xq.len()`
/// (validated once at layer construction).
#[target_feature(enable = "avx2")]
pub unsafe fn row_mac_q(recs: &[IdxQ], xq: &[i32]) -> i32 {
    // SAFETY: AVX2 present per the fn contract; the record-stream loads
    // stay in bounds by the `i + 8 <= n` guard (8 records == 32 bytes ==
    // one __m256i, size asserted in sparsity::quantized), and every
    // gathered lane reads `xq[rec.idx]` with `rec.idx < xq.len()` per
    // the fn contract.
    unsafe {
        let n = recs.len();
        let m16 = _mm256_set1_epi32(0xFFFF);
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 8 <= n {
            let v = _mm256_loadu_si256(recs.as_ptr().add(i) as *const __m256i);
            let idx = _mm256_and_si256(v, m16);
            let q = _mm256_srai_epi32::<24>(_mm256_slli_epi32::<8>(v));
            let xv = _mm256_i32gather_epi32::<4>(xq.as_ptr(), idx);
            let prod = _mm256_madd_epi16(_mm256_and_si256(xv, m16), q);
            acc = _mm256_add_epi32(acc, prod);
            i += 8;
        }
        let mut s = hsum_i32(acc);
        while i < n {
            let p = recs.get_unchecked(i);
            s += p.q as i32 * *xq.get_unchecked(p.idx as usize);
            i += 1;
        }
        s
    }
}

/// The quantized batch-tiled hot loop: for each record, load the 8
/// contiguous i8 batch values of its column from the transposed i8
/// staging (8 **bytes** per stored weight — a quarter of the f32 tile
/// traffic), sign-extend with `vpmovsxbd`, multiply by the broadcast
/// weight via `vpmaddwd` (exact — see [`row_mac_q`]), and add into the
/// i32 lane accumulators. Dual chains for ILP; integer adds make the
/// merged result equal the scalar oracle exactly, so chain shape is
/// a pure perf choice here, unlike the f32 tile kernel where it is
/// part of the bit-for-bit contract.
///
/// # Safety
/// AVX2 must be available, and `xtq` must hold at least
/// `(max idx + 1) * TILE` bytes.
#[target_feature(enable = "avx2")]
pub unsafe fn tile_mac_q(recs: &[IdxQ], xtq: &[i8], acc: &mut [i32; TILE]) {
    // SAFETY: AVX2 present per the fn contract; each 8-byte column load
    // at `idx * TILE` is in bounds because `xtq` holds
    // `(max idx + 1) * TILE` bytes per the fn contract, and the
    // accumulator is exactly TILE (== 8) i32 wide by its type.
    unsafe {
        let m16 = _mm256_set1_epi32(0xFFFF);
        let mut a0 = _mm256_loadu_si256(acc.as_ptr() as *const __m256i);
        let mut a1 = _mm256_setzero_si256();
        let mut it = recs.chunks_exact(2);
        for p in &mut it {
            let x0 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(
                xtq.as_ptr().add(p[0].idx as usize * TILE) as *const __m128i,
            ));
            let q0 = _mm256_set1_epi32(p[0].q as i32);
            a0 = _mm256_add_epi32(a0, _mm256_madd_epi16(_mm256_and_si256(x0, m16), q0));
            let x1 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(
                xtq.as_ptr().add(p[1].idx as usize * TILE) as *const __m128i,
            ));
            let q1 = _mm256_set1_epi32(p[1].q as i32);
            a1 = _mm256_add_epi32(a1, _mm256_madd_epi16(_mm256_and_si256(x1, m16), q1));
        }
        if let [p] = it.remainder() {
            let x0 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(
                xtq.as_ptr().add(p.idx as usize * TILE) as *const __m128i,
            ));
            let q0 = _mm256_set1_epi32(p.q as i32);
            a0 = _mm256_add_epi32(a0, _mm256_madd_epi16(_mm256_and_si256(x0, m16), q0));
        }
        _mm256_storeu_si256(acc.as_mut_ptr() as *mut __m256i, _mm256_add_epi32(a0, a1));
    }
}

/// Fixed-order i32 horizontal sum (exact — integer adds commute).
///
/// # Safety
/// AVX2 must be available (inherited from every caller's contract).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum_i32(v: __m256i) -> i32 {
    // SAFETY: register-only lane arithmetic — the only precondition is
    // AVX2 availability, which the fn contract inherits from its callers.
    unsafe {
        let lo = _mm256_castsi256_si128(v);
        let hi = _mm256_extracti128_si256::<1>(v);
        let q = _mm_add_epi32(lo, hi);
        let d = _mm_add_epi32(q, _mm_shuffle_epi32::<0x4E>(q));
        let s = _mm_add_epi32(d, _mm_shuffle_epi32::<0x01>(d));
        _mm_cvtsi128_si32(s)
    }
}

/// Fixed-order horizontal sum: low128 + high128, then pairwise within
/// the quad.
///
/// # Safety
/// AVX2 must be available (inherited from every caller's contract).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum(v: __m256) -> f32 {
    // SAFETY: register-only lane arithmetic — the only precondition is
    // AVX2 availability, which the fn contract inherits from its callers.
    unsafe {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let q = _mm_add_ps(lo, hi);
        let d = _mm_add_ps(q, _mm_movehl_ps(q, q));
        let s = _mm_add_ss(d, _mm_shuffle_ps::<0b01>(d, d));
        _mm_cvtss_f32(s)
    }
}
