//! The portable SIMD microkernels: fixed-width `[f32; 8]` accumulator
//! loops on stable Rust, written so LLVM's autovectorizer can lower the
//! lane loops to whatever vector ISA the target actually has (SSE, NEON,
//! AVX under `-C target-cpu=native`, or plain scalar with 8-way ILP).
//! The default kind when AVX2+FMA is not runtime-detected.
//!
//! Reduction order is fixed — `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` —
//! so results are deterministic across runs and thread counts (they may
//! differ from the scalar oracle only by f32 re-association; the ULP
//! tests pin that gap).

use super::TILE;

/// Dense dot product with `TILE` independent accumulator lanes.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0f32; TILE];
    let mut ai = a.chunks_exact(TILE);
    let mut bi = b.chunks_exact(TILE);
    for (a8, b8) in (&mut ai).zip(&mut bi) {
        for l in 0..TILE {
            acc[l] += a8[l] * b8[l];
        }
    }
    let mut s = reduce(&acc);
    for (x, y) in ai.remainder().iter().zip(bi.remainder()) {
        s += x * y;
    }
    s
}

/// Gather-MAC with `TILE` independent accumulator lanes. The indexed
/// loads stay scalar on most targets (a true vector gather needs AVX2 —
/// see [`super::avx2`]), but the 8 independent chains keep the FP units
/// saturated.
///
/// # Safety
/// Every `idx[i] as usize` must be `< xb.len()`.
pub unsafe fn gather(vals: &[f32], idx: &[u32], xb: &[f32]) -> f32 {
    let mut acc = [0f32; TILE];
    let mut vi = vals.chunks_exact(TILE);
    let mut ii = idx.chunks_exact(TILE);
    for (v8, i8) in (&mut vi).zip(&mut ii) {
        for l in 0..TILE {
            // SAFETY: fn contract — every `idx` element is `< xb.len()`.
            acc[l] += v8[l] * unsafe { *xb.get_unchecked(i8[l] as usize) };
        }
    }
    let mut s = reduce(&acc);
    for (v, i) in vi.remainder().iter().zip(ii.remainder()) {
        // SAFETY: fn contract — every `idx` element is `< xb.len()`.
        s += v * unsafe { *xb.get_unchecked(*i as usize) };
    }
    s
}

#[inline]
fn reduce(acc: &[f32; TILE]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}
