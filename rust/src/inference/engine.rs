//! The serving engine abstraction: one [`Engine`] trait every execution
//! strategy implements, and one [`EngineBuilder`] every serving caller
//! (CLI, manifest, front-end, benches, examples) constructs engines
//! through.
//!
//! Before this module the serving path had three parallel dispatch
//! surfaces — `ServeMode` (in-process benchmark), the
//! `ServeEngine`/`EngineScratch` enum pair (socket front-end, which
//! panicked on a scratch mismatch), and ad-hoc `FrontendConfig` knobs —
//! that every new caller rewired by hand. The trait collapses them:
//!
//! * [`Engine`] — `scratch`/`forward` plus the shape/diagnostic surface.
//!   The scratch is an **associated type**, so handing an engine the wrong
//!   workspace is a compile error, not a runtime panic: there is no way to
//!   write the old `EngineScratch does not match its ServeEngine` bug.
//! * [`ReplicatedEngine`] — wraps an `Arc<SparseModel>`; every pool worker
//!   owns a private [`Scratch`] and runs whole forwards.
//! * [`PersistentShardedEngine`] — a **long-lived shard team** parked on
//!   per-shard mailbox condvars. A forward hands the team a job through
//!   the mailboxes, the shards run the exact same
//!   `ShardedModel::shard_pass` layer walk as the scoped reference
//!   implementation (same per-layer barrier), and a completion latch wakes
//!   the caller — **zero thread spawns per request**, replacing the
//!   per-forward `std::thread::scope` in [`ShardedModel::forward`] (which
//!   is kept as the executable specification and pinned bit-for-bit
//!   against the team by `rust/tests/engine_conformance.rs`).
//! * [`KernelEngine`] — adapts one bare [`LinearKernel`] so the Fig. 4
//!   single-layer benchmarks drive the same serving loop.
//!
//! [`SparseModel`] and [`ShardedModel`] also implement [`Engine`]
//! directly, so tests and harnesses can drive any execution path through
//! one generic interface.

use std::sync::{Arc, Barrier, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::model::{Scratch, SparseModel};
use super::server::Batching;
use super::shard::{SharedBuf, ShardedModel, ShardedScratch};
use super::LinearKernel;
use crate::runtime::manifest::ServeKnobs;

/// A serving execution strategy: anything that can run batched forwards
/// on its own typed workspace. The associated `Scratch` ties each engine
/// to the only workspace shape it can accept — a mismatch is a type
/// error, which is the whole point of the redesign.
pub trait Engine: Send + Sync {
    /// Per-worker workspace; create one per serving thread via
    /// [`Engine::scratch`] and reuse it across requests
    /// (allocation-free hot path).
    type Scratch;

    /// Allocate a workspace for forwards up to `max_batch` rows.
    fn scratch(&self, max_batch: usize) -> Self::Scratch;

    /// Run `batch` rows of `x` (row-major, width [`Engine::in_width`]),
    /// returning the (batch x [`Engine::out_width`]) activations inside
    /// `scratch`. `threads` is the intra-op kernel thread count (for a
    /// sharded engine: intra-*shard*).
    fn forward<'s>(
        &self,
        x: &[f32],
        batch: usize,
        scratch: &'s mut Self::Scratch,
        threads: usize,
    ) -> &'s [f32];

    fn in_width(&self) -> usize;
    fn out_width(&self) -> usize;

    /// Human-readable topology/strategy line for logs and banners —
    /// includes the process-wide microkernel selection
    /// ([`crate::kernels::describe_selection`]) so served-bench JSON and
    /// startup banners record which kernel actually ran.
    fn describe(&self) -> String;

    /// Bytes of model storage behind this engine (weights+indices+bias).
    fn storage_bytes(&self) -> usize;
}

impl Engine for SparseModel {
    type Scratch = Scratch;

    fn scratch(&self, max_batch: usize) -> Scratch {
        self.make_scratch(max_batch)
    }

    fn forward<'s>(&self, x: &[f32], batch: usize, s: &'s mut Scratch, threads: usize) -> &'s [f32] {
        SparseModel::forward(self, x, batch, s, threads)
    }

    fn in_width(&self) -> usize {
        SparseModel::in_width(self)
    }

    fn out_width(&self) -> usize {
        SparseModel::out_width(self)
    }

    fn describe(&self) -> String {
        SparseModel::describe(self)
    }

    fn storage_bytes(&self) -> usize {
        SparseModel::storage_bytes(self)
    }
}

impl Engine for ShardedModel {
    type Scratch = ShardedScratch;

    fn scratch(&self, max_batch: usize) -> ShardedScratch {
        self.make_scratch(max_batch)
    }

    fn forward<'s>(
        &self,
        x: &[f32],
        batch: usize,
        s: &'s mut ShardedScratch,
        threads: usize,
    ) -> &'s [f32] {
        ShardedModel::forward(self, x, batch, s, threads)
    }

    fn in_width(&self) -> usize {
        ShardedModel::in_width(self)
    }

    fn out_width(&self) -> usize {
        ShardedModel::out_width(self)
    }

    fn describe(&self) -> String {
        format!("{} (scoped spawn)", ShardedModel::describe(self))
    }

    fn storage_bytes(&self) -> usize {
        ShardedModel::storage_bytes(self)
    }
}

// ---------------------------------------------------------------------------
// ReplicatedEngine
// ---------------------------------------------------------------------------

/// The replicate-everything strategy: each serving worker owns a full
/// [`Scratch`] and runs whole forwards on the shared model. Parallelism
/// lives *across* requests.
pub struct ReplicatedEngine {
    model: Arc<SparseModel>,
}

impl ReplicatedEngine {
    pub fn new(model: Arc<SparseModel>) -> ReplicatedEngine {
        ReplicatedEngine { model }
    }

    pub fn model(&self) -> &Arc<SparseModel> {
        &self.model
    }
}

impl Engine for ReplicatedEngine {
    type Scratch = Scratch;

    fn scratch(&self, max_batch: usize) -> Scratch {
        self.model.make_scratch(max_batch)
    }

    fn forward<'s>(&self, x: &[f32], batch: usize, s: &'s mut Scratch, threads: usize) -> &'s [f32] {
        self.model.forward(x, batch, s, threads)
    }

    fn in_width(&self) -> usize {
        self.model.in_width()
    }

    fn out_width(&self) -> usize {
        self.model.out_width()
    }

    fn describe(&self) -> String {
        self.model.describe()
    }

    fn storage_bytes(&self) -> usize {
        self.model.storage_bytes()
    }
}

// ---------------------------------------------------------------------------
// KernelEngine
// ---------------------------------------------------------------------------

/// One bare layer representation behind the [`Engine`] interface — how the
/// single-layer Fig. 4 benchmarks (`srigl serve`) drive the same serving
/// loop as whole model stacks.
pub struct KernelEngine<'a> {
    kernel: &'a dyn LinearKernel,
}

impl<'a> KernelEngine<'a> {
    pub fn new(kernel: &'a dyn LinearKernel) -> KernelEngine<'a> {
        KernelEngine { kernel }
    }
}

impl Engine for KernelEngine<'_> {
    type Scratch = Scratch;

    fn scratch(&self, max_batch: usize) -> Scratch {
        Scratch::single(max_batch, self.kernel.out_width())
    }

    fn forward<'s>(&self, x: &[f32], batch: usize, s: &'s mut Scratch, threads: usize) -> &'s [f32] {
        let ow = self.kernel.out_width();
        self.kernel.forward(x, batch, &mut s.a[..batch * ow], threads);
        &s.a[..batch * ow]
    }

    fn in_width(&self) -> usize {
        self.kernel.in_width()
    }

    fn out_width(&self) -> usize {
        self.kernel.out_width()
    }

    fn describe(&self) -> String {
        format!(
            "{} {}x{} | {}",
            self.kernel.name(),
            self.kernel.out_width(),
            self.kernel.in_width(),
            crate::kernels::describe_selection()
        )
    }

    fn storage_bytes(&self) -> usize {
        self.kernel.storage_bytes()
    }
}

// ---------------------------------------------------------------------------
// PersistentShardedEngine — the long-lived shard team
// ---------------------------------------------------------------------------

/// Raw-pointer job descriptor handed to one shard thread. The pointers
/// stay valid for the whole job because the coordinator keeps `x` and the
/// scratch borrowed (and the team's job mutex held) until every shard has
/// arrived at the completion latch.
struct ForwardJob {
    x: *const f32,
    x_len: usize,
    batch: usize,
    threads: usize,
    buf_a: *const SharedBuf,
    buf_b: *const SharedBuf,
    stage: *mut f32,
    stage_len: usize,
}

// SAFETY: the pointers are only dereferenced while the submitting
// `forward` call blocks on the completion latch (see above), so the
// pointed-to data outlives every access and `stage` is touched by exactly
// one shard thread.
unsafe impl Send for ForwardJob {}

enum ShardJob {
    Forward(ForwardJob),
    Stop,
}

/// One shard's parking spot: a single-slot mailbox. The shard thread
/// sleeps on the condvar until the coordinator posts a job; the job mutex
/// plus the completion latch guarantee the slot is empty at every post.
struct Mailbox {
    slot: Mutex<Option<ShardJob>>,
    cv: Condvar,
}

impl Mailbox {
    fn new() -> Mailbox {
        Mailbox { slot: Mutex::new(None), cv: Condvar::new() }
    }

    fn put(&self, job: ShardJob) {
        let mut g = self.slot.lock().unwrap();
        debug_assert!(g.is_none(), "mailbox must be empty (jobs are serialized)");
        *g = Some(job);
        drop(g);
        self.cv.notify_one();
    }

    fn take(&self) -> ShardJob {
        let mut g = self.slot.lock().unwrap();
        loop {
            if let Some(job) = g.take() {
                return job;
            }
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Counts shard arrivals at the end of a job; the coordinator blocks here
/// instead of joining threads.
struct DoneLatch {
    n: Mutex<usize>,
    cv: Condvar,
}

impl DoneLatch {
    fn new() -> DoneLatch {
        DoneLatch { n: Mutex::new(0), cv: Condvar::new() }
    }

    fn arrive(&self) {
        let mut g = self.n.lock().unwrap();
        *g += 1;
        drop(g);
        self.cv.notify_all();
    }

    /// Wait until `target` arrivals, then reset for the next job. Safe
    /// because the team mutex serializes jobs: no shard can arrive for
    /// job N+1 before the coordinator posts it, which happens after this
    /// returns.
    fn wait_and_reset(&self, target: usize) {
        let mut g = self.n.lock().unwrap();
        while *g < target {
            g = self.cv.wait(g).unwrap();
        }
        *g = 0;
    }
}

/// State shared between the coordinator and the team threads.
struct TeamShared {
    mailboxes: Vec<Mailbox>,
    /// Reused across layers AND jobs (std's `Barrier` resets itself once
    /// all participants pass) — the same per-layer rendezvous as the
    /// scoped reference implementation.
    barrier: Barrier,
    done: DoneLatch,
    /// The `ThreadId` each shard observed while running its most recent
    /// job — the thread-reuse conformance test reads this to prove no
    /// per-request spawning happens.
    last_tid: Vec<Mutex<Option<std::thread::ThreadId>>>,
}

/// A [`ShardedModel`] driven by a **persistent shard team**: S threads
/// spawned once at construction, parked on mailbox condvars between
/// requests, running the identical `ShardedModel::shard_pass` as the
/// scoped reference — so outputs are bit-for-bit equal to both the scoped
/// sharded forward and the replicated [`SparseModel::forward`], with zero
/// thread spawns per request.
///
/// Forwards are serialized by an internal mutex (the team is one physical
/// resource); a worker pool in front of this engine therefore adds
/// batching/packing parallelism, not forward parallelism. Stop/start
/// lifecycle: the team parks when idle and is torn down (Stop message per
/// mailbox + join) when the engine drops.
pub struct PersistentShardedEngine {
    model: Arc<ShardedModel>,
    shared: Arc<TeamShared>,
    team: Vec<JoinHandle<()>>,
    /// Serializes forwards: exactly one job owns the team at a time.
    job: Mutex<()>,
}

impl PersistentShardedEngine {
    /// Shard `model` with a stored-weight-balanced plan and spawn the
    /// team. Fails like [`ShardedModel::from_model`] (typed
    /// [`super::shard::ShardPlanError`] wrapped in `anyhow`).
    pub fn from_model(model: &SparseModel, shards: usize) -> Result<PersistentShardedEngine> {
        PersistentShardedEngine::new(Arc::new(ShardedModel::from_model(model, shards)?))
    }

    /// Spawn a persistent team for a pre-built (possibly custom-planned)
    /// [`ShardedModel`].
    pub fn new(model: Arc<ShardedModel>) -> Result<PersistentShardedEngine> {
        let shards = model.shards();
        let shared = Arc::new(TeamShared {
            mailboxes: (0..shards).map(|_| Mailbox::new()).collect(),
            barrier: Barrier::new(shards),
            done: DoneLatch::new(),
            last_tid: (0..shards).map(|_| Mutex::new(None)).collect(),
        });
        let mut team = Vec::with_capacity(shards);
        for si in 0..shards {
            let model = Arc::clone(&model);
            let shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("srigl-shard-{si}"))
                .spawn(move || shard_thread(&model, &shared, si))
                .map_err(|e| anyhow::anyhow!("spawning shard thread {si}: {e}"))?;
            team.push(handle);
        }
        Ok(PersistentShardedEngine { model, shared, team, job: Mutex::new(()) })
    }

    pub fn shards(&self) -> usize {
        self.model.shards()
    }

    /// The scoped-spawn reference model this team executes.
    pub fn sharded(&self) -> &ShardedModel {
        &self.model
    }

    /// Number of long-lived team threads (== shards for the team's whole
    /// lifetime — there is no per-request spawning to count).
    pub fn team_size(&self) -> usize {
        self.team.len()
    }

    /// The `ThreadId` each shard ran its most recent job on (`None` before
    /// the first forward). The conformance suite asserts these stay
    /// constant across forwards — with per-request scoped spawning every
    /// forward would mint fresh `ThreadId`s, which Rust guarantees are
    /// never reused within a process.
    pub fn last_shard_threads(&self) -> Vec<Option<std::thread::ThreadId>> {
        self.shared.last_tid.iter().map(|m| *m.lock().unwrap()).collect()
    }
}

/// Drop guard: a panic that unwinds out of a shard job cannot be
/// propagated (the coordinator is blocked on the latch, siblings on the
/// barrier) — the team would wedge silently, holding the job mutex and
/// hanging every future forward. Inputs and scratch shapes are validated
/// coordinator-side before a job is posted, so reaching this means a
/// genuine kernel bug; abort loudly instead of deadlocking the server.
struct AbortOnPanic(usize);

impl Drop for AbortOnPanic {
    fn drop(&mut self) {
        if std::thread::panicking() {
            crate::util::log::warn(
                "shard",
                &format!(
                    "srigl-shard-{}: panic inside a shard job; team state is unrecoverable, aborting",
                    self.0
                ),
            );
            std::process::abort();
        }
    }
}

fn shard_thread(model: &ShardedModel, shared: &TeamShared, si: usize) {
    loop {
        match shared.mailboxes[si].take() {
            ShardJob::Stop => return,
            ShardJob::Forward(job) => {
                let _abort_guard = AbortOnPanic(si);
                *shared.last_tid[si].lock().unwrap() = Some(std::thread::current().id());
                // SAFETY: the coordinator blocks on the completion latch
                // (holding the job mutex) until this shard arrives, so the
                // input, the ping-pong buffers, and this shard's private
                // staging slice all outlive the accesses below; `stage` is
                // referenced by this thread only.
                let x = unsafe { std::slice::from_raw_parts(job.x, job.x_len) };
                let stage = unsafe { std::slice::from_raw_parts_mut(job.stage, job.stage_len) };
                let (buf_a, buf_b) = unsafe { (&*job.buf_a, &*job.buf_b) };
                model.shard_pass(si, x, job.batch, stage, buf_a, buf_b, &shared.barrier, job.threads);
                shared.done.arrive();
            }
        }
    }
}

impl Engine for PersistentShardedEngine {
    type Scratch = ShardedScratch;

    fn scratch(&self, max_batch: usize) -> ShardedScratch {
        self.model.make_scratch(max_batch)
    }

    fn forward<'s>(
        &self,
        x: &[f32],
        batch: usize,
        s: &'s mut ShardedScratch,
        threads: usize,
    ) -> &'s [f32] {
        assert!(batch >= 1, "batch must be >= 1");
        assert!(
            batch <= s.max_batch(),
            "batch {batch} exceeds scratch capacity {}",
            s.max_batch()
        );
        assert_eq!(x.len(), batch * self.model.in_width(), "input size mismatch");
        let shards = self.model.shards();
        // Validate the scratch COORDINATOR-SIDE before any job is posted:
        // a too-small workspace (built from a different model) must panic
        // here, not inside a team thread where unwinding would wedge the
        // barrier and the latch.
        self.model.assert_scratch_fits(s, batch);
        // One job owns the team at a time (concurrent pool workers queue
        // here); the guard is held until every shard reports done, which
        // is what keeps the raw pointers below valid.
        let _job = self.job.lock().unwrap();
        let buf_a: *const SharedBuf = &s.a;
        let buf_b: *const SharedBuf = &s.b;
        for (si, stage) in s.stage.iter_mut().enumerate() {
            self.shared.mailboxes[si].put(ShardJob::Forward(ForwardJob {
                x: x.as_ptr(),
                x_len: x.len(),
                batch,
                threads,
                buf_a,
                buf_b,
                stage: stage.as_mut_ptr(),
                stage_len: stage.len(),
            }));
        }
        self.shared.done.wait_and_reset(shards);
        // SAFETY: every shard arrived at the latch — no write is in
        // flight, and we hold &mut scratch.
        unsafe { self.model.final_buf(s).read(batch * self.model.out_width()) }
    }

    fn in_width(&self) -> usize {
        self.model.in_width()
    }

    fn out_width(&self) -> usize {
        self.model.out_width()
    }

    fn describe(&self) -> String {
        format!("{} (persistent team)", self.model.describe())
    }

    fn storage_bytes(&self) -> usize {
        self.model.storage_bytes()
    }
}

impl Drop for PersistentShardedEngine {
    fn drop(&mut self) {
        // &mut self: no forward can be in flight. Park -> Stop -> join.
        for mb in &self.shared.mailboxes {
            mb.put(ShardJob::Stop);
        }
        for handle in self.team.drain(..) {
            let _ = handle.join();
        }
    }
}

// ---------------------------------------------------------------------------
// EngineBuilder
// ---------------------------------------------------------------------------

/// The single construction path for serving engines and the knobs every
/// serving surface shares. `serve`/`serve_model`/`serve_target`
/// ([`super::server`]), [`super::frontend::spawn`], the `serve-model` CLI,
/// the manifest `"serve"` section, and the serve benches all configure
/// through this — there is no other way to wire up a serving stack.
///
/// Fields are public for reading (banners, stats); prefer the chainable
/// setters when constructing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EngineBuilder {
    /// Pool workers draining the request queue. The in-process benchmark
    /// floors this at 1; the front-end accepts `0` (ingestion-only — used
    /// by the deterministic backpressure tests).
    pub workers: usize,
    /// Per-pop batch-limit policy; `Batching::cap()` sizes worker scratch
    /// and bounds the rows one request may carry.
    pub batching: Batching,
    /// Tensor-parallel shards per forward. `<= 1` builds a
    /// [`ReplicatedEngine`]; `> 1` builds a [`PersistentShardedEngine`]
    /// (long-lived team, typically paired with `workers: 1` since the
    /// parallelism lives inside the request).
    pub shards: usize,
    /// Bounded request-queue capacity (requests, not rows).
    pub queue_capacity: usize,
    /// Result-cache entries; `0` disables caching.
    pub cache_capacity: usize,
    /// Per-connection egress-queue capacity (response frames) — a slow
    /// client can absorb at most this many computed responses before
    /// overflow converts them to `Busy` (see `docs/WIRE.md`).
    pub egress_capacity: usize,
    /// Intra-op threads per worker (with sharding: intra-*shard*).
    pub threads: usize,
    /// Backoff hint sent with `Busy` rejections.
    pub retry_after_ms: u32,
    /// Live-connection cap; `0` means unlimited. The front-end's accept
    /// loop refuses connections beyond this with a best-effort `Busy`
    /// frame before any reader thread is spawned (counted in the
    /// `connections_rejected` metric).
    pub max_connections: usize,
}

impl Default for EngineBuilder {
    fn default() -> EngineBuilder {
        EngineBuilder {
            workers: 4,
            batching: Batching::Adaptive { cap: 8 },
            shards: 1,
            queue_capacity: 1024,
            cache_capacity: 1024,
            egress_capacity: 64,
            threads: 1,
            retry_after_ms: 2,
            max_connections: 0,
        }
    }
}

impl EngineBuilder {
    pub fn new() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// Strict batch-1 service on one worker — the paper's online-inference
    /// setting (Fig. 4a).
    pub fn online() -> EngineBuilder {
        EngineBuilder::new().workers(1).fixed_batch(1)
    }

    /// Defaults from a manifest stack's `"serve"` knobs (CLI flags layer
    /// on top via the plain setters).
    pub fn from_knobs(knobs: &ServeKnobs) -> EngineBuilder {
        let b = EngineBuilder::new();
        EngineBuilder {
            batching: if knobs.adaptive {
                Batching::Adaptive { cap: knobs.max_batch.max(1) }
            } else {
                Batching::Fixed(knobs.max_batch.max(1))
            },
            shards: knobs.shards,
            queue_capacity: knobs.queue_capacity,
            cache_capacity: knobs.cache_capacity,
            egress_capacity: knobs.egress_capacity,
            max_connections: knobs.max_connections,
            ..b
        }
    }

    pub fn workers(mut self, workers: usize) -> EngineBuilder {
        self.workers = workers;
        self
    }

    /// Fixed batch limit `n` per pop.
    pub fn fixed_batch(mut self, n: usize) -> EngineBuilder {
        self.batching = Batching::Fixed(n.max(1));
        self
    }

    /// Adaptive (EWMA-of-queue-depth) batching up to `cap`.
    pub fn adaptive(mut self, cap: usize) -> EngineBuilder {
        self.batching = Batching::Adaptive { cap: cap.max(1) };
        self
    }

    pub fn batching(mut self, batching: Batching) -> EngineBuilder {
        self.batching = batching;
        self
    }

    pub fn shards(mut self, shards: usize) -> EngineBuilder {
        self.shards = shards;
        self
    }

    pub fn queue_capacity(mut self, n: usize) -> EngineBuilder {
        self.queue_capacity = n;
        self
    }

    pub fn cache_capacity(mut self, n: usize) -> EngineBuilder {
        self.cache_capacity = n;
        self
    }

    pub fn egress_capacity(mut self, n: usize) -> EngineBuilder {
        self.egress_capacity = n;
        self
    }

    pub fn threads(mut self, threads: usize) -> EngineBuilder {
        self.threads = threads;
        self
    }

    pub fn retry_after_ms(mut self, ms: u32) -> EngineBuilder {
        self.retry_after_ms = ms;
        self
    }

    /// Cap live connections (`0` = unlimited); see the field docs.
    pub fn max_connections(mut self, n: usize) -> EngineBuilder {
        self.max_connections = n;
        self
    }

    /// Upper bound on any batch the configured policy can produce — what
    /// scratch buffers are sized for.
    pub fn max_batch(&self) -> usize {
        self.batching.cap()
    }

    /// True when `EngineBuilder::shards` selects the persistent sharded
    /// engine over the replicated one.
    pub fn is_sharded(&self) -> bool {
        self.shards > 1
    }

    /// Build the replicated engine for `model`.
    pub fn build_replicated(&self, model: Arc<SparseModel>) -> ReplicatedEngine {
        ReplicatedEngine::new(model)
    }

    /// Build (and spawn) the persistent shard team for `model` using the
    /// builder's shard count.
    pub fn build_persistent_sharded(&self, model: &SparseModel) -> Result<PersistentShardedEngine> {
        PersistentShardedEngine::from_model(model, self.shards.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::model::{Activation, LayerSpec, Repr};
    use crate::inference::LayerBundle;
    use crate::util::rng::Rng;

    fn model3(repr: Repr) -> SparseModel {
        let spec = |n, act| LayerSpec {
            n,
            repr,
            sparsity: 0.9,
            ablated_frac: 0.25,
            activation: act,
        };
        SparseModel::synth(
            64,
            &[
                spec(48, Activation::Relu),
                spec(32, Activation::Relu),
                spec(16, Activation::Identity),
            ],
            11,
        )
        .unwrap()
    }

    fn run<E: Engine>(e: &E, x: &[f32], batch: usize) -> Vec<f32> {
        let mut s = e.scratch(batch);
        e.forward(x, batch, &mut s, 1).to_vec()
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn replicated_engine_matches_model() {
        let m = Arc::new(model3(Repr::Condensed));
        let engine = ReplicatedEngine::new(Arc::clone(&m));
        assert_eq!(engine.in_width(), 64);
        assert_eq!(engine.out_width(), 16);
        assert_eq!(engine.storage_bytes(), m.storage_bytes());
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..3 * 64).map(|_| rng.normal_f32()).collect();
        assert_bits_eq(&run(&engine, &x, 3), &m.forward_vec(&x, 3, 1), "replicated");
    }

    #[test]
    fn kernel_engine_matches_direct_forward() {
        let bundle = LayerBundle::synth(24, 32, 0.9, 0.2, 3);
        let engine = KernelEngine::new(&bundle.condensed);
        assert_eq!(engine.in_width(), 32);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..2 * 32).map(|_| rng.normal_f32()).collect();
        let mut want = vec![0f32; 2 * bundle.condensed.out_width()];
        bundle.condensed.forward(&x, 2, &mut want, 1);
        assert_bits_eq(&run(&engine, &x, 2), &want, "kernel engine");
        assert!(engine.describe().contains("condensed"));
    }

    #[test]
    fn persistent_team_matches_scoped_and_replicated() {
        // full cross-product lives in rust/tests/engine_conformance.rs
        let m = model3(Repr::Condensed);
        let scoped = ShardedModel::from_model(&m, 2).unwrap();
        let team = PersistentShardedEngine::from_model(&m, 2).unwrap();
        assert_eq!(team.shards(), 2);
        assert_eq!(team.team_size(), 2);
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..4 * 64).map(|_| rng.normal_f32()).collect();
        let want = m.forward_vec(&x, 4, 1);
        assert_bits_eq(&run(&scoped, &x, 4), &want, "scoped vs replicated");
        assert_bits_eq(&run(&team, &x, 4), &want, "persistent vs replicated");
    }

    #[test]
    fn persistent_team_scratch_reuse_and_varying_batch() {
        let m = model3(Repr::Structured);
        let team = PersistentShardedEngine::from_model(&m, 3).unwrap();
        let mut s = team.scratch(8);
        let mut rng = Rng::new(9);
        for &batch in &[1usize, 5, 8, 1, 3] {
            let x: Vec<f32> = (0..batch * 64).map(|_| rng.normal_f32()).collect();
            let want = m.forward_vec(&x, batch, 1);
            let got = team.forward(&x, batch, &mut s, 1).to_vec();
            assert_bits_eq(&got, &want, &format!("batch {batch}"));
        }
    }

    #[test]
    fn persistent_team_serializes_concurrent_forwards() {
        let m = Arc::new(model3(Repr::Condensed));
        let team = Arc::new(PersistentShardedEngine::from_model(&m, 2).unwrap());
        std::thread::scope(|s| {
            for t in 0..3u64 {
                let team = Arc::clone(&team);
                let m = Arc::clone(&m);
                s.spawn(move || {
                    let mut scratch = team.scratch(4);
                    let mut rng = Rng::new(0xC0 + t);
                    for i in 0..20usize {
                        let batch = 1 + i % 4;
                        let x: Vec<f32> = (0..batch * 64).map(|_| rng.normal_f32()).collect();
                        let want = m.forward_vec(&x, batch, 1);
                        let got = team.forward(&x, batch, &mut scratch, 1).to_vec();
                        assert_bits_eq(&got, &want, &format!("caller {t} iter {i}"));
                    }
                });
            }
        });
    }

    #[test]
    fn persistent_team_rejects_oversized_shard_count() {
        // narrowest layer has 16 neurons
        let m = model3(Repr::Condensed);
        assert!(PersistentShardedEngine::from_model(&m, 17).is_err());
    }

    #[test]
    fn dropping_idle_and_used_teams_terminates() {
        let m = model3(Repr::Dense);
        // never-used team
        drop(PersistentShardedEngine::from_model(&m, 3).unwrap());
        // used team
        let team = PersistentShardedEngine::from_model(&m, 3).unwrap();
        let x = vec![0.25f32; 64];
        let _ = run(&team, &x, 1);
        drop(team); // Stop + join must not hang
    }

    #[test]
    fn builder_defaults_and_knobs() {
        let b = EngineBuilder::new();
        assert_eq!(b.workers, 4);
        assert_eq!(b.shards, 1);
        assert!(!b.is_sharded());
        assert_eq!(b.max_batch(), 8);

        let online = EngineBuilder::online();
        assert_eq!(online.workers, 1);
        assert_eq!(online.batching, Batching::Fixed(1));

        let knobs = ServeKnobs {
            queue_capacity: 64,
            cache_capacity: 0,
            egress_capacity: 7,
            adaptive: false,
            max_batch: 4,
            shards: 3,
            max_connections: 5,
        };
        let b = EngineBuilder::from_knobs(&knobs).workers(2).threads(2).retry_after_ms(9);
        assert_eq!(b.batching, Batching::Fixed(4));
        assert_eq!(b.queue_capacity, 64);
        assert_eq!(b.cache_capacity, 0);
        assert_eq!(b.egress_capacity, 7);
        assert_eq!(b.shards, 3);
        assert!(b.is_sharded());
        assert_eq!(b.workers, 2);
        assert_eq!(b.threads, 2);
        assert_eq!(b.retry_after_ms, 9);
        assert_eq!(b.max_connections, 5);
        assert_eq!(EngineBuilder::new().max_connections, 0, "default: unlimited");
    }

    #[test]
    fn builder_constructs_both_engine_kinds() {
        let m = Arc::new(model3(Repr::Condensed));
        let rep = EngineBuilder::new().build_replicated(Arc::clone(&m));
        let sh = EngineBuilder::new().shards(2).build_persistent_sharded(&m).unwrap();
        assert_eq!(rep.in_width(), sh.in_width());
        assert_eq!(rep.out_width(), sh.out_width());
        assert_eq!(rep.storage_bytes(), sh.storage_bytes(), "weights partition exactly");
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..2 * 64).map(|_| rng.normal_f32()).collect();
        assert_bits_eq(&run(&rep, &x, 2), &run(&sh, &x, 2), "builder engines agree");
    }
}
