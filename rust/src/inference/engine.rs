//! The serving engine abstraction: one [`Engine`] trait every execution
//! strategy implements, and one [`EngineBuilder`] every serving caller
//! (CLI, manifest, front-end, benches, examples) constructs engines
//! through.
//!
//! Before this module the serving path had three parallel dispatch
//! surfaces — `ServeMode` (in-process benchmark), the
//! `ServeEngine`/`EngineScratch` enum pair (socket front-end, which
//! panicked on a scratch mismatch), and ad-hoc `FrontendConfig` knobs —
//! that every new caller rewired by hand. The trait collapses them:
//!
//! * [`Engine`] — `scratch`/`forward` plus the shape/diagnostic surface.
//!   The scratch is an **associated type**, so handing an engine the wrong
//!   workspace is a compile error, not a runtime panic: there is no way to
//!   write the old `EngineScratch does not match its ServeEngine` bug.
//! * [`ReplicatedEngine`] — wraps an `Arc<SparseModel>`; every pool worker
//!   owns a private [`Scratch`] and runs whole forwards.
//! * [`PersistentShardedEngine`] — a **long-lived shard team** parked on
//!   per-shard mailbox condvars. A forward hands the team a job through
//!   the mailboxes, the shards run the exact same
//!   `ShardedModel::shard_pass` layer walk as the scoped reference
//!   implementation (same per-layer barrier), and a completion latch wakes
//!   the caller — **zero thread spawns per request**, replacing the
//!   per-forward `std::thread::scope` in [`ShardedModel::forward`] (which
//!   is kept as the executable specification and pinned bit-for-bit
//!   against the team by `rust/tests/engine_conformance.rs`).
//! * [`KernelEngine`] — adapts one bare [`LinearKernel`] so the Fig. 4
//!   single-layer benchmarks drive the same serving loop.
//!
//! [`SparseModel`] and [`ShardedModel`] also implement [`Engine`]
//! directly, so tests and harnesses can drive any execution path through
//! one generic interface.
//!
//! ## Epochs — live model swap (RCU-style)
//!
//! The three long-lived engines ([`ReplicatedEngine`],
//! [`ScopedShardedEngine`], [`PersistentShardedEngine`], and the
//! [`SwappableEngine`] umbrella over them) do **not** hold their stack by
//! value: they hold an [`EpochCell`] that publishes one immutable
//! [`ModelEpoch`] at a time. Every workspace ([`EpochScratch`] /
//! [`ShardedEpochScratch`]) carries the `Arc` of the stack it was built
//! for, and `forward` computes with **the scratch's** stack — so a forward
//! is atomic on its epoch *by construction*: a concurrent
//! [`Engine::swap`] publishes a new epoch for future scratches while
//! in-flight forwards keep the old stack alive through their `Arc`
//! (classic read-copy-update). Callers opt in to new epochs at batch
//! boundaries via [`Engine::ensure_current`], which rebuilds a stale
//! scratch against the current epoch. Swaps must preserve the input
//! width (connections validate request shape against it once) and must
//! carry a strictly increasing epoch id (the result cache uses the id as
//! its staleness generation — see `docs/RELOAD.md`).

use std::sync::{Arc, Barrier, Mutex};
use std::thread::JoinHandle;

// The epoch cell and the shard team's mailbox/latch handoff are
// model-checked (rust/tests/loom_models.rs), so their primitives come
// from the shim: std normally, loom under `--cfg loom`. The rest of the
// engine (job serialization, tid bookkeeping, the per-layer Barrier)
// stays on std::sync — not modeled.
use crate::util::sync as ssync;

use anyhow::{bail, Result};

use super::model::{ModelEpoch, Scratch, SparseModel};
use super::server::Batching;
use super::shard::{SharedBuf, ShardedModel, ShardedScratch};
use super::LinearKernel;
use crate::runtime::manifest::ServeKnobs;

/// A serving execution strategy: anything that can run batched forwards
/// on its own typed workspace. The associated `Scratch` ties each engine
/// to the only workspace shape it can accept — a mismatch is a type
/// error, which is the whole point of the redesign.
pub trait Engine: Send + Sync {
    /// Per-worker workspace; create one per serving thread via
    /// [`Engine::scratch`] and reuse it across requests
    /// (allocation-free hot path).
    type Scratch;

    /// Allocate a workspace for forwards up to `max_batch` rows.
    fn scratch(&self, max_batch: usize) -> Self::Scratch;

    /// Run `batch` rows of `x` (row-major, width [`Engine::in_width`]),
    /// returning the (batch x [`Engine::out_width`]) activations inside
    /// `scratch`. `threads` is the intra-op kernel thread count (for a
    /// sharded engine: intra-*shard*).
    fn forward<'s>(
        &self,
        x: &[f32],
        batch: usize,
        scratch: &'s mut Self::Scratch,
        threads: usize,
    ) -> &'s [f32];

    fn in_width(&self) -> usize;
    fn out_width(&self) -> usize;

    /// Human-readable topology/strategy line for logs and banners —
    /// includes the process-wide microkernel selection
    /// ([`crate::kernels::describe_selection`]) so served-bench JSON and
    /// startup banners record which kernel actually ran.
    fn describe(&self) -> String;

    /// Bytes of model storage behind this engine (weights+indices+bias).
    fn storage_bytes(&self) -> usize;

    /// The epoch id currently published. Immutable engines are forever at
    /// epoch 0.
    fn epoch(&self) -> u64 {
        0
    }

    /// Atomically publish a new stack. In-flight forwards finish on the
    /// epoch their scratch was built for; future
    /// [`Engine::ensure_current`] calls pick up the new one. Returns the
    /// published epoch id. The default (immutable engines) refuses.
    ///
    /// Contract enforced by swappable implementations: the new stack's
    /// input width must equal the current one (connections validate
    /// request shape against [`Engine::in_width`] once at accept), and
    /// `epoch.id` must be strictly greater than the current id (the
    /// result cache uses the id as its staleness generation).
    fn swap(&self, epoch: ModelEpoch) -> Result<u64> {
        let _ = epoch;
        bail!("this engine does not support live model swap")
    }

    /// Rebuild `scratch` against the current epoch if it was built for an
    /// older one, and return the epoch id the scratch is now pinned to —
    /// the epoch the next [`Engine::forward`] through this scratch will
    /// compute under, even if a swap lands in between. Immutable engines
    /// never rebuild. Call this at batch boundaries, never mid-forward.
    fn ensure_current(&self, scratch: &mut Self::Scratch, max_batch: usize) -> u64 {
        let _ = (scratch, max_batch);
        self.epoch()
    }
}

// ---------------------------------------------------------------------------
// EpochCell — the one-slot RCU publication point
// ---------------------------------------------------------------------------

/// One atomically published `(epoch id, Arc<stack>)` pair. Readers either
/// take a consistent snapshot (`current`, for building scratches) or a
/// lock-free id peek (`epoch`, for the per-request staleness checks on the
/// serving hot path). `publish` enforces strictly increasing ids.
///
/// The coherence invariant — a reader that peeked `epoch()` and then takes
/// a snapshot never sees a snapshot id *older* than the peek — is
/// model-checked in `rust/tests/loom_models.rs` (the shadow id is stored
/// only *after* the locked pair is updated, so the shadow can trail the
/// lock but never lead it). `pub` so the model can drive it directly.
///
/// Lock poisoning: both closures recover with `into_inner` — the guarded
/// pair is updated by single assignment after all fallible work, so a
/// panicked publisher can never leave it torn.
pub struct EpochCell<T> {
    cur: ssync::RwLock<(u64, Arc<T>)>,
    /// Shadow of the published id so `epoch()` never touches the lock.
    id: ssync::atomic::AtomicU64,
}

impl<T> EpochCell<T> {
    pub fn new(id: u64, v: Arc<T>) -> EpochCell<T> {
        EpochCell {
            cur: ssync::RwLock::new((id, Arc::clone(&v))),
            id: ssync::atomic::AtomicU64::new(id),
        }
    }

    pub fn epoch(&self) -> u64 {
        self.id.load(ssync::atomic::Ordering::Acquire)
    }

    /// Consistent `(id, stack)` snapshot.
    pub fn current(&self) -> (u64, Arc<T>) {
        let g = self.cur.read().unwrap_or_else(|poisoned| poisoned.into_inner());
        (g.0, Arc::clone(&g.1))
    }

    /// Publish `(id, v)`; fails without publishing unless `id` is
    /// strictly greater than the current id (two racing swaps serialize
    /// on the write lock and the loser errors out).
    pub fn publish(&self, id: u64, v: Arc<T>) -> Result<()> {
        let mut g = self.cur.write().unwrap_or_else(|poisoned| poisoned.into_inner());
        if id <= g.0 {
            bail!("epoch id {id} is not greater than the published epoch {}", g.0);
        }
        *g = (id, v);
        self.id.store(id, ssync::atomic::Ordering::Release);
        Ok(())
    }
}

impl Engine for SparseModel {
    type Scratch = Scratch;

    fn scratch(&self, max_batch: usize) -> Scratch {
        self.make_scratch(max_batch)
    }

    fn forward<'s>(&self, x: &[f32], batch: usize, s: &'s mut Scratch, threads: usize) -> &'s [f32] {
        SparseModel::forward(self, x, batch, s, threads)
    }

    fn in_width(&self) -> usize {
        SparseModel::in_width(self)
    }

    fn out_width(&self) -> usize {
        SparseModel::out_width(self)
    }

    fn describe(&self) -> String {
        SparseModel::describe(self)
    }

    fn storage_bytes(&self) -> usize {
        SparseModel::storage_bytes(self)
    }
}

impl Engine for ShardedModel {
    type Scratch = ShardedScratch;

    fn scratch(&self, max_batch: usize) -> ShardedScratch {
        self.make_scratch(max_batch)
    }

    fn forward<'s>(
        &self,
        x: &[f32],
        batch: usize,
        s: &'s mut ShardedScratch,
        threads: usize,
    ) -> &'s [f32] {
        ShardedModel::forward(self, x, batch, s, threads)
    }

    fn in_width(&self) -> usize {
        ShardedModel::in_width(self)
    }

    fn out_width(&self) -> usize {
        ShardedModel::out_width(self)
    }

    fn describe(&self) -> String {
        format!("{} (scoped spawn)", ShardedModel::describe(self))
    }

    fn storage_bytes(&self) -> usize {
        ShardedModel::storage_bytes(self)
    }
}

// ---------------------------------------------------------------------------
// ReplicatedEngine
// ---------------------------------------------------------------------------

/// Workspace for [`ReplicatedEngine`]: a plain [`Scratch`] pinned to the
/// epoch it was sized for. The carried `Arc` both keeps the old stack
/// alive while a forward drains on it and is the stack the forward runs —
/// so a concurrent swap can never pair a new model with an old-sized
/// buffer.
pub struct EpochScratch {
    epoch: u64,
    model: Arc<SparseModel>,
    inner: Scratch,
}

impl EpochScratch {
    /// The epoch this workspace (and the next forward through it) is
    /// pinned to.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

/// The replicate-everything strategy: each serving worker owns a full
/// [`Scratch`] and runs whole forwards on the shared model. Parallelism
/// lives *across* requests. The model is epoch-published, so
/// [`Engine::swap`] hot-swaps the stack under traffic.
pub struct ReplicatedEngine {
    cell: EpochCell<SparseModel>,
}

impl ReplicatedEngine {
    pub fn new(model: Arc<SparseModel>) -> ReplicatedEngine {
        ReplicatedEngine { cell: EpochCell::new(0, model) }
    }

    /// The currently published stack.
    pub fn model(&self) -> Arc<SparseModel> {
        self.cell.current().1
    }
}

impl Engine for ReplicatedEngine {
    type Scratch = EpochScratch;

    fn scratch(&self, max_batch: usize) -> EpochScratch {
        let (epoch, model) = self.cell.current();
        EpochScratch { epoch, inner: model.make_scratch(max_batch), model }
    }

    fn forward<'s>(
        &self,
        x: &[f32],
        batch: usize,
        s: &'s mut EpochScratch,
        threads: usize,
    ) -> &'s [f32] {
        // The scratch's stack, not the cell's: atomic on its epoch even
        // if a swap lands mid-forward.
        s.model.forward(x, batch, &mut s.inner, threads)
    }

    fn in_width(&self) -> usize {
        self.cell.current().1.in_width()
    }

    fn out_width(&self) -> usize {
        self.cell.current().1.out_width()
    }

    fn describe(&self) -> String {
        self.cell.current().1.describe()
    }

    fn storage_bytes(&self) -> usize {
        self.cell.current().1.storage_bytes()
    }

    fn epoch(&self) -> u64 {
        self.cell.epoch()
    }

    fn swap(&self, epoch: ModelEpoch) -> Result<u64> {
        let cur = self.cell.current().1;
        if epoch.model.in_width() != cur.in_width() {
            bail!(
                "swap changes input width {} -> {}; connections validate shape against the \
                 accept-time width, so this must be a restart",
                cur.in_width(),
                epoch.model.in_width()
            );
        }
        self.cell.publish(epoch.id, epoch.model)?;
        Ok(epoch.id)
    }

    fn ensure_current(&self, scratch: &mut EpochScratch, max_batch: usize) -> u64 {
        if scratch.epoch != self.cell.epoch() {
            *scratch = self.scratch(max_batch);
        }
        scratch.epoch
    }
}

// ---------------------------------------------------------------------------
// KernelEngine
// ---------------------------------------------------------------------------

/// One bare layer representation behind the [`Engine`] interface — how the
/// single-layer Fig. 4 benchmarks (`srigl serve`) drive the same serving
/// loop as whole model stacks.
pub struct KernelEngine<'a> {
    kernel: &'a dyn LinearKernel,
}

impl<'a> KernelEngine<'a> {
    pub fn new(kernel: &'a dyn LinearKernel) -> KernelEngine<'a> {
        KernelEngine { kernel }
    }
}

impl Engine for KernelEngine<'_> {
    type Scratch = Scratch;

    fn scratch(&self, max_batch: usize) -> Scratch {
        Scratch::single(max_batch, self.kernel.out_width())
    }

    fn forward<'s>(&self, x: &[f32], batch: usize, s: &'s mut Scratch, threads: usize) -> &'s [f32] {
        let ow = self.kernel.out_width();
        self.kernel.forward(x, batch, &mut s.a[..batch * ow], threads);
        &s.a[..batch * ow]
    }

    fn in_width(&self) -> usize {
        self.kernel.in_width()
    }

    fn out_width(&self) -> usize {
        self.kernel.out_width()
    }

    fn describe(&self) -> String {
        format!(
            "{} {}x{} | {}",
            self.kernel.name(),
            self.kernel.out_width(),
            self.kernel.in_width(),
            crate::kernels::describe_selection()
        )
    }

    fn storage_bytes(&self) -> usize {
        self.kernel.storage_bytes()
    }
}

// ---------------------------------------------------------------------------
// ScopedShardedEngine — swappable scoped-spawn sharding
// ---------------------------------------------------------------------------

/// Workspace for the sharded swappable engines ([`ScopedShardedEngine`]
/// and [`PersistentShardedEngine`]): a [`ShardedScratch`] pinned to the
/// epoch's sharded stack. Same atomicity argument as [`EpochScratch`];
/// additionally the persistent team's raw job pointers point into the
/// `Arc` held here, which is what keeps them valid across a swap.
pub struct ShardedEpochScratch {
    epoch: u64,
    model: Arc<ShardedModel>,
    inner: ShardedScratch,
}

impl ShardedEpochScratch {
    /// The epoch this workspace (and the next forward through it) is
    /// pinned to.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

/// Tensor-parallel sharding via the scoped-spawn reference forward
/// ([`ShardedModel::forward`]), behind an epoch cell so the stack can be
/// swapped under traffic. A swap re-plans: the incoming [`SparseModel`]
/// is re-cut into the same number of shards with a fresh
/// weight-balanced [`super::shard::ShardPlan`].
///
/// This exists mainly as the executable specification for swap semantics
/// — the epoch-conformance suite pins [`PersistentShardedEngine`]
/// bit-for-bit against it under concurrent swaps.
pub struct ScopedShardedEngine {
    cell: EpochCell<ShardedModel>,
    shards: usize,
}

impl ScopedShardedEngine {
    /// Shard `model` with a stored-weight-balanced plan. Fails like
    /// [`ShardedModel::from_model`].
    pub fn from_model(model: &SparseModel, shards: usize) -> Result<ScopedShardedEngine> {
        let sharded = Arc::new(ShardedModel::from_model(model, shards)?);
        Ok(ScopedShardedEngine { cell: EpochCell::new(0, sharded), shards })
    }

    pub fn shards(&self) -> usize {
        self.shards
    }
}

/// Shared swap path for the two sharded engines: re-plan the incoming
/// stack into `shards` cuts, refuse input-width changes, publish.
fn swap_sharded(
    cell: &EpochCell<ShardedModel>,
    shards: usize,
    epoch: ModelEpoch,
) -> Result<u64> {
    let cur = cell.current().1;
    if epoch.model.in_width() != cur.in_width() {
        bail!(
            "swap changes input width {} -> {}; connections validate shape against the \
             accept-time width, so this must be a restart",
            cur.in_width(),
            epoch.model.in_width()
        );
    }
    // Re-plan first: a stack too narrow for the shard count must leave
    // the old epoch serving.
    let sharded = Arc::new(ShardedModel::from_model(&epoch.model, shards)?);
    crate::util::log::info(
        "engine",
        &format!("epoch {}: re-planned {}", epoch.id, sharded.plan().summary()),
    );
    cell.publish(epoch.id, sharded)?;
    Ok(epoch.id)
}

impl Engine for ScopedShardedEngine {
    type Scratch = ShardedEpochScratch;

    fn scratch(&self, max_batch: usize) -> ShardedEpochScratch {
        let (epoch, model) = self.cell.current();
        ShardedEpochScratch { epoch, inner: model.make_scratch(max_batch), model }
    }

    fn forward<'s>(
        &self,
        x: &[f32],
        batch: usize,
        s: &'s mut ShardedEpochScratch,
        threads: usize,
    ) -> &'s [f32] {
        s.model.forward(x, batch, &mut s.inner, threads)
    }

    fn in_width(&self) -> usize {
        self.cell.current().1.in_width()
    }

    fn out_width(&self) -> usize {
        self.cell.current().1.out_width()
    }

    fn describe(&self) -> String {
        format!("{} (scoped spawn, swappable)", self.cell.current().1.describe())
    }

    fn storage_bytes(&self) -> usize {
        self.cell.current().1.storage_bytes()
    }

    fn epoch(&self) -> u64 {
        self.cell.epoch()
    }

    fn swap(&self, epoch: ModelEpoch) -> Result<u64> {
        swap_sharded(&self.cell, self.shards, epoch)
    }

    fn ensure_current(&self, scratch: &mut ShardedEpochScratch, max_batch: usize) -> u64 {
        if scratch.epoch != self.cell.epoch() {
            *scratch = self.scratch(max_batch);
        }
        scratch.epoch
    }
}

// ---------------------------------------------------------------------------
// PersistentShardedEngine — the long-lived shard team
// ---------------------------------------------------------------------------

/// Raw-pointer job descriptor handed to one shard thread. The pointers
/// stay valid for the whole job because the coordinator keeps `x` and the
/// scratch borrowed (and the team's job mutex held) until every shard has
/// arrived at the completion latch.
struct ForwardJob {
    /// The epoch's sharded stack this job computes with — every job is
    /// re-stamped with the submitting scratch's model, so the team
    /// threads never hold a stack themselves and a swap takes effect at
    /// the next job boundary with zero team coordination.
    model: *const ShardedModel,
    x: *const f32,
    x_len: usize,
    batch: usize,
    threads: usize,
    buf_a: *const SharedBuf,
    buf_b: *const SharedBuf,
    stage: *mut f32,
    stage_len: usize,
}

// SAFETY: the pointers are only dereferenced while the submitting
// `forward` call blocks on the completion latch (see above), so the
// pointed-to data outlives every access and `stage` is touched by exactly
// one shard thread. `model` points into the `Arc<ShardedModel>` held by
// the submitting scratch, which the blocked `forward` keeps borrowed for
// the same window.
unsafe impl Send for ForwardJob {}

enum ShardJob {
    Forward(ForwardJob),
    Stop,
}

/// One shard's parking spot: a single-slot mailbox. The shard thread
/// sleeps on the condvar until the coordinator posts a job; the job mutex
/// plus the completion latch guarantee the slot is empty at every post.
///
/// Generic over the job type (and `pub`) so `rust/tests/loom_models.rs`
/// can model the post → run → latch handoff with its own probe jobs.
/// Lock poisoning recovers with `into_inner`: every mutation is a single
/// slot assignment, so the state can never be torn (team threads
/// additionally run under [`AbortOnPanic`], which turns any shard panic
/// into an abort before poison propagates).
pub struct Mailbox<T> {
    slot: ssync::Mutex<Option<T>>,
    cv: ssync::Condvar,
}

impl<T> Mailbox<T> {
    pub fn new() -> Mailbox<T> {
        Mailbox { slot: ssync::Mutex::new(None), cv: ssync::Condvar::new() }
    }

    pub fn put(&self, job: T) {
        let mut g = self.slot.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        debug_assert!(g.is_none(), "mailbox must be empty (jobs are serialized)");
        *g = Some(job);
        drop(g);
        self.cv.notify_one();
    }

    pub fn take(&self) -> T {
        let mut g = self.slot.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        loop {
            if let Some(job) = g.take() {
                return job;
            }
            g = self.cv.wait(g).unwrap_or_else(|poisoned| poisoned.into_inner());
        }
    }
}

impl<T> Default for Mailbox<T> {
    fn default() -> Mailbox<T> {
        Mailbox::new()
    }
}

/// Counts shard arrivals at the end of a job; the coordinator blocks here
/// instead of joining threads. `pub` for the loom models; poisoning
/// recovers with `into_inner` (single-counter state, never torn).
pub struct DoneLatch {
    n: ssync::Mutex<usize>,
    cv: ssync::Condvar,
}

impl DoneLatch {
    pub fn new() -> DoneLatch {
        DoneLatch { n: ssync::Mutex::new(0), cv: ssync::Condvar::new() }
    }

    pub fn arrive(&self) {
        let mut g = self.n.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        *g += 1;
        drop(g);
        self.cv.notify_all();
    }

    /// Wait until `target` arrivals, then reset for the next job. Safe
    /// because the team mutex serializes jobs: no shard can arrive for
    /// job N+1 before the coordinator posts it, which happens after this
    /// returns.
    pub fn wait_and_reset(&self, target: usize) {
        let mut g = self.n.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        while *g < target {
            g = self.cv.wait(g).unwrap_or_else(|poisoned| poisoned.into_inner());
        }
        *g = 0;
    }
}

impl Default for DoneLatch {
    fn default() -> DoneLatch {
        DoneLatch::new()
    }
}

/// State shared between the coordinator and the team threads.
struct TeamShared {
    mailboxes: Vec<Mailbox<ShardJob>>,
    /// Reused across layers AND jobs (std's `Barrier` resets itself once
    /// all participants pass) — the same per-layer rendezvous as the
    /// scoped reference implementation.
    barrier: Barrier,
    done: DoneLatch,
    /// The `ThreadId` each shard observed while running its most recent
    /// job — the thread-reuse conformance test reads this to prove no
    /// per-request spawning happens.
    last_tid: Vec<Mutex<Option<std::thread::ThreadId>>>,
}

/// A [`ShardedModel`] driven by a **persistent shard team**: S threads
/// spawned once at construction, parked on mailbox condvars between
/// requests, running the identical `ShardedModel::shard_pass` as the
/// scoped reference — so outputs are bit-for-bit equal to both the scoped
/// sharded forward and the replicated [`SparseModel::forward`], with zero
/// thread spawns per request.
///
/// Forwards are serialized by an internal mutex (the team is one physical
/// resource); a worker pool in front of this engine therefore adds
/// batching/packing parallelism, not forward parallelism. Stop/start
/// lifecycle: the team parks when idle and is torn down (Stop message per
/// mailbox + join) when the engine drops.
///
/// The stack is epoch-published: the team threads hold **no** model —
/// every job carries a pointer to the submitting scratch's epoch stack
/// (see [`ForwardJob::model`]), so a swap never touches the team. An
/// in-flight job drains on its old epoch behind the completion latch; the
/// team threads, barrier, and mailboxes all survive the swap (the
/// thread-constancy conformance test still holds across swaps).
pub struct PersistentShardedEngine {
    cell: EpochCell<ShardedModel>,
    shards: usize,
    shared: Arc<TeamShared>,
    team: Vec<JoinHandle<()>>,
    /// Serializes forwards: exactly one job owns the team at a time.
    job: Mutex<()>,
}

impl PersistentShardedEngine {
    /// Shard `model` with a stored-weight-balanced plan and spawn the
    /// team. Fails like [`ShardedModel::from_model`] (typed
    /// [`super::shard::ShardPlanError`] wrapped in `anyhow`).
    pub fn from_model(model: &SparseModel, shards: usize) -> Result<PersistentShardedEngine> {
        PersistentShardedEngine::new(Arc::new(ShardedModel::from_model(model, shards)?))
    }

    /// Spawn a persistent team for a pre-built (possibly custom-planned)
    /// [`ShardedModel`].
    pub fn new(model: Arc<ShardedModel>) -> Result<PersistentShardedEngine> {
        let shards = model.shards();
        let shared = Arc::new(TeamShared {
            mailboxes: (0..shards).map(|_| Mailbox::new()).collect(),
            barrier: Barrier::new(shards),
            done: DoneLatch::new(),
            last_tid: (0..shards).map(|_| Mutex::new(None)).collect(),
        });
        let mut team = Vec::with_capacity(shards);
        for si in 0..shards {
            let shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("srigl-shard-{si}"))
                .spawn(move || shard_thread(&shared, si))
                .map_err(|e| anyhow::anyhow!("spawning shard thread {si}: {e}"))?;
            team.push(handle);
        }
        Ok(PersistentShardedEngine {
            cell: EpochCell::new(0, model),
            shards,
            shared,
            team,
            job: Mutex::new(()),
        })
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The currently published scoped-spawn reference model this team
    /// executes.
    pub fn sharded(&self) -> Arc<ShardedModel> {
        self.cell.current().1
    }

    /// Number of long-lived team threads (== shards for the team's whole
    /// lifetime — there is no per-request spawning to count).
    pub fn team_size(&self) -> usize {
        self.team.len()
    }

    /// The `ThreadId` each shard ran its most recent job on (`None` before
    /// the first forward). The conformance suite asserts these stay
    /// constant across forwards — with per-request scoped spawning every
    /// forward would mint fresh `ThreadId`s, which Rust guarantees are
    /// never reused within a process.
    pub fn last_shard_threads(&self) -> Vec<Option<std::thread::ThreadId>> {
        self.shared
            .last_tid
            .iter()
            .map(|m| *m.lock().unwrap_or_else(|poisoned| poisoned.into_inner()))
            .collect()
    }
}

/// Drop guard: a panic that unwinds out of a shard job cannot be
/// propagated (the coordinator is blocked on the latch, siblings on the
/// barrier) — the team would wedge silently, holding the job mutex and
/// hanging every future forward. Inputs and scratch shapes are validated
/// coordinator-side before a job is posted, so reaching this means a
/// genuine kernel bug; abort loudly instead of deadlocking the server.
struct AbortOnPanic(usize);

impl Drop for AbortOnPanic {
    fn drop(&mut self) {
        if std::thread::panicking() {
            crate::util::log::warn(
                "shard",
                &format!(
                    "srigl-shard-{}: panic inside a shard job; team state is unrecoverable, aborting",
                    self.0
                ),
            );
            std::process::abort();
        }
    }
}

fn shard_thread(shared: &TeamShared, si: usize) {
    loop {
        match shared.mailboxes[si].take() {
            ShardJob::Stop => return,
            ShardJob::Forward(job) => {
                let _abort_guard = AbortOnPanic(si);
                *shared.last_tid[si].lock().unwrap_or_else(|poisoned| poisoned.into_inner()) =
                    Some(std::thread::current().id());
                // SAFETY: the coordinator blocks on the completion latch
                // (holding the job mutex) until this shard arrives, so the
                // epoch's model (kept alive by the submitting scratch's
                // `Arc`), the input, the ping-pong buffers, and this
                // shard's private staging slice all outlive the accesses
                // below; `stage` is referenced by this thread only.
                let model = unsafe { &*job.model };
                let x = unsafe { std::slice::from_raw_parts(job.x, job.x_len) };
                let stage = unsafe { std::slice::from_raw_parts_mut(job.stage, job.stage_len) };
                let (buf_a, buf_b) = unsafe { (&*job.buf_a, &*job.buf_b) };
                model.shard_pass(si, x, job.batch, stage, buf_a, buf_b, &shared.barrier, job.threads);
                shared.done.arrive();
            }
        }
    }
}

impl Engine for PersistentShardedEngine {
    type Scratch = ShardedEpochScratch;

    fn scratch(&self, max_batch: usize) -> ShardedEpochScratch {
        let (epoch, model) = self.cell.current();
        ShardedEpochScratch { epoch, inner: model.make_scratch(max_batch), model }
    }

    fn forward<'s>(
        &self,
        x: &[f32],
        batch: usize,
        s: &'s mut ShardedEpochScratch,
        threads: usize,
    ) -> &'s [f32] {
        // The scratch's epoch stack, not the cell's: the job is atomic on
        // the epoch the scratch was built for, and the `Arc` held by the
        // scratch keeps that stack alive while the team drains it even if
        // a swap publishes a successor mid-job.
        let ShardedEpochScratch { model, inner, .. } = s;
        assert!(batch >= 1, "batch must be >= 1");
        assert!(
            batch <= inner.max_batch(),
            "batch {batch} exceeds scratch capacity {}",
            inner.max_batch()
        );
        assert_eq!(x.len(), batch * model.in_width(), "input size mismatch");
        let shards = model.shards();
        assert_eq!(shards, self.team.len(), "epoch re-plan must preserve the shard count");
        // Validate the scratch COORDINATOR-SIDE before any job is posted:
        // a too-small workspace (built from a different model) must panic
        // here, not inside a team thread where unwinding would wedge the
        // barrier and the latch.
        model.assert_scratch_fits(inner, batch);
        // One job owns the team at a time (concurrent pool workers queue
        // here); the guard is held until every shard reports done, which
        // is what keeps the raw pointers below valid. A poisoned job
        // mutex means a coordinator panicked with the team mid-job —
        // mailbox slots and the latch count are then unknowable, so abort
        // loudly (the shard-side twin of AbortOnPanic) instead of
        // wedging every future forward.
        let _job = self.job.lock().unwrap_or_else(|_poisoned| {
            crate::util::log::warn(
                "engine",
                "shard-team job mutex poisoned (coordinator panicked mid-job); \
                 team state is unrecoverable, aborting",
            );
            std::process::abort();
        });
        let model_ptr: *const ShardedModel = Arc::as_ptr(model);
        let buf_a: *const SharedBuf = &inner.a;
        let buf_b: *const SharedBuf = &inner.b;
        for (si, stage) in inner.stage.iter_mut().enumerate() {
            self.shared.mailboxes[si].put(ShardJob::Forward(ForwardJob {
                model: model_ptr,
                x: x.as_ptr(),
                x_len: x.len(),
                batch,
                threads,
                buf_a,
                buf_b,
                stage: stage.as_mut_ptr(),
                stage_len: stage.len(),
            }));
        }
        self.shared.done.wait_and_reset(shards);
        // SAFETY: every shard arrived at the latch — no write is in
        // flight, and we hold &mut scratch.
        unsafe { model.final_buf(inner).read(batch * model.out_width()) }
    }

    fn in_width(&self) -> usize {
        self.cell.current().1.in_width()
    }

    fn out_width(&self) -> usize {
        self.cell.current().1.out_width()
    }

    fn describe(&self) -> String {
        format!("{} (persistent team)", self.cell.current().1.describe())
    }

    fn storage_bytes(&self) -> usize {
        self.cell.current().1.storage_bytes()
    }

    fn epoch(&self) -> u64 {
        self.cell.epoch()
    }

    fn swap(&self, epoch: ModelEpoch) -> Result<u64> {
        swap_sharded(&self.cell, self.shards, epoch)
    }

    fn ensure_current(&self, scratch: &mut ShardedEpochScratch, max_batch: usize) -> u64 {
        if scratch.epoch != self.cell.epoch() {
            *scratch = self.scratch(max_batch);
        }
        scratch.epoch
    }
}

impl Drop for PersistentShardedEngine {
    fn drop(&mut self) {
        // &mut self: no forward can be in flight. Park -> Stop -> join.
        for mb in &self.shared.mailboxes {
            mb.put(ShardJob::Stop);
        }
        for handle in self.team.drain(..) {
            let _ = handle.join();
        }
    }
}

// ---------------------------------------------------------------------------
// SwappableEngine — one umbrella over every hot-swappable strategy
// ---------------------------------------------------------------------------

/// The serving front door for live-reloadable deployments: one concrete
/// type over every swappable strategy, so `serve-model --reload`, the
/// SIGHUP watcher, and `srigl train --serve` all hold an
/// `Arc<SwappableEngine>` and call [`Engine::swap`] without caring which
/// execution strategy is underneath. Built by
/// [`EngineBuilder::build_swappable`].
pub enum SwappableEngine {
    Replicated(ReplicatedEngine),
    Scoped(ScopedShardedEngine),
    Persistent(PersistentShardedEngine),
}

/// Workspace for [`SwappableEngine`] — mirrors the engine variant. A
/// scratch only ever returns to the engine that built it (workers own
/// their scratch), so a variant mismatch is a logic bug and panics.
pub enum SwappableScratch {
    Replicated(EpochScratch),
    Sharded(ShardedEpochScratch),
}

impl SwappableScratch {
    /// The epoch this workspace is pinned to.
    pub fn epoch(&self) -> u64 {
        match self {
            SwappableScratch::Replicated(s) => s.epoch(),
            SwappableScratch::Sharded(s) => s.epoch(),
        }
    }
}

impl Engine for SwappableEngine {
    type Scratch = SwappableScratch;

    fn scratch(&self, max_batch: usize) -> SwappableScratch {
        match self {
            SwappableEngine::Replicated(e) => SwappableScratch::Replicated(e.scratch(max_batch)),
            SwappableEngine::Scoped(e) => SwappableScratch::Sharded(e.scratch(max_batch)),
            SwappableEngine::Persistent(e) => SwappableScratch::Sharded(e.scratch(max_batch)),
        }
    }

    fn forward<'s>(
        &self,
        x: &[f32],
        batch: usize,
        s: &'s mut SwappableScratch,
        threads: usize,
    ) -> &'s [f32] {
        match (self, s) {
            (SwappableEngine::Replicated(e), SwappableScratch::Replicated(s)) => {
                e.forward(x, batch, s, threads)
            }
            (SwappableEngine::Scoped(e), SwappableScratch::Sharded(s)) => {
                e.forward(x, batch, s, threads)
            }
            (SwappableEngine::Persistent(e), SwappableScratch::Sharded(s)) => {
                e.forward(x, batch, s, threads)
            }
            _ => panic!("SwappableScratch does not match its SwappableEngine variant"),
        }
    }

    fn in_width(&self) -> usize {
        match self {
            SwappableEngine::Replicated(e) => e.in_width(),
            SwappableEngine::Scoped(e) => e.in_width(),
            SwappableEngine::Persistent(e) => e.in_width(),
        }
    }

    fn out_width(&self) -> usize {
        match self {
            SwappableEngine::Replicated(e) => e.out_width(),
            SwappableEngine::Scoped(e) => e.out_width(),
            SwappableEngine::Persistent(e) => e.out_width(),
        }
    }

    fn describe(&self) -> String {
        match self {
            SwappableEngine::Replicated(e) => e.describe(),
            SwappableEngine::Scoped(e) => e.describe(),
            SwappableEngine::Persistent(e) => e.describe(),
        }
    }

    fn storage_bytes(&self) -> usize {
        match self {
            SwappableEngine::Replicated(e) => e.storage_bytes(),
            SwappableEngine::Scoped(e) => e.storage_bytes(),
            SwappableEngine::Persistent(e) => e.storage_bytes(),
        }
    }

    fn epoch(&self) -> u64 {
        match self {
            SwappableEngine::Replicated(e) => e.epoch(),
            SwappableEngine::Scoped(e) => e.epoch(),
            SwappableEngine::Persistent(e) => e.epoch(),
        }
    }

    fn swap(&self, epoch: ModelEpoch) -> Result<u64> {
        match self {
            SwappableEngine::Replicated(e) => e.swap(epoch),
            SwappableEngine::Scoped(e) => e.swap(epoch),
            SwappableEngine::Persistent(e) => e.swap(epoch),
        }
    }

    fn ensure_current(&self, scratch: &mut SwappableScratch, max_batch: usize) -> u64 {
        match (self, scratch) {
            (SwappableEngine::Replicated(e), SwappableScratch::Replicated(s)) => {
                e.ensure_current(s, max_batch)
            }
            (SwappableEngine::Scoped(e), SwappableScratch::Sharded(s)) => {
                e.ensure_current(s, max_batch)
            }
            (SwappableEngine::Persistent(e), SwappableScratch::Sharded(s)) => {
                e.ensure_current(s, max_batch)
            }
            _ => panic!("SwappableScratch does not match its SwappableEngine variant"),
        }
    }
}

// ---------------------------------------------------------------------------
// EngineBuilder
// ---------------------------------------------------------------------------

/// Whether (and how) a serving stack is int8-quantized at build time —
/// the `quant=` knob of the arena spec and the serving CLI. Quantization
/// is a *model transform* ([`SparseModel::quantized`]), applied by
/// [`EngineBuilder::prepare_model`] before the stack reaches an engine,
/// so every execution strategy (replicated/scoped/persistent, swappable
/// or not) serves the quantized weights identically.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMode {
    /// Serve the stack's own (f32) representations unchanged.
    Off,
    /// Quantize every layer to the int8 row-gather driver
    /// ([`crate::inference::QuantizedLayer`]).
    Rows,
    /// Quantize every layer to the int8 batch-tiled driver
    /// ([`crate::inference::QuantizedTiledLayer`]).
    Tiled,
}

impl QuantMode {
    pub fn parse(s: &str) -> Result<QuantMode> {
        match s {
            "off" | "none" | "f32" => Ok(QuantMode::Off),
            "rows" | "quantized" | "int8" => Ok(QuantMode::Rows),
            "tiled" | "quantized-tiled" => Ok(QuantMode::Tiled),
            other => bail!("unknown quant mode {other:?} (off|rows|tiled)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QuantMode::Off => "off",
            QuantMode::Rows => "rows",
            QuantMode::Tiled => "tiled",
        }
    }
}

/// The single construction path for serving engines and the knobs every
/// serving surface shares. `serve`/`serve_model`/`serve_target`
/// ([`super::server`]), [`super::frontend::spawn`], the `serve-model` CLI,
/// the manifest `"serve"` section, and the serve benches all configure
/// through this — there is no other way to wire up a serving stack.
///
/// Fields are public for reading (banners, stats); prefer the chainable
/// setters when constructing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EngineBuilder {
    /// Pool workers draining the request queue. The in-process benchmark
    /// floors this at 1; the front-end accepts `0` (ingestion-only — used
    /// by the deterministic backpressure tests).
    pub workers: usize,
    /// Per-pop batch-limit policy; `Batching::cap()` sizes worker scratch
    /// and bounds the rows one request may carry.
    pub batching: Batching,
    /// Tensor-parallel shards per forward. `<= 1` builds a
    /// [`ReplicatedEngine`]; `> 1` builds a [`PersistentShardedEngine`]
    /// (long-lived team, typically paired with `workers: 1` since the
    /// parallelism lives inside the request).
    pub shards: usize,
    /// Bounded request-queue capacity (requests, not rows).
    pub queue_capacity: usize,
    /// Result-cache entries; `0` disables caching.
    pub cache_capacity: usize,
    /// Per-connection egress-queue capacity (response frames) — a slow
    /// client can absorb at most this many computed responses before
    /// overflow converts them to `Busy` (see `docs/WIRE.md`).
    pub egress_capacity: usize,
    /// Intra-op threads per worker (with sharding: intra-*shard*).
    pub threads: usize,
    /// Backoff hint sent with `Busy` rejections.
    pub retry_after_ms: u32,
    /// Live-connection cap; `0` means unlimited. The front-end's accept
    /// loop refuses connections beyond this with a best-effort `Busy`
    /// frame before any reader thread is spawned (counted in the
    /// `connections_rejected` metric).
    pub max_connections: usize,
    /// Int8 quantization applied to the stack by
    /// [`EngineBuilder::prepare_model`] before engine construction.
    pub quant: QuantMode,
    /// Per-engine microkernel override ([`crate::kernels::KernelKind`]);
    /// `None` serves on the process-wide auto selection. Set by the
    /// arena's per-side `kernel=` key so f32-vs-int8 (or avx2-vs-scalar)
    /// duels can share one process.
    pub kernel: Option<crate::kernels::KernelKind>,
}

impl Default for EngineBuilder {
    fn default() -> EngineBuilder {
        EngineBuilder {
            workers: 4,
            batching: Batching::Adaptive { cap: 8 },
            shards: 1,
            queue_capacity: 1024,
            cache_capacity: 1024,
            egress_capacity: 64,
            threads: 1,
            retry_after_ms: 2,
            max_connections: 0,
            quant: QuantMode::Off,
            kernel: None,
        }
    }
}

impl EngineBuilder {
    pub fn new() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// Strict batch-1 service on one worker — the paper's online-inference
    /// setting (Fig. 4a).
    pub fn online() -> EngineBuilder {
        EngineBuilder::new().workers(1).fixed_batch(1)
    }

    /// Defaults from a manifest stack's `"serve"` knobs (CLI flags layer
    /// on top via the plain setters).
    pub fn from_knobs(knobs: &ServeKnobs) -> EngineBuilder {
        let b = EngineBuilder::new();
        EngineBuilder {
            batching: if knobs.adaptive {
                Batching::Adaptive { cap: knobs.max_batch.max(1) }
            } else {
                Batching::Fixed(knobs.max_batch.max(1))
            },
            shards: knobs.shards,
            queue_capacity: knobs.queue_capacity,
            cache_capacity: knobs.cache_capacity,
            egress_capacity: knobs.egress_capacity,
            max_connections: knobs.max_connections,
            ..b
        }
    }

    pub fn workers(mut self, workers: usize) -> EngineBuilder {
        self.workers = workers;
        self
    }

    /// Fixed batch limit `n` per pop.
    pub fn fixed_batch(mut self, n: usize) -> EngineBuilder {
        self.batching = Batching::Fixed(n.max(1));
        self
    }

    /// Adaptive (EWMA-of-queue-depth) batching up to `cap`.
    pub fn adaptive(mut self, cap: usize) -> EngineBuilder {
        self.batching = Batching::Adaptive { cap: cap.max(1) };
        self
    }

    pub fn batching(mut self, batching: Batching) -> EngineBuilder {
        self.batching = batching;
        self
    }

    pub fn shards(mut self, shards: usize) -> EngineBuilder {
        self.shards = shards;
        self
    }

    pub fn queue_capacity(mut self, n: usize) -> EngineBuilder {
        self.queue_capacity = n;
        self
    }

    pub fn cache_capacity(mut self, n: usize) -> EngineBuilder {
        self.cache_capacity = n;
        self
    }

    pub fn egress_capacity(mut self, n: usize) -> EngineBuilder {
        self.egress_capacity = n;
        self
    }

    pub fn threads(mut self, threads: usize) -> EngineBuilder {
        self.threads = threads;
        self
    }

    pub fn retry_after_ms(mut self, ms: u32) -> EngineBuilder {
        self.retry_after_ms = ms;
        self
    }

    /// Cap live connections (`0` = unlimited); see the field docs.
    pub fn max_connections(mut self, n: usize) -> EngineBuilder {
        self.max_connections = n;
        self
    }

    /// Int8-quantize the stack at build time (see [`QuantMode`]).
    pub fn quant(mut self, mode: QuantMode) -> EngineBuilder {
        self.quant = mode;
        self
    }

    /// Force a specific microkernel kind for this engine's stack
    /// (`None` = the process-wide auto selection).
    pub fn kernel(mut self, kind: Option<crate::kernels::KernelKind>) -> EngineBuilder {
        self.kernel = kind;
        self
    }

    /// Apply the builder's model transforms — int8 quantization
    /// (`quant=`) then the microkernel re-stamp (`kernel=`) — returning
    /// the stack engines should be built from. With both knobs at their
    /// defaults this is a cheap `Arc` clone. Fails when a layer cannot be
    /// quantized (no condensed structure / width over the u16 index) or
    /// the forced kernel kind is not available on this CPU — both are
    /// startup errors, never a serving panic.
    pub fn prepare_model(&self, model: &Arc<SparseModel>) -> Result<Arc<SparseModel>> {
        let mut out = Arc::clone(model);
        match self.quant {
            QuantMode::Off => {}
            QuantMode::Rows => out = Arc::new(out.quantized(false)?),
            QuantMode::Tiled => out = Arc::new(out.quantized(true)?),
        }
        if let Some(kind) = self.kernel {
            if !kind.available() {
                bail!("kernel={} is not available on this CPU", kind.name());
            }
            out = Arc::new(out.with_kernel(crate::kernels::Microkernel::of(kind))?);
        }
        Ok(out)
    }

    /// Upper bound on any batch the configured policy can produce — what
    /// scratch buffers are sized for.
    pub fn max_batch(&self) -> usize {
        self.batching.cap()
    }

    /// True when `EngineBuilder::shards` selects the persistent sharded
    /// engine over the replicated one.
    pub fn is_sharded(&self) -> bool {
        self.shards > 1
    }

    /// Build the replicated engine for `model`.
    pub fn build_replicated(&self, model: Arc<SparseModel>) -> ReplicatedEngine {
        ReplicatedEngine::new(model)
    }

    /// Build (and spawn) the persistent shard team for `model` using the
    /// builder's shard count.
    pub fn build_persistent_sharded(&self, model: &SparseModel) -> Result<PersistentShardedEngine> {
        PersistentShardedEngine::from_model(model, self.shards.max(1))
    }

    /// Build the hot-swappable umbrella engine: the persistent shard team
    /// when `shards > 1`, the replicated engine otherwise — the same
    /// strategy selection as the immutable build paths, behind one type
    /// that supports [`Engine::swap`].
    pub fn build_swappable(&self, model: Arc<SparseModel>) -> Result<SwappableEngine> {
        if self.is_sharded() {
            Ok(SwappableEngine::Persistent(PersistentShardedEngine::from_model(
                &model,
                self.shards,
            )?))
        } else {
            Ok(SwappableEngine::Replicated(ReplicatedEngine::new(model)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::model::{Activation, LayerSpec, Repr};
    use crate::inference::LayerBundle;
    use crate::util::rng::Rng;

    fn model3_seed(repr: Repr, seed: u64) -> SparseModel {
        let spec = |n, act| LayerSpec {
            n,
            repr,
            sparsity: 0.9,
            ablated_frac: 0.25,
            activation: act,
        };
        SparseModel::synth(
            64,
            &[
                spec(48, Activation::Relu),
                spec(32, Activation::Relu),
                spec(16, Activation::Identity),
            ],
            seed,
        )
        .unwrap()
    }

    fn model3(repr: Repr) -> SparseModel {
        model3_seed(repr, 11)
    }

    fn run<E: Engine>(e: &E, x: &[f32], batch: usize) -> Vec<f32> {
        let mut s = e.scratch(batch);
        e.forward(x, batch, &mut s, 1).to_vec()
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn replicated_engine_matches_model() {
        let m = Arc::new(model3(Repr::Condensed));
        let engine = ReplicatedEngine::new(Arc::clone(&m));
        assert_eq!(engine.in_width(), 64);
        assert_eq!(engine.out_width(), 16);
        assert_eq!(engine.storage_bytes(), m.storage_bytes());
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..3 * 64).map(|_| rng.normal_f32()).collect();
        assert_bits_eq(&run(&engine, &x, 3), &m.forward_vec(&x, 3, 1), "replicated");
    }

    #[test]
    fn kernel_engine_matches_direct_forward() {
        let bundle = LayerBundle::synth(24, 32, 0.9, 0.2, 3);
        let engine = KernelEngine::new(&bundle.condensed);
        assert_eq!(engine.in_width(), 32);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..2 * 32).map(|_| rng.normal_f32()).collect();
        let mut want = vec![0f32; 2 * bundle.condensed.out_width()];
        bundle.condensed.forward(&x, 2, &mut want, 1);
        assert_bits_eq(&run(&engine, &x, 2), &want, "kernel engine");
        assert!(engine.describe().contains("condensed"));
    }

    #[test]
    fn persistent_team_matches_scoped_and_replicated() {
        // full cross-product lives in rust/tests/engine_conformance.rs
        let m = model3(Repr::Condensed);
        let scoped = ShardedModel::from_model(&m, 2).unwrap();
        let team = PersistentShardedEngine::from_model(&m, 2).unwrap();
        assert_eq!(team.shards(), 2);
        assert_eq!(team.team_size(), 2);
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..4 * 64).map(|_| rng.normal_f32()).collect();
        let want = m.forward_vec(&x, 4, 1);
        assert_bits_eq(&run(&scoped, &x, 4), &want, "scoped vs replicated");
        assert_bits_eq(&run(&team, &x, 4), &want, "persistent vs replicated");
    }

    #[test]
    fn persistent_team_scratch_reuse_and_varying_batch() {
        let m = model3(Repr::Structured);
        let team = PersistentShardedEngine::from_model(&m, 3).unwrap();
        let mut s = team.scratch(8);
        let mut rng = Rng::new(9);
        for &batch in &[1usize, 5, 8, 1, 3] {
            let x: Vec<f32> = (0..batch * 64).map(|_| rng.normal_f32()).collect();
            let want = m.forward_vec(&x, batch, 1);
            let got = team.forward(&x, batch, &mut s, 1).to_vec();
            assert_bits_eq(&got, &want, &format!("batch {batch}"));
        }
    }

    #[test]
    fn persistent_team_serializes_concurrent_forwards() {
        let m = Arc::new(model3(Repr::Condensed));
        let team = Arc::new(PersistentShardedEngine::from_model(&m, 2).unwrap());
        std::thread::scope(|s| {
            for t in 0..3u64 {
                let team = Arc::clone(&team);
                let m = Arc::clone(&m);
                s.spawn(move || {
                    let mut scratch = team.scratch(4);
                    let mut rng = Rng::new(0xC0 + t);
                    for i in 0..20usize {
                        let batch = 1 + i % 4;
                        let x: Vec<f32> = (0..batch * 64).map(|_| rng.normal_f32()).collect();
                        let want = m.forward_vec(&x, batch, 1);
                        let got = team.forward(&x, batch, &mut scratch, 1).to_vec();
                        assert_bits_eq(&got, &want, &format!("caller {t} iter {i}"));
                    }
                });
            }
        });
    }

    #[test]
    fn persistent_team_rejects_oversized_shard_count() {
        // narrowest layer has 16 neurons
        let m = model3(Repr::Condensed);
        assert!(PersistentShardedEngine::from_model(&m, 17).is_err());
    }

    #[test]
    fn dropping_idle_and_used_teams_terminates() {
        let m = model3(Repr::Dense);
        // never-used team
        drop(PersistentShardedEngine::from_model(&m, 3).unwrap());
        // used team
        let team = PersistentShardedEngine::from_model(&m, 3).unwrap();
        let x = vec![0.25f32; 64];
        let _ = run(&team, &x, 1);
        drop(team); // Stop + join must not hang
    }

    #[test]
    fn builder_defaults_and_knobs() {
        let b = EngineBuilder::new();
        assert_eq!(b.workers, 4);
        assert_eq!(b.shards, 1);
        assert!(!b.is_sharded());
        assert_eq!(b.max_batch(), 8);

        let online = EngineBuilder::online();
        assert_eq!(online.workers, 1);
        assert_eq!(online.batching, Batching::Fixed(1));

        let knobs = ServeKnobs {
            queue_capacity: 64,
            cache_capacity: 0,
            egress_capacity: 7,
            adaptive: false,
            max_batch: 4,
            shards: 3,
            max_connections: 5,
        };
        let b = EngineBuilder::from_knobs(&knobs).workers(2).threads(2).retry_after_ms(9);
        assert_eq!(b.batching, Batching::Fixed(4));
        assert_eq!(b.queue_capacity, 64);
        assert_eq!(b.cache_capacity, 0);
        assert_eq!(b.egress_capacity, 7);
        assert_eq!(b.shards, 3);
        assert!(b.is_sharded());
        assert_eq!(b.workers, 2);
        assert_eq!(b.threads, 2);
        assert_eq!(b.retry_after_ms, 9);
        assert_eq!(b.max_connections, 5);
        assert_eq!(EngineBuilder::new().max_connections, 0, "default: unlimited");
    }

    #[test]
    fn swap_publishes_new_epoch_and_stale_scratch_stays_atomic() {
        let m0 = Arc::new(model3_seed(Repr::Condensed, 11));
        let m1 = Arc::new(model3_seed(Repr::Condensed, 23));
        let engine = ReplicatedEngine::new(Arc::clone(&m0));
        assert_eq!(engine.epoch(), 0);
        let mut s = engine.scratch(2);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..2 * 64).map(|_| rng.normal_f32()).collect();

        assert_eq!(engine.swap(ModelEpoch::new(1, Arc::clone(&m1))).unwrap(), 1);
        assert_eq!(engine.epoch(), 1);
        // The stale scratch keeps computing on its pinned epoch...
        let got_old = engine.forward(&x, 2, &mut s, 1).to_vec();
        assert_bits_eq(&got_old, &m0.forward_vec(&x, 2, 1), "stale scratch = epoch 0");
        assert_eq!(s.epoch(), 0);
        // ...until ensure_current rebuilds it at a batch boundary.
        assert_eq!(engine.ensure_current(&mut s, 2), 1);
        assert_eq!(s.epoch(), 1);
        let got_new = engine.forward(&x, 2, &mut s, 1).to_vec();
        assert_bits_eq(&got_new, &m1.forward_vec(&x, 2, 1), "rebuilt scratch = epoch 1");
    }

    #[test]
    fn swap_rejects_width_change_and_stale_ids() {
        let m = Arc::new(model3(Repr::Condensed));
        let engine = ReplicatedEngine::new(Arc::clone(&m));
        // non-monotonic id
        assert!(engine.swap(ModelEpoch::new(0, Arc::clone(&m))).is_err());
        // input-width change
        let narrow = Arc::new(
            SparseModel::synth(
                32,
                &[LayerSpec {
                    n: 16,
                    repr: Repr::Condensed,
                    sparsity: 0.9,
                    ablated_frac: 0.0,
                    activation: Activation::Identity,
                }],
                5,
            )
            .unwrap(),
        );
        assert!(engine.swap(ModelEpoch::new(1, narrow)).is_err());
        assert_eq!(engine.epoch(), 0, "failed swaps must not publish");
        // immutable engines refuse outright
        assert!(m.swap(ModelEpoch::new(1, Arc::clone(&m))).is_err());
    }

    #[test]
    fn persistent_team_swaps_without_respawning_threads() {
        let m0 = model3_seed(Repr::Condensed, 11);
        let m1 = Arc::new(model3_seed(Repr::Condensed, 23));
        let team = PersistentShardedEngine::from_model(&m0, 2).unwrap();
        let mut s = team.scratch(4);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..4 * 64).map(|_| rng.normal_f32()).collect();
        let _ = team.forward(&x, 4, &mut s, 1);
        let tids_before = team.last_shard_threads();

        assert_eq!(team.swap(ModelEpoch::new(1, Arc::clone(&m1))).unwrap(), 1);
        assert_eq!(team.ensure_current(&mut s, 4), 1);
        let got = team.forward(&x, 4, &mut s, 1).to_vec();
        assert_bits_eq(&got, &m1.forward_vec(&x, 4, 1), "post-swap team = new stack");
        assert_eq!(team.last_shard_threads(), tids_before, "swap must not respawn the team");
        assert_eq!(team.team_size(), 2);
        // a stack too narrow for the shard count leaves the old epoch up
        let narrow = Arc::new(
            SparseModel::synth(
                64,
                &[LayerSpec {
                    n: 1,
                    repr: Repr::Condensed,
                    sparsity: 0.5,
                    ablated_frac: 0.0,
                    activation: Activation::Identity,
                }],
                5,
            )
            .unwrap(),
        );
        assert!(team.swap(ModelEpoch::new(2, narrow)).is_err());
        assert_eq!(team.epoch(), 1);
    }

    #[test]
    fn swappable_umbrella_dispatches_and_swaps() {
        let m0 = Arc::new(model3_seed(Repr::Condensed, 11));
        let m1 = Arc::new(model3_seed(Repr::Condensed, 23));
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..2 * 64).map(|_| rng.normal_f32()).collect();
        for builder in [EngineBuilder::new(), EngineBuilder::new().shards(2)] {
            let e = builder.build_swappable(Arc::clone(&m0)).unwrap();
            assert_bits_eq(&run(&e, &x, 2), &m0.forward_vec(&x, 2, 1), "epoch 0");
            assert_eq!(e.swap(ModelEpoch::new(1, Arc::clone(&m1))).unwrap(), 1);
            let mut s = e.scratch(2);
            assert_eq!(s.epoch(), 1);
            let got = e.forward(&x, 2, &mut s, 1).to_vec();
            assert_bits_eq(&got, &m1.forward_vec(&x, 2, 1), "epoch 1");
        }
        let scoped = ScopedShardedEngine::from_model(&m0, 2).unwrap();
        assert_eq!(scoped.shards(), 2);
        assert_bits_eq(&run(&scoped, &x, 2), &m0.forward_vec(&x, 2, 1), "scoped epoch 0");
        assert_eq!(scoped.swap(ModelEpoch::new(1, Arc::clone(&m1))).unwrap(), 1);
        assert_bits_eq(&run(&scoped, &x, 2), &m1.forward_vec(&x, 2, 1), "scoped epoch 1");
    }

    #[test]
    fn prepare_model_quantizes_and_restamps() {
        let m = Arc::new(model3(Repr::Condensed));
        // defaults: a cheap Arc clone, same stack
        let same = EngineBuilder::new().prepare_model(&m).unwrap();
        assert!(Arc::ptr_eq(&m, &same));
        // quantized: int8 storage, same widths, bit-for-bit row-vs-tiled
        let rows = EngineBuilder::new().quant(QuantMode::Rows).prepare_model(&m).unwrap();
        let tiled = EngineBuilder::new().quant(QuantMode::Tiled).prepare_model(&m).unwrap();
        assert_eq!(rows.in_width(), m.in_width());
        assert_eq!(rows.out_width(), m.out_width());
        assert!(rows.storage_bytes() < m.storage_bytes(), "int8 must shrink the stack");
        assert!(rows.describe().contains("quantized"), "{}", rows.describe());
        assert!(tiled.describe().contains("quantized-tiled"), "{}", tiled.describe());
        let mut rng = Rng::new(6);
        let x: Vec<f32> = (0..3 * 64).map(|_| rng.normal_f32()).collect();
        assert_bits_eq(
            &rows.forward_vec(&x, 3, 1),
            &tiled.forward_vec(&x, 3, 1),
            "quant row vs tiled drivers",
        );
        // kernel= re-stamp: scalar is always available, and on the int8
        // path even a kind change keeps outputs bit-for-bit
        let scalar = EngineBuilder::new()
            .quant(QuantMode::Rows)
            .kernel(Some(crate::kernels::KernelKind::Scalar))
            .prepare_model(&m)
            .unwrap();
        assert_bits_eq(
            &scalar.forward_vec(&x, 3, 1),
            &rows.forward_vec(&x, 3, 1),
            "int8 is kind-invariant",
        );
        // a dense stack has no quantized form: startup error, not a panic
        let dense = Arc::new(model3(Repr::Dense));
        assert!(EngineBuilder::new().quant(QuantMode::Rows).prepare_model(&dense).is_err());
    }

    #[test]
    fn quant_mode_parses() {
        assert_eq!(QuantMode::parse("off").unwrap(), QuantMode::Off);
        assert_eq!(QuantMode::parse("rows").unwrap(), QuantMode::Rows);
        assert_eq!(QuantMode::parse("int8").unwrap(), QuantMode::Rows);
        assert_eq!(QuantMode::parse("tiled").unwrap(), QuantMode::Tiled);
        assert!(QuantMode::parse("fp4").is_err());
        for m in [QuantMode::Off, QuantMode::Rows, QuantMode::Tiled] {
            assert_eq!(QuantMode::parse(m.name()).unwrap(), m);
        }
    }

    #[test]
    fn builder_constructs_both_engine_kinds() {
        let m = Arc::new(model3(Repr::Condensed));
        let rep = EngineBuilder::new().build_replicated(Arc::clone(&m));
        let sh = EngineBuilder::new().shards(2).build_persistent_sharded(&m).unwrap();
        assert_eq!(rep.in_width(), sh.in_width());
        assert_eq!(rep.out_width(), sh.out_width());
        assert_eq!(rep.storage_bytes(), sh.storage_bytes(), "weights partition exactly");
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..2 * 64).map(|_| rng.normal_f32()).collect();
        assert_bits_eq(&run(&rep, &x, 2), &run(&sh, &x, 2), "builder engines agree");
    }
}
