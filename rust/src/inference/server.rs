//! Online-inference server: the L3 coordination piece for the paper's
//! §2 "Online inference" scenario — single-sample, latency-critical
//! requests served from a queue, plus a dynamic batcher for throughput
//! mode and a worker pool for multi-core scale-out (the vLLM-router-shaped
//! component of this repo).
//!
//! Architecture: a submitter thread enqueues requests at a configured rate
//! into a shared [`Injector`] queue; N workers drain it, coalescing up to
//! the configured batch limit per pop, run the selected [`Engine`] (a
//! whole [`SparseModel`] stack, a persistent shard team, or — via
//! [`KernelEngine`] — one bare layer representation) on per-worker typed
//! scratch, and record end-to-end latency per request. Per-worker latency
//! records are merged into one [`LatencyStats`] at the end.
//!
//! All knobs (workers, batching policy, shards, intra-op threads) come
//! from one [`EngineBuilder`] — the same configuration surface the socket
//! front-end, the CLI, and the manifest use.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::engine::{Engine, EngineBuilder, KernelEngine};
use super::{LinearKernel, SparseModel};
use crate::util::rng::Rng;
use crate::util::threadpool::Injector;

/// How a worker picks its per-pop batch limit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Batching {
    /// Always pop up to `n` requests.
    Fixed(usize),
    /// Pop up to `AdaptiveBatcher::next_batch(queue depth)`, never more
    /// than `cap` (which also sizes the per-worker scratch).
    Adaptive { cap: usize },
}

impl Batching {
    /// Upper bound on any batch this policy can produce — what scratch
    /// buffers must be sized for.
    pub fn cap(self) -> usize {
        match self {
            Batching::Fixed(n) => n.max(1),
            Batching::Adaptive { cap } => cap.max(1),
        }
    }
}

/// Shared adaptive batch-size controller: an exponentially weighted moving
/// average of the queue depth observed at each pop. Workers call
/// [`AdaptiveBatcher::next_batch`] with the current depth and get back the
/// batch limit to use for that pop, `ceil(ewma)` clamped to `[1, cap]`.
/// The EWMA is stored as f64 bits in an atomic so the controller is shared
/// lock-free across workers; the update is racy by design (a lost update
/// just means one pop sees a slightly stale depth estimate).
///
/// **Cold start**: the average is seeded from the *first observation*, not
/// from 0.0. A server that comes up already under load used to serve its
/// first pops at batch≈1 while the EWMA warmed up from zero toward the
/// real depth (≈1/α pops of under-batching); now the first pop lands on
/// the observed depth directly.
pub struct AdaptiveBatcher {
    cap: usize,
    alpha: f64,
    ewma_bits: AtomicU64,
}

impl AdaptiveBatcher {
    /// Default smoothing: new depth observations carry 25% weight.
    pub const DEFAULT_ALPHA: f64 = 0.25;

    pub fn new(cap: usize) -> AdaptiveBatcher {
        AdaptiveBatcher::with_alpha(cap, Self::DEFAULT_ALPHA)
    }

    pub fn with_alpha(cap: usize, alpha: f64) -> AdaptiveBatcher {
        AdaptiveBatcher {
            cap: cap.max(1),
            alpha: alpha.clamp(0.01, 1.0),
            // NaN = "no observation yet": the first next_batch seeds the
            // average at the observed depth instead of decaying up from 0.
            ewma_bits: AtomicU64::new(f64::NAN.to_bits()),
        }
    }

    /// Fold one queue-depth observation into the EWMA and return the batch
    /// limit for this pop. The first observation seeds the average.
    pub fn next_batch(&self, depth: usize) -> usize {
        let prev = f64::from_bits(self.ewma_bits.load(Ordering::Relaxed));
        let e = if prev.is_nan() {
            depth as f64
        } else {
            (1.0 - self.alpha) * prev + self.alpha * depth as f64
        };
        self.ewma_bits.store(e.to_bits(), Ordering::Relaxed);
        (e.ceil() as usize).clamp(1, self.cap)
    }

    /// Current depth estimate (diagnostics); NaN before the first
    /// observation.
    pub fn ewma(&self) -> f64 {
        f64::from_bits(self.ewma_bits.load(Ordering::Relaxed))
    }
}

/// The synthetic-load half of a serving run: how many requests to submit
/// and at what Poisson rate. Execution knobs (workers, batching, shards,
/// threads) live in [`EngineBuilder`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub n_requests: usize,
    /// Mean inter-arrival time; exponential distribution (Poisson load).
    pub mean_interarrival: Duration,
    pub seed: u64,
}

/// Raw per-worker serving record; merged via [`LatencyStats::from_workers`].
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub latencies_us: Vec<f64>,
    pub served: usize,
    pub batches: usize,
}

#[derive(Clone, Debug)]
pub struct LatencyStats {
    /// Finite latency samples the aggregates below are computed over.
    pub n: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    pub throughput_rps: f64,
    pub mean_batch: f64,
    /// Samples excluded from every aggregate because they were NaN
    /// (a poisoned clock or a corrupted record). Nonzero means some
    /// upstream measurement is broken — but the stats path itself must
    /// keep serving (the old `partial_cmp(..).unwrap()` sort panicked the
    /// merge, killing a whole arena/bench run over one bad sample).
    pub nan_samples: usize,
}

impl LatencyStats {
    /// Merge per-worker records into aggregate statistics. Percentiles are
    /// exact: computed over the concatenation of all workers' *finite*
    /// samples; NaN samples are counted in [`LatencyStats::nan_samples`]
    /// and excluded (they would otherwise poison the sort, the mean, and
    /// every percentile). The sort uses `f64::total_cmp`, which is total
    /// over all floats — there is no comparison that can panic here.
    pub fn from_workers(workers: &[WorkerStats], wall_s: f64) -> LatencyStats {
        let mut sorted: Vec<f64> = Vec::new();
        let mut nan_samples = 0usize;
        for w in workers {
            for &v in &w.latencies_us {
                if v.is_nan() {
                    nan_samples += 1;
                } else {
                    sorted.push(v);
                }
            }
        }
        sorted.sort_by(f64::total_cmp);
        let n = sorted.len();
        let served: usize = workers.iter().map(|w| w.served).sum();
        let batches: usize = workers.iter().map(|w| w.batches).sum();
        LatencyStats {
            n,
            mean_us: sorted.iter().sum::<f64>() / n.max(1) as f64,
            p50_us: percentile(&sorted, 50.0),
            p95_us: percentile(&sorted, 95.0),
            p99_us: percentile(&sorted, 99.0),
            max_us: sorted.last().copied().unwrap_or(f64::NAN),
            throughput_rps: n as f64 / wall_s.max(1e-9),
            mean_batch: served as f64 / batches.max(1) as f64,
            nan_samples,
        }
    }

    /// The stats as a JSON object (non-finite values map to `null` so the
    /// output is always valid JSON) — the shared shape every persisted
    /// bench/arena record uses for a latency block.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj, Json};
        let fnum = |v: f64| if v.is_finite() { num(v) } else { Json::Null };
        obj(vec![
            ("n", num(self.n as f64)),
            ("mean_us", fnum(self.mean_us)),
            ("p50_us", fnum(self.p50_us)),
            ("p95_us", fnum(self.p95_us)),
            ("p99_us", fnum(self.p99_us)),
            ("max_us", fnum(self.max_us)),
            ("rps", fnum(self.throughput_rps)),
            ("mean_batch", fnum(self.mean_batch)),
            ("nan_samples", num(self.nan_samples as f64)),
        ])
    }
}

/// Percentile by linear interpolation between closest ranks
/// (`rank = p/100 * (n-1)`, the numpy/NIST default). The old nearest-rank
/// round-half-away-from-zero variant biased percentiles high — p50 of
/// 1..=100 reported 51.0 instead of 50.5.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        return sorted[lo];
    }
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

struct Request {
    x: Vec<f32>,
    t_submit: Instant,
}

/// Drive a single layer representation with a synthetic Poisson request
/// stream and return end-to-end latency statistics. Wraps the kernel in a
/// [`KernelEngine`] so it runs the same loop as whole stacks.
pub fn serve(layer: &dyn LinearKernel, builder: &EngineBuilder, cfg: &ServeConfig) -> LatencyStats {
    serve_target(&KernelEngine::new(layer), builder, cfg)
}

/// Drive a whole [`SparseModel`] stack through the serving loop.
/// `builder.shards > 1` re-materializes the stack as a
/// [`super::engine::PersistentShardedEngine`] (stored-weight-balanced
/// plan, long-lived team); otherwise the model itself serves replicated
/// across workers.
/// Fails only when the shard plan does
/// (`shards > narrowest layer width`, a typed
/// [`super::shard::ShardPlanError`]).
pub fn serve_model(
    model: &SparseModel,
    builder: &EngineBuilder,
    cfg: &ServeConfig,
) -> Result<LatencyStats> {
    if builder.is_sharded() {
        let team = builder.build_persistent_sharded(model)?;
        Ok(serve_target(&team, builder, cfg))
    } else {
        Ok(serve_target(model, builder, cfg))
    }
}

/// One Poisson inter-arrival gap: exponential with the configured mean,
/// clamped at 10x the mean so one extreme tail draw cannot stall the
/// submitter for unbounded time. (The old code clamped to an absolute
/// 10 ms — `gap.min(0.01)` — which silently floored any configured mean
/// above ~10 ms into a flood; the realized mean now tracks the configured
/// one for every `mean`.)
pub fn poisson_gap(mean: Duration, rng: &mut Rng) -> Duration {
    let mean_s = mean.as_secs_f64();
    let u = rng.uniform().max(1e-12);
    Duration::from_secs_f64((mean_s * -u.ln()).min(10.0 * mean_s))
}

/// The serving loop every configuration shares: Poisson submitter, shared
/// queue, `builder.workers` poppers (floored at 1), each with a private
/// typed scratch for the generic [`Engine`].
pub fn serve_target<E: Engine>(
    engine: &E,
    builder: &EngineBuilder,
    cfg: &ServeConfig,
) -> LatencyStats {
    let workers = builder.workers.max(1);
    let batching = builder.batching;
    let max_batch = batching.cap();
    let batcher = AdaptiveBatcher::new(max_batch);
    let d = engine.in_width();
    let threads = builder.threads;
    let mean_gap = cfg.mean_interarrival;
    let n_req = cfg.n_requests;
    let seed = cfg.seed;
    let injector: Injector<Request> = Injector::new();

    let t_start = Instant::now();
    let worker_stats: Vec<WorkerStats> = std::thread::scope(|s| {
        let inj = &injector;

        // Submitter: Poisson arrivals of random feature vectors.
        s.spawn(move || {
            let mut rng = Rng::new(seed);
            for _ in 0..n_req {
                let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                inj.push(Request { x, t_submit: Instant::now() });
                if mean_gap > Duration::ZERO {
                    std::thread::sleep(poisson_gap(mean_gap, &mut rng));
                }
            }
            inj.close();
        });

        // Workers: pop-batch + forward on private scratch.
        let batcher = &batcher;
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(move || {
                    let mut scratch = engine.scratch(max_batch);
                    let mut xbuf = vec![0f32; max_batch * d];
                    let mut batch: Vec<Request> = Vec::with_capacity(max_batch);
                    let mut ws = WorkerStats::default();
                    loop {
                        batch.clear();
                        let want = match batching {
                            Batching::Fixed(n) => n,
                            Batching::Adaptive { .. } => batcher.next_batch(inj.len()),
                        };
                        if inj.pop_batch(want, &mut batch) == 0 {
                            break;
                        }
                        let b = batch.len();
                        for (i, r) in batch.iter().enumerate() {
                            xbuf[i * d..(i + 1) * d].copy_from_slice(&r.x);
                        }
                        let _ = engine.forward(&xbuf[..b * d], b, &mut scratch, threads);
                        let t_done = Instant::now();
                        for r in &batch {
                            ws.latencies_us
                                .push(t_done.duration_since(r.t_submit).as_secs_f64() * 1e6);
                        }
                        ws.served += b;
                        ws.batches += 1;
                    }
                    ws
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    LatencyStats::from_workers(&worker_stats, t_start.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::model::{Activation, LayerSpec, Repr};
    use crate::inference::LayerBundle;

    fn model3(repr: Repr) -> SparseModel {
        let spec = |n, act| LayerSpec {
            n,
            repr,
            sparsity: 0.9,
            ablated_frac: 0.25,
            activation: act,
        };
        SparseModel::synth(
            64,
            &[
                spec(48, Activation::Relu),
                spec(32, Activation::Relu),
                spec(16, Activation::Identity),
            ],
            11,
        )
        .unwrap()
    }

    #[test]
    fn online_serves_all_requests() {
        let bundle = LayerBundle::synth(32, 64, 0.9, 0.2, 0);
        let cfg = ServeConfig {
            n_requests: 50,
            mean_interarrival: Duration::ZERO,
            seed: 1,
        };
        let stats = serve(&bundle.condensed, &EngineBuilder::online(), &cfg);
        assert_eq!(stats.n, 50);
        assert!(stats.p50_us > 0.0 && stats.p99_us >= stats.p50_us);
        assert!((stats.mean_batch - 1.0).abs() < 1e-9, "online must be batch-1");
    }

    #[test]
    fn batched_mode_coalesces() {
        let bundle = LayerBundle::synth(32, 64, 0.9, 0.2, 0);
        let cfg = ServeConfig {
            n_requests: 200,
            mean_interarrival: Duration::ZERO, // flood -> batches form
            seed: 2,
        };
        let stats = serve(&bundle.dense, &EngineBuilder::new().workers(1).fixed_batch(16), &cfg);
        assert_eq!(stats.n, 200);
        assert!(stats.mean_batch > 1.0, "flooded queue should batch, got {}", stats.mean_batch);
    }

    #[test]
    fn pooled_layer_serves_all_requests() {
        let bundle = LayerBundle::synth(32, 64, 0.9, 0.2, 0);
        let cfg = ServeConfig {
            n_requests: 300,
            mean_interarrival: Duration::ZERO,
            seed: 3,
        };
        let stats = serve(&bundle.condensed, &EngineBuilder::new().workers(4).fixed_batch(8), &cfg);
        assert_eq!(stats.n, 300, "pool must serve every request exactly once");
        assert!(stats.mean_batch >= 1.0);
        assert!(stats.throughput_rps > 0.0);
    }

    #[test]
    fn pooled_model_serves_all_requests() {
        let m = model3(Repr::Condensed);
        let cfg = ServeConfig {
            n_requests: 120,
            mean_interarrival: Duration::from_micros(20),
            seed: 4,
        };
        let stats =
            serve_model(&m, &EngineBuilder::new().workers(3).fixed_batch(4), &cfg).unwrap();
        assert_eq!(stats.n, 120);
        assert!(stats.p99_us >= stats.p50_us);
    }

    #[test]
    fn adaptive_mode_serves_all_requests() {
        let m = model3(Repr::Condensed);
        let cfg = ServeConfig {
            n_requests: 200,
            mean_interarrival: Duration::ZERO, // flood -> depth EWMA rises
            seed: 6,
        };
        let stats = serve_model(&m, &EngineBuilder::new().workers(2).adaptive(8), &cfg).unwrap();
        assert_eq!(stats.n, 200, "adaptive pool must serve every request exactly once");
        assert!(stats.mean_batch >= 1.0 && stats.mean_batch <= 8.0);
    }

    #[test]
    fn sharded_builder_serves_all_requests() {
        let m = model3(Repr::Condensed);
        for shards in [2usize, 3] {
            let cfg = ServeConfig {
                n_requests: 120,
                mean_interarrival: Duration::ZERO,
                seed: 5,
            };
            let b = EngineBuilder::new().workers(1).fixed_batch(4).shards(shards);
            let stats = serve_model(&m, &b, &cfg).unwrap();
            assert_eq!(stats.n, 120, "shards={shards}: every request served exactly once");
            assert!(stats.p99_us >= stats.p50_us);
            assert!(stats.mean_batch >= 1.0 && stats.mean_batch <= 4.0);
        }
    }

    #[test]
    fn sharded_builder_propagates_plan_error() {
        // narrowest layer has 16 neurons: 17 shards is a typed plan error
        let m = model3(Repr::Condensed);
        let cfg = ServeConfig { n_requests: 1, mean_interarrival: Duration::ZERO, seed: 1 };
        let err = serve_model(&m, &EngineBuilder::new().shards(17), &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("17 shards"), "{err:#}");
    }

    #[test]
    fn poisson_gap_mean_tracks_configured_mean() {
        // 10k deterministic draws at a 50 ms mean: the sample mean must sit
        // near 50 ms (the old absolute 10 ms clamp floored every draw)
        let mean = Duration::from_millis(50);
        let mut rng = Rng::new(42);
        let n = 10_000;
        let mut total = 0.0f64;
        let mut max_gap = 0.0f64;
        for _ in 0..n {
            let g = poisson_gap(mean, &mut rng).as_secs_f64();
            total += g;
            max_gap = max_gap.max(g);
        }
        let sample_mean = total / n as f64;
        assert!(
            (sample_mean - 0.05).abs() < 0.005,
            "sample mean {:.2} ms should track the configured 50 ms",
            sample_mean * 1e3
        );
        assert!(max_gap <= 0.5 + 1e-9, "clamp is 10x the mean, got {max_gap}");
        assert!(max_gap > 0.05, "tail draws exceed the mean (old clamp capped them at 10 ms)");
    }

    #[test]
    fn poisson_submitter_realizes_configured_mean_gap() {
        // regression for the absolute-10ms clamp: a run configured at
        // mean_interarrival = 50 ms must realize ~50 ms mean gaps (the old
        // code floored them to 10 ms, a 5x flood)
        let bundle = LayerBundle::synth(8, 8, 0.5, 0.0, 0);
        let n_requests = 40;
        let cfg = ServeConfig {
            n_requests,
            mean_interarrival: Duration::from_millis(50),
            // This seed's 40 exponential draws average 46.25 ms — a little
            // under the mean on purpose: sleep can only overshoot, so the
            // slack absorbs scheduler oversleep when the parallel test
            // sweep loads the machine, while the lower bound (> 40 ms) is
            // guaranteed by the draws themselves.
            seed: 15,
        };
        let t0 = Instant::now();
        let stats = serve(&bundle.condensed, &EngineBuilder::online(), &cfg);
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(stats.n, n_requests);
        let mean_gap = wall / n_requests as f64;
        assert!(
            (mean_gap - 0.05).abs() <= 0.01,
            "realized mean gap {:.1} ms must be within 20% of the configured 50 ms",
            mean_gap * 1e3
        );
    }

    #[test]
    fn adaptive_batcher_tracks_depth() {
        let b = AdaptiveBatcher::new(8);
        assert_eq!(b.next_batch(0), 1, "empty queue serves batch-1");
        // sustained flood drives the limit to the cap
        let mut last = 0;
        for _ in 0..50 {
            last = b.next_batch(100);
        }
        assert_eq!(last, 8, "flood saturates at cap");
        assert!(b.ewma() > 8.0);
        // sustained idle decays back to batch-1
        for _ in 0..100 {
            last = b.next_batch(0);
        }
        assert_eq!(last, 1, "idle decays to batch-1");
        assert!(b.ewma() < 1.0);
    }

    #[test]
    fn adaptive_batcher_intermediate_depths() {
        let b = AdaptiveBatcher::with_alpha(16, 1.0); // no smoothing: limit == depth
        assert_eq!(b.next_batch(3), 3);
        assert_eq!(b.next_batch(40), 16, "clamped to cap");
        assert_eq!(b.next_batch(0), 1, "floor 1");
        assert_eq!(AdaptiveBatcher::new(0).next_batch(100), 1, "cap floor is 1");
    }

    #[test]
    fn adaptive_batcher_cold_start_seeds_from_first_observation() {
        // Sudden load at startup: depth 8 with cap 16 must batch 8 on the
        // FIRST pop. The old zero-seeded EWMA returned ceil(0.25*8)=2 and
        // needed ~1/alpha pops to warm up to the real depth.
        let b = AdaptiveBatcher::new(16);
        assert!(b.ewma().is_nan(), "no observation yet");
        assert_eq!(b.next_batch(8), 8, "first pop lands on the observed depth");
        assert!((b.ewma() - 8.0).abs() < 1e-12, "average seeded at the observation");
        // subsequent observations smooth as before
        assert_eq!(b.next_batch(8), 8);
        // cold start under idle is unchanged: seed 0 -> batch-1
        let idle = AdaptiveBatcher::new(16);
        assert_eq!(idle.next_batch(0), 1);
        assert!((idle.ewma() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_ordered() {
        let sorted: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        // interpolated: rank 49.5 -> midway between 50 and 51 (the old
        // nearest-rank variant reported 51.0, biased high)
        assert_eq!(percentile(&sorted, 50.0), 50.5);
        assert!(percentile(&sorted, 99.0) >= percentile(&sorted, 95.0));
    }

    #[test]
    fn percentile_interpolates_between_ranks() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 25.0), 17.5, "rank 0.75 -> 10 + 0.75*10");
        assert_eq!(percentile(&xs, 50.0), 25.0, "rank 1.5 -> midway");
        assert_eq!(percentile(&xs, 100.0), 40.0);
        // out-of-range p clamps instead of indexing out of bounds
        assert_eq!(percentile(&xs, 150.0), 40.0);
        assert_eq!(percentile(&xs, -5.0), 10.0);
    }

    #[test]
    fn percentile_edge_cases() {
        assert!(percentile(&[], 50.0).is_nan(), "empty slice is NaN");
        let one = [42.0];
        assert_eq!(percentile(&one, 0.0), 42.0);
        assert_eq!(percentile(&one, 50.0), 42.0);
        assert_eq!(percentile(&one, 100.0), 42.0);
        let many: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&many, 0.0), 0.0, "p0 is the minimum");
        assert_eq!(percentile(&many, 100.0), 100.0, "p100 is the maximum");
        assert_eq!(percentile(&many, 50.0), 50.0);
    }

    #[test]
    fn merged_worker_stats_consistent() {
        let w1 = WorkerStats { latencies_us: vec![300.0, 100.0, 200.0], served: 3, batches: 2 };
        let w2 = WorkerStats { latencies_us: vec![400.0], served: 1, batches: 1 };
        let s = LatencyStats::from_workers(&[w1, w2], 2.0);
        assert_eq!(s.n, 4);
        assert_eq!(s.mean_us, 250.0);
        assert_eq!(s.max_us, 400.0);
        assert_eq!(s.throughput_rps, 2.0, "n / wall");
        assert!((s.mean_batch - 4.0 / 3.0).abs() < 1e-12, "served / batches across workers");
        // merged sorted samples [100,200,300,400]: interpolated p50 at
        // rank 1.5 is 250 (the old nearest-rank variant said 300)
        assert_eq!(s.p50_us, 250.0, "interpolated percentile over the merged samples");
        assert!(s.p99_us <= s.max_us && s.p95_us <= s.p99_us);
    }

    #[test]
    fn merged_empty_is_nan_but_finite_counts() {
        let s = LatencyStats::from_workers(&[], 1.0);
        assert_eq!(s.n, 0);
        assert!(s.p50_us.is_nan() && s.max_us.is_nan());
        assert_eq!(s.throughput_rps, 0.0);
        assert!(s.mean_us.is_finite(), "empty mean must not divide by zero");
        assert_eq!(s.mean_batch, 0.0, "no batches -> mean_batch 0, not NaN");
    }

    #[test]
    fn merged_workers_with_no_samples() {
        // workers that never popped a request: non-empty worker list, zero samples
        let s = LatencyStats::from_workers(&[WorkerStats::default(), WorkerStats::default()], 0.5);
        assert_eq!(s.n, 0);
        assert!(s.p50_us.is_nan() && s.p99_us.is_nan() && s.max_us.is_nan());
        assert!(s.mean_us.is_finite() && s.mean_batch.is_finite());
        assert_eq!(s.throughput_rps, 0.0);
    }

    #[test]
    fn merged_stats_survive_nan_samples() {
        // Regression: one poisoned sample used to panic the whole stats
        // path mid-serve (`partial_cmp(..).unwrap()` in the merge sort).
        // Now NaNs are counted and excluded; aggregates cover the finite
        // samples only.
        let w1 = WorkerStats {
            latencies_us: vec![100.0, f64::NAN, 300.0],
            served: 3,
            batches: 3,
        };
        let w2 = WorkerStats { latencies_us: vec![f64::NAN, 200.0], served: 2, batches: 2 };
        let s = LatencyStats::from_workers(&[w1, w2], 1.0);
        assert_eq!(s.nan_samples, 2, "both poisoned samples counted");
        assert_eq!(s.n, 3, "aggregates over the finite samples only");
        assert_eq!(s.mean_us, 200.0);
        assert_eq!(s.p50_us, 200.0);
        assert_eq!(s.max_us, 300.0, "max not poisoned by NaN");
        assert!(s.p99_us.is_finite() && s.p95_us.is_finite());
        assert_eq!(s.throughput_rps, 3.0, "finite samples / wall");
    }

    #[test]
    fn merged_stats_all_nan_is_empty_but_counted() {
        let w = WorkerStats { latencies_us: vec![f64::NAN; 4], served: 4, batches: 1 };
        let s = LatencyStats::from_workers(&[w], 1.0);
        assert_eq!(s.nan_samples, 4);
        assert_eq!(s.n, 0);
        assert!(s.p50_us.is_nan() && s.max_us.is_nan(), "no finite samples to aggregate");
        assert!(s.mean_us.is_finite(), "empty mean must not divide by zero");
    }

    #[test]
    fn latency_stats_json_is_valid_even_when_empty() {
        use crate::util::json::Json;
        let s = LatencyStats::from_workers(&[], 1.0);
        let j = s.to_json();
        // NaN percentiles serialize as null, so the line must re-parse
        let parsed = Json::parse(&j.to_string()).expect("valid JSON");
        assert_eq!(parsed.get("p50_us").unwrap(), &Json::Null);
        assert_eq!(parsed.get("n").unwrap().as_usize().unwrap(), 0);

        let w = WorkerStats { latencies_us: vec![50.0], served: 1, batches: 1 };
        let j = LatencyStats::from_workers(&[w], 2.0).to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("p50_us").unwrap().as_f64().unwrap(), 50.0);
        assert_eq!(parsed.get("rps").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(parsed.get("nan_samples").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn merged_single_sample() {
        let w = WorkerStats { latencies_us: vec![123.0], served: 1, batches: 1 };
        let s = LatencyStats::from_workers(&[w], 2.0);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean_us, 123.0);
        // every percentile of a single sample is that sample
        assert_eq!(s.p50_us, 123.0);
        assert_eq!(s.p95_us, 123.0);
        assert_eq!(s.p99_us, 123.0);
        assert_eq!(s.max_us, 123.0);
        assert_eq!(s.throughput_rps, 0.5);
        assert_eq!(s.mean_batch, 1.0);
    }
}
