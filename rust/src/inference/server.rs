//! Online-inference server: the L3 coordination piece for the paper's
//! §2 "Online inference" scenario — single-sample, latency-critical
//! requests served from a queue, plus a dynamic batcher for throughput
//! mode (the vLLM-router-shaped component of this repo).
//!
//! Architecture: a submitter thread enqueues requests at a configured
//! rate; the worker drains the queue — one-at-a-time in `Online` mode,
//! up to `max_batch` at once in `Batched` mode — runs the selected layer
//! representation, and records end-to-end latency per request.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::LinearKernel;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeMode {
    /// Strict batch-1 service (paper Fig. 4a setting).
    Online,
    /// Dynamic batching: coalesce whatever is queued, up to `max_batch`.
    Batched { max_batch: usize },
}

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub mode: ServeMode,
    pub n_requests: usize,
    /// Mean inter-arrival time; exponential distribution (Poisson load).
    pub mean_interarrival: Duration,
    pub threads: usize,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct LatencyStats {
    pub n: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    pub throughput_rps: f64,
    pub mean_batch: f64,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

struct Request {
    x: Vec<f32>,
    t_submit: Instant,
}

/// Drive `layer` with a synthetic Poisson request stream and return
/// end-to-end latency statistics.
pub fn serve(layer: &dyn LinearKernel, cfg: &ServeConfig) -> LatencyStats {
    let d = layer.in_width();
    let (tx, rx) = mpsc::channel::<Request>();
    let mean_gap = cfg.mean_interarrival;
    let n_req = cfg.n_requests;
    let seed = cfg.seed;

    let t_start = Instant::now();
    std::thread::scope(|s| {
        // Submitter: Poisson arrivals of random feature vectors.
        s.spawn(move || {
            let mut rng = Rng::new(seed);
            for _ in 0..n_req {
                let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                let _ = tx.send(Request { x, t_submit: Instant::now() });
                if mean_gap > Duration::ZERO {
                    // exponential inter-arrival
                    let u = rng.uniform().max(1e-12);
                    let gap = mean_gap.as_secs_f64() * -u.ln();
                    std::thread::sleep(Duration::from_secs_f64(gap.min(0.01)));
                }
            }
        });

        // Worker: drain + serve.
        let mut latencies: Vec<f64> = Vec::with_capacity(n_req);
        let mut batches = 0usize;
        let mut served = 0usize;
        let max_batch = match cfg.mode {
            ServeMode::Online => 1,
            ServeMode::Batched { max_batch } => max_batch.max(1),
        };
        let mut out = vec![0f32; max_batch * layer.out_width()];
        let mut xbuf = vec![0f32; max_batch * d];
        while served < n_req {
            // blocking pop for the first element, then opportunistic drain
            let first = match rx.recv() {
                Ok(r) => r,
                Err(_) => break,
            };
            let mut batch = vec![first];
            while batch.len() < max_batch {
                match rx.try_recv() {
                    Ok(r) => batch.push(r),
                    Err(_) => break,
                }
            }
            let b = batch.len();
            for (i, r) in batch.iter().enumerate() {
                xbuf[i * d..(i + 1) * d].copy_from_slice(&r.x);
            }
            layer.forward(&xbuf[..b * d], b, &mut out[..b * layer.out_width()], cfg.threads);
            let t_done = Instant::now();
            for r in &batch {
                latencies.push(t_done.duration_since(r.t_submit).as_secs_f64() * 1e6);
            }
            served += b;
            batches += 1;
        }

        let wall = t_start.elapsed().as_secs_f64();
        let mut sorted = latencies.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencyStats {
            n: latencies.len(),
            mean_us: latencies.iter().sum::<f64>() / latencies.len().max(1) as f64,
            p50_us: percentile(&sorted, 50.0),
            p95_us: percentile(&sorted, 95.0),
            p99_us: percentile(&sorted, 99.0),
            max_us: sorted.last().copied().unwrap_or(f64::NAN),
            throughput_rps: latencies.len() as f64 / wall.max(1e-9),
            mean_batch: served as f64 / batches.max(1) as f64,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::LayerBundle;

    #[test]
    fn online_serves_all_requests() {
        let bundle = LayerBundle::synth(32, 64, 0.9, 0.2, 0);
        let cfg = ServeConfig {
            mode: ServeMode::Online,
            n_requests: 50,
            mean_interarrival: Duration::ZERO,
            threads: 1,
            seed: 1,
        };
        let stats = serve(&bundle.condensed, &cfg);
        assert_eq!(stats.n, 50);
        assert!(stats.p50_us > 0.0 && stats.p99_us >= stats.p50_us);
        assert!((stats.mean_batch - 1.0).abs() < 1e-9, "online must be batch-1");
    }

    #[test]
    fn batched_mode_coalesces() {
        let bundle = LayerBundle::synth(32, 64, 0.9, 0.2, 0);
        let cfg = ServeConfig {
            mode: ServeMode::Batched { max_batch: 16 },
            n_requests: 200,
            mean_interarrival: Duration::ZERO, // flood -> batches form
            threads: 1,
            seed: 2,
        };
        let stats = serve(&bundle.dense, &cfg);
        assert_eq!(stats.n, 200);
        assert!(stats.mean_batch > 1.0, "flooded queue should batch, got {}", stats.mean_batch);
    }

    #[test]
    fn percentiles_ordered() {
        let sorted: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&sorted, 50.0), 51.0);
        assert!(percentile(&sorted, 99.0) >= percentile(&sorted, 95.0));
    }
}
