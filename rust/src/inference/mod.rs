//! Native CPU inference engine — the four linear-layer representations the
//! paper benchmarks against each other (Fig. 4, Appendices I/J/K):
//!
//! * [`DenseLayer`]      — dense GEMM baseline;
//! * [`CsrLayer`]        — unstructured sparse (CSR SpMM) baseline;
//! * [`StructuredLayer`] — exploits *only* neuron ablation: dense GEMM over
//!                         the surviving rows;
//! * [`CondensedLayer`]  — Algorithm 1: exploits ablation *and* constant
//!                         fan-in via the (n_active × k) value/index
//!                         gather-MAC.
//!
//! All kernels share a threading scheme (`threads` parameter — the paper
//! sweeps 1/4/8 CPU threads in Figs. 18-20): batch-1 splits the single
//! output row across threads; batched splits batch rows.

pub mod engine;
pub mod frontend;
pub mod model;
pub mod server;
pub mod shard;

pub use engine::{Engine, EngineBuilder, KernelEngine, PersistentShardedEngine, ReplicatedEngine};
pub use frontend::{FrontendHandle, FrontendStats};
pub use model::{Activation, LayerSpec, ModelLayer, Repr, Scratch, SparseModel};
pub use shard::{ShardPlan, ShardPlanError, ShardedModel, ShardedScratch};

use crate::sparsity::{Condensed, Csr, Mask};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::threadpool::par_rows_mut;

/// A linear layer representation that can run a batched forward pass.
pub trait LinearKernel: Send + Sync {
    fn name(&self) -> &'static str;
    /// Output features per example (n for dense/CSR; n_active for the
    /// structured/condensed compact forms).
    fn out_width(&self) -> usize;
    fn in_width(&self) -> usize;
    /// x: (batch, d) row-major; out: (batch, out_width) row-major,
    /// preallocated. `threads` >= 1.
    fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize);
    /// Bytes this representation occupies (weights + indices + bias).
    fn storage_bytes(&self) -> usize;
    /// Surviving (non-ablated) output-neuron ids in ascending *full
    /// logical* coordinates — `Some` only for the compact forms that emit
    /// fewer rows than the layer's logical width.
    fn active_rows(&self) -> Option<&[u32]> {
        None
    }
    /// Slice this kernel to the contiguous full-logical-width output-neuron
    /// range `lo..hi` — the tensor-parallel sharding primitive. The paper's
    /// constant fan-in makes every contiguous neuron range of a condensed
    /// kernel itself a valid condensed kernel (each output neuron owns
    /// exactly k weights), and the same holds trivially for the other three
    /// representations. The slice copies the underlying rows verbatim, so a
    /// sliced forward is bit-for-bit identical to the corresponding rows of
    /// the unsliced forward.
    fn slice_rows(&self, lo: usize, hi: usize) -> Box<dyn LinearKernel>;
    /// Stored weights per full logical output neuron (len `full_width`) —
    /// the [`shard::ShardPlan`] balancing costs. Ablated neurons cost 0 in
    /// the compact forms and their CSR rows are empty, so balancing by
    /// these weights (not by neuron count) keeps shard compute even.
    fn row_weights(&self, full_width: usize) -> Vec<usize>;
}

/// Split a single output row into per-thread contiguous chunks (batch-1
/// fast path; avoids the useless spawn when threads == 1).
fn par_single_row<F>(out: &mut [f32], threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync, // (start_col, chunk)
{
    let n = out.len();
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        f(0, out);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut start = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let f = &f;
            s.spawn(move || f(start, head));
            start += take;
            rest = tail;
        }
    });
}

// ---------------------------------------------------------------------------
// Dense
// ---------------------------------------------------------------------------

pub struct DenseLayer {
    pub n: usize,
    pub d: usize,
    /// (n, d) row-major.
    pub w: Vec<f32>,
    pub bias: Vec<f32>,
}

impl DenseLayer {
    pub fn new(w: &Tensor, bias: Vec<f32>) -> DenseLayer {
        let (n, d) = w.neuron_view();
        assert_eq!(bias.len(), n);
        DenseLayer { n, d, w: w.data.clone(), bias }
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    // 4-way unrolled accumulators: breaks the FP add dependency chain so
    // the compiler can keep multiple FMAs in flight (see §Perf).
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

impl LinearKernel for DenseLayer {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn out_width(&self) -> usize {
        self.n
    }

    fn in_width(&self) -> usize {
        self.d
    }

    fn storage_bytes(&self) -> usize {
        (self.w.len() + self.bias.len()) * 4
    }

    fn slice_rows(&self, lo: usize, hi: usize) -> Box<dyn LinearKernel> {
        assert!(lo <= hi && hi <= self.n, "slice {lo}..{hi} out of 0..{}", self.n);
        Box::new(DenseLayer {
            n: hi - lo,
            d: self.d,
            w: self.w[lo * self.d..hi * self.d].to_vec(),
            bias: self.bias[lo..hi].to_vec(),
        })
    }

    fn row_weights(&self, full_width: usize) -> Vec<usize> {
        assert_eq!(full_width, self.n);
        // dense stores (and computes) every row, ablated or not
        vec![self.d; self.n]
    }

    fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize) {
        debug_assert_eq!(x.len(), batch * self.d);
        debug_assert_eq!(out.len(), batch * self.n);
        if batch == 1 {
            par_single_row(out, threads, |start, chunk| {
                for (i, o) in chunk.iter_mut().enumerate() {
                    let r = start + i;
                    *o = dot(&self.w[r * self.d..(r + 1) * self.d], x) + self.bias[r];
                }
            });
        } else {
            par_rows_mut(out, self.n, threads, |b, row| {
                let xb = &x[b * self.d..(b + 1) * self.d];
                for (r, o) in row.iter_mut().enumerate() {
                    *o = dot(&self.w[r * self.d..(r + 1) * self.d], xb) + self.bias[r];
                }
            });
        }
    }
}

// ---------------------------------------------------------------------------
// CSR (unstructured)
// ---------------------------------------------------------------------------

pub struct CsrLayer {
    pub csr: Csr,
    pub bias: Vec<f32>,
}

impl CsrLayer {
    pub fn new(w: &Tensor, bias: Vec<f32>) -> CsrLayer {
        let csr = Csr::from_dense(w);
        assert_eq!(bias.len(), csr.rows);
        // Same once-validated invariant as CondensedLayer (§Perf iter. 2):
        // column indices in range, so the gather can skip bounds checks.
        assert!(csr.indices.iter().all(|&j| (j as usize) < csr.cols));
        CsrLayer { csr, bias }
    }
}

impl LinearKernel for CsrLayer {
    fn name(&self) -> &'static str {
        "csr"
    }

    fn out_width(&self) -> usize {
        self.csr.rows
    }

    fn in_width(&self) -> usize {
        self.csr.cols
    }

    fn storage_bytes(&self) -> usize {
        self.csr.storage_bytes() + self.bias.len() * 4
    }

    fn slice_rows(&self, lo: usize, hi: usize) -> Box<dyn LinearKernel> {
        assert!(lo <= hi && hi <= self.csr.rows, "slice {lo}..{hi} out of 0..{}", self.csr.rows);
        let base = self.csr.indptr[lo];
        let csr = Csr {
            rows: hi - lo,
            cols: self.csr.cols,
            indptr: self.csr.indptr[lo..=hi].iter().map(|&p| p - base).collect(),
            indices: self.csr.indices[base as usize..self.csr.indptr[hi] as usize].to_vec(),
            values: self.csr.values[base as usize..self.csr.indptr[hi] as usize].to_vec(),
        };
        Box::new(CsrLayer { csr, bias: self.bias[lo..hi].to_vec() })
    }

    fn row_weights(&self, full_width: usize) -> Vec<usize> {
        assert_eq!(full_width, self.csr.rows);
        self.csr
            .indptr
            .windows(2)
            .map(|w| (w[1] - w[0]) as usize)
            .collect()
    }

    fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize) {
        let (n, d) = (self.csr.rows, self.csr.cols);
        debug_assert_eq!(out.len(), batch * n);
        let row_kernel = |xb: &[f32], r: usize| -> f32 {
            let lo = self.csr.indptr[r] as usize;
            let hi = self.csr.indptr[r + 1] as usize;
            let vals = &self.csr.values[lo..hi];
            let idx = &self.csr.indices[lo..hi];
            // 4-way unrolled, bounds-check-free gather (matched to the
            // condensed kernel so the Fig. 4 comparison is fair — §Perf).
            let mut acc = [0f32; 4];
            let mut vi = vals.chunks_exact(4);
            let mut ii = idx.chunks_exact(4);
            for (v4, i4) in (&mut vi).zip(&mut ii) {
                unsafe {
                    acc[0] += v4[0] * *xb.get_unchecked(i4[0] as usize);
                    acc[1] += v4[1] * *xb.get_unchecked(i4[1] as usize);
                    acc[2] += v4[2] * *xb.get_unchecked(i4[2] as usize);
                    acc[3] += v4[3] * *xb.get_unchecked(i4[3] as usize);
                }
            }
            let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
            for (v, i) in vi.remainder().iter().zip(ii.remainder()) {
                s += v * unsafe { *xb.get_unchecked(*i as usize) };
            }
            s + self.bias[r]
        };
        if batch == 1 {
            par_single_row(out, threads, |start, chunk| {
                for (i, o) in chunk.iter_mut().enumerate() {
                    *o = row_kernel(x, start + i);
                }
            });
        } else {
            par_rows_mut(out, n, threads, |b, row| {
                let xb = &x[b * d..(b + 1) * d];
                for (r, o) in row.iter_mut().enumerate() {
                    *o = row_kernel(xb, r);
                }
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Structured-only (neuron ablation, dense surviving rows)
// ---------------------------------------------------------------------------

pub struct StructuredLayer {
    pub n_active: usize,
    /// Logical rows of the original matrix (incl. ablated) — retained so
    /// slicing can validate ranges like the other representations.
    pub n_orig: usize,
    pub d: usize,
    /// (n_active, d) packed dense rows of the surviving neurons.
    pub w: Vec<f32>,
    pub bias: Vec<f32>,
    pub active: Vec<u32>,
}

impl StructuredLayer {
    /// Pack the surviving rows of a (possibly sparse) weight matrix. The
    /// rows keep their zeros — structured-only ignores fine-grained
    /// sparsity by design (paper Fig. 4 "structured").
    pub fn new(w: &Tensor, mask: &Mask, bias: &[f32]) -> StructuredLayer {
        let (n, d) = w.neuron_view();
        assert_eq!(bias.len(), n);
        let counts = mask.fan_in_counts();
        let mut packed = Vec::new();
        let mut pbias = Vec::new();
        let mut active = Vec::new();
        for r in 0..n {
            if counts[r] > 0 {
                packed.extend_from_slice(&w.data[r * d..(r + 1) * d]);
                pbias.push(bias[r]);
                active.push(r as u32);
            }
        }
        StructuredLayer { n_active: active.len(), n_orig: n, d, w: packed, bias: pbias, active }
    }
}

impl LinearKernel for StructuredLayer {
    fn name(&self) -> &'static str {
        "structured"
    }

    fn out_width(&self) -> usize {
        self.n_active
    }

    fn in_width(&self) -> usize {
        self.d
    }

    fn storage_bytes(&self) -> usize {
        (self.w.len() + self.bias.len() + self.active.len()) * 4
    }

    fn active_rows(&self) -> Option<&[u32]> {
        Some(&self.active)
    }

    fn slice_rows(&self, lo: usize, hi: usize) -> Box<dyn LinearKernel> {
        assert!(lo <= hi && hi <= self.n_orig, "slice {lo}..{hi} out of 0..{}", self.n_orig);
        // active is ascending, so the surviving rows of lo..hi are a
        // contiguous run of the packed storage
        let p = self.active.partition_point(|&a| (a as usize) < lo);
        let q = self.active.partition_point(|&a| (a as usize) < hi);
        Box::new(StructuredLayer {
            n_active: q - p,
            n_orig: hi - lo,
            d: self.d,
            w: self.w[p * self.d..q * self.d].to_vec(),
            bias: self.bias[p..q].to_vec(),
            active: self.active[p..q].iter().map(|&a| a - lo as u32).collect(),
        })
    }

    fn row_weights(&self, full_width: usize) -> Vec<usize> {
        assert_eq!(full_width, self.n_orig);
        let mut w = vec![0usize; full_width];
        for &a in &self.active {
            w[a as usize] = self.d; // structured stores the full dense row
        }
        w
    }

    fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize) {
        debug_assert_eq!(out.len(), batch * self.n_active);
        if batch == 1 {
            par_single_row(out, threads, |start, chunk| {
                for (i, o) in chunk.iter_mut().enumerate() {
                    let r = start + i;
                    *o = dot(&self.w[r * self.d..(r + 1) * self.d], x) + self.bias[r];
                }
            });
        } else {
            par_rows_mut(out, self.n_active, threads, |b, row| {
                let xb = &x[b * self.d..(b + 1) * self.d];
                for (r, o) in row.iter_mut().enumerate() {
                    *o = dot(&self.w[r * self.d..(r + 1) * self.d], xb) + self.bias[r];
                }
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Condensed (Algorithm 1)
// ---------------------------------------------------------------------------

pub struct CondensedLayer {
    pub c: Condensed,
    pub bias: Vec<f32>, // packed to active neurons
}

impl CondensedLayer {
    pub fn new(w: &Tensor, mask: &Mask, bias: &[f32]) -> CondensedLayer {
        let c = Condensed::from_masked(w, mask);
        // Validate the index invariant once so the forward pass can gather
        // without per-element bounds checks (§Perf iteration 1).
        assert!(c.idx.iter().all(|&j| (j as usize) < c.d), "index out of range");
        let pbias = c.active.iter().map(|&r| bias[r as usize]).collect();
        CondensedLayer { c, bias: pbias }
    }
}

impl LinearKernel for CondensedLayer {
    fn name(&self) -> &'static str {
        "condensed"
    }

    fn out_width(&self) -> usize {
        self.c.n_active()
    }

    fn in_width(&self) -> usize {
        self.c.d
    }

    fn storage_bytes(&self) -> usize {
        self.c.storage_bytes() + self.bias.len() * 4
    }

    fn active_rows(&self) -> Option<&[u32]> {
        Some(&self.c.active)
    }

    fn slice_rows(&self, lo: usize, hi: usize) -> Box<dyn LinearKernel> {
        assert!(lo <= hi && hi <= self.c.n_orig, "slice {lo}..{hi} out of 0..{}", self.c.n_orig);
        let k = self.c.k;
        let p = self.c.active.partition_point(|&a| (a as usize) < lo);
        let q = self.c.active.partition_point(|&a| (a as usize) < hi);
        let c = Condensed {
            d: self.c.d,
            n_orig: hi - lo,
            k,
            active: self.c.active[p..q].iter().map(|&a| a - lo as u32).collect(),
            values: self.c.values[p * k..q * k].to_vec(),
            idx: self.c.idx[p * k..q * k].to_vec(),
        };
        Box::new(CondensedLayer { c, bias: self.bias[p..q].to_vec() })
    }

    fn row_weights(&self, full_width: usize) -> Vec<usize> {
        assert_eq!(full_width, self.c.n_orig);
        let mut w = vec![0usize; full_width];
        for &a in &self.c.active {
            w[a as usize] = self.c.k; // constant fan-in: k stored weights each
        }
        w
    }

    fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize) {
        let k = self.c.k;
        let n = self.c.n_active();
        let d = self.c.d;
        debug_assert_eq!(out.len(), batch * n);
        let row_kernel = |xb: &[f32], r: usize| -> f32 {
            let vals = &self.c.values[r * k..(r + 1) * k];
            let idx = &self.c.idx[r * k..(r + 1) * k];
            // 4-way unrolled gather-MAC (paper Algorithm 1 inner loop).
            // Indices are validated once in `new`, so the gather skips
            // bounds checks; 4 accumulators break the FP dependency chain
            // (§Perf iteration 1: 2-way safe -> 4-way unchecked).
            let mut acc = [0f32; 4];
            let mut vi = vals.chunks_exact(4);
            let mut ii = idx.chunks_exact(4);
            for (v4, i4) in (&mut vi).zip(&mut ii) {
                unsafe {
                    acc[0] += v4[0] * *xb.get_unchecked(i4[0] as usize);
                    acc[1] += v4[1] * *xb.get_unchecked(i4[1] as usize);
                    acc[2] += v4[2] * *xb.get_unchecked(i4[2] as usize);
                    acc[3] += v4[3] * *xb.get_unchecked(i4[3] as usize);
                }
            }
            let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
            for (v, i) in vi.remainder().iter().zip(ii.remainder()) {
                s += v * unsafe { *xb.get_unchecked(*i as usize) };
            }
            s + self.bias[r]
        };
        if batch == 1 {
            par_single_row(out, threads, |start, chunk| {
                for (i, o) in chunk.iter_mut().enumerate() {
                    *o = row_kernel(x, start + i);
                }
            });
        } else {
            par_rows_mut(out, n, threads, |b, row| {
                let xb = &x[b * d..(b + 1) * d];
                for (r, o) in row.iter_mut().enumerate() {
                    *o = row_kernel(xb, r);
                }
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Test-layer factory: an SRigL-shaped sparse layer (constant fan-in +
// a fraction of ablated neurons), used by benches and the exp harnesses.
// ---------------------------------------------------------------------------

pub struct LayerBundle {
    pub dense: DenseLayer,
    /// CSR of the *same* SRigL matrix (pattern = constant fan-in) —
    /// used by correctness tests; rows are uniform so this flatters CSR.
    pub csr: CsrLayer,
    /// CSR of an *unstructured* mask with identical nnz — the paper's
    /// Fig. 4 "unstructured (CSR)" baseline (timing harnesses use this).
    pub csr_unstructured: CsrLayer,
    pub structured: StructuredLayer,
    pub condensed: CondensedLayer,
    pub w: Tensor,
    pub mask: Mask,
    pub bias: Vec<f32>,
}

impl LayerBundle {
    /// `sparsity` sets k = round(d*(1-s)); `ablated_frac` of neurons are
    /// fully masked (what SRigL's dynamic ablation produces). The synthesis
    /// recipe lives in [`model::synth_layer`] (shared with the test suites).
    pub fn synth(n: usize, d: usize, sparsity: f64, ablated_frac: f64, seed: u64) -> LayerBundle {
        let mut rng = Rng::new(seed);
        let (w, mask, bias) = model::synth_layer(n, d, sparsity, ablated_frac, &mut rng);
        LayerBundle::build(w, mask, bias)
    }

    pub fn build(w: Tensor, mask: Mask, bias: Vec<f32>) -> LayerBundle {
        let dense = DenseLayer::new(&w, bias.clone());
        let csr = CsrLayer::new(&w, bias.clone());
        // unstructured twin: same shape and nnz, random positions/values
        let (n, d) = w.neuron_view();
        let nnz = mask.nnz();
        let mut rng = Rng::new(0x5eed ^ nnz as u64);
        let um = Mask::random_per_layer(&[n, d], nnz, &mut rng);
        let mut uw = Tensor::normal(&[n, d], 1.0, &mut rng);
        uw.mul_assign(&um.t);
        let csr_unstructured = CsrLayer::new(&uw, bias.clone());
        let structured = StructuredLayer::new(&w, &mask, &bias);
        let condensed = CondensedLayer::new(&w, &mask, &bias);
        LayerBundle { dense, csr, csr_unstructured, structured, condensed, w, mask, bias }
    }

    /// The four Fig. 4 representations (CSR = the unstructured baseline).
    pub fn kernels(&self) -> Vec<&dyn LinearKernel> {
        vec![&self.dense, &self.csr_unstructured, &self.structured, &self.condensed]
    }
}

/// Gather a compact (active-only) output back into full-width layout —
/// used when a downstream consumer expects the original width.
pub fn scatter_compact(compact: &[f32], active: &[u32], n_orig: usize, batch: usize) -> Vec<f32> {
    let na = active.len();
    let mut out = vec![0f32; batch * n_orig];
    for b in 0..batch {
        for (i, &r) in active.iter().enumerate() {
            out[b * n_orig + r as usize] = compact[b * na + i];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_forward(w: &Tensor, bias: &[f32], x: &[f32], batch: usize) -> Vec<f32> {
        let (n, d) = w.neuron_view();
        let mut out = vec![0f32; batch * n];
        for b in 0..batch {
            for r in 0..n {
                let mut acc = bias[r];
                for j in 0..d {
                    acc += w.data[r * d + j] * x[b * d + j];
                }
                out[b * n + r] = acc;
            }
        }
        out
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + x.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn all_representations_agree() {
        for &(batch, threads) in &[(1usize, 1usize), (1, 4), (7, 1), (7, 3), (16, 8)] {
            let bundle = LayerBundle::synth(48, 96, 0.9, 0.25, 42);
            let mut rng = Rng::new(9);
            let x: Vec<f32> = (0..batch * 96).map(|_| rng.normal_f32()).collect();
            let expect = naive_forward(&bundle.w, &bundle.bias, &x, batch);

            let mut out_d = vec![0f32; batch * bundle.dense.out_width()];
            bundle.dense.forward(&x, batch, &mut out_d, threads);
            assert_close(&out_d, &expect, 1e-4);

            let mut out_c = vec![0f32; batch * bundle.csr.out_width()];
            bundle.csr.forward(&x, batch, &mut out_c, threads);
            assert_close(&out_c, &expect, 1e-4);

            // compact outputs scatter back to the dense layout (ablated
            // rows only carry their bias in the dense result; compare on
            // active rows).
            let mut out_s = vec![0f32; batch * bundle.structured.out_width()];
            bundle.structured.forward(&x, batch, &mut out_s, threads);
            let mut out_k = vec![0f32; batch * bundle.condensed.out_width()];
            bundle.condensed.forward(&x, batch, &mut out_k, threads);
            assert_close(&out_k, &out_s, 1e-4);
            for b in 0..batch {
                for (i, &r) in bundle.structured.active.iter().enumerate() {
                    let e = expect[b * 48 + r as usize];
                    let g = out_s[b * bundle.structured.n_active + i];
                    assert!((e - g).abs() < 1e-4 * (1.0 + e.abs()), "b={b} r={r}: {e} vs {g}");
                }
            }
        }
    }

    #[test]
    fn condensed_matches_xla_semantics_with_k1() {
        let bundle = LayerBundle::synth(8, 16, 0.95, 0.0, 1);
        assert_eq!(bundle.condensed.c.k, 1);
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut out = vec![0f32; 8];
        bundle.condensed.forward(&x, 1, &mut out, 1);
        let mut expect = vec![0f32; 8];
        bundle.dense.forward(&x, 1, &mut expect, 1);
        assert_close(&out, &expect, 1e-5);
    }

    #[test]
    fn scatter_compact_roundtrip() {
        let compact = vec![1.0, 2.0, 3.0, 4.0]; // batch 2, 2 active
        let full = scatter_compact(&compact, &[1, 3], 5, 2);
        assert_eq!(full, vec![0., 1., 0., 2., 0., 0., 3., 0., 4., 0.]);
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(3);
        for len in [0usize, 1, 3, 4, 7, 64, 100] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4 * (1.0 + naive.abs()));
        }
    }

    #[test]
    fn slice_rows_partitions_every_representation() {
        // two slices at an arbitrary cut must reproduce the full forward
        // bit-for-bit, rows concatenated (compact forms: the active lists
        // partition, so the compact outputs concatenate too)
        let bundle = LayerBundle::synth(24, 32, 0.85, 0.3, 5);
        let batch = 3;
        let mut rng = Rng::new(77);
        let x: Vec<f32> = (0..batch * 32).map(|_| rng.normal_f32()).collect();
        for kernel in bundle.kernels() {
            let ow = kernel.out_width();
            let mut full = vec![0f32; batch * ow];
            kernel.forward(&x, batch, &mut full, 1);
            for cut in [0usize, 7, 13, 24] {
                let (a, b) = (kernel.slice_rows(0, cut), kernel.slice_rows(cut, 24));
                let (wa, wb) = (a.out_width(), b.out_width());
                assert_eq!(wa + wb, ow, "{} cut {cut}: slices must partition", kernel.name());
                let mut oa = vec![0f32; batch * wa];
                let mut ob = vec![0f32; batch * wb];
                a.forward(&x, batch, &mut oa, 1);
                b.forward(&x, batch, &mut ob, 1);
                for bi in 0..batch {
                    let got: Vec<u32> = oa[bi * wa..(bi + 1) * wa]
                        .iter()
                        .chain(&ob[bi * wb..(bi + 1) * wb])
                        .map(|v| v.to_bits())
                        .collect();
                    let want: Vec<u32> =
                        full[bi * ow..(bi + 1) * ow].iter().map(|v| v.to_bits()).collect();
                    assert_eq!(got, want, "{} cut {cut} row {bi}", kernel.name());
                }
            }
        }
    }

    #[test]
    fn row_weights_reflect_stored_weights() {
        let bundle = LayerBundle::synth(16, 20, 0.8, 0.25, 9);
        let n_active = bundle.condensed.c.n_active();
        let k = bundle.condensed.c.k;
        assert_eq!(bundle.dense.row_weights(16).iter().sum::<usize>(), 16 * 20);
        assert_eq!(bundle.csr.row_weights(16).iter().sum::<usize>(), bundle.csr.csr.nnz());
        assert_eq!(bundle.structured.row_weights(16).iter().sum::<usize>(), n_active * 20);
        let cw = bundle.condensed.row_weights(16);
        assert_eq!(cw.iter().sum::<usize>(), n_active * k);
        // ablated rows cost 0 in the compact forms
        for r in 0..16 {
            let ablated = !bundle.condensed.c.active.contains(&(r as u32));
            assert_eq!(cw[r] == 0, ablated, "row {r}");
        }
    }

    #[test]
    fn storage_ordering_fig4() {
        // condensed < csr < dense bytes at 90% sparsity (memory claim §1).
        let b = LayerBundle::synth(768, 3072, 0.9, 0.1, 7);
        let dense_bytes = b.w.numel() * 4;
        assert!(b.condensed.c.storage_bytes() < b.csr.csr.storage_bytes());
        assert!(b.csr.csr.storage_bytes() < dense_bytes);
    }
}
