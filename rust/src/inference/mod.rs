//! Native CPU inference engine — the linear-layer representations the
//! paper benchmarks against each other (Fig. 4, Appendices I/J/K):
//!
//! * [`DenseLayer`]      — dense GEMM baseline;
//! * [`CsrLayer`]        — unstructured sparse (CSR SpMM) baseline;
//! * [`StructuredLayer`] — exploits *only* neuron ablation: dense GEMM over
//!                         the surviving rows;
//! * [`CondensedLayer`]  — Algorithm 1: exploits ablation *and* constant
//!                         fan-in via the (n_active × k) value/index
//!                         gather-MAC;
//! * [`CondensedTiledLayer`] — the same condensed semantics on the
//!                         batch-tiled interleaved layout: at batch >=
//!                         [`crate::kernels::TILE`] the input tile is
//!                         transposed once and every stored weight costs
//!                         one contiguous 8-wide load + broadcast-MAC
//!                         instead of `TILE` indexed loads.
//! * [`QuantizedLayer`] / [`QuantizedTiledLayer`] — the int8 serving
//!                         path (NNUE-style): 4-byte `(u16 idx, i8 q)`
//!                         records with calibrated per-row scales,
//!                         i32 accumulation, and a documented per-row
//!                         error budget against the f32 oracle (see
//!                         [`crate::sparsity::quantized`] and
//!                         docs/KERNELS.md). Halves the weight stream of
//!                         the f32 condensed forms; outputs are
//!                         bit-for-bit identical across kernel kinds.
//!
//! The arithmetic inner loops live in [`crate::kernels`] (runtime-
//! dispatched scalar / portable-SIMD / AVX2+FMA microkernels); each layer
//! carries a copyable [`Microkernel`] handle stamped at construction and
//! preserved through [`LinearKernel::slice_rows`], so a model and all of
//! its tensor-parallel shard slices always run the same kernel kind.
//! The shared threading scheme (`threads` parameter — the paper sweeps
//! 1/4/8 CPU threads in Figs. 18-20) also lives there
//! ([`crate::kernels::forward_rows`]): batch-1 splits the single output
//! row across threads; batched splits batch rows (tile-aligned for the
//! tiled layer).

pub mod engine;
pub mod frontend;
pub mod model;
pub mod server;
pub mod shard;

pub use engine::{
    Engine, EngineBuilder, EpochScratch, KernelEngine, PersistentShardedEngine, QuantMode,
    ReplicatedEngine, ScopedShardedEngine, ShardedEpochScratch, SwappableEngine, SwappableScratch,
};
pub use frontend::{FrontendHandle, FrontendStats};
pub use model::{Activation, LayerSpec, ModelEpoch, ModelLayer, Repr, Scratch, SparseModel};
pub use shard::{ShardPlan, ShardPlanError, ShardedModel, ShardedScratch};

use crate::kernels::{self, Microkernel};
use crate::sparsity::{Condensed, CondensedError, CondensedTiled, Csr, Mask, QuantizedCondensed};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A linear layer representation that can run a batched forward pass.
pub trait LinearKernel: Send + Sync {
    fn name(&self) -> &'static str;
    /// Output features per example (n for dense/CSR; n_active for the
    /// structured/condensed compact forms).
    fn out_width(&self) -> usize;
    fn in_width(&self) -> usize;
    /// x: (batch, d) row-major; out: (batch, out_width) row-major,
    /// preallocated. `threads` >= 1.
    fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize);
    /// Bytes this representation occupies (weights + indices + bias).
    fn storage_bytes(&self) -> usize;
    /// Surviving (non-ablated) output-neuron ids in ascending *full
    /// logical* coordinates — `Some` only for the compact forms that emit
    /// fewer rows than the layer's logical width.
    fn active_rows(&self) -> Option<&[u32]> {
        None
    }
    /// Slice this kernel to the contiguous full-logical-width output-neuron
    /// range `lo..hi` — the tensor-parallel sharding primitive. The paper's
    /// constant fan-in makes every contiguous neuron range of a condensed
    /// kernel itself a valid condensed kernel (each output neuron owns
    /// exactly k weights), and the same holds trivially for the other
    /// representations — including the batch-tiled one, whose tiling runs
    /// over the *batch* dimension and is untouched by a neuron-range cut.
    /// The slice copies the underlying rows verbatim (and inherits the
    /// microkernel handle), so a sliced forward is bit-for-bit identical
    /// to the corresponding rows of the unsliced forward.
    fn slice_rows(&self, lo: usize, hi: usize) -> Box<dyn LinearKernel>;
    /// Stored weights per full logical output neuron (len `full_width`) —
    /// the [`shard::ShardPlan`] balancing costs. Ablated neurons cost 0 in
    /// the compact forms and their CSR rows are empty, so balancing by
    /// these weights (not by neuron count) keeps shard compute even.
    fn row_weights(&self, full_width: usize) -> Vec<usize>;
    /// The int8 quantized twin of this kernel (`tiled` selects the
    /// batch-tiled variant), calibrated against this kernel's own f32
    /// weights. `None` for representations without the constant-fan-in
    /// condensed structure quantization relies on (dense/CSR/structured);
    /// `Some(Err(..))` when the geometry cannot be quantized (input width
    /// over the u16 index limit). The quantized forms return a
    /// re-wrapped clone of themselves, so the transform is idempotent.
    fn quantized(&self, _tiled: bool) -> Option<Result<Box<dyn LinearKernel>, CondensedError>> {
        None
    }
    /// The same representation re-stamped onto a different microkernel
    /// handle — the per-side `kernel=` override of the arena (a process
    /// has one auto-selected kind; dueling scalar-vs-AVX2 inside that
    /// process needs per-model stamps). Callers must only pass kinds
    /// that are available on this CPU.
    fn with_kernel(&self, mk: Microkernel) -> Box<dyn LinearKernel>;
}

// ---------------------------------------------------------------------------
// Dense
// ---------------------------------------------------------------------------

pub struct DenseLayer {
    pub n: usize,
    pub d: usize,
    /// (n, d) row-major.
    pub w: Vec<f32>,
    pub bias: Vec<f32>,
    /// Microkernel selection (inherited by slices; see [`crate::kernels`]).
    pub mk: Microkernel,
}

impl DenseLayer {
    pub fn new(w: &Tensor, bias: Vec<f32>) -> DenseLayer {
        let (n, d) = w.neuron_view();
        assert_eq!(bias.len(), n);
        DenseLayer { n, d, w: w.data.clone(), bias, mk: Microkernel::auto() }
    }
}

impl LinearKernel for DenseLayer {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn out_width(&self) -> usize {
        self.n
    }

    fn in_width(&self) -> usize {
        self.d
    }

    fn storage_bytes(&self) -> usize {
        (self.w.len() + self.bias.len()) * 4
    }

    fn slice_rows(&self, lo: usize, hi: usize) -> Box<dyn LinearKernel> {
        assert!(lo <= hi && hi <= self.n, "slice {lo}..{hi} out of 0..{}", self.n);
        Box::new(DenseLayer {
            n: hi - lo,
            d: self.d,
            w: self.w[lo * self.d..hi * self.d].to_vec(),
            bias: self.bias[lo..hi].to_vec(),
            mk: self.mk,
        })
    }

    fn row_weights(&self, full_width: usize) -> Vec<usize> {
        assert_eq!(full_width, self.n);
        // dense stores (and computes) every row, ablated or not
        vec![self.d; self.n]
    }

    fn with_kernel(&self, mk: Microkernel) -> Box<dyn LinearKernel> {
        Box::new(DenseLayer { n: self.n, d: self.d, w: self.w.clone(), bias: self.bias.clone(), mk })
    }

    fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize) {
        debug_assert_eq!(x.len(), batch * self.d);
        debug_assert_eq!(out.len(), batch * self.n);
        let mk = self.mk;
        kernels::forward_rows(x, self.d, batch, out, threads, |xb, r| {
            mk.dot(&self.w[r * self.d..(r + 1) * self.d], xb) + self.bias[r]
        });
    }
}

// ---------------------------------------------------------------------------
// CSR (unstructured)
// ---------------------------------------------------------------------------

pub struct CsrLayer {
    pub csr: Csr,
    pub bias: Vec<f32>,
    /// Microkernel selection (inherited by slices; see [`crate::kernels`]).
    pub mk: Microkernel,
}

impl CsrLayer {
    pub fn new(w: &Tensor, bias: Vec<f32>) -> CsrLayer {
        let csr = Csr::from_dense(w);
        assert_eq!(bias.len(), csr.rows);
        // Same once-validated invariant as CondensedLayer (§Perf iter. 2):
        // column indices in range, so the gather can skip bounds checks.
        assert!(csr.indices.iter().all(|&j| (j as usize) < csr.cols));
        CsrLayer { csr, bias, mk: Microkernel::auto() }
    }
}

impl LinearKernel for CsrLayer {
    fn name(&self) -> &'static str {
        "csr"
    }

    fn out_width(&self) -> usize {
        self.csr.rows
    }

    fn in_width(&self) -> usize {
        self.csr.cols
    }

    fn storage_bytes(&self) -> usize {
        self.csr.storage_bytes() + self.bias.len() * 4
    }

    fn slice_rows(&self, lo: usize, hi: usize) -> Box<dyn LinearKernel> {
        assert!(lo <= hi && hi <= self.csr.rows, "slice {lo}..{hi} out of 0..{}", self.csr.rows);
        let base = self.csr.indptr[lo];
        let csr = Csr {
            rows: hi - lo,
            cols: self.csr.cols,
            indptr: self.csr.indptr[lo..=hi].iter().map(|&p| p - base).collect(),
            indices: self.csr.indices[base as usize..self.csr.indptr[hi] as usize].to_vec(),
            values: self.csr.values[base as usize..self.csr.indptr[hi] as usize].to_vec(),
        };
        Box::new(CsrLayer { csr, bias: self.bias[lo..hi].to_vec(), mk: self.mk })
    }

    fn row_weights(&self, full_width: usize) -> Vec<usize> {
        assert_eq!(full_width, self.csr.rows);
        self.csr
            .indptr
            .windows(2)
            .map(|w| (w[1] - w[0]) as usize)
            .collect()
    }

    fn with_kernel(&self, mk: Microkernel) -> Box<dyn LinearKernel> {
        Box::new(CsrLayer { csr: self.csr.clone(), bias: self.bias.clone(), mk })
    }

    fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize) {
        debug_assert_eq!(out.len(), batch * self.csr.rows);
        let mk = self.mk;
        kernels::forward_rows(x, self.csr.cols, batch, out, threads, |xb, r| {
            let lo = self.csr.indptr[r] as usize;
            let hi = self.csr.indptr[r + 1] as usize;
            // SAFETY: column indices validated `< cols` once in `new`.
            let s = unsafe {
                mk.gather(&self.csr.values[lo..hi], &self.csr.indices[lo..hi], xb)
            };
            s + self.bias[r]
        });
    }
}

// ---------------------------------------------------------------------------
// Structured-only (neuron ablation, dense surviving rows)
// ---------------------------------------------------------------------------

pub struct StructuredLayer {
    pub n_active: usize,
    /// Logical rows of the original matrix (incl. ablated) — retained so
    /// slicing can validate ranges like the other representations.
    pub n_orig: usize,
    pub d: usize,
    /// (n_active, d) packed dense rows of the surviving neurons.
    pub w: Vec<f32>,
    pub bias: Vec<f32>,
    pub active: Vec<u32>,
    /// Microkernel selection (inherited by slices; see [`crate::kernels`]).
    pub mk: Microkernel,
}

impl StructuredLayer {
    /// Pack the surviving rows of a (possibly sparse) weight matrix. The
    /// rows keep their zeros — structured-only ignores fine-grained
    /// sparsity by design (paper Fig. 4 "structured").
    pub fn new(w: &Tensor, mask: &Mask, bias: &[f32]) -> StructuredLayer {
        let (n, d) = w.neuron_view();
        assert_eq!(bias.len(), n);
        let counts = mask.fan_in_counts();
        let mut packed = Vec::new();
        let mut pbias = Vec::new();
        let mut active = Vec::new();
        for r in 0..n {
            if counts[r] > 0 {
                packed.extend_from_slice(&w.data[r * d..(r + 1) * d]);
                pbias.push(bias[r]);
                active.push(r as u32);
            }
        }
        StructuredLayer {
            n_active: active.len(),
            n_orig: n,
            d,
            w: packed,
            bias: pbias,
            active,
            mk: Microkernel::auto(),
        }
    }
}

impl LinearKernel for StructuredLayer {
    fn name(&self) -> &'static str {
        "structured"
    }

    fn out_width(&self) -> usize {
        self.n_active
    }

    fn in_width(&self) -> usize {
        self.d
    }

    fn storage_bytes(&self) -> usize {
        (self.w.len() + self.bias.len() + self.active.len()) * 4
    }

    fn active_rows(&self) -> Option<&[u32]> {
        Some(&self.active)
    }

    fn slice_rows(&self, lo: usize, hi: usize) -> Box<dyn LinearKernel> {
        assert!(lo <= hi && hi <= self.n_orig, "slice {lo}..{hi} out of 0..{}", self.n_orig);
        // active is ascending, so the surviving rows of lo..hi are a
        // contiguous run of the packed storage
        let p = self.active.partition_point(|&a| (a as usize) < lo);
        let q = self.active.partition_point(|&a| (a as usize) < hi);
        Box::new(StructuredLayer {
            n_active: q - p,
            n_orig: hi - lo,
            d: self.d,
            w: self.w[p * self.d..q * self.d].to_vec(),
            bias: self.bias[p..q].to_vec(),
            active: self.active[p..q].iter().map(|&a| a - lo as u32).collect(),
            mk: self.mk,
        })
    }

    fn row_weights(&self, full_width: usize) -> Vec<usize> {
        assert_eq!(full_width, self.n_orig);
        let mut w = vec![0usize; full_width];
        for &a in &self.active {
            w[a as usize] = self.d; // structured stores the full dense row
        }
        w
    }

    fn with_kernel(&self, mk: Microkernel) -> Box<dyn LinearKernel> {
        Box::new(StructuredLayer {
            n_active: self.n_active,
            n_orig: self.n_orig,
            d: self.d,
            w: self.w.clone(),
            bias: self.bias.clone(),
            active: self.active.clone(),
            mk,
        })
    }

    fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize) {
        debug_assert_eq!(out.len(), batch * self.n_active);
        let mk = self.mk;
        kernels::forward_rows(x, self.d, batch, out, threads, |xb, r| {
            mk.dot(&self.w[r * self.d..(r + 1) * self.d], xb) + self.bias[r]
        });
    }
}

// ---------------------------------------------------------------------------
// Condensed (Algorithm 1)
// ---------------------------------------------------------------------------

pub struct CondensedLayer {
    pub c: Condensed,
    pub bias: Vec<f32>, // packed to active neurons
    /// Microkernel selection (inherited by slices; see [`crate::kernels`]).
    pub mk: Microkernel,
}

impl CondensedLayer {
    /// Build from weights + constant-fan-in mask. Fails with a typed
    /// [`CondensedError`] (fan-in disagreement, shape mismatch) instead of
    /// panicking — a bad manifest must be a startup error, not a worker
    /// crash.
    pub fn new(w: &Tensor, mask: &Mask, bias: &[f32]) -> Result<CondensedLayer, CondensedError> {
        let c = Condensed::from_masked(w, mask)?;
        // Validate the index invariant once so the forward pass can gather
        // without per-element bounds checks (§Perf iteration 1).
        assert!(c.idx.iter().all(|&j| (j as usize) < c.d), "index out of range");
        let pbias = c.active.iter().map(|&r| bias[r as usize]).collect();
        Ok(CondensedLayer { c, bias: pbias, mk: Microkernel::auto() })
    }
}

impl LinearKernel for CondensedLayer {
    fn name(&self) -> &'static str {
        "condensed"
    }

    fn out_width(&self) -> usize {
        self.c.n_active()
    }

    fn in_width(&self) -> usize {
        self.c.d
    }

    fn storage_bytes(&self) -> usize {
        self.c.storage_bytes() + self.bias.len() * 4
    }

    fn active_rows(&self) -> Option<&[u32]> {
        Some(&self.c.active)
    }

    fn slice_rows(&self, lo: usize, hi: usize) -> Box<dyn LinearKernel> {
        assert!(lo <= hi && hi <= self.c.n_orig, "slice {lo}..{hi} out of 0..{}", self.c.n_orig);
        let k = self.c.k;
        let p = self.c.active.partition_point(|&a| (a as usize) < lo);
        let q = self.c.active.partition_point(|&a| (a as usize) < hi);
        let c = Condensed {
            d: self.c.d,
            n_orig: hi - lo,
            k,
            active: self.c.active[p..q].iter().map(|&a| a - lo as u32).collect(),
            values: self.c.values[p * k..q * k].to_vec(),
            idx: self.c.idx[p * k..q * k].to_vec(),
        };
        Box::new(CondensedLayer { c, bias: self.bias[p..q].to_vec(), mk: self.mk })
    }

    fn row_weights(&self, full_width: usize) -> Vec<usize> {
        assert_eq!(full_width, self.c.n_orig);
        let mut w = vec![0usize; full_width];
        for &a in &self.c.active {
            w[a as usize] = self.c.k; // constant fan-in: k stored weights each
        }
        w
    }

    fn quantized(&self, tiled: bool) -> Option<Result<Box<dyn LinearKernel>, CondensedError>> {
        Some(QuantizedCondensed::from_condensed(&self.c).map(|q| {
            if tiled {
                Box::new(QuantizedTiledLayer { q, bias: self.bias.clone(), mk: self.mk })
                    as Box<dyn LinearKernel>
            } else {
                Box::new(QuantizedLayer { q, bias: self.bias.clone(), mk: self.mk })
            }
        }))
    }

    fn with_kernel(&self, mk: Microkernel) -> Box<dyn LinearKernel> {
        Box::new(CondensedLayer { c: self.c.clone(), bias: self.bias.clone(), mk })
    }

    fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize) {
        let k = self.c.k;
        debug_assert_eq!(out.len(), batch * self.c.n_active());
        let mk = self.mk;
        kernels::forward_rows(x, self.c.d, batch, out, threads, |xb, r| {
            // SAFETY: indices validated `< d` once in `new` — the gather
            // (paper Algorithm 1 inner loop) skips bounds checks.
            let s = unsafe {
                mk.gather(&self.c.values[r * k..(r + 1) * k], &self.c.idx[r * k..(r + 1) * k], xb)
            };
            s + self.bias[r]
        });
    }
}

// ---------------------------------------------------------------------------
// Condensed, batch-tiled (Algorithm 1 + input-tile transpose)
// ---------------------------------------------------------------------------

/// The batch-tiled condensed representation: identical semantics (and
/// storage bytes) to [`CondensedLayer`], but on the interleaved
/// [`CondensedTiled`] layout consumed by [`crate::kernels::tiled`] — at
/// batch >= [`crate::kernels::TILE`] each stored weight costs one
/// contiguous 8-wide load + broadcast-MAC across the batch columns
/// instead of `TILE` indexed loads. Batches below the tile width (and the
/// ragged remainder) run a row kernel with the identical per-element
/// association, so outputs never depend on where a row landed in the
/// batch (the serving front-end's packing requires exactly this).
pub struct CondensedTiledLayer {
    pub t: CondensedTiled,
    pub bias: Vec<f32>, // packed to active neurons
    /// Microkernel selection (inherited by slices; see [`crate::kernels`]).
    pub mk: Microkernel,
}

impl CondensedTiledLayer {
    /// Build from weights + constant-fan-in mask (same typed-error
    /// contract as [`CondensedLayer::new`]).
    pub fn new(
        w: &Tensor,
        mask: &Mask,
        bias: &[f32],
    ) -> Result<CondensedTiledLayer, CondensedError> {
        let t = CondensedTiled::from_masked(w, mask)?;
        assert!(t.pairs.iter().all(|p| (p.idx as usize) < t.d), "index out of range");
        let pbias = t.active.iter().map(|&r| bias[r as usize]).collect();
        Ok(CondensedTiledLayer { t, bias: pbias, mk: Microkernel::auto() })
    }
}

impl LinearKernel for CondensedTiledLayer {
    fn name(&self) -> &'static str {
        "condensed-tiled"
    }

    fn out_width(&self) -> usize {
        self.t.n_active()
    }

    fn in_width(&self) -> usize {
        self.t.d
    }

    fn storage_bytes(&self) -> usize {
        self.t.storage_bytes() + self.bias.len() * 4
    }

    fn active_rows(&self) -> Option<&[u32]> {
        Some(&self.t.active)
    }

    fn slice_rows(&self, lo: usize, hi: usize) -> Box<dyn LinearKernel> {
        assert!(lo <= hi && hi <= self.t.n_orig, "slice {lo}..{hi} out of 0..{}", self.t.n_orig);
        let k = self.t.k;
        let p = self.t.active.partition_point(|&a| (a as usize) < lo);
        let q = self.t.active.partition_point(|&a| (a as usize) < hi);
        let t = CondensedTiled {
            d: self.t.d,
            n_orig: hi - lo,
            k,
            active: self.t.active[p..q].iter().map(|&a| a - lo as u32).collect(),
            pairs: self.t.pairs[p * k..q * k].to_vec(),
        };
        Box::new(CondensedTiledLayer { t, bias: self.bias[p..q].to_vec(), mk: self.mk })
    }

    fn row_weights(&self, full_width: usize) -> Vec<usize> {
        assert_eq!(full_width, self.t.n_orig);
        let mut w = vec![0usize; full_width];
        for &a in &self.t.active {
            w[a as usize] = self.t.k; // constant fan-in: k stored weights each
        }
        w
    }

    fn quantized(&self, tiled: bool) -> Option<Result<Box<dyn LinearKernel>, CondensedError>> {
        Some(QuantizedCondensed::from_condensed(&self.t.to_condensed()).map(|q| {
            if tiled {
                Box::new(QuantizedTiledLayer { q, bias: self.bias.clone(), mk: self.mk })
                    as Box<dyn LinearKernel>
            } else {
                Box::new(QuantizedLayer { q, bias: self.bias.clone(), mk: self.mk })
            }
        }))
    }

    fn with_kernel(&self, mk: Microkernel) -> Box<dyn LinearKernel> {
        Box::new(CondensedTiledLayer { t: self.t.clone(), bias: self.bias.clone(), mk })
    }

    fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize) {
        kernels::tiled::forward_tiled(
            &self.t.pairs,
            self.t.k,
            self.t.n_active(),
            self.t.d,
            &self.bias,
            x,
            batch,
            out,
            threads,
            self.mk,
        );
    }
}

// ---------------------------------------------------------------------------
// Quantized condensed (int8 weights, i32 accumulate, calibrated scales)
// ---------------------------------------------------------------------------

/// The int8 quantized condensed representation (row-gather driver):
/// [`CondensedLayer`] semantics within the documented per-row error
/// budget, on 4-byte `(u16 idx, i8 q)` records with least-squares
/// calibrated per-row scales ([`crate::sparsity::quantized`]). The i32
/// accumulation is exact, so — unlike the f32 family's ULP bound —
/// outputs are bit-for-bit identical across kernel kinds, batch
/// positions, thread counts, shard cuts, and engines.
pub struct QuantizedLayer {
    pub q: QuantizedCondensed,
    pub bias: Vec<f32>, // packed to active neurons
    /// Microkernel selection (inherited by slices; see [`crate::kernels`]).
    pub mk: Microkernel,
}

impl QuantizedLayer {
    /// Build from weights + constant-fan-in mask (same typed-error
    /// contract as [`CondensedLayer::new`], plus
    /// [`CondensedError::WidthTooLarge`] when `d` overflows the u16
    /// index). `bias` is full-width; it is packed to active neurons.
    pub fn new(w: &Tensor, mask: &Mask, bias: &[f32]) -> Result<QuantizedLayer, CondensedError> {
        let q = QuantizedCondensed::from_masked(w, mask)?;
        // Validate the index invariant once so the forward pass can
        // gather without per-element bounds checks (same contract as the
        // f32 condensed forms).
        assert!(q.recs.iter().all(|p| (p.idx as usize) < q.d), "index out of range");
        let pbias = q.active.iter().map(|&r| bias[r as usize]).collect();
        Ok(QuantizedLayer { q, bias: pbias, mk: Microkernel::auto() })
    }
}

impl LinearKernel for QuantizedLayer {
    fn name(&self) -> &'static str {
        "quantized"
    }

    fn out_width(&self) -> usize {
        self.q.n_active()
    }

    fn in_width(&self) -> usize {
        self.q.d
    }

    fn storage_bytes(&self) -> usize {
        self.q.storage_bytes() + self.bias.len() * 4
    }

    fn active_rows(&self) -> Option<&[u32]> {
        Some(&self.q.active)
    }

    fn slice_rows(&self, lo: usize, hi: usize) -> Box<dyn LinearKernel> {
        assert!(lo <= hi && hi <= self.q.n_orig, "slice {lo}..{hi} out of 0..{}", self.q.n_orig);
        Box::new(QuantizedLayer {
            q: slice_quantized(&self.q, lo, hi),
            bias: slice_packed(&self.q.active, &self.bias, lo, hi),
            mk: self.mk,
        })
    }

    fn row_weights(&self, full_width: usize) -> Vec<usize> {
        assert_eq!(full_width, self.q.n_orig);
        let mut w = vec![0usize; full_width];
        for &a in &self.q.active {
            w[a as usize] = self.q.k; // constant fan-in: k stored weights each
        }
        w
    }

    fn quantized(&self, tiled: bool) -> Option<Result<Box<dyn LinearKernel>, CondensedError>> {
        // already quantized: re-wrap under the requested driver
        Some(Ok(if tiled {
            Box::new(QuantizedTiledLayer { q: self.q.clone(), bias: self.bias.clone(), mk: self.mk })
        } else {
            Box::new(QuantizedLayer { q: self.q.clone(), bias: self.bias.clone(), mk: self.mk })
        }))
    }

    fn with_kernel(&self, mk: Microkernel) -> Box<dyn LinearKernel> {
        Box::new(QuantizedLayer { q: self.q.clone(), bias: self.bias.clone(), mk })
    }

    fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize) {
        kernels::quant::forward_quant(
            &self.q.recs,
            self.q.k,
            self.q.n_active(),
            self.q.d,
            &self.q.scales,
            &self.bias,
            x,
            batch,
            out,
            threads,
            self.mk,
        );
    }
}

/// The batch-tiled twin of [`QuantizedLayer`]: same stored records and
/// scales, driven by the transposed-i8-tile kernel — `d x TILE` *bytes*
/// of staging per tile (4x smaller than the f32 tile buffer), one 8-byte
/// contiguous load + integer broadcast-MAC per stored weight at batch >=
/// [`crate::kernels::TILE`]. Remainder rows reuse the row driver, which
/// quantizes to the same integers — outputs stay bit-for-bit
/// batch-position invariant.
pub struct QuantizedTiledLayer {
    pub q: QuantizedCondensed,
    pub bias: Vec<f32>, // packed to active neurons
    /// Microkernel selection (inherited by slices; see [`crate::kernels`]).
    pub mk: Microkernel,
}

impl QuantizedTiledLayer {
    /// Build from weights + constant-fan-in mask (same contract as
    /// [`QuantizedLayer::new`]).
    pub fn new(
        w: &Tensor,
        mask: &Mask,
        bias: &[f32],
    ) -> Result<QuantizedTiledLayer, CondensedError> {
        let q = QuantizedCondensed::from_masked(w, mask)?;
        assert!(q.recs.iter().all(|p| (p.idx as usize) < q.d), "index out of range");
        let pbias = q.active.iter().map(|&r| bias[r as usize]).collect();
        Ok(QuantizedTiledLayer { q, bias: pbias, mk: Microkernel::auto() })
    }
}

impl LinearKernel for QuantizedTiledLayer {
    fn name(&self) -> &'static str {
        "quantized-tiled"
    }

    fn out_width(&self) -> usize {
        self.q.n_active()
    }

    fn in_width(&self) -> usize {
        self.q.d
    }

    fn storage_bytes(&self) -> usize {
        self.q.storage_bytes() + self.bias.len() * 4
    }

    fn active_rows(&self) -> Option<&[u32]> {
        Some(&self.q.active)
    }

    fn slice_rows(&self, lo: usize, hi: usize) -> Box<dyn LinearKernel> {
        assert!(lo <= hi && hi <= self.q.n_orig, "slice {lo}..{hi} out of 0..{}", self.q.n_orig);
        Box::new(QuantizedTiledLayer {
            q: slice_quantized(&self.q, lo, hi),
            bias: slice_packed(&self.q.active, &self.bias, lo, hi),
            mk: self.mk,
        })
    }

    fn row_weights(&self, full_width: usize) -> Vec<usize> {
        assert_eq!(full_width, self.q.n_orig);
        let mut w = vec![0usize; full_width];
        for &a in &self.q.active {
            w[a as usize] = self.q.k; // constant fan-in: k stored weights each
        }
        w
    }

    fn quantized(&self, tiled: bool) -> Option<Result<Box<dyn LinearKernel>, CondensedError>> {
        // already quantized: re-wrap under the requested driver
        Some(Ok(if tiled {
            Box::new(QuantizedTiledLayer { q: self.q.clone(), bias: self.bias.clone(), mk: self.mk })
        } else {
            Box::new(QuantizedLayer { q: self.q.clone(), bias: self.bias.clone(), mk: self.mk })
        }))
    }

    fn with_kernel(&self, mk: Microkernel) -> Box<dyn LinearKernel> {
        Box::new(QuantizedTiledLayer { q: self.q.clone(), bias: self.bias.clone(), mk })
    }

    fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize) {
        kernels::quant::forward_quant_tiled(
            &self.q.recs,
            self.q.k,
            self.q.n_active(),
            self.q.d,
            &self.q.scales,
            &self.bias,
            x,
            batch,
            out,
            threads,
            self.mk,
        );
    }
}

/// Slice the quantized storage to the full-logical-width neuron range
/// `lo..hi` (shared by both quantized drivers): `active` is ascending,
/// so the surviving rows are a contiguous run `p..q` of the packed
/// arrays, and the per-row scale/budget side arrays slice with them.
fn slice_quantized(src: &QuantizedCondensed, lo: usize, hi: usize) -> QuantizedCondensed {
    let k = src.k;
    let p = src.active.partition_point(|&a| (a as usize) < lo);
    let q = src.active.partition_point(|&a| (a as usize) < hi);
    QuantizedCondensed {
        d: src.d,
        n_orig: hi - lo,
        k,
        active: src.active[p..q].iter().map(|&a| a - lo as u32).collect(),
        recs: src.recs[p * k..q * k].to_vec(),
        scales: src.scales[p..q].to_vec(),
        resid_l1: src.resid_l1[p..q].to_vec(),
        qabs_l1: src.qabs_l1[p..q].to_vec(),
    }
}

/// The `p..q` run of a packed (active-neurons-only) side array for the
/// neuron range `lo..hi`.
fn slice_packed(active: &[u32], packed: &[f32], lo: usize, hi: usize) -> Vec<f32> {
    let p = active.partition_point(|&a| (a as usize) < lo);
    let q = active.partition_point(|&a| (a as usize) < hi);
    packed[p..q].to_vec()
}

// ---------------------------------------------------------------------------
// Test-layer factory: an SRigL-shaped sparse layer (constant fan-in +
// a fraction of ablated neurons), used by benches and the exp harnesses.
// ---------------------------------------------------------------------------

pub struct LayerBundle {
    pub dense: DenseLayer,
    /// CSR of the *same* SRigL matrix (pattern = constant fan-in) —
    /// used by correctness tests; rows are uniform so this flatters CSR.
    pub csr: CsrLayer,
    /// CSR of an *unstructured* mask with identical nnz — the paper's
    /// Fig. 4 "unstructured (CSR)" baseline (timing harnesses use this).
    pub csr_unstructured: CsrLayer,
    pub structured: StructuredLayer,
    pub condensed: CondensedLayer,
    /// The batch-tiled twin of `condensed` (same weights, interleaved
    /// layout) — what the kernel benches race against it.
    pub condensed_tiled: CondensedTiledLayer,
    /// The int8 quantization of `condensed` (row-gather driver) — close
    /// to the f32 layers within its error budget, bit-for-bit only
    /// against its own tiled twin.
    pub quantized: QuantizedLayer,
    /// The batch-tiled twin of `quantized` (same records and scales).
    pub quantized_tiled: QuantizedTiledLayer,
    pub w: Tensor,
    pub mask: Mask,
    pub bias: Vec<f32>,
}

impl LayerBundle {
    /// `sparsity` sets k = round(d*(1-s)); `ablated_frac` of neurons are
    /// fully masked (what SRigL's dynamic ablation produces). The synthesis
    /// recipe lives in [`model::synth_layer`] (shared with the test suites).
    pub fn synth(n: usize, d: usize, sparsity: f64, ablated_frac: f64, seed: u64) -> LayerBundle {
        let mut rng = Rng::new(seed);
        let (w, mask, bias) = model::synth_layer(n, d, sparsity, ablated_frac, &mut rng);
        LayerBundle::build(w, mask, bias)
    }

    pub fn build(w: Tensor, mask: Mask, bias: Vec<f32>) -> LayerBundle {
        let dense = DenseLayer::new(&w, bias.clone());
        let csr = CsrLayer::new(&w, bias.clone());
        // unstructured twin: same shape and nnz, random positions/values
        let (n, d) = w.neuron_view();
        let nnz = mask.nnz();
        let mut rng = Rng::new(0x5eed ^ nnz as u64);
        let um = Mask::random_per_layer(&[n, d], nnz, &mut rng);
        let mut uw = Tensor::normal(&[n, d], 1.0, &mut rng);
        uw.mul_assign(&um.t);
        let csr_unstructured = CsrLayer::new(&uw, bias.clone());
        let structured = StructuredLayer::new(&w, &mask, &bias);
        let condensed =
            CondensedLayer::new(&w, &mask, &bias).expect("synth masks have constant fan-in");
        let condensed_tiled =
            CondensedTiledLayer::new(&w, &mask, &bias).expect("synth masks have constant fan-in");
        let quantized =
            QuantizedLayer::new(&w, &mask, &bias).expect("synth layers fit the u16 index");
        let quantized_tiled =
            QuantizedTiledLayer::new(&w, &mask, &bias).expect("synth layers fit the u16 index");
        LayerBundle {
            dense,
            csr,
            csr_unstructured,
            structured,
            condensed,
            condensed_tiled,
            quantized,
            quantized_tiled,
            w,
            mask,
            bias,
        }
    }

    /// The four Fig. 4 representations (CSR = the unstructured baseline).
    pub fn kernels(&self) -> Vec<&dyn LinearKernel> {
        vec![&self.dense, &self.csr_unstructured, &self.structured, &self.condensed]
    }

    /// Every representation of the *same* matrix (CSR here is the
    /// constant-fan-in twin, not the unstructured baseline) — what the
    /// equivalence/slicing suites iterate. The quantized pair carries the
    /// same matrix *within its error budget* — suites comparing outputs
    /// across representations must compare within-kernel only (slice
    /// partitions, batch-position invariance), which hold bit-for-bit for
    /// every entry here.
    pub fn kernels_same_matrix(&self) -> Vec<&dyn LinearKernel> {
        vec![
            &self.dense,
            &self.csr,
            &self.structured,
            &self.condensed,
            &self.condensed_tiled,
            &self.quantized,
            &self.quantized_tiled,
        ]
    }
}

/// Gather a compact (active-only) output back into full-width layout —
/// used when a downstream consumer expects the original width.
pub fn scatter_compact(compact: &[f32], active: &[u32], n_orig: usize, batch: usize) -> Vec<f32> {
    let na = active.len();
    let mut out = vec![0f32; batch * n_orig];
    for b in 0..batch {
        for (i, &r) in active.iter().enumerate() {
            out[b * n_orig + r as usize] = compact[b * na + i];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_forward(w: &Tensor, bias: &[f32], x: &[f32], batch: usize) -> Vec<f32> {
        let (n, d) = w.neuron_view();
        let mut out = vec![0f32; batch * n];
        for b in 0..batch {
            for r in 0..n {
                let mut acc = bias[r];
                for j in 0..d {
                    acc += w.data[r * d + j] * x[b * d + j];
                }
                out[b * n + r] = acc;
            }
        }
        out
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + x.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn all_representations_agree() {
        for &(batch, threads) in &[(1usize, 1usize), (1, 4), (7, 1), (7, 3), (16, 8)] {
            let bundle = LayerBundle::synth(48, 96, 0.9, 0.25, 42);
            let mut rng = Rng::new(9);
            let x: Vec<f32> = (0..batch * 96).map(|_| rng.normal_f32()).collect();
            let expect = naive_forward(&bundle.w, &bundle.bias, &x, batch);

            let mut out_d = vec![0f32; batch * bundle.dense.out_width()];
            bundle.dense.forward(&x, batch, &mut out_d, threads);
            assert_close(&out_d, &expect, 1e-4);

            let mut out_c = vec![0f32; batch * bundle.csr.out_width()];
            bundle.csr.forward(&x, batch, &mut out_c, threads);
            assert_close(&out_c, &expect, 1e-4);

            // compact outputs scatter back to the dense layout (ablated
            // rows only carry their bias in the dense result; compare on
            // active rows).
            let mut out_s = vec![0f32; batch * bundle.structured.out_width()];
            bundle.structured.forward(&x, batch, &mut out_s, threads);
            let mut out_k = vec![0f32; batch * bundle.condensed.out_width()];
            bundle.condensed.forward(&x, batch, &mut out_k, threads);
            let mut out_t = vec![0f32; batch * bundle.condensed_tiled.out_width()];
            bundle.condensed_tiled.forward(&x, batch, &mut out_t, threads);
            assert_close(&out_k, &out_s, 1e-4);
            assert_close(&out_t, &out_s, 1e-4);
            for b in 0..batch {
                for (i, &r) in bundle.structured.active.iter().enumerate() {
                    let e = expect[b * 48 + r as usize];
                    let g = out_s[b * bundle.structured.n_active + i];
                    assert!((e - g).abs() < 1e-4 * (1.0 + e.abs()), "b={b} r={r}: {e} vs {g}");
                }
            }
        }
    }

    #[test]
    fn condensed_matches_xla_semantics_with_k1() {
        let bundle = LayerBundle::synth(8, 16, 0.95, 0.0, 1);
        assert_eq!(bundle.condensed.c.k, 1);
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut out = vec![0f32; 8];
        bundle.condensed.forward(&x, 1, &mut out, 1);
        let mut expect = vec![0f32; 8];
        bundle.dense.forward(&x, 1, &mut expect, 1);
        assert_close(&out, &expect, 1e-5);
    }

    #[test]
    fn scatter_compact_roundtrip() {
        let compact = vec![1.0, 2.0, 3.0, 4.0]; // batch 2, 2 active
        let full = scatter_compact(&compact, &[1, 3], 5, 2);
        assert_eq!(full, vec![0., 1., 0., 2., 0., 0., 3., 0., 4., 0.]);
    }

    #[test]
    fn slice_rows_partitions_every_representation() {
        // two slices at an arbitrary cut must reproduce the full forward
        // bit-for-bit, rows concatenated (compact forms: the active lists
        // partition, so the compact outputs concatenate too). Batch 9
        // covers the tiled layer's full-tile AND ragged-remainder paths.
        let bundle = LayerBundle::synth(24, 32, 0.85, 0.3, 5);
        let batch = 9;
        let mut rng = Rng::new(77);
        let x: Vec<f32> = (0..batch * 32).map(|_| rng.normal_f32()).collect();
        for kernel in bundle.kernels_same_matrix() {
            let ow = kernel.out_width();
            let mut full = vec![0f32; batch * ow];
            kernel.forward(&x, batch, &mut full, 1);
            for cut in [0usize, 7, 13, 24] {
                let (a, b) = (kernel.slice_rows(0, cut), kernel.slice_rows(cut, 24));
                let (wa, wb) = (a.out_width(), b.out_width());
                assert_eq!(wa + wb, ow, "{} cut {cut}: slices must partition", kernel.name());
                let mut oa = vec![0f32; batch * wa];
                let mut ob = vec![0f32; batch * wb];
                a.forward(&x, batch, &mut oa, 1);
                b.forward(&x, batch, &mut ob, 1);
                for bi in 0..batch {
                    let got: Vec<u32> = oa[bi * wa..(bi + 1) * wa]
                        .iter()
                        .chain(&ob[bi * wb..(bi + 1) * wb])
                        .map(|v| v.to_bits())
                        .collect();
                    let want: Vec<u32> =
                        full[bi * ow..(bi + 1) * ow].iter().map(|v| v.to_bits()).collect();
                    assert_eq!(got, want, "{} cut {cut} row {bi}", kernel.name());
                }
            }
        }
    }

    #[test]
    fn row_weights_reflect_stored_weights() {
        let bundle = LayerBundle::synth(16, 20, 0.8, 0.25, 9);
        let n_active = bundle.condensed.c.n_active();
        let k = bundle.condensed.c.k;
        assert_eq!(bundle.dense.row_weights(16).iter().sum::<usize>(), 16 * 20);
        assert_eq!(bundle.csr.row_weights(16).iter().sum::<usize>(), bundle.csr.csr.nnz());
        assert_eq!(bundle.structured.row_weights(16).iter().sum::<usize>(), n_active * 20);
        let cw = bundle.condensed.row_weights(16);
        assert_eq!(cw.iter().sum::<usize>(), n_active * k);
        // the tiled twin stores exactly the same weights per neuron
        assert_eq!(bundle.condensed_tiled.row_weights(16), cw);
        assert_eq!(
            bundle.condensed_tiled.storage_bytes(),
            bundle.condensed.storage_bytes(),
            "interleaving is byte-neutral"
        );
        // ablated rows cost 0 in the compact forms
        for r in 0..16 {
            let ablated = !bundle.condensed.c.active.contains(&(r as u32));
            assert_eq!(cw[r] == 0, ablated, "row {r}");
        }
    }

    #[test]
    fn storage_ordering_fig4() {
        // condensed < csr < dense bytes at 90% sparsity (memory claim §1).
        let b = LayerBundle::synth(768, 3072, 0.9, 0.1, 7);
        let dense_bytes = b.w.numel() * 4;
        assert!(b.condensed.c.storage_bytes() < b.csr.csr.storage_bytes());
        assert!(b.csr.csr.storage_bytes() < dense_bytes);
    }
}
