//! Tensor-parallel sharded execution: split every layer's output neurons
//! across a team of S shard workers so one request's forward runs on S
//! cores *within* the request — the alternative to the worker-pool's
//! replicate-everything scaling.
//!
//! The paper's constant fan-in constraint makes output-neuron sharding
//! natural: each output neuron owns exactly k weights, so any contiguous
//! neuron range of a condensed layer is itself a valid condensed kernel
//! (the same property that makes N:M-style structured sparsity
//! hardware-friendly). The other three representations slice the same way.
//!
//! Pieces:
//!
//! * [`ShardPlan`] — per layer, S+1 monotone cut points over the *full
//!   logical* neuron range, balanced by **stored weights** rather than
//!   neuron count so ablated neurons (which cost nothing in the compact
//!   forms) don't skew shard load. [`ShardPlan::balanced`] returns a typed
//!   [`ShardPlanError`] when the request cannot be satisfied (zero shards,
//!   or more shards than the narrowest layer has neurons) instead of
//!   silently clamping.
//! * [`ShardedModel`] — each shard holds [`ModelLayer::slice`]s of every
//!   layer. `ShardedModel::shard_pass` is one shard's walk over the
//!   stack: at layer l, shard s computes its slice into private staging,
//!   then writes the disjoint column range `cuts[l][s]..cuts[l][s+1]` of a
//!   shared full-width activation buffer and waits on a [`Barrier`] so
//!   every shard sees the complete layer output before reading it as the
//!   next layer's input.
//!
//! Two drivers share `shard_pass` byte for byte:
//!
//! * [`ShardedModel::forward`] — the **scoped reference implementation**:
//!   spawns one scoped thread per shard per call. Kept as the executable
//!   specification the persistent team is pinned against.
//! * [`crate::inference::engine::PersistentShardedEngine`] — the
//!   production driver: a long-lived team parked on per-shard mailbox
//!   condvars, zero thread spawns per request.
//!
//! Outputs are **bit-for-bit identical** to the replicated
//! [`SparseModel::forward`]: slicing copies rows verbatim, each neuron's
//! dot product runs unchanged, and the scatter/zero-fill/ReLU sequence per
//! element matches the replicated path (`rust/tests/engine_conformance.rs`
//! pins all three execution paths against each other across reprs, shard
//! counts, and batch sizes).

use std::cell::UnsafeCell;
use std::ops::Range;
use std::sync::Barrier;

use anyhow::Result;

use super::model::ModelLayer;
use super::SparseModel;

/// Typed error from [`ShardPlan::balanced`]: the requested shard count
/// cannot give every shard a (possibly empty) contiguous range of every
/// layer in a useful way. Callers that *want* empty shards (e.g. tests of
/// the barrier protocol) can still build an explicit plan via
/// [`ShardedModel::with_plan`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardPlanError {
    /// `shards == 0` — a team needs at least one member.
    ZeroShards,
    /// `shards` exceeds the width of `layer` (its full logical neuron
    /// count): at least one shard would own nothing on every request.
    ShardsExceedWidth { shards: usize, layer: usize, width: usize },
}

impl std::fmt::Display for ShardPlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardPlanError::ZeroShards => write!(f, "shard plan needs at least one shard"),
            ShardPlanError::ShardsExceedWidth { shards, layer, width } => write!(
                f,
                "{shards} shards exceed layer {layer}'s width of {width} neurons \
                 (every shard must be able to own at least one neuron)"
            ),
        }
    }
}

impl std::error::Error for ShardPlanError {}

/// Per-layer contiguous partition of the output-neuron range into S
/// shards, balanced by stored weights.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    shards: usize,
    /// `cuts[layer]`: S+1 monotone entries, `cuts[layer][0] == 0`,
    /// `cuts[layer][S] == layer full width`. Shard s owns
    /// `cuts[layer][s]..cuts[layer][s+1]` (possibly empty).
    cuts: Vec<Vec<usize>>,
}

impl ShardPlan {
    /// Balance each layer's neurons over `shards` contiguous ranges so the
    /// stored weights (= gather-MAC work) per shard are as even as the
    /// neuron granularity allows. Ablated neurons carry zero weight in the
    /// compact representations, so a run of ablated neurons is absorbed
    /// into a shard for free instead of counting like live ones.
    ///
    /// Errors (typed, not clamped): [`ShardPlanError::ZeroShards`] for
    /// `shards == 0`, and [`ShardPlanError::ShardsExceedWidth`] when any
    /// layer is narrower than the team — a plan that structurally idles
    /// shards is almost always a caller mistake; build one explicitly via
    /// [`ShardedModel::with_plan`] if that is really what you want.
    pub fn balanced(model: &SparseModel, shards: usize) -> Result<ShardPlan, ShardPlanError> {
        if shards == 0 {
            return Err(ShardPlanError::ZeroShards);
        }
        for (layer, l) in model.layers().iter().enumerate() {
            let width = l.out_full_width();
            if shards > width {
                return Err(ShardPlanError::ShardsExceedWidth { shards, layer, width });
            }
        }
        let cuts =
            model.layers().iter().map(|l| balance_layer(&l.row_weights(), shards)).collect();
        Ok(ShardPlan { shards, cuts })
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn layers(&self) -> usize {
        self.cuts.len()
    }

    /// Neuron range of `shard` within `layer` (full logical coordinates).
    pub fn range(&self, layer: usize, shard: usize) -> Range<usize> {
        self.cuts[layer][shard]..self.cuts[layer][shard + 1]
    }

    /// One-line description of the partition — `shards x layers` plus each
    /// layer's cut points. Logged when a live swap re-plans the stack
    /// (`docs/RELOAD.md`) so operators can see how the new epoch was cut.
    pub fn summary(&self) -> String {
        let cuts: Vec<String> = self
            .cuts
            .iter()
            .map(|c| c.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("/"))
            .collect();
        format!("plan {}x{} cuts [{}]", self.shards, self.cuts.len(), cuts.join(" "))
    }

    /// Largest shard cost divided by ideal (total/S) cost for one layer —
    /// 1.0 is perfect balance. Diagnostics for the bench/docs.
    pub fn imbalance(&self, model: &SparseModel, layer: usize) -> f64 {
        let w = model.layers()[layer].row_weights();
        let total: usize = w.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let ideal = total as f64 / self.shards as f64;
        (0..self.shards)
            .map(|s| w[self.range(layer, s)].iter().sum::<usize>() as f64 / ideal)
            .fold(1.0, f64::max)
    }
}

/// Contiguous partition of `cost` into `shards` ranges with near-equal
/// sums: greedy prefix walk that stops each cut at the boundary closest to
/// the j/S quantile of total cost. Zero-cost layers fall back to an even
/// neuron split. Cuts are monotone; ranges may be empty when the cost mass
/// is too concentrated to fill every shard.
fn balance_layer(cost: &[usize], shards: usize) -> Vec<usize> {
    let n = cost.len();
    let total: usize = cost.iter().sum();
    let mut cuts = Vec::with_capacity(shards + 1);
    cuts.push(0);
    if total == 0 {
        for j in 1..shards {
            cuts.push(n * j / shards);
        }
        cuts.push(n);
        return cuts;
    }
    let mut prefix = 0usize;
    let mut i = 0usize;
    for j in 1..shards {
        let target = total as f64 * j as f64 / shards as f64;
        while i < n {
            let next = prefix + cost[i];
            // advance while the next boundary is at least as close to the
            // target as the current one (ties advance: prefer spending
            // neurons early so trailing shards can't starve the walk)
            if (next as f64 - target).abs() <= (target - prefix as f64).abs() {
                prefix = next;
                i += 1;
            } else {
                break;
            }
        }
        cuts.push(i);
    }
    cuts.push(n);
    cuts
}

/// A full-width activation buffer shards write disjoint column ranges of.
/// `UnsafeCell` per element: shards mutate through shared references, with
/// disjointness and write/read phase separation enforced by the caller
/// (`ShardedModel::shard_pass`'s barrier discipline).
pub(crate) struct SharedBuf {
    cells: Vec<UnsafeCell<f32>>,
}

// SAFETY: all concurrent access goes through the raw-pointer accessors
// below under shard_pass's protocol — writers touch disjoint ranges, and a
// Barrier separates every write phase from the reads of the next layer.
unsafe impl Sync for SharedBuf {}

impl SharedBuf {
    fn new(len: usize) -> SharedBuf {
        SharedBuf { cells: (0..len).map(|_| UnsafeCell::new(0.0)).collect() }
    }

    /// # Safety
    /// No other reference to `start..start+len` may exist for the returned
    /// lifetime (shards uphold this by owning disjoint column ranges).
    #[allow(clippy::mut_from_ref)]
    unsafe fn region_mut(&self, start: usize, len: usize) -> &mut [f32] {
        debug_assert!(start + len <= self.cells.len());
        // SAFETY: UnsafeCell<f32> is repr(transparent) over f32, the
        // range is in bounds (callers pass plan-derived ranges; debug
        // asserted above), and exclusivity of `start..start+len` is the
        // caller's contract.
        unsafe { std::slice::from_raw_parts_mut(self.cells.as_ptr().add(start) as *mut f32, len) }
    }

    /// # Safety
    /// No write to `0..len` may be in flight (callers read only buffers
    /// completed behind a barrier).
    pub(crate) unsafe fn read(&self, len: usize) -> &[f32] {
        debug_assert!(len <= self.cells.len());
        // SAFETY: UnsafeCell<f32> is repr(transparent) over f32, `len` is
        // within the allocation, and quiescence of `0..len` is the
        // caller's contract.
        unsafe { std::slice::from_raw_parts(self.cells.as_ptr() as *const f32, len) }
    }

    pub(crate) fn capacity(&self) -> usize {
        self.cells.len()
    }
}

/// Per-call workspace for a sharded forward (scoped or persistent): two
/// shared ping-pong full-width buffers plus one private staging buffer per
/// shard (kernel outputs are (batch, slice width) contiguous; the shared
/// buffer's rows are strided by the full width, so every shard stages then
/// copies).
pub struct ShardedScratch {
    pub(crate) a: SharedBuf,
    pub(crate) b: SharedBuf,
    pub(crate) stage: Vec<Vec<f32>>,
    pub(crate) max_batch: usize,
}

impl ShardedScratch {
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// How many shards this workspace was allocated for (one staging
    /// buffer each) — forwards assert it matches their team size.
    pub fn stage_count(&self) -> usize {
        self.stage.len()
    }
}

/// A [`SparseModel`] re-materialized as S shard slices per layer, sharing
/// one barrier-synchronized layer walk (`ShardedModel::shard_pass`).
/// Build via [`ShardedModel::from_model`] (balanced plan) or
/// [`ShardedModel::with_plan`].
pub struct ShardedModel {
    /// `layers[layer][shard]` — zero-width slices are legal (an explicit
    /// plan may leave shards empty on some layers; they still synchronize).
    layers: Vec<Vec<ModelLayer>>,
    plan: ShardPlan,
    d_in: usize,
    out_width: usize,
    /// Full logical width per layer (the shared-buffer row stride).
    widths: Vec<usize>,
}

impl ShardedModel {
    /// Shard `model` with a stored-weight-balanced [`ShardPlan`].
    pub fn from_model(model: &SparseModel, shards: usize) -> Result<ShardedModel> {
        ShardedModel::with_plan(model, ShardPlan::balanced(model, shards)?)
    }

    /// Shard `model` with an explicit plan (must cover every layer's full
    /// width with monotone cuts; empty ranges are allowed here).
    pub fn with_plan(model: &SparseModel, plan: ShardPlan) -> Result<ShardedModel> {
        anyhow::ensure!(
            plan.cuts.len() == model.depth(),
            "plan has {} layers, model has {}",
            plan.cuts.len(),
            model.depth()
        );
        let shards = plan.shards;
        let mut layers = Vec::with_capacity(model.depth());
        for (li, layer) in model.layers().iter().enumerate() {
            let cuts = &plan.cuts[li];
            anyhow::ensure!(
                cuts.len() == shards + 1
                    && cuts[0] == 0
                    && cuts[shards] == layer.out_full_width()
                    && cuts.windows(2).all(|w| w[0] <= w[1]),
                "layer {li}: cuts {cuts:?} must rise monotonically 0..={}",
                layer.out_full_width()
            );
            layers.push((0..shards).map(|s| layer.slice(cuts[s]..cuts[s + 1])).collect());
        }
        Ok(ShardedModel {
            layers,
            plan,
            d_in: model.in_width(),
            out_width: model.out_width(),
            widths: model.layers().iter().map(|l| l.out_full_width()).collect(),
        })
    }

    pub fn shards(&self) -> usize {
        self.plan.shards
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    pub fn in_width(&self) -> usize {
        self.d_in
    }

    pub fn out_width(&self) -> usize {
        self.out_width
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Total stored bytes across all shard slices — each weight lives in
    /// exactly one shard, so this matches the replicated model's storage
    /// (CSR slices add one 4-byte indptr sentinel per extra shard).
    pub fn storage_bytes(&self) -> usize {
        self.layers.iter().flatten().map(|l| l.kernel().storage_bytes()).sum()
    }

    pub fn describe(&self) -> String {
        let widths: Vec<String> = self.widths.iter().map(|w| w.to_string()).collect();
        format!(
            "{} -> {} x{} shards | {}",
            self.d_in,
            widths.join(" -> "),
            self.plan.shards,
            crate::kernels::describe_selection()
        )
    }

    /// Allocate a workspace for forwards up to `max_batch` rows.
    pub fn make_scratch(&self, max_batch: usize) -> ShardedScratch {
        let max_batch = max_batch.max(1);
        let maxw = self.widths.iter().copied().max().unwrap_or(1).max(1);
        let stage = (0..self.plan.shards)
            .map(|s| {
                let maxc =
                    self.layers.iter().map(|l| l[s].kernel().out_width()).max().unwrap_or(0);
                vec![0f32; max_batch * maxc]
            })
            .collect();
        ShardedScratch {
            a: SharedBuf::new(max_batch * maxw),
            b: SharedBuf::new(max_batch * maxw),
            stage,
            max_batch,
        }
    }

    /// One-shot forward that allocates its own scratch (tests/examples).
    pub fn forward_vec(&self, x: &[f32], batch: usize, threads: usize) -> Vec<f32> {
        let mut s = self.make_scratch(batch);
        self.forward(x, batch, &mut s, threads).to_vec()
    }

    /// Reject a workspace that is too small for this model at `batch` —
    /// coordinator-side, BEFORE any shard work starts. Without this, a
    /// scratch built from a *different* sharded model (same shard count,
    /// narrower buffers) would panic inside a shard thread, where
    /// unwinding cannot be propagated and would wedge the barrier.
    pub(crate) fn assert_scratch_fits(&self, s: &ShardedScratch, batch: usize) {
        assert_eq!(
            s.stage.len(),
            self.plan.shards,
            "scratch was built for a different shard count (create it via make_scratch/scratch())"
        );
        let maxw = self.widths.iter().copied().max().unwrap_or(1).max(1);
        let need = batch * maxw;
        assert!(
            s.a.capacity() >= need && s.b.capacity() >= need,
            "scratch activation buffers hold {} elements, this model needs {need} at batch {batch} \
             (scratch from a different model?)",
            s.a.capacity().min(s.b.capacity())
        );
        for (si, stage) in s.stage.iter().enumerate() {
            let maxc = self.layers.iter().map(|l| l[si].kernel().out_width()).max().unwrap_or(0);
            assert!(
                stage.len() >= batch * maxc,
                "shard {si} staging holds {} elements, needs {} at batch {batch} \
                 (scratch from a different model?)",
                stage.len(),
                batch * maxc
            );
        }
    }

    /// The shared-buffer parity of the final layer: which ping-pong buffer
    /// holds the stack's output after a forward.
    pub(crate) fn final_buf<'s>(&self, s: &'s ShardedScratch) -> &'s SharedBuf {
        if (self.layers.len() - 1) % 2 == 0 {
            &s.a
        } else {
            &s.b
        }
    }

    /// One shard's walk over every layer — THE sharded execution path,
    /// shared verbatim by the scoped reference forward below and the
    /// persistent team ([`crate::inference::engine::PersistentShardedEngine`]),
    /// which is what makes the two bit-for-bit identical.
    ///
    /// Protocol per layer: compute the slice into `stage`, write the
    /// disjoint column range into the destination ping-pong buffer
    /// (zero-fill + scatter for compact kernels), apply the activation to
    /// that range only, then `barrier.wait()`. Empty slices skip compute
    /// but still wait, keeping the barrier count consistent.
    pub(crate) fn shard_pass(
        &self,
        si: usize,
        x: &[f32],
        batch: usize,
        stage: &mut [f32],
        buf_a: &SharedBuf,
        buf_b: &SharedBuf,
        barrier: &Barrier,
        threads: usize,
    ) {
        let depth = self.layers.len();
        for li in 0..depth {
            let layer = &self.layers[li][si];
            let w_full = self.widths[li];
            let r = self.plan.range(li, si);
            let sw = r.end - r.start;
            // same ping-pong parity as the replicated forward:
            // layer 0 writes `a`, layer 1 writes `b`, ...
            let (dst, src) = if li % 2 == 0 { (buf_a, buf_b) } else { (buf_b, buf_a) };
            let src: &[f32] = if li == 0 {
                x
            } else {
                // SAFETY: the barrier at the end of the previous iteration
                // ordered every shard's writes to `src` before this read;
                // nobody writes `src` this phase.
                unsafe { src.read(batch * layer.in_width()) }
            };
            if sw > 0 {
                let na = layer.kernel().out_width();
                let c = &mut stage[..batch * na];
                layer.kernel().forward(src, batch, c, threads);
                for bi in 0..batch {
                    // SAFETY: shard si exclusively owns columns
                    // r.start..r.end of every row this phase (ShardPlan
                    // ranges are disjoint).
                    let region = unsafe { dst.region_mut(bi * w_full + r.start, sw) };
                    match layer.active_ids() {
                        None => region.copy_from_slice(&c[bi * na..(bi + 1) * na]),
                        Some(active) => {
                            crate::kernels::scatter_row(&c[bi * na..(bi + 1) * na], active, region)
                        }
                    }
                    layer.activation().apply(region);
                }
            }
            barrier.wait();
        }
    }

    /// Run the sharded stack on `batch` rows of `x` — the **scoped
    /// reference implementation**: spawns one scoped thread per shard per
    /// call. `threads` is the *intra-shard* kernel thread count (total
    /// parallelism = shards x threads). Bit-for-bit equal to the
    /// replicated [`SparseModel::forward`] — and to the persistent team,
    /// which runs the same `ShardedModel::shard_pass` on long-lived
    /// threads instead.
    pub fn forward<'s>(
        &self,
        x: &[f32],
        batch: usize,
        s: &'s mut ShardedScratch,
        threads: usize,
    ) -> &'s [f32] {
        assert!(batch >= 1, "batch must be >= 1");
        assert!(batch <= s.max_batch, "batch {batch} exceeds scratch capacity {}", s.max_batch);
        assert_eq!(x.len(), batch * self.d_in, "input size mismatch");
        self.assert_scratch_fits(s, batch);
        let shards = self.plan.shards;
        let barrier = Barrier::new(shards);
        let (buf_a, buf_b) = (&s.a, &s.b);
        std::thread::scope(|scope| {
            for (si, stage) in s.stage.iter_mut().enumerate() {
                let barrier = &barrier;
                scope.spawn(move || {
                    self.shard_pass(si, x, batch, stage, buf_a, buf_b, barrier, threads)
                });
            }
        });
        // SAFETY: the scope joined every shard; we hold &mut scratch.
        unsafe { self.final_buf(s).read(batch * self.out_width) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::model::{Activation, LayerSpec, Repr};
    use crate::sparsity::Mask;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn model3(repr: Repr, ablated: f64) -> SparseModel {
        let spec = |n, act| LayerSpec { n, repr, sparsity: 0.9, ablated_frac: ablated, activation: act };
        SparseModel::synth(
            64,
            &[
                spec(48, Activation::Relu),
                spec(32, Activation::Relu),
                spec(16, Activation::Identity),
            ],
            11,
        )
        .unwrap()
    }

    #[test]
    fn balance_layer_properties() {
        for (cost, shards) in [
            (vec![4usize; 16], 4usize),
            (vec![0, 0, 0, 0, 4, 4, 4, 4], 2),
            (vec![1, 100, 1, 1], 2),
            (vec![0; 8], 3),
            (vec![5], 4),
        ] {
            let cuts = balance_layer(&cost, shards);
            assert_eq!(cuts.len(), shards + 1, "{cost:?}");
            assert_eq!(cuts[0], 0);
            assert_eq!(*cuts.last().unwrap(), cost.len());
            assert!(cuts.windows(2).all(|w| w[0] <= w[1]), "monotone: {cuts:?}");
        }
        // uniform cost splits evenly
        assert_eq!(balance_layer(&[4; 16], 4), vec![0, 4, 8, 12, 16]);
        // an ablated (zero-cost) head is absorbed: the cut lands at the
        // weight midpoint, not the neuron midpoint
        assert_eq!(balance_layer(&[0, 0, 0, 0, 4, 4, 4, 4], 2), vec![0, 6, 8]);
    }

    #[test]
    fn plan_balances_by_stored_weights_not_neurons() {
        // neurons 0..8 ablated, 8..16 live with k=4: a 2-shard plan must
        // cut at neuron 12 (weight midpoint), not 8 (neuron midpoint)
        let n = 16;
        let d = 8;
        let mut mask = Mask::from_tensor(Tensor::zeros(&[n, d]));
        for r in 8..n {
            for j in 0..4 {
                mask.set(r, j, true);
            }
        }
        let mut rng = Rng::new(3);
        let mut w = Tensor::normal(&[n, d], 1.0, &mut rng);
        w.mul_assign(&mask.t);
        let bias = vec![0.0f32; n];
        let layer =
            ModelLayer::from_weights(&w, &mask, &bias, Repr::Condensed, Activation::Identity)
                .unwrap();
        let model = SparseModel::new(vec![layer]).unwrap();
        let plan = ShardPlan::balanced(&model, 2).unwrap();
        assert_eq!(plan.range(0, 0), 0..12);
        assert_eq!(plan.range(0, 1), 12..16);
        assert!((plan.imbalance(&model, 0) - 1.0).abs() < 1e-9, "perfectly even split");
    }

    #[test]
    fn balanced_rejects_zero_and_oversized_shard_counts() {
        let m = model3(Repr::Condensed, 0.25);
        assert_eq!(ShardPlan::balanced(&m, 0), Err(ShardPlanError::ZeroShards));
        // narrowest layer has 16 neurons: 17 shards cannot all own one
        match ShardPlan::balanced(&m, 17) {
            Err(ShardPlanError::ShardsExceedWidth { shards, layer, width }) => {
                assert_eq!(shards, 17);
                assert_eq!(layer, 2);
                assert_eq!(width, 16);
            }
            other => panic!("expected ShardsExceedWidth, got {other:?}"),
        }
        // the error formats into a readable diagnostic (and converts into
        // anyhow::Error through std::error::Error)
        let msg = ShardPlanError::ShardsExceedWidth { shards: 17, layer: 2, width: 16 }.to_string();
        assert!(msg.contains("17") && msg.contains("16"), "{msg}");
        let e: anyhow::Error = ShardPlanError::ZeroShards.into();
        assert!(format!("{e}").contains("at least one shard"));
    }

    #[test]
    fn sharded_matches_replicated_smoke() {
        // full cross-product lives in rust/tests/engine_conformance.rs
        let m = model3(Repr::Condensed, 0.25);
        let sh = ShardedModel::from_model(&m, 3).unwrap();
        assert_eq!(sh.shards(), 3);
        assert_eq!(sh.storage_bytes(), m.storage_bytes(), "weights partition exactly");
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..4 * 64).map(|_| rng.normal_f32()).collect();
        let want = m.forward_vec(&x, 4, 1);
        let got = sh.forward_vec(&x, 4, 1);
        assert_eq!(want.len(), got.len());
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "idx {i}: {w} vs {g}");
        }
    }

    #[test]
    fn explicit_plan_with_empty_shards_still_agrees() {
        // balanced() refuses shards > narrowest width, but an explicit
        // plan may leave shards empty — the barrier protocol must still
        // hold (empty shards skip compute but keep synchronizing)
        let spec = |n, act| LayerSpec {
            n,
            repr: Repr::Condensed,
            sparsity: 0.5,
            ablated_frac: 0.0,
            activation: act,
        };
        let m = SparseModel::synth(8, &[spec(4, Activation::Relu), spec(2, Activation::Identity)], 2)
            .unwrap();
        // 5 shards over widths [4, 2]: trailing shards own nothing
        let plan = ShardPlan {
            shards: 5,
            cuts: vec![vec![0, 1, 2, 3, 4, 4], vec![0, 1, 2, 2, 2, 2]],
        };
        let sh = ShardedModel::with_plan(&m, plan).unwrap();
        let x = vec![0.5f32; 8];
        let want = m.forward_vec(&x, 1, 1);
        let got = sh.forward_vec(&x, 1, 1);
        assert_eq!(
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        let widths: Vec<usize> = (0..5).map(|s| sh.plan().range(1, s).len()).collect();
        assert_eq!(widths.iter().sum::<usize>(), 2);
        assert_eq!(widths.iter().filter(|&&w| w == 0).count(), 3);
    }

    #[test]
    fn single_shard_is_the_replicated_model() {
        let m = model3(Repr::Dense, 0.25);
        let sh = ShardedModel::from_model(&m, 1).unwrap();
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        assert_eq!(
            m.forward_vec(&x, 1, 1).iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            sh.forward_vec(&x, 1, 1).iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn with_plan_rejects_malformed_cuts() {
        let m = model3(Repr::Csr, 0.0);
        let good = ShardPlan::balanced(&m, 2).unwrap();
        assert!(ShardedModel::with_plan(&m, good).is_ok());
        let mut bad = ShardPlan::balanced(&m, 2).unwrap();
        bad.cuts[1][1] = 1000; // beyond the layer width
        assert!(ShardedModel::with_plan(&m, bad).is_err());
        let mut short = ShardPlan::balanced(&m, 2).unwrap();
        short.cuts.pop(); // wrong layer count
        assert!(ShardedModel::with_plan(&m, short).is_err());
    }
}
