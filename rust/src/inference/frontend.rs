//! Network serving front-end: turns the inference engine into a real
//! socket server. The ROADMAP's "serving scale-out" block: async IO
//! ingestion, backpressure, adaptive batching, a result cache, and — via
//! `EngineBuilder::shards` — tensor-parallel execution on a persistent
//! shard team behind the same queue machinery.
//!
//! Data path:
//!
//! ```text
//! TcpListener (blocking accept)
//!   └─ one reader thread per connection
//!        ├─ parse length-prefixed request frames (crate::net)
//!        ├─ FNV-1a hash of the row bytes → LRU result cache: hit answers
//!        │    immediately without touching the queue
//!        ├─ miss → Injector::push_bounded: a full queue answers
//!        │    Busy{retry_after_ms} (backpressure, never unbounded growth)
//!        └─ responses route through the connection's bounded Egress
//!             queue, drained by one writer thread per connection
//!   workers (N threads, shared queue)
//!        ├─ pop up to AdaptiveBatcher::next_batch(queue depth) requests
//!        ├─ greedily pack popped requests into ≤ cap-row forwards on a
//!        │    per-worker typed scratch (allocation-free)
//!        └─ push each result onto the owning connection's egress queue —
//!             NEVER a blocking socket write from a pool worker
//! ```
//!
//! **Slow-client isolation**: a client that stops reading its socket
//! blocks only its own writer thread. Its egress queue then fills; once
//! full, each further response is dropped and (headroom permitting)
//! replaced by a `Busy{retry_after_ms}` frame, and the server-wide
//! `dropped_responses` counter increments. Pool workers never block on a
//! socket, so one stalled client cannot hold a batch hostage
//! (see `docs/WIRE.md` for the client-visible semantics).
//!
//! Responses carry the request id, so a pipelined connection may see them
//! out of submission order (cache hits overtake queued work). The
//! synchronous [`crate::net::Client`] keeps one request in flight and never
//! observes this.
//!
//! The front-end is generic over [`Engine`], so each worker's scratch has
//! exactly the engine's associated type — the old `ServeEngine` /
//! `EngineScratch` runtime mismatch panic is now unrepresentable.
//!
//! **Live model swap** ([`spawn_swappable`]): the engine publishes
//! immutable epochs; workers pin an epoch per batch
//! (`Engine::ensure_current`), cache entries carry the epoch they were
//! computed on, and a `reload` wire control frame / `SIGHUP` /
//! [`FrontendHandle::publish_model`] moves traffic to a new stack with
//! zero dropped requests. See `docs/RELOAD.md`.

use std::collections::HashMap;
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

// The Egress queue is model-checked (rust/tests/loom_models.rs), so its
// primitives come from the shim: std normally, loom under `--cfg loom`.
// The rest of the front-end (control plane, gates, cache) stays on
// std::sync — it is not modeled, and loom types only work inside a model.
use crate::util::sync as ssync;

use anyhow::{bail, Context, Result};

use super::engine::{Engine, EngineBuilder, SwappableEngine};
use super::model::ModelEpoch;
use super::server::{AdaptiveBatcher, Batching, LatencyStats, WorkerStats};
use super::SparseModel;
use crate::net::{
    fnv1a_f32, read_request, write_response, Incoming, ResponseBody, ResponseFrame,
    CONTROL_OP_RELOAD,
};
use crate::obs::{self, Counter, Gauge, Histogram, MetricsServer, Registry};
use crate::util::lru::LruCache;
use crate::util::threadpool::{Injector, QueueFull};

/// End-of-run accounting returned by [`FrontendHandle::stop`].
#[derive(Clone, Debug)]
pub struct FrontendStats {
    /// Latency/throughput over the queue-served (compute) requests.
    pub latency: LatencyStats,
    /// Requests answered by the worker pool.
    pub served: usize,
    /// Requests answered straight from the result cache.
    pub cache_hits: usize,
    /// Requests rejected with `Busy` (bounded ingress queue full).
    pub rejected: usize,
    /// Malformed requests answered with `Error`.
    pub bad_requests: usize,
    /// Responses a slow client failed to absorb: its egress queue was
    /// full, so the computed output was discarded (answered `Busy` when
    /// headroom allowed). Nonzero means some client is reading slower
    /// than it submits.
    pub dropped_responses: usize,
    /// Connections accepted over the run (cumulative).
    pub connections_total: usize,
    /// Connections still open when the run ended (readers alive). Zero
    /// after a clean `stop()` — teardown waits for every reader.
    pub connections_active: usize,
    /// Connections refused at accept because `max_connections` was
    /// reached (each got a best-effort Busy frame, never a reader).
    pub connections_rejected: usize,
    /// Smallest / largest packed forward (rows) any worker ran — under a
    /// trickle these collapse to 1/1; under a flood the max approaches the
    /// batching cap (how the adaptive batcher shows up in the numbers).
    pub min_forward_rows: usize,
    pub max_forward_rows: usize,
}

impl FrontendStats {
    /// Counter block for persisted bench/arena records.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj};
        obj(vec![
            ("latency", self.latency.to_json()),
            ("served", num(self.served as f64)),
            ("cache_hits", num(self.cache_hits as f64)),
            ("rejected", num(self.rejected as f64)),
            ("bad_requests", num(self.bad_requests as f64)),
            ("dropped_responses", num(self.dropped_responses as f64)),
            // legacy key (pre-split consumers read "connections"): the
            // cumulative count, alongside the three explicit series
            ("connections", num(self.connections_total as f64)),
            ("connections_total", num(self.connections_total as f64)),
            ("connections_active", num(self.connections_active as f64)),
            ("connections_rejected", num(self.connections_rejected as f64)),
            ("min_forward_rows", num(self.min_forward_rows as f64)),
            ("max_forward_rows", num(self.max_forward_rows as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Per-connection egress queue
// ---------------------------------------------------------------------------

/// Extra slots past capacity reserved for `Busy` conversion frames (a few
/// bytes each), so an overflowing client still learns it should retry
/// instead of waiting forever. Beyond the headroom responses are dropped
/// outright — the queue stays bounded no matter what the client does.
const EGRESS_BUSY_HEADROOM: usize = 32;

/// What happened to a frame handed to [`Egress::send`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendOutcome {
    /// Queued for the writer thread.
    Queued,
    /// Queue full: the frame was replaced by a `Busy` hint (counts as a
    /// dropped response).
    ConvertedBusy,
    /// Queue and Busy headroom full: nothing was queued (counts as a
    /// dropped response).
    Dropped,
    /// Connection already torn down; the frame went nowhere (not counted —
    /// the client is gone, not slow).
    Gone,
}

struct EgressInner {
    /// Each frame carries its enqueue instant so the writer can record
    /// the egress-wait stage (time a response sat behind the socket).
    q: std::collections::VecDeque<(ResponseFrame, Instant)>,
    /// Jobs enqueued for this connection and not yet answered.
    inflight: usize,
    /// The reader has exited; close once the last in-flight job answers.
    reader_done: bool,
    /// No more frames will be accepted; the writer drains and exits.
    closed: bool,
}

/// Bounded per-connection response queue between producers (pool workers,
/// the reader's cache-hit/error paths) and this connection's single writer
/// thread. The bound is what keeps a slow client's memory footprint — and
/// its ability to stall a worker — finite.
///
/// `pub` (and shim-backed) so `rust/tests/loom_models.rs` can model-check
/// the overflow accounting and the close-vs-drain race exhaustively.
pub struct Egress {
    inner: ssync::Mutex<EgressInner>,
    cv: ssync::Condvar,
    capacity: usize,
    /// Slots past `capacity` reserved for Busy conversions; see
    /// [`EGRESS_BUSY_HEADROOM`].
    headroom: usize,
    retry_after_ms: u32,
    /// Optional live depth gauge (`srigl_egress_depth{conn=...}`),
    /// updated on every push/pop so a scrape shows which connection is
    /// reading slower than it submits.
    depth: Option<Arc<Gauge>>,
}

impl Egress {
    fn new(capacity: usize, retry_after_ms: u32) -> Egress {
        Egress::with_gauge(capacity, retry_after_ms, None)
    }

    /// [`Egress::new`] with an explicit Busy headroom instead of the
    /// serving default ([`EGRESS_BUSY_HEADROOM`]). The model-checking
    /// constructor: loom models use `headroom = 1` so the overflow ladder
    /// (Queued → ConvertedBusy → Dropped) is reachable in a few steps.
    pub fn with_headroom(capacity: usize, headroom: usize, retry_after_ms: u32) -> Egress {
        let mut e = Egress::with_gauge(capacity, retry_after_ms, None);
        e.headroom = headroom;
        e
    }

    fn with_gauge(capacity: usize, retry_after_ms: u32, depth: Option<Arc<Gauge>>) -> Egress {
        Egress {
            inner: ssync::Mutex::new(EgressInner {
                q: std::collections::VecDeque::new(),
                inflight: 0,
                reader_done: false,
                closed: false,
            }),
            cv: ssync::Condvar::new(),
            capacity: capacity.max(1),
            headroom: EGRESS_BUSY_HEADROOM,
            retry_after_ms,
            depth,
        }
    }

    /// Lock the egress state, recovering from poison with a warning: every
    /// mutation below keeps the queue structurally consistent before any
    /// panic-capable code runs, so a panicked producer degrades this one
    /// connection instead of cascading panics through every thread that
    /// routes a response to it.
    fn lock_inner(&self) -> ssync::MutexGuard<'_, EgressInner> {
        self.inner.lock().unwrap_or_else(|poisoned| {
            crate::util::log::warn("frontend", "egress mutex poisoned; recovering");
            poisoned.into_inner()
        })
    }

    fn note_depth(&self, n: usize) {
        if let Some(g) = &self.depth {
            g.set(n as u64);
        }
    }

    /// Queue a response for the writer. Never blocks: on overflow, a bulky
    /// `Output` frame is converted to `Busy` (within headroom) or dropped;
    /// small control frames (`Busy`, `Error`) pass through the headroom
    /// verbatim — an Error must never morph into Busy, or a client
    /// following the retry-on-Busy protocol would resend a malformed
    /// request forever.
    pub fn send(&self, frame: ResponseFrame) -> SendOutcome {
        let now = Instant::now();
        let mut g = self.lock_inner();
        if g.closed {
            return SendOutcome::Gone;
        }
        if g.q.len() < self.capacity {
            g.q.push_back((frame, now));
            let n = g.q.len();
            drop(g);
            self.note_depth(n);
            self.cv.notify_all();
            return SendOutcome::Queued;
        }
        if g.q.len() < self.capacity + self.headroom {
            let outcome = match frame.body {
                ResponseBody::Output { .. } => {
                    g.q.push_back((
                        ResponseFrame {
                            id: frame.id,
                            body: ResponseBody::Busy { retry_after_ms: self.retry_after_ms },
                        },
                        now,
                    ));
                    SendOutcome::ConvertedBusy
                }
                _ => {
                    g.q.push_back((frame, now));
                    SendOutcome::Queued
                }
            };
            let n = g.q.len();
            drop(g);
            self.note_depth(n);
            self.cv.notify_all();
            return outcome;
        }
        SendOutcome::Dropped
    }

    /// A job for this connection entered the shared queue.
    pub fn job_started(&self) {
        self.lock_inner().inflight += 1;
    }

    /// A job for this connection was answered (or rejected). Closes the
    /// queue once the reader is gone and nothing is outstanding, letting
    /// the writer drain and exit.
    pub fn job_finished(&self) {
        let mut g = self.lock_inner();
        g.inflight -= 1;
        if g.reader_done && g.inflight == 0 {
            g.closed = true;
            drop(g);
            self.cv.notify_all();
        }
    }

    /// The reader exited (EOF, framing error, shutdown).
    pub fn reader_done(&self) {
        let mut g = self.lock_inner();
        g.reader_done = true;
        if g.inflight == 0 {
            g.closed = true;
            drop(g);
            self.cv.notify_all();
        }
    }

    /// Force-close (teardown path for jobs that will never be answered,
    /// e.g. a drained-but-unserved queue with zero workers). Queued frames
    /// are still drained by the writer before it exits.
    pub fn close(&self) {
        let mut g = self.lock_inner();
        g.closed = true;
        drop(g);
        self.cv.notify_all();
    }

    /// Blocking pop for the writer thread; `None` once closed and drained.
    pub fn recv(&self) -> Option<(ResponseFrame, Instant)> {
        let mut g = self.lock_inner();
        loop {
            if let Some(f) = g.q.pop_front() {
                let n = g.q.len();
                drop(g);
                self.note_depth(n);
                return Some(f);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap_or_else(|poisoned| {
                crate::util::log::warn("frontend", "egress mutex poisoned; recovering");
                poisoned.into_inner()
            });
        }
    }

    /// Non-blocking pop (writer batching between flushes).
    pub fn try_recv(&self) -> Option<(ResponseFrame, Instant)> {
        let mut g = self.lock_inner();
        let f = g.q.pop_front();
        if f.is_some() {
            let n = g.q.len();
            drop(g);
            self.note_depth(n);
        }
        f
    }
}

// ---------------------------------------------------------------------------
// Shared state
// ---------------------------------------------------------------------------

/// Lock a control-plane mutex, recovering from poison with a warning.
/// The maps these mutexes guard (`conns`, `egresses`, gate counters) are
/// structurally consistent at every await-free critical section, so after
/// a worker/reader/writer panic the right degradation is "that connection
/// dies", not "every thread that touches the map panics too".
fn lock_unpoisoned<'a, T>(m: &'a Mutex<T>, what: &str) -> std::sync::MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|poisoned| {
        crate::util::log::warn(
            "frontend",
            &format!("{what} mutex poisoned by a panicked thread; recovering"),
        );
        poisoned.into_inner()
    })
}

/// Counts live threads of one kind so shutdown can wait for them without
/// collecting an unbounded Vec of join handles (connections come and go).
struct Gate {
    n: Mutex<usize>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Gate {
        Gate { n: Mutex::new(0), cv: Condvar::new() }
    }

    fn enter(gate: &Arc<Gate>) -> GateTicket {
        *lock_unpoisoned(&gate.n, "gate") += 1;
        GateTicket(Arc::clone(gate))
    }

    fn wait_idle(&self) {
        let mut g = lock_unpoisoned(&self.n, "gate");
        while *g > 0 {
            g = self.cv.wait(g).unwrap_or_else(|poisoned| {
                crate::util::log::warn("frontend", "gate mutex poisoned; recovering");
                poisoned.into_inner()
            });
        }
    }
}

/// Drop guard: decrements the gate even if the thread panics.
struct GateTicket(Arc<Gate>);

impl Drop for GateTicket {
    fn drop(&mut self) {
        *lock_unpoisoned(&self.0.n, "gate") -= 1;
        self.0.cv.notify_all();
    }
}

/// One family for every serve-path stage so a single scrape shows where
/// the time goes; the stage rides a label.
const STAGE_FAMILY: &str = "srigl_stage_latency_us";
const STAGE_HELP: &str = "Per-stage request timing in microseconds \
(ingress -> queue_wait -> batch_assembly -> forward -> egress_wait; \
stage=\"total\" is submit-to-forward-done, the LatencyStats sample).";

/// Live frontend metric handles, registered on the spawn's [`Registry`].
/// These ARE the counters (not mirrors): the serve path bumps them
/// inline and teardown reads the same atomics into [`FrontendStats`], so
/// a live scrape and the end-of-run stats can never disagree.
struct FrontendMetrics {
    served: Arc<Counter>,
    batches: Arc<Counter>,
    cache_hits: Arc<Counter>,
    rejected: Arc<Counter>,
    bad_requests: Arc<Counter>,
    dropped_responses: Arc<Counter>,
    connections_total: Arc<Counter>,
    connections_active: Arc<Gauge>,
    connections_rejected: Arc<Counter>,
    forward_rows_min: Arc<Gauge>,
    forward_rows_max: Arc<Gauge>,
    /// Jobs waiting in the shared ingress queue, sampled after every
    /// reader push and worker pop — a live scrape shows the backlog the
    /// adaptive batcher is reacting to.
    queue_depth: Arc<Gauge>,
    /// Frame-parsed -> handed off (cache answer or queue push). One
    /// shared instance: readers come and go with connections, so
    /// per-reader registration would grow the registry unboundedly.
    ingress: Arc<Histogram>,
    /// Response enqueued -> writer dequeued (time sat behind the
    /// socket). Shared across writers for the same reason.
    egress_wait: Arc<Histogram>,
}

impl FrontendMetrics {
    fn register(r: &Registry) -> FrontendMetrics {
        FrontendMetrics {
            served: r.counter(
                "srigl_requests_served_total",
                "Requests answered by the worker pool.",
            ),
            batches: r.counter(
                "srigl_forward_batches_total",
                "Packed forward passes run by the pool.",
            ),
            cache_hits: r.counter(
                "srigl_cache_hits_total",
                "Requests answered straight from the result cache.",
            ),
            rejected: r.counter(
                "srigl_requests_rejected_total",
                "Requests rejected with Busy (bounded ingress queue full).",
            ),
            bad_requests: r.counter(
                "srigl_bad_requests_total",
                "Malformed requests answered with Error.",
            ),
            dropped_responses: r.counter(
                "srigl_dropped_responses_total",
                "Computed responses a slow client failed to absorb (converted to Busy or dropped).",
            ),
            connections_total: r.counter(
                "srigl_connections_total",
                "Connections accepted over the run.",
            ),
            connections_active: r.gauge(
                "srigl_connections_active",
                "Connections currently open (reader thread running).",
            ),
            connections_rejected: r.counter(
                "srigl_connections_rejected_total",
                "Connections refused at accept because max_connections was reached.",
            ),
            forward_rows_min: r.gauge(
                "srigl_forward_rows_min",
                "Smallest packed forward (rows) any worker ran; 0 until the first forward.",
            ),
            forward_rows_max: r.gauge(
                "srigl_forward_rows_max",
                "Largest packed forward (rows) any worker ran.",
            ),
            queue_depth: r.gauge(
                "srigl_queue_depth",
                "Jobs waiting in the shared ingress queue (sampled at reader push / worker pop).",
            ),
            ingress: r.histogram_with(STAGE_FAMILY, STAGE_HELP, &[("stage", "ingress")]),
            egress_wait: r.histogram_with(STAGE_FAMILY, STAGE_HELP, &[("stage", "egress_wait")]),
        }
    }
}

/// Per-worker stage histograms (workers are a fixed, small set, so each
/// gets its own contention-free instance; the registry merges same-label
/// instances at scrape).
struct StageHists {
    queue_wait: Arc<Histogram>,
    assembly: Arc<Histogram>,
    forward: Arc<Histogram>,
    /// Submit -> forward-done: records exactly the samples that feed
    /// `WorkerStats::latencies_us`, so the aggregate histogram percentile
    /// agrees with the exact end-of-run `LatencyStats` to within one
    /// bucket's resolution.
    total: Arc<Histogram>,
}

impl StageHists {
    fn register(r: &Registry) -> StageHists {
        let h = |stage| r.histogram_with(STAGE_FAMILY, STAGE_HELP, &[("stage", stage)]);
        StageHists {
            queue_wait: h("queue_wait"),
            assembly: h("batch_assembly"),
            forward: h("forward"),
            total: h("total"),
        }
    }
}

/// Publish hook installed by [`spawn_swappable`]: hand it a model and it
/// swaps the engine to the next epoch, bumps the epoch gauge, and (when a
/// metrics endpoint is live) republishes the per-layer fact gauges.
/// `Arc` rather than `Box` so the reload hook can compose with it.
pub type PublishFn = Arc<dyn Fn(Arc<SparseModel>) -> Result<u64> + Send + Sync>;

/// Reload hook: re-read the model from its configured source (manifest
/// dir, synth spec, ...) and publish it. Driven by the wire control frame
/// and by `FrontendHandle::reload_now` (the SIGHUP path).
type ReloadFn = Arc<dyn Fn() -> Result<u64> + Send + Sync>;

/// Where a reloadable front-end re-reads its model from
/// (`spawn_swappable`'s `source`); composed with the publish hook to form
/// the reload hook.
pub type ReloadSource = Box<dyn Fn() -> Result<Arc<SparseModel>> + Send + Sync>;

/// Swap hooks threaded into the control plane. Empty for the classic
/// immutable spawns — the serve path only pays for what it uses.
#[derive(Default)]
struct Hooks {
    publish: Option<PublishFn>,
    reload: Option<ReloadFn>,
}

/// Engine-independent control plane: everything [`FrontendHandle`] and the
/// teardown sequence need, with no generic parameter so the handle type
/// stays plain.
struct Control {
    cfg: EngineBuilder,
    /// Live-swap hooks; `None` on immutable spawns (then a reload control
    /// frame answers `Error` and `publish_model` bails).
    hooks: Hooks,
    shutdown: AtomicBool,
    /// The spawn's metric registry (served by the optional `/metrics`
    /// endpoint; also where each worker registers its stage histograms).
    registry: Arc<Registry>,
    metrics: FrontendMetrics,
    /// Live connection streams (clones) so shutdown can unblock readers.
    conns: Mutex<HashMap<u64, TcpStream>>,
    /// Live egress queues so teardown can force-close connections whose
    /// jobs will never be answered (removed by each writer on exit).
    egresses: Mutex<HashMap<u64, Arc<Egress>>>,
    next_conn_id: AtomicUsize,
    readers: Arc<Gate>,
    writers: Arc<Gate>,
}

impl Control {
    /// Record an egress overflow of a **computed output** (converted to
    /// Busy or dropped). Only called for `Output` sends — control frames
    /// (Busy/Error) are not "responses a slow client failed to absorb".
    fn count_send(&self, outcome: SendOutcome) {
        if matches!(outcome, SendOutcome::ConvertedBusy | SendOutcome::Dropped) {
            self.metrics.dropped_responses.inc();
        }
    }
}

/// The generic data plane: the engine plus the queue/cache machinery its
/// workers share.
struct Shared<E: Engine> {
    engine: Arc<E>,
    injector: Injector<Job>,
    /// hash -> (epoch generation, input bits, output); the input defeats
    /// hash collisions, the generation defeats cross-epoch hits — a reader
    /// only answers from an entry whose generation equals the engine's
    /// current epoch, so a response is never a stale stack's output.
    /// Immutable engines report epoch 0 forever, making the check free.
    cache: Option<Mutex<LruCache<u64, (u64, Vec<f32>, Vec<f32>)>>>,
    batcher: AdaptiveBatcher,
    ctrl: Arc<Control>,
}

/// One enqueued request: features plus the route back to its connection.
struct Job {
    id: u64,
    rows: usize,
    x: Vec<f32>,
    hash: u64,
    egress: Arc<Egress>,
    t_submit: Instant,
}

/// Running front-end: keep it to keep serving; [`FrontendHandle::stop`]
/// drains and returns stats.
pub struct FrontendHandle {
    addr: SocketAddr,
    ctrl: Arc<Control>,
    join: Option<JoinHandle<FrontendStats>>,
    /// The optional `/metrics` endpoint; stopped after the serve thread
    /// joins so the final counter state stays scrapeable until `stop()`
    /// returns.
    metrics: Option<MetricsServer>,
}

impl FrontendHandle {
    /// The bound address (resolves port 0 to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The metrics endpoint's bound address, when one was requested
    /// (resolves port 0 to the real port).
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics.as_ref().map(|m| m.addr())
    }

    /// Publish `model` as the next epoch on a swappable spawn: in-flight
    /// forwards finish on the epoch they started on; workers pick up the
    /// new stack at their next batch; cache entries from older epochs stop
    /// hitting. Bails on spawns without swap support (everything but
    /// [`spawn_swappable`]) and on a model whose input width differs from
    /// the serving stack's. The `srigl train --serve` path.
    pub fn publish_model(&self, model: Arc<SparseModel>) -> Result<u64> {
        match &self.ctrl.hooks.publish {
            Some(publish) => publish(model),
            None => bail!("this front-end was not spawned swappable (use spawn_swappable)"),
        }
    }

    /// Re-read the model from the spawn's [`ReloadSource`] and publish it
    /// as the next epoch. Bails when no source was configured. The SIGHUP
    /// path (`serve-model --reload`).
    pub fn reload_now(&self) -> Result<u64> {
        match &self.ctrl.hooks.reload {
            Some(reload) => reload(),
            None => bail!("no reload source configured (spawn_swappable's `source` was None)"),
        }
    }

    /// Stop accepting, hang up on clients, drain the queue, and return the
    /// run's statistics.
    pub fn stop(mut self) -> FrontendStats {
        self.shutdown_and_join()
            .expect("handle already joined") // lint:allow-unwrap caller-facing API misuse, not a serve-path thread
            .expect("frontend thread panicked") // lint:allow-unwrap propagate the acceptor's panic to the owning (main) thread
    }

    /// Serve until the process dies (the `serve-model --listen` path).
    pub fn run_forever(mut self) -> FrontendStats {
        self.join
            .take()
            .expect("handle not yet joined") // lint:allow-unwrap caller-facing API misuse, not a serve-path thread
            .join()
            .expect("frontend thread panicked") // lint:allow-unwrap propagate the acceptor's panic to the owning (main) thread
    }

    fn shutdown_and_join(&mut self) -> Option<std::thread::Result<FrontendStats>> {
        let join = self.join.take()?;
        self.ctrl.shutdown.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let mut addr = self.addr;
        if addr.ip().is_unspecified() {
            addr.set_ip(match addr.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(addr);
        let res = join.join();
        if let Some(m) = self.metrics.as_mut() {
            m.stop();
        }
        Some(res)
    }
}

/// Dropping an unjoined handle (early `?` return in the caller) must not
/// leak the acceptor, the worker threads, and the bound port for the rest
/// of the process: run the same shutdown sequence as
/// [`FrontendHandle::stop`], discarding the stats (and swallowing a
/// thread panic — we may already be unwinding).
impl Drop for FrontendHandle {
    fn drop(&mut self) {
        let _ = self.shutdown_and_join();
    }
}

/// Bind `addr` (e.g. `"127.0.0.1:0"`) and serve `model` with the engine
/// the builder selects: replicated across pool workers, or — when
/// `builder.shards > 1` — a persistent shard team
/// ([`super::engine::PersistentShardedEngine`], the
/// `serve-model --listen --shards N` path).
pub fn spawn(model: Arc<SparseModel>, addr: &str, builder: &EngineBuilder) -> Result<FrontendHandle> {
    spawn_with_metrics(model, addr, builder, None)
}

/// [`spawn`] plus an optional live metrics endpoint: when `metrics_addr`
/// is `Some` (e.g. `"127.0.0.1:0"`), a plaintext HTTP/1.1 `GET /metrics`
/// responder (Prometheus text format — see docs/METRICS.md) serves the
/// spawn's registry on its own listener, and the per-layer engine facts
/// (repr, stored weights, measured GFLOP/s) are registered as labeled
/// gauges. The `serve-model --metrics ADDR` and wire-mode arena paths.
pub fn spawn_with_metrics(
    model: Arc<SparseModel>,
    addr: &str,
    builder: &EngineBuilder,
    metrics_addr: Option<&str>,
) -> Result<FrontendHandle> {
    let registry = Arc::new(Registry::new());
    if metrics_addr.is_some() {
        // only when scrapeable: the per-layer GFLOP/s probe costs a few
        // milliseconds per layer, which metric-less spawns must not pay
        obs::facts::register_model_facts(&registry, &model, builder.max_batch(), builder.threads);
    }
    if builder.is_sharded() {
        let team = builder.build_persistent_sharded(&model).context("building shard team")?;
        spawn_engine_on(Arc::new(team), addr, builder, registry, metrics_addr, Hooks::default())
    } else {
        spawn_engine_on(
            Arc::new(builder.build_replicated(model)),
            addr,
            builder,
            registry,
            metrics_addr,
            Hooks::default(),
        )
    }
}

/// [`spawn_with_metrics`] on a live-swappable engine
/// ([`SwappableEngine`]: persistent shard team when `builder.shards > 1`,
/// replicated otherwise). The returned handle accepts
/// [`FrontendHandle::publish_model`]; when `source` is `Some`, the wire
/// `reload` control frame and [`FrontendHandle::reload_now`] re-read the
/// model from it and publish the result as the next epoch
/// (`serve-model --reload`; see docs/RELOAD.md).
///
/// Swaps are atomic per response: every forward runs entirely on the
/// epoch its worker pinned at the batch boundary, and the result cache
/// only answers from entries stamped with the current epoch.
pub fn spawn_swappable(
    model: Arc<SparseModel>,
    addr: &str,
    builder: &EngineBuilder,
    metrics_addr: Option<&str>,
    source: Option<ReloadSource>,
) -> Result<FrontendHandle> {
    let registry = Arc::new(Registry::new());
    let metrics_enabled = metrics_addr.is_some();
    if metrics_enabled {
        obs::facts::register_model_facts(&registry, &model, builder.max_batch(), builder.threads);
    }
    let engine = Arc::new(builder.build_swappable(model).context("building swappable engine")?);
    let epoch_gauge = registry.gauge(
        "srigl_model_epoch",
        "Epoch id of the stack currently serving; bumps on each live swap.",
    );
    epoch_gauge.set(engine.epoch());
    // The publish hook serializes swaps (two concurrent publishes must not
    // race for the same next-epoch id) and keeps gauge + fact metrics in
    // step with the engine.
    let cfg = *builder;
    let publish: PublishFn = {
        let engine = Arc::clone(&engine);
        let registry = Arc::clone(&registry);
        let swap_lock = Mutex::new(());
        Arc::new(move |model: Arc<SparseModel>| -> Result<u64> {
            // Poison recovery is trivially sound here: the lock guards no
            // data, only mutual exclusion of concurrent publishes.
            let _serialized = swap_lock.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
            let id = engine.epoch() + 1;
            let epoch = engine.swap(ModelEpoch::new(id, Arc::clone(&model)))?;
            epoch_gauge.set(epoch);
            if metrics_enabled {
                obs::facts::republish_model_facts(&registry, &model, cfg.max_batch(), cfg.threads);
            }
            crate::util::log::info("frontend", &format!("serving model epoch {epoch}"));
            Ok(epoch)
        })
    };
    let reload: Option<ReloadFn> = source.map(|src| {
        let publish = Arc::clone(&publish);
        Arc::new(move || -> Result<u64> { publish(src()?) }) as ReloadFn
    });
    spawn_engine_on(
        engine,
        addr,
        builder,
        registry,
        metrics_addr,
        Hooks { publish: Some(publish), reload },
    )
}

/// Bind `addr` and serve a pre-built [`Engine`] (any implementation —
/// replicated, persistent-sharded with a custom plan, or the scoped
/// reference). The worker scratch type follows the engine's associated
/// type, so there is no scratch/engine mismatch to get wrong.
pub fn spawn_engine<E: Engine + 'static>(
    engine: Arc<E>,
    addr: &str,
    builder: &EngineBuilder,
) -> Result<FrontendHandle> {
    spawn_engine_with_metrics(engine, addr, builder, None)
}

/// [`spawn_engine`] plus the optional `/metrics` endpoint (engine-fact
/// gauges for custom engines are the caller's business — the model-aware
/// per-layer facts come from [`spawn_with_metrics`]).
pub fn spawn_engine_with_metrics<E: Engine + 'static>(
    engine: Arc<E>,
    addr: &str,
    builder: &EngineBuilder,
    metrics_addr: Option<&str>,
) -> Result<FrontendHandle> {
    spawn_engine_on(engine, addr, builder, Arc::new(Registry::new()), metrics_addr, Hooks::default())
}

fn spawn_engine_on<E: Engine + 'static>(
    engine: Arc<E>,
    addr: &str,
    builder: &EngineBuilder,
    registry: Arc<Registry>,
    metrics_addr: Option<&str>,
    hooks: Hooks,
) -> Result<FrontendHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let bound = listener.local_addr().context("resolving bound address")?;
    let cap = builder.batching.cap();
    let metrics = FrontendMetrics::register(&registry);
    let metrics_server = match metrics_addr {
        Some(a) => Some(obs::http::serve(a, Arc::clone(&registry))?),
        None => None,
    };
    let ctrl = Arc::new(Control {
        cfg: *builder,
        hooks,
        shutdown: AtomicBool::new(false),
        registry,
        metrics,
        conns: Mutex::new(HashMap::new()),
        egresses: Mutex::new(HashMap::new()),
        next_conn_id: AtomicUsize::new(0),
        readers: Arc::new(Gate::new()),
        writers: Arc::new(Gate::new()),
    });
    let shared = Arc::new(Shared {
        engine,
        injector: Injector::with_capacity(builder.queue_capacity),
        cache: (builder.cache_capacity > 0)
            .then(|| Mutex::new(LruCache::new(builder.cache_capacity))),
        batcher: AdaptiveBatcher::new(cap),
        ctrl: Arc::clone(&ctrl),
    });
    let join = std::thread::Builder::new()
        .name("srigl-frontend".into())
        .spawn(move || serve_loop(listener, shared))
        .context("spawning front-end thread")?;
    Ok(FrontendHandle { addr: bound, ctrl, join: Some(join), metrics: metrics_server })
}

/// Acceptor body: runs on the dedicated front-end thread until shutdown,
/// then tears down readers -> queue/workers -> egresses/writers in
/// dependency order.
fn serve_loop<E: Engine>(listener: TcpListener, shared: Arc<Shared<E>>) -> FrontendStats {
    let t_start = Instant::now();
    let ctrl = Arc::clone(&shared.ctrl);
    let worker_handles: Vec<JoinHandle<(WorkerStats, usize, usize)>> = (0..ctrl.cfg.workers)
        .map(|w| {
            let shared = Arc::clone(&shared);
            let stages = StageHists::register(&ctrl.registry);
            std::thread::Builder::new()
                .name(format!("srigl-worker-{w}"))
                .spawn(move || worker_loop(&shared, &stages))
                .expect("spawning pool worker") // lint:allow-unwrap startup resource exhaustion; no clients are connected yet
        })
        .collect();

    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if ctrl.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                // Transient accept error (EMFILE under connection flood):
                // back off instead of spinning a core while the workers
                // are trying to drain jobs and free fds.
                std::thread::sleep(std::time::Duration::from_millis(10));
                continue;
            }
        };
        if ctrl.shutdown.load(Ordering::SeqCst) {
            break; // the wake-up connection from stop()
        }
        let max_conns = ctrl.cfg.max_connections;
        if max_conns > 0 && ctrl.metrics.connections_active.get() >= max_conns as u64 {
            // Over the cap: refuse BEFORE spawning a reader, with a
            // best-effort Busy frame (id 0, the reserved control id) so
            // a protocol-following client backs off and retries instead
            // of diagnosing a silent hang-up.
            ctrl.metrics.connections_rejected.inc();
            let _ = write_response(
                &mut (&stream),
                &ResponseFrame {
                    id: 0,
                    body: ResponseBody::Busy { retry_after_ms: ctrl.cfg.retry_after_ms },
                },
            );
            let _ = stream.shutdown(Shutdown::Both);
            continue;
        }
        ctrl.metrics.connections_total.inc();
        let conn_id = ctrl.next_conn_id.fetch_add(1, Ordering::Relaxed) as u64;
        let Ok(registry_clone) = stream.try_clone() else { continue };
        lock_unpoisoned(&ctrl.conns, "conns").insert(conn_id, registry_clone);
        // The active gauge covers exactly the reader's lifetime: inc
        // here (before the cap check can run again), dec when the
        // reader exits — the admission slot a new connection competes
        // for.
        ctrl.metrics.connections_active.inc();
        let ticket = Gate::enter(&ctrl.readers);
        let reader_shared = Arc::clone(&shared);
        // The conns entry is removed by the connection's WRITER thread (the
        // last one out): the socket must stay reachable for teardown to
        // unblock a writer stuck on a slow client even after its reader
        // has exited.
        let spawned = std::thread::Builder::new()
            .name(format!("srigl-conn-{conn_id}"))
            .spawn(move || {
                let _ticket = ticket; // decrements the gate on exit/panic
                reader_loop(stream, &reader_shared, conn_id);
                reader_shared.ctrl.metrics.connections_active.dec();
            });
        if spawned.is_err() {
            lock_unpoisoned(&ctrl.conns, "conns").remove(&conn_id);
            ctrl.metrics.connections_active.dec();
        }
    }

    // Teardown, in dependency order:
    // 1. hang up on every live connection so blocked readers (and writers
    //    stuck on a full socket) unblock...
    for (_, c) in lock_unpoisoned(&ctrl.conns, "conns").iter() {
        let _ = c.shutdown(Shutdown::Both);
    }
    ctrl.readers.wait_idle();
    // 2. ...then close the queue (readers are gone, nobody can push) and
    //    let the workers drain what is left into the egress queues...
    shared.injector.close();
    let mut worker_stats = Vec::with_capacity(worker_handles.len());
    let (mut min_rows, mut max_rows) = (usize::MAX, 0usize);
    for h in worker_handles {
        // A panicked worker must not cascade: its batches are lost (and
        // their jobs' clients hang up or time out), but the remaining
        // workers' stats and every other connection still drain cleanly.
        match h.join() {
            Ok((ws, lo, hi)) => {
                min_rows = min_rows.min(lo);
                max_rows = max_rows.max(hi);
                worker_stats.push(ws);
            }
            Err(_) => crate::util::log::warn(
                "frontend",
                "a pool worker panicked; its stats are lost and its in-flight jobs unanswered",
            ),
        }
    }
    // 3. ...then force-close any egress still open (a connection whose
    //    queued jobs could never be answered — e.g. zero workers) and wait
    //    for the writers to drain and exit.
    for (_, e) in lock_unpoisoned(&ctrl.egresses, "egresses").iter() {
        e.close();
    }
    ctrl.writers.wait_idle();

    let served = worker_stats.iter().map(|w| w.served).sum();
    FrontendStats {
        latency: LatencyStats::from_workers(&worker_stats, t_start.elapsed().as_secs_f64()),
        served,
        cache_hits: ctrl.metrics.cache_hits.get() as usize,
        rejected: ctrl.metrics.rejected.get() as usize,
        bad_requests: ctrl.metrics.bad_requests.get() as usize,
        dropped_responses: ctrl.metrics.dropped_responses.get() as usize,
        connections_total: ctrl.metrics.connections_total.get() as usize,
        connections_active: ctrl.metrics.connections_active.get() as usize,
        connections_rejected: ctrl.metrics.connections_rejected.get() as usize,
        min_forward_rows: if max_rows == 0 { 0 } else { min_rows },
        max_forward_rows: max_rows,
    }
}

/// One connection's writer: drains the egress queue onto the socket. This
/// is the ONLY place a response touches the network, so a stalled socket
/// blocks exactly this thread. Exits once the egress closes and drains
/// (or the socket dies), then unregisters the egress.
fn writer_loop(stream: TcpStream, egress: Arc<Egress>, ctrl: Arc<Control>, conn_id: u64) {
    let mut w = std::io::BufWriter::new(stream);
    'outer: while let Some((frame, t_enq)) = egress.recv() {
        ctrl.metrics.egress_wait.record(t_enq.elapsed());
        if write_response(&mut w, &frame).is_err() {
            break;
        }
        // Opportunistically coalesce queued frames into one flush.
        while let Some((frame, t_enq)) = egress.try_recv() {
            ctrl.metrics.egress_wait.record(t_enq.elapsed());
            if write_response(&mut w, &frame).is_err() {
                break 'outer;
            }
        }
        if std::io::Write::flush(&mut w).is_err() {
            break;
        }
    }
    // Socket death or close: stop accepting frames so producers see Gone,
    // then unregister the connection (the writer is the last one out).
    egress.close();
    let _ = std::io::Write::flush(&mut w);
    lock_unpoisoned(&ctrl.egresses, "egresses").remove(&conn_id);
    lock_unpoisoned(&ctrl.conns, "conns").remove(&conn_id);
    // The connection is gone; its depth series goes with it.
    ctrl.registry.retract("srigl_egress_depth", &[("conn", &conn_id.to_string())]);
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Per-connection ingestion: parse frames, consult the cache, enqueue or
/// reject. Exits on EOF, a framing error, or socket shutdown. Framing
/// errors (bad length prefix, ragged payload, truncated frame) count as
/// `bad_requests`; an `InvalidData` frame additionally gets a best-effort
/// `Error` response with the reserved id 0 (docs/WIRE.md — clients use
/// ids >= 1) before the hang-up.
fn reader_loop<E: Engine>(stream: TcpStream, shared: &Shared<E>, conn_id: u64) {
    let _ = stream.set_nodelay(true);
    let ctrl = &shared.ctrl;
    let Ok(wstream) = stream.try_clone() else {
        lock_unpoisoned(&ctrl.conns, "conns").remove(&conn_id);
        return;
    };
    // Per-connection egress depth gauge: registered for the connection's
    // lifetime, retracted by its writer on exit so the registry doesn't
    // grow without bound as connections come and go.
    let conn_label = conn_id.to_string();
    let depth_gauge = ctrl.registry.gauge_with(
        "srigl_egress_depth",
        "Responses queued behind this connection's socket (a reading-slower-than-submitting client).",
        &[("conn", &conn_label)],
    );
    let egress = Arc::new(Egress::with_gauge(
        ctrl.cfg.egress_capacity,
        ctrl.cfg.retry_after_ms,
        Some(depth_gauge),
    ));
    lock_unpoisoned(&ctrl.egresses, "egresses").insert(conn_id, Arc::clone(&egress));
    let wticket = Gate::enter(&ctrl.writers);
    let wegress = Arc::clone(&egress);
    let wctrl = Arc::clone(ctrl);
    let spawned = std::thread::Builder::new()
        .name(format!("srigl-write-{conn_id}"))
        .spawn(move || {
            let _ticket = wticket; // decrements the gate on exit/panic
            writer_loop(wstream, wegress, wctrl, conn_id);
        });
    if spawned.is_err() {
        lock_unpoisoned(&ctrl.egresses, "egresses").remove(&conn_id);
        lock_unpoisoned(&ctrl.conns, "conns").remove(&conn_id);
        return;
    }

    let mut rd = std::io::BufReader::new(stream);
    let d = shared.engine.in_width();
    let cap = ctrl.cfg.batching.cap();
    loop {
        let incoming = match read_request(&mut rd) {
            Ok(Some(incoming)) => incoming,
            Ok(None) => break, // clean EOF (client hung up between frames)
            Err(e) => {
                match e.kind() {
                    std::io::ErrorKind::InvalidData => {
                        ctrl.metrics.bad_requests.inc();
                        // control frame: not a computed response, so an
                        // overflow here is not a "dropped response"
                        let _ = egress.send(ResponseFrame {
                            id: 0,
                            body: ResponseBody::Error(format!("framing error: {e}")),
                        });
                    }
                    std::io::ErrorKind::UnexpectedEof => {
                        // truncated frame: the peer died mid-write; count
                        // it, but there is nobody left to answer
                        ctrl.metrics.bad_requests.inc();
                    }
                    _ => {} // transport error (reset/shutdown): not a bad request
                }
                break;
            }
        };
        let req = match incoming {
            Incoming::Request(req) => req,
            Incoming::Control { id, op } => {
                // Control plane rides the reader thread: a reload blocks
                // only this connection's reads, never the worker pool.
                if op == CONTROL_OP_RELOAD {
                    match &ctrl.hooks.reload {
                        Some(reload) => {
                            let body = match reload() {
                                Ok(epoch) => ResponseBody::Epoch(epoch),
                                Err(e) => ResponseBody::Error(format!("reload failed: {e:#}")),
                            };
                            let _ = egress.send(ResponseFrame { id, body });
                        }
                        None => {
                            let _ = egress.send(ResponseFrame {
                                id,
                                body: ResponseBody::Error(
                                    "reload not enabled on this server".into(),
                                ),
                            });
                        }
                    }
                } else {
                    ctrl.metrics.bad_requests.inc();
                    let _ = egress.send(ResponseFrame {
                        id,
                        body: ResponseBody::Error(format!("unknown control opcode {op}")),
                    });
                }
                continue;
            }
        };
        // Ingress stage: frame fully read -> handed off (cache answer or
        // queue push). Excludes the blocking frame read itself — time
        // waiting for client bytes is the client's, not the server's.
        let t_ingress = Instant::now();
        let rows = req.rows as usize;
        if rows == 0 || rows > cap || req.payload.len() != rows * d {
            ctrl.metrics.bad_requests.inc();
            let msg = format!(
                "bad request: rows={rows} payload={} (need 1..={cap} rows of width {d})",
                req.payload.len()
            );
            let _ = egress.send(ResponseFrame { id: req.id, body: ResponseBody::Error(msg) });
            continue;
        }
        let hash = fnv1a_f32(&req.payload);
        // A cache poisoned by a worker that panicked mid-insert has an
        // untrustworthy LRU recency order — treat it as a permanent miss
        // (correct, just slower) rather than panicking every reader.
        if let Some(Ok(mut c)) = shared.cache.as_ref().map(|cache| cache.lock()) {
            let epoch = shared.engine.epoch();
            // peek, verify, then promote: a plain `get` would bump a hash-
            // *colliding* entry to most-recently-used before the bits_eq
            // check rejects it, polluting the recency order. The epoch
            // stamp must match too — an entry computed on a swapped-out
            // stack is a miss, never a stale answer.
            let verified = match c.peek(&hash) {
                Some((gen, input, output)) if *gen == epoch && bits_eq(input, &req.payload) => {
                    Some(output.clone())
                }
                _ => None, // miss, FNV collision, or dead epoch: recompute
                           // (the worker's insert overwrites the entry)
            };
            if let Some(data) = verified {
                c.touch(&hash);
                drop(c);
                ctrl.metrics.cache_hits.inc();
                let frame = ResponseFrame {
                    id: req.id,
                    body: ResponseBody::Output { rows: req.rows, data },
                };
                ctrl.metrics.ingress.record(t_ingress.elapsed());
                ctrl.count_send(egress.send(frame));
                continue;
            }
        }
        let job = Job {
            id: req.id,
            rows,
            x: req.payload,
            hash,
            egress: Arc::clone(&egress),
            t_submit: Instant::now(),
        };
        job.egress.job_started();
        ctrl.metrics.ingress.record(t_ingress.elapsed());
        if let Err(QueueFull(job)) = shared.injector.push_bounded(job) {
            ctrl.metrics.rejected.inc();
            // already counted as `rejected`; the Busy control frame must
            // not also count as a dropped response
            let _ = job.egress.send(ResponseFrame {
                id: job.id,
                body: ResponseBody::Busy { retry_after_ms: ctrl.cfg.retry_after_ms },
            });
            job.egress.job_finished();
        } else {
            ctrl.metrics.queue_depth.set(shared.injector.len() as u64);
        }
    }
    egress.reader_done();
}

/// Pool worker: adaptive pop, greedy row-packing, forward, route results
/// through each job's egress queue (never a blocking socket write).
/// Returns (stats, min packed rows, max packed rows).
fn worker_loop<E: Engine>(shared: &Shared<E>, stages: &StageHists) -> (WorkerStats, usize, usize) {
    let engine = &*shared.engine;
    let ctrl = &shared.ctrl;
    // The input width is a swap invariant (Engine::swap rejects a model
    // that changes it), so `d` and `xbuf` are safe to size once. The
    // output width is NOT — it is re-derived from each forward's actual
    // output, so a swap that changes it is picked up with the epoch.
    let d = engine.in_width();
    let cap = ctrl.cfg.batching.cap();
    let threads = ctrl.cfg.threads;
    let mut scratch = engine.scratch(cap);
    let mut xbuf = vec![0f32; cap * d];
    let mut jobs: Vec<Job> = Vec::with_capacity(cap);
    let mut ws = WorkerStats::default();
    let (mut min_rows, mut max_rows) = (usize::MAX, 0usize);
    loop {
        jobs.clear();
        let want = match ctrl.cfg.batching {
            Batching::Fixed(n) => n.max(1),
            Batching::Adaptive { .. } => shared.batcher.next_batch(shared.injector.len()),
        };
        if shared.injector.pop_batch(want, &mut jobs) == 0 {
            break;
        }
        ctrl.metrics.queue_depth.set(shared.injector.len() as u64);
        // Batch boundary: adopt the current epoch (rebuilds this worker's
        // scratch iff a swap landed since the last batch). Every job
        // popped here runs — and is cache-stamped — on exactly `gen`.
        let gen = engine.ensure_current(&mut scratch, cap);
        let t_pop = Instant::now();
        for job in &jobs {
            stages.queue_wait.record(t_pop.duration_since(job.t_submit));
        }
        while !jobs.is_empty() {
            // pack leading jobs while their rows fit one forward (every
            // job has rows <= cap, enforced at ingress, so take >= 1)
            let t_pack = Instant::now();
            let mut rows = 0usize;
            let mut take = 0usize;
            while take < jobs.len() && rows + jobs[take].rows <= cap {
                rows += jobs[take].rows;
                take += 1;
            }
            let mut off = 0usize;
            for job in &jobs[..take] {
                xbuf[off * d..(off + job.rows) * d].copy_from_slice(&job.x);
                off += job.rows;
            }
            let t_fwd = Instant::now();
            stages.assembly.record(t_fwd.duration_since(t_pack));
            let out = engine.forward(&xbuf[..rows * d], rows, &mut scratch, threads);
            let t_done = Instant::now();
            stages.forward.record(t_done.duration_since(t_fwd));
            // Derived from THIS forward's output, so it always matches the
            // epoch the scratch is pinned to — even right after a swap
            // that changed the stack's output width.
            let ow = out.len() / rows;
            min_rows = min_rows.min(rows);
            max_rows = max_rows.max(rows);
            ctrl.metrics.forward_rows_min.record_min_nonzero(rows as u64);
            ctrl.metrics.forward_rows_max.record_max(rows as u64);
            ws.batches += 1;
            ws.served += take;
            ctrl.metrics.batches.inc();
            ctrl.metrics.served.add(take as u64);
            let mut off = 0usize;
            for job in jobs.drain(..take) {
                let data = out[off * ow..(off + job.rows) * ow].to_vec();
                off += job.rows;
                // one sample, two sinks: the exact end-of-run LatencyStats
                // and the live stage="total" histogram stay consistent
                let us = t_done.duration_since(job.t_submit).as_secs_f64() * 1e6;
                ws.latencies_us.push(us);
                stages.total.record_us(us);
                // Insert BEFORE responding: once a client holds the answer
                // it may resend the same payload, which must then hit.
                // Stamped with the epoch this batch ran on, so a reader
                // after a swap treats it as a miss rather than serving a
                // dead stack's output. A poisoned cache (another worker
                // panicked mid-insert) is skipped: readers already treat
                // it as a permanent miss, so inserts are wasted anyway.
                if let Some(Ok(mut c)) = shared.cache.as_ref().map(|cache| cache.lock()) {
                    c.insert(job.hash, (gen, job.x, data.clone()));
                }
                let frame = ResponseFrame {
                    id: job.id,
                    body: ResponseBody::Output { rows: job.rows as u32, data },
                };
                ctrl.count_send(job.egress.send(frame));
                job.egress.job_finished();
            }
        }
    }
    (ws, min_rows, max_rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn out_frame(id: u64) -> ResponseFrame {
        ResponseFrame { id, body: ResponseBody::Output { rows: 1, data: vec![1.0, 2.0] } }
    }

    #[test]
    fn egress_overflow_converts_to_busy_then_drops() {
        let e = Egress::new(2, 7);
        assert_eq!(e.send(out_frame(1)), SendOutcome::Queued);
        assert_eq!(e.send(out_frame(2)), SendOutcome::Queued);
        // full: data frames convert to Busy within the headroom
        for i in 0..EGRESS_BUSY_HEADROOM as u64 {
            assert_eq!(e.send(out_frame(3 + i)), SendOutcome::ConvertedBusy, "headroom {i}");
        }
        // headroom exhausted: dropped outright — bounded no matter what
        assert_eq!(e.send(out_frame(99)), SendOutcome::Dropped);
        assert_eq!(e.send(out_frame(100)), SendOutcome::Dropped);

        // the writer sees the data frames first, then the Busy hints
        assert_eq!(e.try_recv().unwrap().0, out_frame(1));
        assert_eq!(e.try_recv().unwrap().0, out_frame(2));
        let busy = e.try_recv().unwrap().0;
        assert_eq!(busy.id, 3);
        assert_eq!(busy.body, ResponseBody::Busy { retry_after_ms: 7 });
        // draining reopens capacity for data frames
        assert_eq!(e.send(out_frame(200)), SendOutcome::Queued);
    }

    #[test]
    fn egress_overflow_passes_control_frames_through_verbatim() {
        // an Error must never morph into Busy (a retry-on-Busy client
        // would resend a malformed request forever), and a Busy stays a
        // Busy with its original hint
        let e = Egress::new(1, 7);
        assert_eq!(e.send(out_frame(1)), SendOutcome::Queued);
        let err = ResponseFrame { id: 2, body: ResponseBody::Error("bad shape".into()) };
        assert_eq!(e.send(err.clone()), SendOutcome::Queued, "control frame uses headroom");
        let busy = ResponseFrame { id: 3, body: ResponseBody::Busy { retry_after_ms: 99 } };
        assert_eq!(e.send(busy.clone()), SendOutcome::Queued);
        assert_eq!(e.try_recv().unwrap().0, out_frame(1));
        assert_eq!(e.try_recv().unwrap().0, err, "Error delivered verbatim");
        assert_eq!(e.try_recv().unwrap().0, busy, "Busy keeps its own hint (99, not 7)");
    }

    #[test]
    fn egress_closes_after_reader_done_and_jobs_drain() {
        let e = Egress::new(4, 1);
        e.job_started();
        e.job_started();
        e.reader_done();
        assert_eq!(e.send(out_frame(1)), SendOutcome::Queued, "still open: jobs in flight");
        e.job_finished();
        e.job_finished(); // last job out + reader gone -> closed
        assert_eq!(e.send(out_frame(2)), SendOutcome::Gone);
        // queued frames still drain after close...
        assert_eq!(e.recv().unwrap().0, out_frame(1));
        // ...then recv reports closed
        assert!(e.recv().is_none());
    }

    #[test]
    fn egress_reader_done_with_no_jobs_closes_immediately() {
        let e = Egress::new(4, 1);
        e.reader_done();
        assert_eq!(e.send(out_frame(1)), SendOutcome::Gone);
        assert!(e.recv().is_none());
    }

    #[test]
    fn egress_capacity_floor_is_one() {
        let e = Egress::new(0, 1);
        assert_eq!(e.send(out_frame(1)), SendOutcome::Queued);
        assert_eq!(e.send(out_frame(2)), SendOutcome::ConvertedBusy);
    }

    #[test]
    fn egress_recv_blocks_until_send() {
        let e = Arc::new(Egress::new(2, 1));
        let e2 = Arc::clone(&e);
        let h = std::thread::spawn(move || e2.recv());
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(e.send(out_frame(5)), SendOutcome::Queued);
        assert_eq!(h.join().unwrap().unwrap().0, out_frame(5));
    }
}
