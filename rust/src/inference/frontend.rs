//! Network serving front-end: turns the worker-pool inference engine into
//! a real socket server. The ROADMAP's "serving scale-out" block: async IO
//! ingestion, backpressure, adaptive batching, a result cache, and — via
//! [`FrontendConfig::shards`] — tensor-parallel sharded execution
//! ([`crate::inference::shard`]) behind the same queue machinery.
//!
//! Data path:
//!
//! ```text
//! TcpListener (blocking accept)
//!   └─ one reader thread per connection
//!        ├─ parse length-prefixed request frames (crate::net)
//!        ├─ FNV-1a hash of the row bytes → LRU result cache: hit answers
//!        │    immediately without touching the queue
//!        ├─ miss → Injector::push_bounded: a full queue answers
//!        │    Busy{retry_after_ms} (backpressure, never unbounded growth)
//!        └─ per-connection writer (Mutex<TcpStream>) shared with workers
//!   workers (N threads, shared queue)
//!        ├─ pop up to AdaptiveBatcher::next_batch(queue depth) requests
//!        ├─ greedily pack popped requests into ≤ cap-row forwards on a
//!        │    per-worker Scratch (allocation-free)
//!        └─ route each result back through the owning connection's writer
//! ```
//!
//! Responses carry the request id, so a pipelined connection may see them
//! out of submission order (cache hits overtake queued work). The
//! synchronous [`crate::net::Client`] keeps one request in flight and never
//! observes this.
//!
//! Known limitation (documented, not fixed here): a worker blocks while
//! writing to a slow client's socket, stalling the rest of its batch —
//! per-connection egress queues are future work.

use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use super::server::{AdaptiveBatcher, Batching, LatencyStats, WorkerStats};
use super::shard::{ServeEngine, ShardedModel};
use super::SparseModel;
use crate::net::{fnv1a_f32, read_request, write_response, ResponseBody, ResponseFrame};
use crate::util::lru::LruCache;
use crate::util::threadpool::{Injector, QueueFull};

#[derive(Clone, Copy, Debug)]
pub struct FrontendConfig {
    /// Pool workers draining the queue. `0` is allowed and means ingestion
    /// only — nothing drains, so the bounded queue fills deterministically
    /// (used by the backpressure tests).
    pub workers: usize,
    /// Batch-limit policy per pop; `Batching::cap()` also bounds the rows
    /// a single request may carry.
    pub batching: Batching,
    /// Bounded request-queue capacity (requests, not rows).
    pub queue_capacity: usize,
    /// Result-cache entries; `0` disables caching.
    pub cache_capacity: usize,
    /// Intra-op threads per worker (the kernel `threads` parameter; with
    /// sharding, the intra-*shard* thread count).
    pub threads: usize,
    /// Backoff hint sent with `Busy` rejections.
    pub retry_after_ms: u32,
    /// Tensor-parallel shards per forward (`<= 1` = replicated). With
    /// `shards > 1` each worker's forward fans out over a shard team
    /// ([`crate::inference::shard::ShardedModel`]); pair with `workers: 1`
    /// unless you want teams x workers oversubscription.
    pub shards: usize,
}

impl Default for FrontendConfig {
    fn default() -> FrontendConfig {
        FrontendConfig {
            workers: 4,
            batching: Batching::Adaptive { cap: 8 },
            queue_capacity: 1024,
            cache_capacity: 1024,
            threads: 1,
            retry_after_ms: 2,
            shards: 1,
        }
    }
}

/// End-of-run accounting returned by [`FrontendHandle::stop`].
#[derive(Clone, Debug)]
pub struct FrontendStats {
    /// Latency/throughput over the queue-served (compute) requests.
    pub latency: LatencyStats,
    /// Requests answered by the worker pool.
    pub served: usize,
    /// Requests answered straight from the result cache.
    pub cache_hits: usize,
    /// Requests rejected with `Busy` (bounded queue full).
    pub rejected: usize,
    /// Malformed requests answered with `Error`.
    pub bad_requests: usize,
    /// Connections accepted over the run.
    pub connections: usize,
    /// Smallest / largest packed forward (rows) any worker ran — under a
    /// trickle these collapse to 1/1; under a flood the max approaches the
    /// batching cap (how the adaptive batcher shows up in the numbers).
    pub min_forward_rows: usize,
    pub max_forward_rows: usize,
}

/// One enqueued request: features plus the route back to its connection.
struct Job {
    id: u64,
    rows: usize,
    x: Vec<f32>,
    hash: u64,
    writer: Arc<Mutex<TcpStream>>,
    t_submit: Instant,
}

/// Counts reader threads so shutdown can wait for them without collecting
/// an unbounded Vec of join handles (connections come and go).
struct ReaderGate {
    n: Mutex<usize>,
    cv: Condvar,
}

impl ReaderGate {
    fn new() -> ReaderGate {
        ReaderGate { n: Mutex::new(0), cv: Condvar::new() }
    }

    fn enter(gate: &Arc<ReaderGate>) -> ReaderTicket {
        *gate.n.lock().unwrap() += 1;
        ReaderTicket(Arc::clone(gate))
    }

    fn wait_idle(&self) {
        let mut g = self.n.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Drop guard: decrements the gate even if a reader panics.
struct ReaderTicket(Arc<ReaderGate>);

impl Drop for ReaderTicket {
    fn drop(&mut self) {
        *self.0.n.lock().unwrap() -= 1;
        self.0.cv.notify_all();
    }
}

struct Shared {
    engine: Arc<ServeEngine>,
    injector: Injector<Job>,
    /// hash -> (input bits, output); input kept to defeat hash collisions.
    cache: Option<Mutex<LruCache<u64, (Vec<f32>, Vec<f32>)>>>,
    batcher: AdaptiveBatcher,
    cfg: FrontendConfig,
    shutdown: AtomicBool,
    cache_hits: AtomicUsize,
    rejected: AtomicUsize,
    bad_requests: AtomicUsize,
    connections: AtomicUsize,
    /// Live connection streams (clones) so shutdown can unblock readers.
    conns: Mutex<std::collections::HashMap<u64, TcpStream>>,
    next_conn_id: AtomicUsize,
    gate: Arc<ReaderGate>,
}

/// Running front-end: keep it to keep serving; [`FrontendHandle::stop`]
/// drains and returns stats.
pub struct FrontendHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    join: Option<JoinHandle<FrontendStats>>,
}

impl FrontendHandle {
    /// The bound address (resolves port 0 to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, hang up on clients, drain the queue, and return the
    /// run's statistics.
    pub fn stop(mut self) -> FrontendStats {
        self.shutdown_and_join()
            .expect("handle already joined")
            .expect("frontend thread panicked")
    }

    /// Serve until the process dies (the `serve-model --listen` path).
    pub fn run_forever(mut self) -> FrontendStats {
        self.join.take().expect("handle not yet joined").join().expect("frontend thread panicked")
    }

    fn shutdown_and_join(&mut self) -> Option<std::thread::Result<FrontendStats>> {
        let join = self.join.take()?;
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let mut addr = self.addr;
        if addr.ip().is_unspecified() {
            addr.set_ip(match addr.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(addr);
        Some(join.join())
    }
}

/// Dropping an unjoined handle (early `?` return in the caller) must not
/// leak the acceptor, the worker threads, and the bound port for the rest
/// of the process: run the same shutdown sequence as
/// [`FrontendHandle::stop`], discarding the stats (and swallowing a
/// thread panic — we may already be unwinding).
impl Drop for FrontendHandle {
    fn drop(&mut self) {
        let _ = self.shutdown_and_join();
    }
}

/// Bind `addr` (e.g. `"127.0.0.1:0"`) and serve `model` until
/// [`FrontendHandle::stop`] — replicated across workers, or tensor-parallel
/// sharded when `cfg.shards > 1` (the `serve-model --listen --shards N`
/// path).
pub fn spawn(model: Arc<SparseModel>, addr: &str, cfg: FrontendConfig) -> Result<FrontendHandle> {
    let engine = if cfg.shards > 1 {
        ServeEngine::Sharded(Arc::new(
            ShardedModel::from_model(&model, cfg.shards).context("building shard plan")?,
        ))
    } else {
        ServeEngine::Replicated(model)
    };
    spawn_engine(Arc::new(engine), addr, cfg)
}

/// Bind `addr` and serve a pre-built [`ServeEngine`] (replicated or
/// sharded with a custom plan).
pub fn spawn_engine(
    engine: Arc<ServeEngine>,
    addr: &str,
    cfg: FrontendConfig,
) -> Result<FrontendHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let bound = listener.local_addr().context("resolving bound address")?;
    let cap = cfg.batching.cap();
    let shared = Arc::new(Shared {
        engine,
        injector: Injector::with_capacity(cfg.queue_capacity),
        cache: (cfg.cache_capacity > 0).then(|| Mutex::new(LruCache::new(cfg.cache_capacity))),
        batcher: AdaptiveBatcher::new(cap),
        cfg,
        shutdown: AtomicBool::new(false),
        cache_hits: AtomicUsize::new(0),
        rejected: AtomicUsize::new(0),
        bad_requests: AtomicUsize::new(0),
        connections: AtomicUsize::new(0),
        conns: Mutex::new(std::collections::HashMap::new()),
        next_conn_id: AtomicUsize::new(0),
        gate: Arc::new(ReaderGate::new()),
    });
    let thread_shared = Arc::clone(&shared);
    let join = std::thread::Builder::new()
        .name("srigl-frontend".into())
        .spawn(move || serve_loop(listener, thread_shared))
        .context("spawning front-end thread")?;
    Ok(FrontendHandle { addr: bound, shared, join: Some(join) })
}

/// Acceptor body: runs on the dedicated front-end thread until shutdown,
/// then tears down readers -> queue -> workers in dependency order.
fn serve_loop(listener: TcpListener, shared: Arc<Shared>) -> FrontendStats {
    let t_start = Instant::now();
    let worker_handles: Vec<JoinHandle<(WorkerStats, usize, usize)>> = (0..shared.cfg.workers)
        .map(|w| {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("srigl-worker-{w}"))
                .spawn(move || worker_loop(&shared))
                .expect("spawning pool worker")
        })
        .collect();

    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                // Transient accept error (EMFILE under connection flood):
                // back off instead of spinning a core while the workers
                // are trying to drain jobs and free fds.
                std::thread::sleep(std::time::Duration::from_millis(10));
                continue;
            }
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            break; // the wake-up connection from stop()
        }
        shared.connections.fetch_add(1, Ordering::Relaxed);
        let conn_id = shared.next_conn_id.fetch_add(1, Ordering::Relaxed) as u64;
        let Ok(registry_clone) = stream.try_clone() else { continue };
        shared.conns.lock().unwrap().insert(conn_id, registry_clone);
        let ticket = ReaderGate::enter(&shared.gate);
        let reader_shared = Arc::clone(&shared);
        let spawned = std::thread::Builder::new()
            .name(format!("srigl-conn-{conn_id}"))
            .spawn(move || {
                let _ticket = ticket; // decrements the gate on exit/panic
                reader_loop(stream, &reader_shared);
                reader_shared.conns.lock().unwrap().remove(&conn_id);
            });
        if spawned.is_err() {
            shared.conns.lock().unwrap().remove(&conn_id);
        }
    }

    // Teardown: hang up on every live connection so readers unblock...
    for (_, c) in shared.conns.lock().unwrap().iter() {
        let _ = c.shutdown(Shutdown::Both);
    }
    shared.gate.wait_idle();
    // ...then close the queue (readers are gone, nobody can push) and let
    // the workers drain what is left.
    shared.injector.close();
    let mut worker_stats = Vec::with_capacity(worker_handles.len());
    let (mut min_rows, mut max_rows) = (usize::MAX, 0usize);
    for h in worker_handles {
        let (ws, lo, hi) = h.join().expect("pool worker panicked");
        min_rows = min_rows.min(lo);
        max_rows = max_rows.max(hi);
        worker_stats.push(ws);
    }
    let served = worker_stats.iter().map(|w| w.served).sum();
    FrontendStats {
        latency: LatencyStats::from_workers(&worker_stats, t_start.elapsed().as_secs_f64()),
        served,
        cache_hits: shared.cache_hits.load(Ordering::Relaxed),
        rejected: shared.rejected.load(Ordering::Relaxed),
        bad_requests: shared.bad_requests.load(Ordering::Relaxed),
        connections: shared.connections.load(Ordering::Relaxed),
        min_forward_rows: if max_rows == 0 { 0 } else { min_rows },
        max_forward_rows: max_rows,
    }
}

fn respond(writer: &Mutex<TcpStream>, id: u64, body: ResponseBody) {
    // Write errors mean the client hung up; the reader will notice EOF.
    let mut w = writer.lock().unwrap();
    let _ = write_response(&mut *w, &ResponseFrame { id, body });
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Per-connection ingestion: parse frames, consult the cache, enqueue or
/// reject. Exits on EOF, a framing error, or socket shutdown. Framing
/// errors (bad length prefix, ragged payload, truncated frame) count as
/// `bad_requests`; an `InvalidData` frame additionally gets a best-effort
/// `Error` response with the reserved id 0 (docs/WIRE.md — clients use
/// ids >= 1) before the hang-up.
fn reader_loop(stream: TcpStream, shared: &Shared) {
    let _ = stream.set_nodelay(true);
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let mut rd = std::io::BufReader::new(stream);
    let d = shared.engine.in_width();
    let cap = shared.cfg.batching.cap();
    loop {
        let req = match read_request(&mut rd) {
            Ok(Some(req)) => req,
            Ok(None) => break, // clean EOF (client hung up between frames)
            Err(e) => {
                match e.kind() {
                    std::io::ErrorKind::InvalidData => {
                        shared.bad_requests.fetch_add(1, Ordering::Relaxed);
                        respond(
                            &writer,
                            0,
                            ResponseBody::Error(format!("framing error: {e}")),
                        );
                    }
                    std::io::ErrorKind::UnexpectedEof => {
                        // truncated frame: the peer died mid-write; count
                        // it, but there is nobody left to answer
                        shared.bad_requests.fetch_add(1, Ordering::Relaxed);
                    }
                    _ => {} // transport error (reset/shutdown): not a bad request
                }
                break;
            }
        };
        let rows = req.rows as usize;
        if rows == 0 || rows > cap || req.payload.len() != rows * d {
            shared.bad_requests.fetch_add(1, Ordering::Relaxed);
            let msg = format!(
                "bad request: rows={rows} payload={} (need 1..={cap} rows of width {d})",
                req.payload.len()
            );
            respond(&writer, req.id, ResponseBody::Error(msg));
            continue;
        }
        let hash = fnv1a_f32(&req.payload);
        if let Some(cache) = &shared.cache {
            let mut c = cache.lock().unwrap();
            // peek, verify, then promote: a plain `get` would bump a hash-
            // *colliding* entry to most-recently-used before the bits_eq
            // check rejects it, polluting the recency order
            let verified = match c.peek(&hash) {
                Some((input, output)) if bits_eq(input, &req.payload) => Some(output.clone()),
                _ => None, // miss, or FNV collision: recompute (the worker's
                           // insert overwrites the colliding entry)
            };
            if let Some(data) = verified {
                c.touch(&hash);
                drop(c);
                shared.cache_hits.fetch_add(1, Ordering::Relaxed);
                respond(&writer, req.id, ResponseBody::Output { rows: req.rows, data });
                continue;
            }
        }
        let job = Job {
            id: req.id,
            rows,
            x: req.payload,
            hash,
            writer: Arc::clone(&writer),
            t_submit: Instant::now(),
        };
        if let Err(QueueFull(job)) = shared.injector.push_bounded(job) {
            shared.rejected.fetch_add(1, Ordering::Relaxed);
            respond(
                &job.writer,
                job.id,
                ResponseBody::Busy { retry_after_ms: shared.cfg.retry_after_ms },
            );
        }
    }
}

/// Pool worker: adaptive pop, greedy row-packing, forward, route results.
/// Returns (stats, min packed rows, max packed rows).
fn worker_loop(shared: &Shared) -> (WorkerStats, usize, usize) {
    let engine = &shared.engine;
    let d = engine.in_width();
    let ow = engine.out_width();
    let cap = shared.cfg.batching.cap();
    let threads = shared.cfg.threads;
    let mut scratch = engine.make_scratch(cap);
    let mut xbuf = vec![0f32; cap * d];
    let mut jobs: Vec<Job> = Vec::with_capacity(cap);
    let mut ws = WorkerStats::default();
    let (mut min_rows, mut max_rows) = (usize::MAX, 0usize);
    loop {
        jobs.clear();
        let want = match shared.cfg.batching {
            Batching::Fixed(n) => n.max(1),
            Batching::Adaptive { .. } => shared.batcher.next_batch(shared.injector.len()),
        };
        if shared.injector.pop_batch(want, &mut jobs) == 0 {
            break;
        }
        while !jobs.is_empty() {
            // pack leading jobs while their rows fit one forward (every
            // job has rows <= cap, enforced at ingress, so take >= 1)
            let mut rows = 0usize;
            let mut take = 0usize;
            while take < jobs.len() && rows + jobs[take].rows <= cap {
                rows += jobs[take].rows;
                take += 1;
            }
            let mut off = 0usize;
            for job in &jobs[..take] {
                xbuf[off * d..(off + job.rows) * d].copy_from_slice(&job.x);
                off += job.rows;
            }
            let out = engine.forward(&xbuf[..rows * d], rows, &mut scratch, threads);
            let t_done = Instant::now();
            min_rows = min_rows.min(rows);
            max_rows = max_rows.max(rows);
            ws.batches += 1;
            ws.served += take;
            let mut off = 0usize;
            for job in jobs.drain(..take) {
                let data = out[off * ow..(off + job.rows) * ow].to_vec();
                off += job.rows;
                ws.latencies_us
                    .push(t_done.duration_since(job.t_submit).as_secs_f64() * 1e6);
                // Insert BEFORE responding: once a client holds the answer
                // it may resend the same payload, which must then hit.
                if let Some(cache) = &shared.cache {
                    cache.lock().unwrap().insert(job.hash, (job.x, data.clone()));
                }
                respond(
                    &job.writer,
                    job.id,
                    ResponseBody::Output { rows: job.rows as u32, data },
                );
            }
        }
    }
    (ws, min_rows, max_rows)
}
