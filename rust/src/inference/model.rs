//! Multi-layer sparse model serving: [`SparseModel`] — an owned stack of
//! [`LinearKernel`] layers with per-layer activations, the forward path
//! behind the replicated serving engine (it implements
//! [`crate::inference::engine::Engine`] directly, and
//! [`crate::inference::engine::ReplicatedEngine`] wraps it for the
//! worker-pool server and socket front-end).
//!
//! Each layer may use any of the representations the paper benchmarks
//! (dense / CSR / structured / condensed) plus the batch-tiled condensed
//! variant, mixed freely per layer via [`Repr`]. Compact representations
//! (structured/condensed/condensed-tiled) emit only the
//! surviving neurons; between layers the compact output is scattered back
//! to the layer's full logical width so the next layer sees a fixed-width
//! input regardless of representation. A fully-ablated neuron is removed
//! from the network *including its bias* — dense/CSR kernels zero the bias
//! of ablated rows so all four representations of the same weights are
//! exactly equivalent end to end (the kernel-equivalence suite pins this).
//!
//! The forward pass is double-buffered through a caller-owned [`Scratch`]
//! (two ping-pong activation buffers plus one compact staging buffer), so
//! serving performs **no per-request allocation**; each server worker owns
//! one `Scratch` sized for its `max_batch`.
//!
//! Construction paths:
//! * [`SparseModel::synth`] — random SRigL-shaped stack from [`LayerSpec`]s
//!   (benches, the `serve-model` subcommand, tests);
//! * [`SparseModel::from_trained`] — from per-layer (weights, mask, bias)
//!   triples, e.g. a trained [`crate::train::Trainer`]'s sparse layers via
//!   `Trainer::export_model`;
//! * [`SparseModel::from_stack`] — from a `runtime::manifest` stack
//!   description (`"stacks"` section of artifacts/manifest.json).

use std::sync::Arc;

use anyhow::Result;

use super::{
    CondensedLayer, CondensedTiledLayer, CsrLayer, DenseLayer, LinearKernel, QuantizedLayer,
    QuantizedTiledLayer, StructuredLayer,
};
use crate::kernels::{self, Microkernel};
use crate::runtime::manifest::StackEntry;
use crate::sparsity::Mask;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Per-layer nonlinearity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Relu,
    Identity,
}

impl Activation {
    pub fn apply(self, xs: &mut [f32]) {
        if self == Activation::Relu {
            for v in xs.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
    }

    pub fn parse(s: &str) -> Result<Activation> {
        match s {
            "relu" => Ok(Activation::Relu),
            "identity" | "none" | "linear" => Ok(Activation::Identity),
            other => anyhow::bail!("unknown activation {other:?} (relu|identity)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Activation::Relu => "relu",
            Activation::Identity => "identity",
        }
    }
}

/// Which layer representation to build (paper Fig. 4 rows, plus the
/// batch-tiled condensed variant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Repr {
    Dense,
    Csr,
    Structured,
    Condensed,
    /// Condensed semantics on the interleaved batch-tiled layout
    /// ([`CondensedTiledLayer`]) — fastest at batch >=
    /// [`crate::kernels::TILE`].
    CondensedTiled,
    /// The int8 quantization of the condensed form ([`QuantizedLayer`]):
    /// same function within the documented per-row error budget
    /// (docs/KERNELS.md), half the weight-stream bytes.
    Quantized,
    /// The batch-tiled twin of the quantized form
    /// ([`QuantizedTiledLayer`]) — bit-for-bit the same outputs as
    /// [`Repr::Quantized`], faster at batch >= [`crate::kernels::TILE`].
    QuantizedTiled,
}

impl Repr {
    pub const ALL: [Repr; 7] = [
        Repr::Dense,
        Repr::Csr,
        Repr::Structured,
        Repr::Condensed,
        Repr::CondensedTiled,
        Repr::Quantized,
        Repr::QuantizedTiled,
    ];

    pub fn parse(s: &str) -> Result<Repr> {
        match s {
            "dense" => Ok(Repr::Dense),
            "csr" => Ok(Repr::Csr),
            "structured" => Ok(Repr::Structured),
            "condensed" => Ok(Repr::Condensed),
            "condensed-tiled" | "tiled" => Ok(Repr::CondensedTiled),
            "quantized" | "quant" => Ok(Repr::Quantized),
            "quantized-tiled" | "quant-tiled" => Ok(Repr::QuantizedTiled),
            other => anyhow::bail!(
                "unknown repr {other:?} (dense|csr|structured|condensed|condensed-tiled|quantized|quantized-tiled)"
            ),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Repr::Dense => "dense",
            Repr::Csr => "csr",
            Repr::Structured => "structured",
            Repr::Condensed => "condensed",
            Repr::CondensedTiled => "condensed-tiled",
            Repr::Quantized => "quantized",
            Repr::QuantizedTiled => "quantized-tiled",
        }
    }
}

/// One layer of a [`SparseModel`]: a kernel plus scatter metadata mapping
/// its (possibly compact) output back to the layer's full logical width.
pub struct ModelLayer {
    kernel: Box<dyn LinearKernel>,
    activation: Activation,
    /// `Some(active-neuron ids)` when the kernel emits compact rows.
    active: Option<Vec<u32>>,
    /// Logical output width n, including ablated neurons.
    full_width: usize,
}

impl ModelLayer {
    /// Build one layer from (possibly unmasked) weights + mask + bias in the
    /// requested representation. Weights are masked internally so every
    /// representation computes the same function; ablated neurons emit 0
    /// (their bias is dead weight and is dropped/zeroed). Fails (typed
    /// [`crate::sparsity::CondensedError`] through `anyhow`) when a
    /// condensed representation is requested for a mask without constant
    /// fan-in — a bad manifest is a startup error, not a worker panic.
    pub fn from_weights(
        w: &Tensor,
        mask: &Mask,
        bias: &[f32],
        repr: Repr,
        activation: Activation,
    ) -> Result<ModelLayer> {
        let (n, _d) = w.neuron_view();
        anyhow::ensure!(bias.len() == n, "bias len {} != neurons {n}", bias.len());
        let mut wm = w.clone();
        wm.mul_assign(&mask.t);
        let counts = mask.fan_in_counts();
        let bias_z: Vec<f32> = bias
            .iter()
            .enumerate()
            .map(|(r, &b)| if counts[r] == 0 { 0.0 } else { b })
            .collect();
        let (kernel, active): (Box<dyn LinearKernel>, Option<Vec<u32>>) = match repr {
            Repr::Dense => (Box::new(DenseLayer::new(&wm, bias_z)), None),
            Repr::Csr => (Box::new(CsrLayer::new(&wm, bias_z)), None),
            Repr::Structured => {
                let l = StructuredLayer::new(&wm, mask, bias);
                let a = l.active.clone();
                (Box::new(l), Some(a))
            }
            Repr::Condensed => {
                let l = CondensedLayer::new(&wm, mask, bias)?;
                let a = l.c.active.clone();
                (Box::new(l), Some(a))
            }
            Repr::CondensedTiled => {
                let l = CondensedTiledLayer::new(&wm, mask, bias)?;
                let a = l.t.active.clone();
                (Box::new(l), Some(a))
            }
            Repr::Quantized => {
                let l = QuantizedLayer::new(&wm, mask, bias)?;
                let a = l.q.active.clone();
                (Box::new(l), Some(a))
            }
            Repr::QuantizedTiled => {
                let l = QuantizedTiledLayer::new(&wm, mask, bias)?;
                let a = l.q.active.clone();
                (Box::new(l), Some(a))
            }
        };
        // A compact form with no ablated rows is already full-width: skip
        // the per-request scatter and write the output buffer directly.
        let active = active.filter(|a| a.len() < n);
        Ok(ModelLayer { kernel, activation, active, full_width: n })
    }

    pub fn in_width(&self) -> usize {
        self.kernel.in_width()
    }

    /// Logical output width (original n, including ablated neurons).
    pub fn out_full_width(&self) -> usize {
        self.full_width
    }

    pub fn kernel(&self) -> &dyn LinearKernel {
        self.kernel.as_ref()
    }

    pub fn activation(&self) -> Activation {
        self.activation
    }

    /// Scatter ids mapping the kernel's compact output rows back to full
    /// logical neuron positions — `None` when the kernel already emits the
    /// full width.
    pub fn active_ids(&self) -> Option<&[u32]> {
        self.active.as_deref()
    }

    /// Stored weights per logical output neuron — what
    /// [`crate::inference::shard::ShardPlan`] balances shards on.
    pub fn row_weights(&self) -> Vec<usize> {
        self.kernel.row_weights(self.full_width)
    }

    /// The int8 quantized twin of this layer (`tiled` selects the
    /// batch-tiled driver), calibrated against this layer's own f32
    /// weights; activation, logical width, and scatter ids are preserved.
    /// Errors when the kernel's representation has no quantized form
    /// (dense/CSR/structured) or its geometry cannot be quantized.
    pub fn quantized(&self, tiled: bool) -> Result<ModelLayer> {
        let kernel = match self.kernel.quantized(tiled) {
            Some(q) => q?,
            None => anyhow::bail!(
                "repr {:?} has no int8 quantized form (quantization needs the condensed \
                 constant-fan-in structure)",
                self.kernel.name()
            ),
        };
        Ok(ModelLayer {
            kernel,
            activation: self.activation,
            active: self.active.clone(),
            full_width: self.full_width,
        })
    }

    /// The same layer re-stamped onto a different microkernel handle (the
    /// arena's per-side `kernel=` override). Callers must only pass kinds
    /// available on this CPU.
    pub fn with_kernel(&self, mk: Microkernel) -> ModelLayer {
        ModelLayer {
            kernel: self.kernel.with_kernel(mk),
            activation: self.activation,
            active: self.active.clone(),
            full_width: self.full_width,
        }
    }

    /// Slice this layer to the contiguous logical output-neuron range —
    /// the tensor-parallel sharding primitive. The slice's logical width is
    /// `range.len()`; its scatter ids are rebased to the range start, and
    /// its per-neuron arithmetic is bit-for-bit that of the full layer.
    pub fn slice(&self, range: std::ops::Range<usize>) -> ModelLayer {
        assert!(
            range.start <= range.end && range.end <= self.full_width,
            "slice {range:?} out of 0..{}",
            self.full_width
        );
        let kernel = self.kernel.slice_rows(range.start, range.end);
        let w = range.end - range.start;
        let active = kernel.active_rows().map(<[u32]>::to_vec).filter(|a| a.len() < w);
        ModelLayer { kernel, activation: self.activation, active, full_width: w }
    }
}

/// Per-worker workspace for [`SparseModel::forward`]: two ping-pong
/// activation buffers plus a staging buffer for compact kernel outputs.
/// Created once per worker via [`SparseModel::make_scratch`].
pub struct Scratch {
    pub(crate) a: Vec<f32>,
    pub(crate) b: Vec<f32>,
    compact: Vec<f32>,
    max_batch: usize,
}

impl Scratch {
    /// A scratch for driving one bare kernel (single-layer serving).
    pub(crate) fn single(max_batch: usize, out_width: usize) -> Scratch {
        let max_batch = max_batch.max(1);
        Scratch { a: vec![0.0; max_batch * out_width], b: Vec::new(), compact: Vec::new(), max_batch }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }
}

/// Spec for one synthesized layer of [`SparseModel::synth`].
#[derive(Clone, Debug)]
pub struct LayerSpec {
    pub n: usize,
    pub repr: Repr,
    pub sparsity: f64,
    pub ablated_frac: f64,
    pub activation: Activation,
}

/// One immutable published generation of a serving stack: the stack itself
/// behind an [`Arc`] plus the monotonically increasing epoch id under which
/// it serves. Swappable engines ([`crate::inference::SwappableEngine`] and
/// its members) publish a `ModelEpoch` atomically; in-flight forwards keep
/// the previous epoch's `Arc` alive until they drain (RCU-style), so a swap
/// never mixes two stacks inside one response.
///
/// Sharded engines derive their [`crate::inference::ShardPlan`] from
/// `model` at publish time — the plan is a pure function of the stack and
/// the shard count, so it is not carried here.
#[derive(Clone)]
pub struct ModelEpoch {
    pub id: u64,
    pub model: Arc<SparseModel>,
}

impl ModelEpoch {
    pub fn new(id: u64, model: Arc<SparseModel>) -> Self {
        Self { id, model }
    }
}

/// A stack of sparse linear layers sharing one double-buffered forward.
pub struct SparseModel {
    layers: Vec<ModelLayer>,
    d_in: usize,
}

impl SparseModel {
    /// Compose pre-built layers; validates that widths chain (layer i+1's
    /// fan-in equals layer i's full logical width).
    pub fn new(layers: Vec<ModelLayer>) -> Result<SparseModel> {
        anyhow::ensure!(!layers.is_empty(), "model needs at least one layer");
        for w in layers.windows(2) {
            anyhow::ensure!(
                w[1].in_width() == w[0].full_width,
                "layer width mismatch: {} feeds a layer expecting {}",
                w[0].full_width,
                w[1].in_width()
            );
        }
        Ok(SparseModel { d_in: layers[0].in_width(), layers })
    }

    /// Synthesize an SRigL-shaped stack: constant fan-in masks at the given
    /// sparsity with a fraction of fully-ablated neurons per layer (what
    /// SRigL's dynamic ablation produces), He-scaled weights.
    pub fn synth(d_in: usize, specs: &[LayerSpec], seed: u64) -> Result<SparseModel> {
        anyhow::ensure!(!specs.is_empty(), "model needs at least one layer spec");
        anyhow::ensure!(d_in > 0, "input width must be positive");
        let mut rng = Rng::new(seed);
        let mut layers = Vec::with_capacity(specs.len());
        let mut d = d_in;
        for spec in specs {
            anyhow::ensure!(spec.n > 0, "layer width must be positive");
            let (w, mask, bias) = synth_layer(spec.n, d, spec.sparsity, spec.ablated_frac, &mut rng);
            layers.push(ModelLayer::from_weights(&w, &mask, &bias, spec.repr, spec.activation)?);
            d = spec.n;
        }
        SparseModel::new(layers)
    }

    /// Build from trained per-layer (weights, mask, bias) triples — the
    /// `Session`-weights path (`Trainer::export_model`). Hidden layers get
    /// ReLU, the last layer is linear. MLP-shaped stacks only.
    pub fn from_trained(layers: &[(Tensor, Mask, Vec<f32>)], repr: Repr) -> Result<SparseModel> {
        anyhow::ensure!(!layers.is_empty(), "no layers to export");
        let mut out = Vec::with_capacity(layers.len());
        for (i, (w, m, b)) in layers.iter().enumerate() {
            let act =
                if i + 1 == layers.len() { Activation::Identity } else { Activation::Relu };
            out.push(ModelLayer::from_weights(w, m, b, repr, act)?);
        }
        SparseModel::new(out)
    }

    /// Build from a manifest stack description (synthesized weights at the
    /// described shapes/sparsities — the manifest carries no weight data).
    pub fn from_stack(entry: &StackEntry) -> Result<SparseModel> {
        let mut specs = Vec::with_capacity(entry.layers.len());
        for l in &entry.layers {
            specs.push(LayerSpec {
                n: l.n,
                repr: Repr::parse(&l.repr)?,
                sparsity: l.sparsity,
                ablated_frac: l.ablated_frac,
                activation: Activation::parse(&l.activation)?,
            });
        }
        SparseModel::synth(entry.d_in, &specs, entry.seed)
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    pub fn in_width(&self) -> usize {
        self.d_in
    }

    /// Full logical output width of the last layer.
    pub fn out_width(&self) -> usize {
        self.layers.last().map(|l| l.full_width).unwrap_or(0)
    }

    pub fn layers(&self) -> &[ModelLayer] {
        &self.layers
    }

    pub fn storage_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.kernel.storage_bytes()).sum()
    }

    /// The int8 quantized twin of the whole stack (`tiled` selects the
    /// batch-tiled driver per layer) — what the engine builder's
    /// `quant=` mode and the arena's per-side spec build at startup.
    /// Every layer must carry a condensed-structured representation.
    pub fn quantized(&self, tiled: bool) -> Result<SparseModel> {
        let mut layers = Vec::with_capacity(self.layers.len());
        for (i, l) in self.layers.iter().enumerate() {
            layers.push(
                l.quantized(tiled)
                    .map_err(|e| e.context(format!("quantizing layer {i}")))?,
            );
        }
        SparseModel::new(layers)
    }

    /// The same stack re-stamped onto a different microkernel handle (the
    /// arena's per-side `kernel=` override).
    pub fn with_kernel(&self, mk: Microkernel) -> Result<SparseModel> {
        SparseModel::new(self.layers.iter().map(|l| l.with_kernel(mk)).collect())
    }

    /// Human-readable topology, e.g. `3072 -[condensed]-> 768(relu) -...`,
    /// suffixed with the process-wide microkernel selection (so serving
    /// banners and bench logs record which kernel actually ran).
    pub fn describe(&self) -> String {
        let mut s = format!("{}", self.d_in);
        for l in &self.layers {
            s.push_str(&format!(" -[{}]-> {}", l.kernel.name(), l.full_width));
            if l.activation == Activation::Relu {
                s.push_str("(relu)");
            }
        }
        s.push_str(&format!(" | {}", kernels::describe_selection()));
        s
    }

    /// Allocate a workspace sized for forwards up to `max_batch` rows.
    pub fn make_scratch(&self, max_batch: usize) -> Scratch {
        let max_batch = max_batch.max(1);
        let maxw = self.layers.iter().map(|l| l.full_width).max().unwrap_or(1).max(1);
        let maxc = self
            .layers
            .iter()
            .filter(|l| l.active.is_some())
            .map(|l| l.kernel.out_width())
            .max()
            .unwrap_or(0);
        Scratch {
            a: vec![0.0; max_batch * maxw],
            b: vec![0.0; max_batch * maxw],
            compact: vec![0.0; max_batch * maxc],
            max_batch,
        }
    }

    /// One-shot forward that allocates its own scratch and returns an owned
    /// result — for tests, examples, and cross-checking served outputs
    /// against the direct path. Hot paths should hold a [`Scratch`] and
    /// call [`SparseModel::forward`] instead.
    pub fn forward_vec(&self, x: &[f32], batch: usize, threads: usize) -> Vec<f32> {
        let mut s = self.make_scratch(batch);
        self.forward(x, batch, &mut s, threads).to_vec()
    }

    /// Run the stack on `batch` rows of `x` (row-major, width `in_width`),
    /// returning the final activations (batch x out_width) inside `s`.
    /// Allocation-free: ping-pongs between the two scratch buffers, staging
    /// compact kernel outputs in `s.compact` before scattering them back to
    /// full width (ablated neurons read 0).
    pub fn forward<'s>(
        &self,
        x: &[f32],
        batch: usize,
        s: &'s mut Scratch,
        threads: usize,
    ) -> &'s [f32] {
        assert!(batch >= 1, "batch must be >= 1");
        assert!(batch <= s.max_batch, "batch {batch} exceeds scratch capacity {}", s.max_batch);
        assert_eq!(x.len(), batch * self.d_in, "input size mismatch");
        let Scratch { a, b, compact, .. } = s;
        let mut out_is_a = true;
        for (i, layer) in self.layers.iter().enumerate() {
            let (dst, src_buf): (&mut Vec<f32>, &Vec<f32>) =
                if out_is_a { (&mut *a, &*b) } else { (&mut *b, &*a) };
            let src: &[f32] = if i == 0 { x } else { &src_buf[..batch * layer.in_width()] };
            let w = layer.full_width;
            match &layer.active {
                None => {
                    layer.kernel.forward(src, batch, &mut dst[..batch * w], threads);
                }
                Some(active) => {
                    let na = layer.kernel.out_width();
                    let c = &mut compact[..batch * na];
                    layer.kernel.forward(src, batch, c, threads);
                    let d = &mut dst[..batch * w];
                    for bi in 0..batch {
                        kernels::scatter_row(
                            &c[bi * na..(bi + 1) * na],
                            active,
                            &mut d[bi * w..(bi + 1) * w],
                        );
                    }
                }
            }
            layer.activation.apply(&mut dst[..batch * w]);
            out_is_a = !out_is_a;
        }
        let outw = batch * self.out_width();
        if out_is_a {
            &b[..outw]
        } else {
            &a[..outw]
        }
    }
}

/// Synthesize one SRigL-shaped layer: a constant-fan-in mask with
/// `k = round(d*(1-sparsity))`, `ablated_frac` of neurons fully masked,
/// He-scaled masked weights, small random bias. The single source of the
/// synthesis recipe — `LayerBundle::synth` and the test suites reuse it.
pub fn synth_layer(
    n: usize,
    d: usize,
    sparsity: f64,
    ablated_frac: f64,
    rng: &mut Rng,
) -> (Tensor, Mask, Vec<f32>) {
    let k = (((1.0 - sparsity) * d as f64).round() as usize).clamp(1, d);
    let mut mask = Mask::random_constant_fan_in(&[n, d], k, rng);
    let n_ablate = ((n as f64 * ablated_frac) as usize).min(n.saturating_sub(1));
    for &r in rng.choose_k(n, n_ablate).iter() {
        for j in 0..d {
            mask.set(r, j, false);
        }
    }
    let mut w = Tensor::normal(&[n, d], (2.0 / k as f64).sqrt(), rng);
    w.mul_assign(&mask.t);
    let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.01).collect();
    (w, mask, bias)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(n: usize, repr: Repr, act: Activation) -> LayerSpec {
        LayerSpec { n, repr, sparsity: 0.9, ablated_frac: 0.25, activation: act }
    }

    fn three_layer(repr: Repr) -> SparseModel {
        SparseModel::synth(
            64,
            &[
                spec(48, repr, Activation::Relu),
                spec(32, repr, Activation::Relu),
                spec(16, repr, Activation::Identity),
            ],
            7,
        )
        .unwrap()
    }

    fn forward_vec(model: &SparseModel, x: &[f32], batch: usize) -> Vec<f32> {
        model.forward_vec(x, batch, 1)
    }

    #[test]
    fn widths_chain_and_output_shape() {
        let m = three_layer(Repr::Condensed);
        assert_eq!(m.depth(), 3);
        assert_eq!(m.in_width(), 64);
        assert_eq!(m.out_width(), 16);
        let mut s = m.make_scratch(4);
        let x = vec![0.5f32; 4 * 64];
        let out = m.forward(&x, 4, &mut s, 1);
        assert_eq!(out.len(), 4 * 16);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn mismatched_widths_rejected() {
        let (w1, m1, b1) = synth_layer(8, 16, 0.5, 0.0, &mut Rng::new(0));
        let (w2, m2, b2) = synth_layer(4, 9, 0.5, 0.0, &mut Rng::new(1)); // expects 9, gets 8
        let l1 = ModelLayer::from_weights(&w1, &m1, &b1, Repr::Dense, Activation::Relu).unwrap();
        let l2 =
            ModelLayer::from_weights(&w2, &m2, &b2, Repr::Dense, Activation::Identity).unwrap();
        assert!(SparseModel::new(vec![l1, l2]).is_err());
    }

    #[test]
    fn bad_mask_is_a_typed_startup_error_not_a_panic() {
        // a hand-broken mask (non-constant fan-in) must fail layer
        // construction with a CondensedError routed through anyhow
        let mut rng = Rng::new(5);
        let (w, mut m, b) = synth_layer(8, 16, 0.5, 0.0, &mut rng);
        // knock one weight out of one row: fan-ins now disagree
        let j = (0..16).find(|&j| m.is_active(0, j)).unwrap();
        m.set(0, j, false);
        for repr in [Repr::Condensed, Repr::CondensedTiled] {
            let err = ModelLayer::from_weights(&w, &m, &b, repr, Activation::Relu).unwrap_err();
            assert!(format!("{err:#}").contains("fan-in"), "{repr:?}: {err:#}");
        }
        // the dense/structured forms don't require constant fan-in
        assert!(ModelLayer::from_weights(&w, &m, &b, Repr::Dense, Activation::Relu).is_ok());
    }

    #[test]
    fn zero_widths_rejected() {
        let s = spec(8, Repr::Dense, Activation::Identity);
        assert!(SparseModel::synth(0, &[s.clone()], 1).is_err(), "d_in 0");
        let z = LayerSpec { n: 0, ..s };
        assert!(SparseModel::synth(16, &[z], 1).is_err(), "layer width 0");
    }

    #[test]
    fn batch_equals_sequential_single_rows() {
        let m = three_layer(Repr::Condensed);
        let mut rng = Rng::new(3);
        let batch = 5;
        let x: Vec<f32> = (0..batch * 64).map(|_| rng.normal_f32()).collect();
        let batched = forward_vec(&m, &x, batch);
        let mut s = m.make_scratch(1);
        for b in 0..batch {
            let row = m.forward(&x[b * 64..(b + 1) * 64], 1, &mut s, 1);
            for (i, (got, want)) in row.iter().zip(&batched[b * 16..(b + 1) * 16]).enumerate() {
                assert!(
                    (got - want).abs() < 1e-6 * (1.0 + want.abs()),
                    "b={b} i={i}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        let m = three_layer(Repr::Structured);
        let mut s = m.make_scratch(2);
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..2 * 64).map(|_| rng.normal_f32()).collect();
        let first = m.forward(&x, 2, &mut s, 2).to_vec();
        let second = m.forward(&x, 2, &mut s, 2).to_vec();
        assert_eq!(first, second, "scratch reuse must not leak state");
    }

    #[test]
    fn describe_and_storage() {
        let m = three_layer(Repr::Condensed);
        let d = m.describe();
        assert!(d.starts_with("64"), "{d}");
        assert!(d.contains("condensed"), "{d}");
        assert!(
            d.contains(&crate::kernels::describe_selection()),
            "describe must report the kernel selection: {d}"
        );
        assert!(m.storage_bytes() > 0);
    }

    #[test]
    fn activation_and_repr_parse() {
        assert_eq!(Activation::parse("relu").unwrap(), Activation::Relu);
        assert_eq!(Activation::parse("none").unwrap(), Activation::Identity);
        assert!(Activation::parse("gelu").is_err());
        for r in Repr::ALL {
            assert_eq!(Repr::parse(r.name()).unwrap(), r);
        }
        assert!(Repr::parse("coo").is_err());
    }
}
