//! Scoped data-parallel helpers — in-tree replacement for `rayon`
//! (offline environment). Used by the inference engine's thread sweeps
//! (paper Figs. 18-20 run 1/4/8 CPU threads).

/// Run `f(chunk_index, range)` over `n` items split into `threads` nearly
/// equal contiguous ranges, in parallel via scoped threads. `threads == 1`
/// runs inline (no spawn overhead — matters for online batch-1 latency).
pub fn par_ranges<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        f(0, 0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(t, lo..hi));
        }
    });
}

/// Parallel map over disjoint mutable row-chunks of `out` (each of width
/// `row_width`), the shape of every kernel in the inference engine:
/// thread t computes rows `range` of the output matrix.
pub fn par_rows_mut<F>(out: &mut [f32], row_width: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync, // (row index, row slice)
{
    if row_width == 0 || out.is_empty() {
        return;
    }
    let n_rows = out.len() / row_width;
    debug_assert_eq!(out.len(), n_rows * row_width);
    let threads = threads.max(1).min(n_rows.max(1));
    if threads == 1 {
        for (r, row) in out.chunks_mut(row_width).enumerate() {
            f(r, row);
        }
        return;
    }
    let chunk_rows = n_rows.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut row0 = 0usize;
        while !rest.is_empty() {
            let take = (chunk_rows * row_width).min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let f = &f;
            let base = row0;
            s.spawn(move || {
                for (i, row) in head.chunks_mut(row_width).enumerate() {
                    f(base + i, row);
                }
            });
            row0 += take / row_width;
            rest = tail;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_ranges_covers_all() {
        for threads in [1, 2, 4, 7] {
            for n in [0usize, 1, 5, 100] {
                let hits = AtomicUsize::new(0);
                par_ranges(n, threads, |_, r| {
                    hits.fetch_add(r.len(), Ordering::Relaxed);
                });
                assert_eq!(hits.load(Ordering::Relaxed), n, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn par_rows_mut_writes_each_row_once() {
        for threads in [1, 3, 8] {
            let (rows, width) = (17, 5);
            let mut out = vec![0.0f32; rows * width];
            par_rows_mut(&mut out, width, threads, |r, row| {
                for v in row.iter_mut() {
                    *v += (r + 1) as f32;
                }
            });
            for r in 0..rows {
                for c in 0..width {
                    assert_eq!(out[r * width + c], (r + 1) as f32);
                }
            }
        }
    }

    #[test]
    fn zero_width_ok() {
        let mut out: Vec<f32> = vec![];
        par_rows_mut(&mut out, 0, 4, |_, _| panic!("no rows"));
    }
}
