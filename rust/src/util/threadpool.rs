//! Scoped data-parallel helpers — in-tree replacement for `rayon`
//! (offline environment). Used by the inference engine's thread sweeps
//! (paper Figs. 18-20 run 1/4/8 CPU threads) — plus the [`Injector`]
//! work queue feeding the worker-pool inference server.

use std::collections::VecDeque;

// Shimmed primitives: std normally, loom under `--cfg loom` so the loom
// models in rust/tests/loom_models.rs can check the Injector exhaustively.
use crate::util::sync::{Condvar, Mutex};

/// Run `f(chunk_index, range)` over `n` items split into `threads` nearly
/// equal contiguous ranges, in parallel via scoped threads. `threads == 1`
/// runs inline (no spawn overhead — matters for online batch-1 latency).
pub fn par_ranges<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        f(0, 0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(t, lo..hi));
        }
    });
}

/// Parallel map over disjoint mutable row-chunks of `out` (each of width
/// `row_width`), the shape of every kernel in the inference engine:
/// thread t computes rows `range` of the output matrix.
pub fn par_rows_mut<F>(out: &mut [f32], row_width: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync, // (row index, row slice)
{
    if row_width == 0 || out.is_empty() {
        return;
    }
    let n_rows = out.len() / row_width;
    debug_assert_eq!(out.len(), n_rows * row_width);
    let threads = threads.max(1).min(n_rows.max(1));
    if threads == 1 {
        for (r, row) in out.chunks_mut(row_width).enumerate() {
            f(r, row);
        }
        return;
    }
    let chunk_rows = n_rows.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut row0 = 0usize;
        while !rest.is_empty() {
            let take = (chunk_rows * row_width).min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let f = &f;
            let base = row0;
            s.spawn(move || {
                for (i, row) in head.chunks_mut(row_width).enumerate() {
                    f(base + i, row);
                }
            });
            row0 += take / row_width;
            rest = tail;
        }
    });
}

// ---------------------------------------------------------------------------
// Injector queue (worker-pool server)
// ---------------------------------------------------------------------------

struct InjectorInner<T> {
    q: VecDeque<T>,
    closed: bool,
}

/// Error returned by [`Injector::push_bounded`] when the queue is at
/// capacity; carries the rejected item back so the caller can answer the
/// originating client (the front-end's backpressure path).
#[derive(Debug)]
pub struct QueueFull<T>(pub T);

/// A multi-producer / multi-consumer FIFO work queue: producers `push`,
/// workers block in [`Injector::pop_batch`] until items arrive (draining up
/// to `max` at once — the server's dynamic batching) or the queue is
/// closed *and* empty. Plain Mutex + Condvar: contention is one lock per
/// batch, negligible next to a layer forward.
///
/// [`Injector::with_capacity`] bounds the queue: [`Injector::push_bounded`]
/// then rejects with [`QueueFull`] instead of growing without limit — the
/// hook the network front-end uses to shed load. `push` stays infallible
/// (and ignores the bound) for trusted in-process producers.
pub struct Injector<T> {
    inner: Mutex<InjectorInner<T>>,
    cv: Condvar,
    capacity: usize,
}

impl<T> Injector<T> {
    /// Unbounded queue (`push_bounded` never rejects).
    pub fn new() -> Injector<T> {
        Injector::with_capacity(usize::MAX)
    }

    /// Queue bounded at `capacity` items (floor 1) for `push_bounded`.
    pub fn with_capacity(capacity: usize) -> Injector<T> {
        Injector {
            inner: Mutex::new(InjectorInner { q: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Enqueue one item. Panics if the queue was closed.
    pub fn push(&self, item: T) {
        let mut g = self.inner.lock().unwrap();
        assert!(!g.closed, "push after close");
        g.q.push_back(item);
        drop(g);
        self.cv.notify_one();
    }

    /// Enqueue one item unless the queue already holds `capacity` items;
    /// on rejection the item is handed back inside [`QueueFull`]. Panics if
    /// the queue was closed (same contract as [`Injector::push`] — shut
    /// producers down before closing).
    pub fn push_bounded(&self, item: T) -> Result<(), QueueFull<T>> {
        let mut g = self.inner.lock().unwrap();
        assert!(!g.closed, "push after close");
        if g.q.len() >= self.capacity {
            return Err(QueueFull(item));
        }
        g.q.push_back(item);
        drop(g);
        self.cv.notify_one();
        Ok(())
    }

    /// No more items will arrive; wakes all blocked workers.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Pop 1..=max items into `out`, blocking while the queue is open and
    /// empty. Returns the number popped; 0 means closed-and-drained.
    pub fn pop_batch(&self, max: usize, out: &mut Vec<T>) -> usize {
        let max = max.max(1);
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.q.is_empty() {
                let take = max.min(g.q.len());
                out.extend(g.q.drain(..take));
                return take;
            }
            if g.closed {
                return 0;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Default for Injector<T> {
    fn default() -> Self {
        Injector::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_ranges_covers_all() {
        for threads in [1, 2, 4, 7] {
            for n in [0usize, 1, 5, 100] {
                let hits = AtomicUsize::new(0);
                par_ranges(n, threads, |_, r| {
                    hits.fetch_add(r.len(), Ordering::Relaxed);
                });
                assert_eq!(hits.load(Ordering::Relaxed), n, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn par_rows_mut_writes_each_row_once() {
        for threads in [1, 3, 8] {
            let (rows, width) = (17, 5);
            let mut out = vec![0.0f32; rows * width];
            par_rows_mut(&mut out, width, threads, |r, row| {
                for v in row.iter_mut() {
                    *v += (r + 1) as f32;
                }
            });
            for r in 0..rows {
                for c in 0..width {
                    assert_eq!(out[r * width + c], (r + 1) as f32);
                }
            }
        }
    }

    #[test]
    fn zero_width_ok() {
        let mut out: Vec<f32> = vec![];
        par_rows_mut(&mut out, 0, 4, |_, _| panic!("no rows"));
    }

    #[test]
    fn injector_fifo_and_batching() {
        let inj: Injector<usize> = Injector::new();
        for i in 0..10 {
            inj.push(i);
        }
        assert_eq!(inj.len(), 10);
        let mut out = Vec::new();
        assert_eq!(inj.pop_batch(4, &mut out), 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
        out.clear();
        assert_eq!(inj.pop_batch(100, &mut out), 6);
        assert_eq!(out, vec![4, 5, 6, 7, 8, 9]);
        inj.close();
        out.clear();
        assert_eq!(inj.pop_batch(4, &mut out), 0);
        assert!(out.is_empty());
    }

    #[test]
    fn injector_close_drains_remaining() {
        let inj: Injector<u32> = Injector::new();
        inj.push(1);
        inj.push(2);
        inj.close();
        let mut out = Vec::new();
        assert_eq!(inj.pop_batch(1, &mut out), 1);
        assert_eq!(inj.pop_batch(1, &mut out), 1);
        assert_eq!(inj.pop_batch(1, &mut out), 0);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn bounded_push_rejects_at_capacity_and_returns_item() {
        let inj: Injector<u32> = Injector::with_capacity(2);
        assert_eq!(inj.capacity(), 2);
        assert!(inj.push_bounded(10).is_ok());
        assert!(inj.push_bounded(20).is_ok());
        let QueueFull(rejected) = inj.push_bounded(30).unwrap_err();
        assert_eq!(rejected, 30, "QueueFull hands the item back");
        assert_eq!(inj.len(), 2, "rejected item was not enqueued");
        // draining one slot re-admits
        let mut out = Vec::new();
        assert_eq!(inj.pop_batch(1, &mut out), 1);
        assert!(inj.push_bounded(30).is_ok());
        out.clear();
        inj.close();
        assert_eq!(inj.pop_batch(10, &mut out), 2);
        assert_eq!(out, vec![20, 30], "FIFO order preserved across a rejection");
    }

    #[test]
    fn bounded_close_then_pop_drains() {
        let inj: Injector<u32> = Injector::with_capacity(4);
        for i in 0..3 {
            inj.push_bounded(i).unwrap();
        }
        inj.close();
        let mut out = Vec::new();
        assert_eq!(inj.pop_batch(2, &mut out), 2);
        assert_eq!(inj.pop_batch(2, &mut out), 1);
        assert_eq!(inj.pop_batch(2, &mut out), 0, "closed and drained");
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn capacity_floor_is_one() {
        let inj: Injector<u8> = Injector::with_capacity(0);
        assert!(inj.push_bounded(1).is_ok(), "capacity 0 is clamped to 1");
        assert!(inj.push_bounded(2).is_err());
    }

    #[test]
    fn unbounded_push_bounded_never_rejects() {
        let inj: Injector<usize> = Injector::new();
        for i in 0..10_000 {
            inj.push_bounded(i).unwrap();
        }
        assert_eq!(inj.len(), 10_000);
    }

    #[test]
    fn bounded_len_consistent_under_contention() {
        let cap = 8;
        let inj: Injector<usize> = Injector::with_capacity(cap);
        let produced = 2000usize;
        let accepted = AtomicUsize::new(0);
        let rejected = AtomicUsize::new(0);
        let consumed = AtomicUsize::new(0);
        std::thread::scope(|s| {
            // one consumer draining slowly enough that producers hit the bound
            let consumer = {
                let (inj, consumed) = (&inj, &consumed);
                s.spawn(move || {
                    let mut buf = Vec::new();
                    loop {
                        buf.clear();
                        if inj.pop_batch(3, &mut buf) == 0 {
                            break;
                        }
                        consumed.fetch_add(buf.len(), Ordering::Relaxed);
                        assert!(inj.len() <= cap, "len may never exceed capacity");
                    }
                })
            };
            std::thread::scope(|p| {
                for t in 0..4 {
                    let (inj, accepted, rejected) = (&inj, &accepted, &rejected);
                    p.spawn(move || {
                        for i in 0..produced / 4 {
                            match inj.push_bounded(t * 1000 + i) {
                                Ok(()) => accepted.fetch_add(1, Ordering::Relaxed),
                                Err(QueueFull(_)) => rejected.fetch_add(1, Ordering::Relaxed),
                            };
                            assert!(inj.len() <= cap);
                        }
                    });
                }
            });
            inj.close();
            consumer.join().unwrap();
        });
        let (a, r, c) = (
            accepted.load(Ordering::Relaxed),
            rejected.load(Ordering::Relaxed),
            consumed.load(Ordering::Relaxed),
        );
        assert_eq!(a + r, produced, "every push either accepted or rejected");
        assert_eq!(c, a, "exactly the accepted items are consumed");
    }

    #[test]
    fn injector_multi_worker_consumes_everything_once() {
        let inj: Injector<usize> = Injector::new();
        let n = 1000;
        let sum = AtomicUsize::new(0);
        let count = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let (inj, sum, count) = (&inj, &sum, &count);
                s.spawn(move || {
                    let mut buf = Vec::new();
                    loop {
                        buf.clear();
                        if inj.pop_batch(7, &mut buf) == 0 {
                            break;
                        }
                        count.fetch_add(buf.len(), Ordering::Relaxed);
                        sum.fetch_add(buf.iter().sum::<usize>(), Ordering::Relaxed);
                    }
                });
            }
            for i in 0..n {
                inj.push(i);
            }
            inj.close();
        });
        assert_eq!(count.load(Ordering::Relaxed), n);
        assert_eq!(sum.load(Ordering::Relaxed), n * (n - 1) / 2);
    }
}
