//! Tiny declarative CLI flag parser — in-tree replacement for `clap`
//! (offline environment). Supports `--flag value`, `--flag=value`, and
//! boolean `--flag`, plus positional arguments.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse raw argv (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Comma-separated list flag, e.g. `--sparsities 0.8,0.9,0.95`.
    pub fn list_or<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<T>()
                        .map_err(|e| anyhow::anyhow!("--{key} item {p:?}: {e}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["exp", "table1", "--seed", "3", "--verbose", "--lr=0.1"]);
        assert_eq!(a.positional, vec!["exp", "table1"]);
        assert_eq!(a.get("seed"), Some("3"));
        assert_eq!(a.get("lr"), Some("0.1"));
        assert!(a.has("verbose"));
        assert_eq!(a.parse_or("seed", 0u64).unwrap(), 3);
        assert_eq!(a.parse_or("missing", 7u64).unwrap(), 7);
    }

    #[test]
    fn list_flag() {
        let a = parse(&["--sparsities", "0.8,0.9, 0.95"]);
        assert_eq!(a.list_or("sparsities", &[0.5f64]).unwrap(), vec![0.8, 0.9, 0.95]);
        assert_eq!(a.list_or("other", &[1u32, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn bool_flag_before_flag() {
        let a = parse(&["--ablate", "--gamma", "0.3"]);
        assert_eq!(a.get("ablate"), Some("true"));
        assert_eq!(a.get("gamma"), Some("0.3"));
    }

    #[test]
    fn bad_parse_reports_key() {
        let a = parse(&["--seed", "abc"]);
        let err = a.parse_or("seed", 0u64).unwrap_err().to_string();
        assert!(err.contains("seed"), "{err}");
    }
}
