//! In-tree substrates replacing unavailable crates (offline environment);
//! see the note in Cargo.toml and DESIGN.md §4.

pub mod cli;
pub mod json;
pub mod log;
pub mod lru;
pub mod rng;
pub mod sync;
pub mod threadpool;
