//! Fixed-capacity LRU map — in-tree replacement for the `lru` crate
//! (offline environment). Backs the serving front-end's result cache
//! ([`crate::inference::frontend`]): O(1) get/insert via a HashMap into an
//! intrusive doubly-linked list stored as slot indices in a `Vec`.

use std::collections::HashMap;

const NIL: usize = usize::MAX;

struct Slot<K, V> {
    key: K,
    value: V,
    prev: usize,
    next: usize,
}

/// Least-recently-used map with a hard entry cap. `get` refreshes recency;
/// inserting past capacity evicts the coldest entry.
pub struct LruCache<K: std::hash::Hash + Eq + Clone, V> {
    map: HashMap<K, usize>,
    slots: Vec<Slot<K, V>>,
    head: usize, // most recent
    tail: usize, // least recent
    capacity: usize,
}

impl<K: std::hash::Hash + Eq + Clone, V> LruCache<K, V> {
    /// Cache holding at most `capacity` entries (floor 1).
    pub fn new(capacity: usize) -> LruCache<K, V> {
        let capacity = capacity.max(1);
        LruCache {
            map: HashMap::with_capacity(capacity),
            slots: Vec::with_capacity(capacity),
            head: NIL,
            tail: NIL,
            capacity,
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Unlink slot `i` from the recency list (it must be linked).
    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.slots[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.slots[next].prev = prev;
        }
    }

    /// Link slot `i` at the head (most recent).
    fn link_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Look up `key`, marking the entry most-recently-used on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let &i = self.map.get(key)?;
        if self.head != i {
            self.unlink(i);
            self.link_front(i);
        }
        Some(&self.slots[i].value)
    }

    /// Look up `key` **without** refreshing recency. For lookups that
    /// still need verification (e.g. the front-end's collision check on a
    /// hash key): peek first, then [`LruCache::touch`] only once the entry
    /// is confirmed to be the one wanted — an unverified `get` would
    /// promote a colliding entry to most-recently-used.
    pub fn peek(&self, key: &K) -> Option<&V> {
        let &i = self.map.get(key)?;
        Some(&self.slots[i].value)
    }

    /// Promote an existing entry to most-recently-used; returns whether
    /// the key was present. The recency half of [`LruCache::get`].
    pub fn touch(&mut self, key: &K) -> bool {
        let Some(&i) = self.map.get(key) else {
            return false;
        };
        if self.head != i {
            self.unlink(i);
            self.link_front(i);
        }
        true
    }

    /// Insert or overwrite `key`. Returns the evicted `(key, value)` pair
    /// when the cache was full and a cold entry had to make room.
    pub fn insert(&mut self, key: K, value: V) -> Option<(K, V)> {
        if let Some(&i) = self.map.get(&key) {
            self.slots[i].value = value;
            if self.head != i {
                self.unlink(i);
                self.link_front(i);
            }
            return None;
        }
        let mut evicted = None;
        let i = if self.slots.len() < self.capacity {
            self.slots.push(Slot { key: key.clone(), value, prev: NIL, next: NIL });
            self.slots.len() - 1
        } else {
            // reuse the coldest slot in place
            let i = self.tail;
            self.unlink(i);
            let old = std::mem::replace(
                &mut self.slots[i],
                Slot { key: key.clone(), value, prev: NIL, next: NIL },
            );
            self.map.remove(&old.key);
            evicted = Some((old.key, old.value));
            i
        };
        self.map.insert(key, i);
        self.link_front(i);
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_miss_and_overwrite() {
        let mut c: LruCache<u64, i32> = LruCache::new(4);
        assert!(c.is_empty());
        assert!(c.get(&1).is_none());
        c.insert(1, 10);
        c.insert(2, 20);
        assert_eq!(c.get(&1), Some(&10));
        assert_eq!(c.len(), 2);
        c.insert(1, 11);
        assert_eq!(c.get(&1), Some(&11), "insert overwrites");
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c: LruCache<u64, i32> = LruCache::new(3);
        c.insert(1, 1);
        c.insert(2, 2);
        c.insert(3, 3);
        assert_eq!(c.get(&1), Some(&1)); // 1 is now hot; 2 is coldest
        let ev = c.insert(4, 4);
        assert_eq!(ev, Some((2, 2)), "coldest entry evicted");
        assert!(c.get(&2).is_none());
        assert_eq!(c.get(&1), Some(&1));
        assert_eq!(c.get(&3), Some(&3));
        assert_eq!(c.get(&4), Some(&4));
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn capacity_one() {
        let mut c: LruCache<u64, &str> = LruCache::new(0); // clamped to 1
        assert_eq!(c.capacity(), 1);
        assert_eq!(c.insert(1, "a"), None);
        assert_eq!(c.insert(2, "b"), Some((1, "a")));
        assert_eq!(c.get(&2), Some(&"b"));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn overwrite_refreshes_recency() {
        let mut c: LruCache<u64, i32> = LruCache::new(2);
        c.insert(1, 1);
        c.insert(2, 2);
        c.insert(1, 100); // 2 becomes coldest
        assert_eq!(c.insert(3, 3), Some((2, 2)));
        assert_eq!(c.get(&1), Some(&100));
    }

    #[test]
    fn peek_does_not_promote() {
        let mut c: LruCache<u64, i32> = LruCache::new(3);
        c.insert(1, 1);
        c.insert(2, 2);
        c.insert(3, 3);
        assert_eq!(c.peek(&1), Some(&1), "peek sees the value");
        assert_eq!(c.peek(&9), None);
        // 1 was peeked, not promoted: it is still the coldest and evicts
        let ev = c.insert(4, 4);
        assert_eq!(ev, Some((1, 1)), "peek must not refresh recency");
        assert!(c.peek(&1).is_none());
    }

    #[test]
    fn touch_promotes_like_get() {
        let mut c: LruCache<u64, i32> = LruCache::new(3);
        c.insert(1, 1);
        c.insert(2, 2);
        c.insert(3, 3);
        assert!(c.touch(&1), "present key");
        assert!(!c.touch(&9), "absent key");
        let ev = c.insert(4, 4);
        assert_eq!(ev, Some((2, 2)), "touched entry survived; 2 was coldest");
        assert_eq!(c.get(&1), Some(&1));
    }

    #[test]
    fn churn_stays_bounded_and_consistent() {
        let cap = 16;
        let mut c: LruCache<u64, u64> = LruCache::new(cap);
        for i in 0..1000u64 {
            c.insert(i % 37, i);
            assert!(c.len() <= cap);
            // recent insert is always retrievable with its latest value
            assert_eq!(c.get(&(i % 37)), Some(&i));
        }
        // the cap hottest keys of the final window are present
        let mut present = 0;
        for k in 0..37u64 {
            if c.get(&k).is_some() {
                present += 1;
            }
        }
        assert_eq!(present, cap);
    }
}
