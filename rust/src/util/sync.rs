//! Sync-primitive shim: `std::sync` in normal builds, `loom::sync`
//! under `--cfg loom`.
//!
//! The concurrency core (the [`crate::util::threadpool`] injector, the
//! frontend's `Egress` bounded queue, the engine's `EpochCell`
//! publish/shadow-read pair, and the persistent shard team's
//! mailbox + completion latch) imports its primitives from here instead
//! of from `std::sync` directly. A normal build re-exports the std
//! types unchanged — zero cost, identical semantics. A build with
//! `RUSTFLAGS="--cfg loom"` swaps in the vendored loom model checker's
//! types, whose every operation is a scheduler decision point, so
//! `rust/tests/loom_models.rs` can exhaustively explore interleavings
//! (see docs/ANALYSIS.md for the models and the checker's bounds).
//!
//! Rules for ported code:
//! * import `Mutex`/`Condvar`/`RwLock`/`Arc`/`atomic::*` from this
//!   module, never from `std::sync`;
//! * do not use timed waits (`wait_timeout`) or spurious-wakeup
//!   assumptions in the modeled fast paths — loom's condvar wakeups are
//!   exact, and a lost notify surfaces as a model deadlock;
//! * `UnsafeCell` uses the closure API (`with`/`with_mut`) under both
//!   cfgs so each access is a decision point under loom.

#[cfg(not(loom))]
pub use std::sync::{
    Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

#[cfg(not(loom))]
pub mod atomic {
    pub use std::sync::atomic::{
        fence, AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
    };
}

/// Closure-API `UnsafeCell` matching `loom::cell::UnsafeCell`, so code
/// is source-identical under both cfgs.
#[cfg(not(loom))]
#[derive(Debug)]
pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

#[cfg(not(loom))]
impl<T> UnsafeCell<T> {
    pub fn new(t: T) -> UnsafeCell<T> {
        UnsafeCell(std::cell::UnsafeCell::new(t))
    }

    pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        f(self.0.get())
    }

    pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        f(self.0.get())
    }

    pub fn into_inner(self) -> T {
        self.0.into_inner()
    }
}

#[cfg(loom)]
pub use loom::sync::{
    Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

#[cfg(loom)]
pub mod atomic {
    pub use loom::sync::atomic::{
        fence, AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
    };
}

#[cfg(loom)]
pub use loom::cell::UnsafeCell;
