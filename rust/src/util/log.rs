//! Minimal leveled stderr logger (zero dependencies).
//!
//! `SRIGL_LOG=warn|info|debug` selects the level once per process
//! (default `info`); messages print as
//! `[<unix-seconds>.<millis> LEVEL target] message`. Serving paths use
//! this instead of bare `eprintln!` so operators can silence startup
//! chatter (`SRIGL_LOG=warn`) without losing fault reports.

use std::sync::OnceLock;
use std::time::{SystemTime, UNIX_EPOCH};

/// Severity, ordered so `Warn < Info < Debug` filters naturally.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Warn,
    Info,
    Debug,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }

    /// Parse an `SRIGL_LOG` value; `None` for anything unrecognized (the
    /// caller falls back to the default rather than erroring at runtime).
    pub fn parse(v: &str) -> Option<Level> {
        match v.trim().to_ascii_lowercase().as_str() {
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// The process log level: `SRIGL_LOG`, read once; default [`Level::Info`].
pub fn level() -> Level {
    static LEVEL: OnceLock<Level> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        std::env::var("SRIGL_LOG").ok().and_then(|v| Level::parse(&v)).unwrap_or(Level::Info)
    })
}

/// Whether a message at `l` would print — guard expensive formatting.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Emit one timestamped line to stderr if `l` passes the filter.
pub fn log(l: Level, target: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    eprintln!("[{}.{:03} {:<5} {target}] {msg}", now.as_secs(), now.subsec_millis(), l.label());
}

/// Faults and degradations (always on).
pub fn warn(target: &str, msg: &str) {
    log(Level::Warn, target, msg);
}

/// Lifecycle events worth seeing by default.
pub fn info(target: &str, msg: &str) {
    log(Level::Info, target, msg);
}

/// Diagnostics, off by default.
pub fn debug(target: &str, msg: &str) {
    log(Level::Debug, target, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_known_levels_case_insensitively() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("WARNING"), Some(Level::Warn));
        assert_eq!(Level::parse(" Info "), Some(Level::Info));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("trace"), None);
        assert_eq!(Level::parse(""), None);
    }

    #[test]
    fn ordering_matches_filter_semantics() {
        // enabled(l) means l <= level(): warn passes every filter, debug
        // only the debug filter
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }
}
