//! Minimal JSON parser + writer — in-tree replacement for `serde_json`
//! (offline environment). Parses artifacts/manifest.json and writes
//! experiment result records.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use BTreeMap for deterministic iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    // -- writer ------------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building result records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr<I: IntoIterator<Item = Json>>(it: I) -> Json {
    Json::Arr(it.into_iter().collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected EOF"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected {:?} got {:?} at byte {}", b as char, got as char, self.pos - 1);
        }
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        for &b in word.as_bytes() {
            self.expect(b)?;
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected EOF"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + (h as char).to_digit(16).ok_or_else(|| anyhow!("bad \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    e => bail!("bad escape \\{}", e as char),
                },
                _ => {
                    // continue multi-byte UTF-8 sequences verbatim
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    if self.pos > self.src.len() {
                        bail!("truncated UTF-8");
                    }
                    out.push_str(std::str::from_utf8(&self.src[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.src[start..self.pos])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| anyhow!("bad number {txt:?}: {e}"))?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(out)),
                c => bail!("expected ',' or ']' got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(out)),
                c => bail!("expected ',' or '}}' got {:?}", c as char),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": 1e3}"#).unwrap();
        assert_eq!(v.get("d").unwrap().as_f64().unwrap(), 1000.0);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_manifest_like() {
        let src = r#"{"models":{"mlp":{"batch":32,"params":[{"name":"l0.w","shape":[64,32],"sparse":true}]}}}"#;
        let v = Json::parse(src).unwrap();
        let p = &v.get("models").unwrap().get("mlp").unwrap().get("params").unwrap().as_arr().unwrap()[0];
        assert!(p.get("sparse").unwrap().as_bool().unwrap());
        assert_eq!(p.get("shape").unwrap().as_arr().unwrap()[0].as_usize().unwrap(), 64);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }
}
