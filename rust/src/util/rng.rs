//! Deterministic PRNG — xoshiro256** seeded via SplitMix64.
//!
//! In-tree replacement for the `rand` crate (offline environment; see
//! Cargo.toml). Every experiment takes an explicit `u64` seed so paper
//! tables with "5 random seeds" are exactly reproducible.

/// xoshiro256** generator (Blackman & Vigna). Passes BigCrush; more than
/// adequate for mask sampling / synthetic data.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby integer seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates when
    /// k is a large fraction of n; Floyd's algorithm otherwise).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        if k * 3 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            // Floyd's: O(k) expected with a small set.
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below(j + 1);
                let v = if chosen.contains(&t) { j } else { t };
                chosen.insert(v);
                out.push(v);
            }
            out
        }
    }

    /// Fork a decorrelated child generator (for per-layer / per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn choose_k_distinct_and_in_range() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(10, 10), (100, 3), (50, 25), (1, 1), (7, 0)] {
            let v = r.choose_k(n, k);
            assert_eq!(v.len(), k);
            let set: std::collections::HashSet<_> = v.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(v.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
