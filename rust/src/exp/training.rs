//! Training-based harnesses: every paper table/figure that requires
//! actually training models with the DST methods. All runs go through the
//! AOT train_step/dense_grad programs via one shared [`Session`].
//!
//! Scale: models and step counts are the DESIGN.md §4 proxies (synthetic
//! data, hundreds of steps). The claims under test are *relative* —
//! SRigL ≈ RigL, SRigL-no-ablation < RigL at extreme sparsity, ablation
//! restores parity — so each harness prints our deltas next to the
//! paper's.

use anyhow::Result;

use super::{record, Table};
use crate::dst::struct_prune::structured_prune_mask;
use crate::flops::cnn_proxy_flops;
use crate::sparsity::distribution::{layer_densities, Distribution, LayerShape};
use crate::sparsity::Mask;
use crate::stats::ablation::LayerTopology;
use crate::stats::mean_ci95;
use crate::train::{LrSchedule, Method, Session, TrainConfig, TrainReport, Trainer};
use crate::util::cli::Args;
use crate::util::json::{arr, num, obj, s as js, Json};

/// Default step counts per model family (tuned so a full harness run
/// stays in the minutes range on 1 CPU core; scale with --steps).
fn default_steps(model: &str) -> usize {
    match model {
        "mlp_tiny" | "mlp_proxy" => 300,
        "cnn_proxy" | "cnn_wide" => 240,
        "vit_proxy" => 200,
        _ => 200,
    }
}

pub fn base_config(model: &str, method: Method, sparsity: f64, steps: usize, seed: u64) -> TrainConfig {
    let dist = if model == "vit_proxy" { Distribution::Uniform } else { Distribution::Erk };
    TrainConfig {
        model: model.into(),
        method,
        sparsity,
        distribution: dist,
        total_steps: steps,
        delta_t: (steps / 15).max(5),
        alpha: 0.3,
        lr: if model == "vit_proxy" {
            LrSchedule::WarmupCosine { max: 0.05, warmup: steps / 10 }
        } else if method == Method::Dense {
            // the dense baseline needs a gentler lr at this scale
            LrSchedule::step_decay(0.02, &[steps / 2, steps * 3 / 4], 0.2)
        } else if model == "cnn_wide" {
            // the wide net diverges on some seeds at 0.05 (low sparsity)
            LrSchedule::step_decay(0.03, &[steps / 2, steps * 3 / 4], 0.2)
        } else {
            LrSchedule::step_decay(0.05, &[steps / 2, steps * 3 / 4], 0.2)
        },
        grad_accum: 1,
        seed,
        eval_batches: 8,
        dense_first_layer: false,
    }
}

fn run_one(sess: &Session, cfg: TrainConfig) -> Result<TrainReport> {
    let label = format!("{}/{}/{:.0}%/seed{}", cfg.model, cfg.method.label(), cfg.sparsity * 100.0, cfg.seed);
    eprint!("  [{label}] ...");
    let mut t = sess.trainer(cfg)?;
    let rep = t.run()?;
    eprintln!(
        " {}={:.3} ({:.1}s, {:.1} steps/s)",
        rep.eval_kind, rep.eval_metric, rep.wall_s, rep.throughput
    );
    Ok(rep)
}

fn srigl(gamma: f64) -> Method {
    Method::SRigL { ablation: true, gamma_sal: gamma }
}

fn srigl_noabl() -> Method {
    Method::SRigL { ablation: false, gamma_sal: 0.0 }
}

// ---------------------------------------------------------------------------
// Table 1 / Fig. 3a — accuracy vs sparsity, RigL vs SRigL, 1x/2x training
// ---------------------------------------------------------------------------

pub fn table1(args: &Args) -> Result<()> {
    let model = args.get_or("model", "cnn_proxy");
    let steps: usize = args.parse_or("steps", default_steps(&model))?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let sparsities: Vec<f64> = args.list_or("sparsities", &[0.8, 0.9, 0.95, 0.99])?;
    let gamma: f64 = args.parse_or("gamma", 0.3)?;
    let sess = Session::open()?;

    println!("Table 1 / Fig. 3a — {model} ({steps} steps; 2x column = {} steps)", 2 * steps);
    let dense = run_one(&sess, base_config(&model, Method::Dense, 0.0, steps, seed))?;
    let mut t = Table::new(&[
        "sparsity", "RigL 1x", "SRigL w/o 1x", "SRigL 1x", "SRigL 2x",
        "paper RigL1x", "paper SRigL1x",
    ]);
    // paper Table 1 (ResNet-50/ImageNet top-1)
    let paper: &[(f64, f64, f64)] =
        &[(0.8, 74.9, 75.0), (0.9, 72.8, 72.7), (0.95, 69.6, 69.1), (0.99, 51.4, 51.5)];
    let mut recs = Vec::new();
    for &sp in &sparsities {
        let rigl = run_one(&sess, base_config(&model, Method::RigL, sp, steps, seed))?;
        let noabl = run_one(&sess, base_config(&model, srigl_noabl(), sp, steps, seed))?;
        let sr = run_one(&sess, base_config(&model, srigl(gamma), sp, steps, seed))?;
        let sr2 = run_one(&sess, base_config(&model, srigl(gamma), sp, 2 * steps, seed))?;
        let p = paper.iter().find(|(s, _, _)| (*s - sp).abs() < 1e-9);
        t.row(vec![
            format!("{:.0}%", sp * 100.0),
            format!("{:.3}", rigl.eval_metric),
            format!("{:.3}", noabl.eval_metric),
            format!("{:.3}", sr.eval_metric),
            format!("{:.3}", sr2.eval_metric),
            p.map(|p| format!("{:.1}", p.1)).unwrap_or_else(|| "-".into()),
            p.map(|p| format!("{:.1}", p.2)).unwrap_or_else(|| "-".into()),
        ]);
        recs.push(obj(vec![
            ("sparsity", num(sp)),
            ("rigl", num(rigl.eval_metric)),
            ("srigl_noabl", num(noabl.eval_metric)),
            ("srigl", num(sr.eval_metric)),
            ("srigl_2x", num(sr2.eval_metric)),
        ]));
    }
    t.print();
    println!("dense {} = {:.3}", dense.eval_kind, dense.eval_metric);
    record(
        "table1",
        obj(vec![("model", js(&model)), ("steps", num(steps as f64)),
                 ("dense", num(dense.eval_metric)), ("rows", arr(recs))]),
    )
}

// ---------------------------------------------------------------------------
// Fig. 3b — % active neurons after training
// ---------------------------------------------------------------------------

pub fn fig3b(args: &Args) -> Result<()> {
    let model = args.get_or("model", "cnn_proxy");
    let steps: usize = args.parse_or("steps", default_steps(&model))?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let sparsities: Vec<f64> = args.list_or("sparsities", &[0.8, 0.9, 0.95, 0.99])?;
    let gamma: f64 = args.parse_or("gamma", 0.3)?;
    let sess = Session::open()?;

    println!("Fig. 3b — % active neurons after training ({model})");
    let mut t = Table::new(&["sparsity", "RigL active%", "SRigL active%", "paper RigL@95: 89.1%"]);
    let mut recs = Vec::new();
    for &sp in &sparsities {
        let mut fractions = Vec::new();
        for method in [Method::RigL, srigl(gamma)] {
            let mut tr = sess.trainer(base_config(&model, method, sp, steps, seed))?;
            tr.run()?;
            let tops: Vec<LayerTopology> = tr
                .mask_stats()
                .iter()
                .map(|(name, counts)| LayerTopology::from_counts(name, counts))
                .collect();
            fractions.push(crate::stats::active_neuron_fraction(&tops));
        }
        t.row(vec![
            format!("{:.0}%", sp * 100.0),
            format!("{:.1}%", fractions[0] * 100.0),
            format!("{:.1}%", fractions[1] * 100.0),
            String::new(),
        ]);
        recs.push(obj(vec![
            ("sparsity", num(sp)),
            ("rigl_active", num(fractions[0])),
            ("srigl_active", num(fractions[1])),
        ]));
    }
    t.print();
    println!("\nPaper: RigL implicitly ablates neurons as sparsity grows (10.9% of neurons\ngone at 95%); SRigL ablates explicitly via gamma_sal.");
    record("fig3b", obj(vec![("model", js(&model)), ("rows", arr(recs))]))
}

// ---------------------------------------------------------------------------
// Table 2 — 5-seed mean ± 95% CI (ResNet-18/CIFAR-10 proxy)
// ---------------------------------------------------------------------------

pub fn table2(args: &Args) -> Result<()> {
    let model = args.get_or("model", "cnn_proxy");
    let steps: usize = args.parse_or("steps", default_steps(&model))?;
    let seeds: usize = args.parse_or("seeds", 5)?;
    let sparsities: Vec<f64> = args.list_or("sparsities", &[0.8, 0.9, 0.95, 0.99])?;
    let gamma: f64 = args.parse_or("gamma", 0.3)?;
    let sess = Session::open()?;

    println!("Table 2 — {model}, {seeds} seeds, mean ± 95% CI ({steps} steps)");
    let mut dense_accs = Vec::new();
    for s in 0..seeds {
        dense_accs.push(run_one(&sess, base_config(&model, Method::Dense, 0.0, steps, s as u64))?.eval_metric);
    }
    let (dm, dci) = mean_ci95(&dense_accs);

    let mut t = Table::new(&["sparsity", "RigL", "SRigL w/o", "SRigL w/ ablation"]);
    let mut recs = Vec::new();
    for &sp in &sparsities {
        let mut cells = vec![format!("{:.0}%", sp * 100.0)];
        let mut rec = vec![("sparsity", num(sp))];
        for (key, method) in
            [("rigl", Method::RigL), ("srigl_noabl", srigl_noabl()), ("srigl", srigl(gamma))]
        {
            let accs: Vec<f64> = (0..seeds)
                .map(|s| run_one(&sess, base_config(&model, method, sp, steps, s as u64)).map(|r| r.eval_metric))
                .collect::<Result<_>>()?;
            let (m, ci) = mean_ci95(&accs);
            cells.push(format!("{:.3} ± {:.3}", m, ci));
            rec.push((key, num(m)));
        }
        t.row(cells);
        recs.push(obj(rec));
    }
    t.print();
    println!("dense: {:.3} ± {:.3}", dm, dci);
    println!("\nPaper shape: all three within ~CI of each other except SRigL-w/o at 99%\n(91.5 vs RigL 92.9); ablation restores parity (92.8).");
    record("table2", obj(vec![("model", js(&model)), ("dense", num(dm)), ("rows", arr(recs))]))
}

// ---------------------------------------------------------------------------
// Table 3 — DST method comparison
// ---------------------------------------------------------------------------

pub fn table3(args: &Args) -> Result<()> {
    let model = args.get_or("model", "cnn_proxy");
    let steps: usize = args.parse_or("steps", default_steps(&model))?;
    let seed: u64 = args.parse_or("seed", 0)?;
    // default to the band where methods discriminate at this scale
    // (80/90% saturate on the proxy task; paper's is 80/90 on ImageNet)
    let sparsities: Vec<f64> = args.list_or("sparsities", &[0.95, 0.99])?;
    let gamma: f64 = args.parse_or("gamma", 0.3)?;
    let sess = Session::open()?;

    println!("Table 3 — DST methods on {model} ({steps} steps)");
    let methods: Vec<(&str, Method, &str)> = vec![
        ("Static", Method::Static { structured: false }, "no"),
        ("SET", Method::Set, "no"),
        ("RigL", Method::RigL, "no"),
        ("Static-CFI", Method::Static { structured: true }, "yes"),
        ("SRigL", srigl(gamma), "yes"),
    ];
    // paper Table 3 @80/90 (ResNet-50): Static 70.6/65.8, SET 72.9/69.6,
    // RigL 74.98/72.81, SRigL 75.01/72.71.
    let mut t = {
        let mut h: Vec<String> = vec!["method".into(), "structured".into()];
        for sp in &sparsities {
            h.push(format!("{:.0}%", sp * 100.0));
        }
        Table::new(&h.iter().map(|s| s.as_str()).collect::<Vec<_>>())
    };
    let mut recs = Vec::new();
    for (name, method, structured) in &methods {
        let mut cells = vec![name.to_string(), structured.to_string()];
        let mut rec = vec![("method", js(name))];
        for &sp in &sparsities {
            let rep = run_one(&sess, base_config(&model, *method, sp, steps, seed))?;
            cells.push(format!("{:.3}", rep.eval_metric));
            rec.push(("acc", num(rep.eval_metric)));
        }
        t.row(cells);
        recs.push(obj(rec));
    }
    // SR-STE dense-to-sparse baseline: N:M patterns approximating the
    // sparsity column (1:4 ≈ 75-80%, 1:8 impossible on our fan-ins that
    // aren't 8-divisible, so 1:4 only where it applies). Its throughput
    // column shows the dense-training cost the paper criticizes.
    {
        let mut cells = vec!["SR-STE 1:4 (dense)".to_string(), "yes".to_string()];
        let mut rec = vec![("method", js("sr_ste_1_4"))];
        for _ in &sparsities {
            let rep = crate::train::train_srste(
                &sess,
                &crate::train::SrSteConfig {
                    model: model.clone(),
                    n: 1,
                    m: 4,
                    steps,
                    lr: 0.05,
                    lambda_w: 2e-4,
                    momentum: 0.9,
                    seed,
                    eval_batches: 8,
                },
            )?;
            eprintln!(
                "  [{}/sr-ste 1:4] accuracy={:.3} ({:.1} steps/s — dense-cost training)",
                model, rep.eval_metric, rep.throughput
            );
            cells.push(format!("{:.3}", rep.eval_metric));
            rec.push(("acc", num(rep.eval_metric)));
        }
        t.row(cells);
        recs.push(obj(rec));
    }
    t.print();
    println!("\nPaper ordering @90%: Static 65.8 < SET 69.6 < RigL 72.8 ≈ SRigL 72.7 —\ncheck the same ordering holds above (Static worst, RigL≈SRigL best).");
    record("table3", obj(vec![("model", js(&model)), ("rows", arr(recs))]))
}

// ---------------------------------------------------------------------------
// Table 4 / Fig. 9 — ViT proxy
// ---------------------------------------------------------------------------

pub fn table4(args: &Args) -> Result<()> {
    let steps: usize = args.parse_or("steps", default_steps("vit_proxy"))?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let gamma: f64 = args.parse_or("gamma", 0.5)?; // paper uses 0.95 at ViT-B/16 scale (k~100s); our k~6 over-ablates there (see fig9)
    let sparsities: Vec<f64> = args.list_or("sparsities", &[0.8, 0.9])?;
    let sess = Session::open()?;

    println!("Table 4 — vit_proxy, gamma_sal={gamma} ({steps} steps)");
    let dense = run_one(&sess, base_config("vit_proxy", Method::Dense, 0.0, steps, seed))?;
    let mut t = Table::new(&["sparsity", "RigL", "SRigL w/o", "SRigL w/", "paper(RigL/noabl/abl)"]);
    let paper = [(0.8, "77.9/73.5/77.5"), (0.9, "76.4/71.3/76.0")];
    let mut recs = Vec::new();
    for &sp in &sparsities {
        let rigl = run_one(&sess, base_config("vit_proxy", Method::RigL, sp, steps, seed))?;
        let noabl = run_one(&sess, base_config("vit_proxy", srigl_noabl(), sp, steps, seed))?;
        let sr = run_one(&sess, base_config("vit_proxy", srigl(gamma), sp, steps, seed))?;
        t.row(vec![
            format!("{:.0}%", sp * 100.0),
            format!("{:.3}", rigl.eval_metric),
            format!("{:.3}", noabl.eval_metric),
            format!("{:.3}", sr.eval_metric),
            paper
                .iter()
                .find(|(s, _)| (*s - sp).abs() < 1e-9)
                .map(|(_, v)| v.to_string())
                .unwrap_or_default(),
        ]);
        recs.push(obj(vec![
            ("sparsity", num(sp)),
            ("rigl", num(rigl.eval_metric)),
            ("srigl_noabl", num(noabl.eval_metric)),
            ("srigl", num(sr.eval_metric)),
        ]));
    }
    t.print();
    println!("dense = {:.3}", dense.eval_metric);
    println!("\nPaper shape: SRigL w/o ablation clearly below RigL; high-gamma ablation\nrecovers to within ~0.4 points.");
    record("table4", obj(vec![("gamma", num(gamma)), ("dense", num(dense.eval_metric)), ("rows", arr(recs))]))
}

// ---------------------------------------------------------------------------
// Table 9 / Fig. 5 — wide model across sparsities
// ---------------------------------------------------------------------------

pub fn table9(args: &Args) -> Result<()> {
    let model = args.get_or("model", "cnn_wide");
    let steps: usize = args.parse_or("steps", default_steps(&model))?;
    let seeds: usize = args.parse_or("seeds", 3)?;
    let sparsities: Vec<f64> = args.list_or("sparsities", &[0.5, 0.7, 0.9, 0.95, 0.99])?;
    let gamma: f64 = args.parse_or("gamma", 0.3)?;
    let sess = Session::open()?;

    println!("Table 9 / Fig. 5 — {model}, {seeds} seeds ({steps} steps)");
    let mut t = Table::new(&["sparsity", "RigL", "SRigL w/o", "SRigL w/"]);
    let mut recs = Vec::new();
    for &sp in &sparsities {
        let mut cells = vec![format!("{:.0}%", sp * 100.0)];
        let mut rec = vec![("sparsity", num(sp))];
        for (key, method) in
            [("rigl", Method::RigL), ("srigl_noabl", srigl_noabl()), ("srigl", srigl(gamma))]
        {
            let accs: Vec<f64> = (0..seeds)
                .map(|s| run_one(&sess, base_config(&model, method, sp, steps, s as u64)).map(|r| r.eval_metric))
                .collect::<Result<_>>()?;
            let (m, ci) = mean_ci95(&accs);
            cells.push(format!("{m:.3} ± {ci:.3}"));
            rec.push((key, num(m)));
        }
        t.row(cells);
        recs.push(obj(rec));
    }
    t.print();
    println!("\nPaper shape (WRN-22): parity until ~95%; at 99% w/o ablation drops hard\n(76.9 vs RigL 84.9) and ablation recovers most of it (82.7).");
    record("table9", obj(vec![("model", js(&model)), ("rows", arr(recs))]))
}

// ---------------------------------------------------------------------------
// Fig. 8 / Fig. 9a — gamma_sal sweeps
// ---------------------------------------------------------------------------

fn gamma_sweep(model: &str, sparsities: &[f64], gammas: &[f64], steps: usize, seed: u64) -> Result<Vec<Json>> {
    let sess = Session::open()?;
    let mut recs = Vec::new();
    let mut t = {
        let mut h = vec!["gamma".to_string()];
        for sp in sparsities {
            h.push(format!("{:.0}% w/abl", sp * 100.0));
        }
        h.push("no-ablation ref".into());
        Table::new(&h.iter().map(|s| s.as_str()).collect::<Vec<_>>())
    };
    for &g in gammas {
        let mut cells = vec![format!("{g:.2}")];
        let mut rec = vec![("gamma", num(g))];
        for &sp in sparsities {
            let rep = run_one(&sess, base_config(model, srigl(g), sp, steps, seed))?;
            cells.push(format!("{:.3}", rep.eval_metric));
            rec.push(("acc", num(rep.eval_metric)));
        }
        let noabl = run_one(&sess, base_config(model, srigl_noabl(), sparsities[0], steps, seed))?;
        cells.push(format!("{:.3}", noabl.eval_metric));
        t.row(cells);
        recs.push(obj(rec));
    }
    t.print();
    Ok(recs)
}

pub fn fig8(args: &Args) -> Result<()> {
    let model = args.get_or("model", "cnn_proxy");
    let steps: usize = args.parse_or("steps", default_steps(&model))?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let gammas: Vec<f64> = args.list_or("gammas", &[0.0, 0.1, 0.3, 0.5, 0.9])?;
    let sparsities: Vec<f64> = args.list_or("sparsities", &[0.95, 0.99])?;
    println!("Fig. 8 — gamma_sal sweep on {model} ({steps} steps)");
    let recs = gamma_sweep(&model, &sparsities, &gammas, steps, seed)?;
    println!("\nPaper finding: CNNs are largely insensitive to gamma_sal (the min-salient\nclamp of 1 dominates; see `srigl exp fig10`).");
    record("fig8", obj(vec![("model", js(&model)), ("rows", arr(recs))]))
}

pub fn fig9(args: &Args) -> Result<()> {
    let steps: usize = args.parse_or("steps", default_steps("vit_proxy"))?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let gammas: Vec<f64> = args.list_or("gammas", &[0.3, 0.5, 0.9, 0.95])?;
    let sparsities: Vec<f64> = args.list_or("sparsities", &[0.9])?;
    println!("Fig. 9a — gamma_sal sweep on vit_proxy ({steps} steps)");
    let recs = gamma_sweep("vit_proxy", &sparsities, &gammas, steps, seed)?;
    println!("\nPaper finding: ViT is sensitive to gamma_sal; high thresholds (0.9-0.99) win.");
    record("fig9", obj(vec![("rows", arr(recs))]))
}

// ---------------------------------------------------------------------------
// Fig. 11 — layer widths at 99% sparsity
// ---------------------------------------------------------------------------

pub fn fig11(args: &Args) -> Result<()> {
    let model = args.get_or("model", "cnn_proxy");
    let steps: usize = args.parse_or("steps", default_steps(&model))?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let sparsity: f64 = args.parse_or("sparsity", 0.99)?;
    let gammas: Vec<f64> = args.list_or("gammas", &[0.0, 0.3, 0.5])?;
    let sess = Session::open()?;

    println!("Fig. 11 — {model} layer widths after training @ {:.0}%", sparsity * 100.0);
    let mut t = Table::new(&["layer", "orig width", "gamma=0", "gamma=0.3", "gamma=0.5"]);
    let mut per_gamma: Vec<Vec<(String, usize, usize)>> = Vec::new();
    for &g in &gammas {
        let method = if g == 0.0 { srigl_noabl() } else { srigl(g) };
        let mut tr = sess.trainer(base_config(&model, method, sparsity, steps, seed))?;
        tr.run()?;
        per_gamma.push(
            tr.mask_stats()
                .iter()
                .map(|(name, counts)| {
                    let top = LayerTopology::from_counts(name, counts);
                    (name.clone(), top.neurons, top.active_neurons)
                })
                .collect(),
        );
    }
    let mut recs = Vec::new();
    for li in 0..per_gamma[0].len() {
        let (name, width, _) = per_gamma[0][li].clone();
        let mut cells = vec![name.clone(), width.to_string()];
        for gi in 0..gammas.len() {
            cells.push(per_gamma[gi][li].2.to_string());
        }
        t.row(cells);
        recs.push(obj(vec![
            ("layer", js(&name)),
            ("width", num(width as f64)),
            ("active_g0", num(per_gamma[0][li].2 as f64)),
        ]));
    }
    t.print();
    println!("\nPaper: without ablation all widths stay full; gamma_sal controls final width.");
    record("fig11", obj(vec![("sparsity", num(sparsity)), ("rows", arr(recs))]))
}

// ---------------------------------------------------------------------------
// Fig. 12 — RigL fan-in variance on the transformer
// ---------------------------------------------------------------------------

pub fn fig12(args: &Args) -> Result<()> {
    let steps: usize = args.parse_or("steps", default_steps("vit_proxy"))?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let sparsity: f64 = args.parse_or("sparsity", 0.9)?;
    let sess = Session::open()?;

    println!("Fig. 12 — RigL sparse fan-in spread, vit_proxy @ {:.0}%", sparsity * 100.0);
    let mut tr = sess.trainer(base_config("vit_proxy", Method::RigL, sparsity, steps, seed))?;
    tr.run()?;
    let mut t = Table::new(&["layer", "mean fan-in", "max fan-in", "max/mean", "stddev"]);
    let mut recs = Vec::new();
    for (name, counts) in tr.mask_stats() {
        let top = LayerTopology::from_counts(&name, &counts);
        let ratio = if top.fan_in_mean > 0.0 { top.fan_in_max as f64 / top.fan_in_mean } else { 0.0 };
        t.row(vec![
            name.clone(),
            format!("{:.2}", top.fan_in_mean),
            top.fan_in_max.to_string(),
            format!("{ratio:.2}x"),
            format!("{:.2}", top.fan_in_var.sqrt()),
        ]);
        recs.push(obj(vec![
            ("layer", js(&name)),
            ("mean", num(top.fan_in_mean)),
            ("max", num(top.fan_in_max as f64)),
            ("ratio", num(ratio)),
        ]));
    }
    t.print();
    println!("\nPaper: RigL learns highly unbalanced fan-in on ViT (up to 10x the mean) —\nthe 'max/mean' column is the statistic under test. SRigL forces ratio = 1.");
    record("fig12", obj(vec![("rows", arr(recs))]))
}

// ---------------------------------------------------------------------------
// Figs. 14-17 — ITOP rates
// ---------------------------------------------------------------------------

pub fn itop(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mlp_proxy");
    let steps: usize = args.parse_or("steps", default_steps(&model))?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let sparsities: Vec<f64> = args.list_or("sparsities", &[0.8, 0.9])?;
    let sess = Session::open()?;

    println!("Figs. 14-17 — ITOP rate (explored-parameter fraction) on {model}");
    let mut t = Table::new(&["sparsity", "method", "density", "final ITOP", "explored/density"]);
    let mut recs = Vec::new();
    for &sp in &sparsities {
        for method in [Method::RigL, srigl(0.3), Method::Set, Method::Static { structured: true }] {
            let mut tr = sess.trainer(base_config(&model, method, sp, steps, seed))?;
            tr.run()?;
            let rate = tr.itop_rate();
            let density = 1.0 - sp;
            t.row(vec![
                format!("{:.0}%", sp * 100.0),
                method.label(),
                format!("{density:.2}"),
                format!("{rate:.3}"),
                format!("{:.2}x", rate / density),
            ]);
            recs.push(obj(vec![
                ("sparsity", num(sp)),
                ("method", js(&method.label())),
                ("itop", num(rate)),
            ]));
        }
    }
    t.print();
    println!("\nExpected: DST methods explore several times their density; static stays at 1x.");
    record("itop", obj(vec![("model", js(&model)), ("rows", arr(recs))]))
}

// ---------------------------------------------------------------------------
// Table 10 — structured pruning + fine-tune vs SRigL
// ---------------------------------------------------------------------------

pub fn table10(args: &Args) -> Result<()> {
    let model = args.get_or("model", "cnn_proxy");
    let steps: usize = args.parse_or("steps", default_steps(&model))?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let keep_fracs: Vec<f64> = args.list_or("keep", &[0.5, 0.25])?;
    let sess = Session::open()?;

    println!("Table 10 — structured prune+finetune vs SRigL at matched inference FLOPs");
    // 1) dense-train the reference model
    let mut dense_tr = sess.trainer(base_config(&model, Method::Dense, 0.0, steps, seed))?;
    let dense_rep = dense_tr.run()?;

    let mut t = Table::new(&["method", "infer FLOPs frac", "accuracy", "epoch-equiv"]);
    let mut recs = Vec::new();
    t.row(vec!["dense".into(), "1.000".into(), format!("{:.3}", dense_rep.eval_metric), format!("{steps}")]);

    for &keep in &keep_fracs {
        // 2) structured prune: keep top-|neuron| fraction per layer, then
        // fine-tune with static topology for steps/2.
        let mut ft = sess.trainer(base_config(&model, Method::Static { structured: false }, 0.0, steps / 2, seed))?;
        // overwrite params with the dense-trained ones + structured masks
        ft.params = dense_tr.params.clone();
        for (li, &pi) in ft.sparse_idx.clone().iter().enumerate() {
            let w = &ft.params[pi];
            let (n, f) = w.neuron_view();
            let w2 = crate::tensor::Tensor::from_vec(&[n, f], w.data.clone());
            let keep_n = ((n as f64 * keep).round() as usize).max(1);
            let m = structured_prune_mask(&w2, keep_n);
            // reshape the (n, f) mask back to the param's true shape
            let mask_t =
                crate::tensor::Tensor::from_vec(&ft.params[pi].shape.clone(), m.t.data);
            ft.params[pi].mul_assign(&mask_t);
            ft.ks[li] = f;
            ft.masks[li] = Mask::from_tensor(mask_t);
        }
        let ft_rep = ft.run()?;
        // FLOPs fraction of the pruned net: neurons scale ~keep per layer.
        let shapes: Vec<LayerShape> = ft
            .sparse_idx
            .iter()
            .map(|&i| LayerShape { name: ft.entry.params[i].name.clone(), dims: ft.entry.params[i].shape.clone() })
            .collect();
        let dens: Vec<f64> = shapes.iter().map(|_| keep).collect();
        let m = cnn_proxy_flops(&[16, 32, 64], 16, 10, &dens);
        let frac = m.inference() / m.inference_dense() * (1.0 / keep).min(1.0).max(keep); // keep fraction both in+out: ~keep^2 interior
        let _ = frac;
        let flops_frac = keep; // report the per-layer width fraction
        t.row(vec![
            format!("struct-prune+ft (keep {keep:.0}%)", keep = keep * 100.0),
            format!("{flops_frac:.3}"),
            format!("{:.3}", ft_rep.eval_metric),
            format!("{}", steps + steps / 2),
        ]);
        recs.push(obj(vec![("method", js("struct_prune")), ("keep", num(keep)), ("acc", num(ft_rep.eval_metric))]));

        // 3) SRigL trained from scratch at the sparsity matching keep².
        let sp = (1.0 - keep * keep).clamp(0.3, 0.99);
        let sr = run_one(&sess, base_config(&model, srigl(0.3), sp, steps, seed))?;
        t.row(vec![
            format!("SRigL @ {:.0}% (matched)", sp * 100.0),
            format!("{:.3}", 1.0 - sp),
            format!("{:.3}", sr.eval_metric),
            format!("{steps}"),
        ]);
        recs.push(obj(vec![("method", js("srigl")), ("sparsity", num(sp)), ("acc", num(sr.eval_metric))]));

        let densities = layer_densities(Distribution::Erk, &shapes, sp);
        let _ = densities;
    }
    t.print();
    println!("\nPaper shape: SRigL is competitive with structured-pruning pipelines at\nmatched FLOPs with fewer epoch-equivalents (Table 10).");
    record("table10", obj(vec![("rows", arr(recs))]))
}
