//! Analytic harnesses: FLOPs tables (Table 5, Fig. 13) and the
//! min-salient-per-neuron clamp report (Fig. 10).

use anyhow::Result;

use super::{record, Table};
use crate::flops::{cnn_proxy_flops, paper_table5};
use crate::sparsity::distribution::{fan_in_targets, layer_densities, Distribution, LayerShape};
use crate::util::cli::Args;
use crate::util::json::{arr, num, obj, Json};

fn cnn_proxy_shapes() -> Vec<LayerShape> {
    vec![
        LayerShape { name: "conv0".into(), dims: vec![16, 3, 3, 3] },
        LayerShape { name: "conv1".into(), dims: vec![32, 16, 3, 3] },
        LayerShape { name: "conv2".into(), dims: vec![64, 32, 3, 3] },
        LayerShape { name: "fc".into(), dims: vec![10, 64] },
    ]
}

/// Table 5: SRigL training & inference FLOPs across sparsities, with the
/// paper's ResNet-50 values alongside for ratio comparison.
pub fn table5(args: &Args) -> Result<()> {
    let steps: usize = args.parse_or("steps", 400)?;
    let batch: usize = args.parse_or("batch", 32)?;
    let delta_t: usize = args.parse_or("delta-t", 20)?;
    let shapes = cnn_proxy_shapes();

    println!("Table 5 — SRigL FLOPs (cnn_proxy, ERK densities, {steps} steps x batch {batch})");
    let mut t = Table::new(&[
        "sparsity", "train FLOPs", "infer FLOPs", "train/dense", "infer/dense",
        "paper train/dense", "paper infer/dense",
    ]);
    let paper = paper_table5();
    let dense_m = cnn_proxy_flops(&[16, 32, 64], 16, 10, &[1.0; 4]);
    let dense_train = dense_m.train_total(steps, batch, 0);
    let dense_inf = dense_m.inference();
    let mut recs = Vec::new();
    for &(s, p_train, p_inf) in &paper {
        let densities = if s == 0.0 {
            vec![1.0; shapes.len()]
        } else {
            layer_densities(Distribution::Erk, &shapes, s)
        };
        let m = cnn_proxy_flops(&[16, 32, 64], 16, 10, &densities);
        let train = m.train_total(steps, batch, delta_t);
        let inf = m.inference();
        t.row(vec![
            format!("{:.0}%", s * 100.0),
            format!("{train:.3e}"),
            format!("{inf:.3e}"),
            format!("{:.3}", train / dense_train),
            format!("{:.3}", inf / dense_inf),
            format!("{:.3}", p_train / 3.15),
            format!("{:.3}", p_inf / 8.20),
        ]);
        recs.push(obj(vec![
            ("sparsity", num(s)),
            ("train_flops", num(train)),
            ("infer_flops", num(inf)),
            ("train_frac", num(train / dense_train)),
            ("infer_frac", num(inf / dense_inf)),
            ("paper_train_frac", num(p_train / 3.15)),
            ("paper_infer_frac", num(p_inf / 8.20)),
        ]));
    }
    t.print();
    println!("\nShape check: our *fractions of dense* should track the paper's ResNet-50\nfractions (ERK keeps small layers denser, so fractions exceed 1-sparsity).");
    record("table5", obj(vec![("rows", arr(recs))]))
}

/// Fig. 13: normalized training FLOPs across a fine sparsity grid.
pub fn fig13(args: &Args) -> Result<()> {
    let delta_t: usize = args.parse_or("delta-t", 20)?;
    let shapes = cnn_proxy_shapes();
    println!("Fig. 13 — training FLOPs normalized by dense training FLOPs");
    let mut t = Table::new(&["sparsity", "train/dense (SRigL)", "1-sparsity (uniform lower bound)"]);
    let mut recs = Vec::new();
    for s in [0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 0.99] {
        let densities = layer_densities(Distribution::Erk, &shapes, s);
        let m = cnn_proxy_flops(&[16, 32, 64], 16, 10, &densities);
        let frac = m.train_fraction_of_dense(delta_t);
        t.row(vec![format!("{:.0}%", s * 100.0), format!("{frac:.3}"), format!("{:.3}", 1.0 - s)]);
        recs.push(obj(vec![("sparsity", num(s)), ("train_frac", num(frac))]));
    }
    t.print();
    record("fig13", obj(vec![("rows", arr(recs))]))
}

/// Fig. 10: per-layer minimum salient weights per neuron, max(1, γ·k),
/// showing how the clamp to 1 dominates CNNs at γ=0.3.
pub fn fig10(args: &Args) -> Result<()> {
    let gamma: f64 = args.parse_or("gamma", 0.3)?;
    let shapes = cnn_proxy_shapes();
    println!("Fig. 10 — min salient weights per neuron at gamma_sal={gamma}");
    let mut t = Table::new(&["layer", "fan_in", "sparsity", "k", "gamma*k", "min salient", "clamped?"]);
    let mut recs = Vec::new();
    for s in [0.8, 0.9, 0.95, 0.99] {
        let densities = layer_densities(Distribution::Erk, &shapes, s);
        let ks = fan_in_targets(&shapes, &densities);
        for (l, shape) in shapes.iter().enumerate() {
            let gk = gamma * ks[l] as f64;
            let min_sal = crate::stats::ablation::min_salient_per_neuron(gamma, ks[l]);
            t.row(vec![
                format!("{}@{:.0}%", shape.name, s * 100.0),
                shape.fan_in().to_string(),
                format!("{:.0}%", s * 100.0),
                ks[l].to_string(),
                format!("{gk:.2}"),
                format!("{min_sal:.2}"),
                if gk < 1.0 { "yes".into() } else { "no".to_string() },
            ]);
            recs.push(obj(vec![
                ("layer", Json::Str(shape.name.clone())),
                ("sparsity", num(s)),
                ("k", num(ks[l] as f64)),
                ("min_salient", num(min_sal)),
            ]));
        }
    }
    t.print();
    println!("\nPaper observation: at gamma=0.3 many CNN layers clamp to 1 — explaining the\ninsensitivity of CNNs to gamma_sal (App. E).");
    record("fig10", obj(vec![("gamma", num(gamma)), ("rows", arr(recs))]))
}
