//! Wall-clock timing harnesses (Fig. 4, Figs. 18-22): the four layer
//! representations on the paper's exact geometry — the final FF layer of
//! a ViT-B/16 MLP block, 768 neurons x 3072 features.
//!
//! Ablation fractions per sparsity mirror the paper's observation that
//! SRigL ablates *more* neurons at moderate sparsity than at extreme
//! sparsity (Fig. 4 note): {80: 40%, 90: 35%, 95: 15%, 99: 5%}.

use anyhow::Result;
use std::time::Duration;

use super::{record, Table};
use crate::bench::{bench, black_box, fmt_time, Measurement};
use crate::inference::server::{serve, ServeConfig};
use crate::inference::{EngineBuilder, LayerBundle, LinearKernel};
use crate::util::cli::Args;
use crate::util::json::{arr, num, obj, s as js, Json};
use crate::util::rng::Rng;

pub const VIT_FF_N: usize = 768;
pub const VIT_FF_D: usize = 3072;

pub fn ablated_frac_for(sparsity: f64) -> f64 {
    match (sparsity * 100.0).round() as u32 {
        80 => 0.40,
        90 => 0.35,
        95 => 0.15,
        99 => 0.05,
        _ => 0.25,
    }
}

fn time_kernel(k: &dyn LinearKernel, batch: usize, threads: usize, runs: usize) -> Measurement {
    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..batch * k.in_width()).map(|_| rng.normal_f32()).collect();
    let mut out = vec![0f32; batch * k.out_width()];
    bench(k.name(), runs, Duration::from_millis(30), || {
        k.forward(black_box(&x), batch, &mut out, threads);
        black_box(&out);
    })
}

/// Fig. 4: dense/CSR/structured/condensed at sparsities 80-99%, batch 1
/// (4a: CPU online) and batch 256 (4b: GPU substitute — see DESIGN.md §4).
pub fn fig4(args: &Args) -> Result<()> {
    let sparsities: Vec<f64> = args.list_or("sparsities", &[0.8, 0.9, 0.95, 0.99])?;
    let batches: Vec<usize> = args.list_or("batches", &[1usize, 256])?;
    let threads: usize = args.parse_or("threads", 1)?;
    let runs: usize = args.parse_or("runs", 5)?;

    println!(
        "Fig. 4 — ViT-B/16 FF layer ({VIT_FF_N}x{VIT_FF_D}), median of >={runs} runs, {threads} thread(s)"
    );
    let mut recs = Vec::new();
    for &batch in &batches {
        let mut t = Table::new(&["sparsity", "dense", "csr", "structured", "condensed",
                                 "cond/dense", "cond/csr"]);
        for &sp in &sparsities {
            let bundle = LayerBundle::synth(VIT_FF_N, VIT_FF_D, sp, ablated_frac_for(sp), 42);
            let ms: Vec<Measurement> =
                bundle.kernels().iter().map(|k| time_kernel(*k, batch, threads, runs)).collect();
            let med: Vec<f64> = ms.iter().map(|m| m.median_s()).collect();
            t.row(vec![
                format!("{:.0}%", sp * 100.0),
                fmt_time(med[0]),
                fmt_time(med[1]),
                fmt_time(med[2]),
                fmt_time(med[3]),
                format!("{:.2}x", med[0] / med[3]),
                format!("{:.2}x", med[1] / med[3]),
            ]);
            recs.push(obj(vec![
                ("batch", num(batch as f64)),
                ("sparsity", num(sp)),
                ("dense_s", num(med[0])),
                ("csr_s", num(med[1])),
                ("structured_s", num(med[2])),
                ("condensed_s", num(med[3])),
            ]));
        }
        println!("\n-- batch {batch} --");
        t.print();
    }
    println!("\nPaper reference @90%: online condensed = 3.4x dense, 2.5x CSR (Fig. 4a);\nbatched condensed = 1.7x dense, 13.0x CSR on GPU (Fig. 4b — here substituted\nby the threaded CPU engine; crossover *shape* is the claim under test).");
    record("fig4", obj(vec![("rows", arr(recs))]))
}

/// Figs. 18-20: thread x batch sweep (1/4/8 threads, batch 1..64).
pub fn fig18(args: &Args) -> Result<()> {
    let sparsity: f64 = args.parse_or("sparsity", 0.9)?;
    let threads: Vec<usize> = args.list_or("threads", &[1usize, 4, 8])?;
    let batches: Vec<usize> = args.list_or("batches", &[1usize, 4, 16, 64])?;
    let runs: usize = args.parse_or("runs", 5)?;
    let bundle = LayerBundle::synth(VIT_FF_N, VIT_FF_D, sparsity, ablated_frac_for(sparsity), 42);

    println!("Figs. 18-20 — thread x batch sweep @ {:.0}% sparsity", sparsity * 100.0);
    println!("(testbed has 1 physical core: thread scaling flattens here by construction)");
    let mut recs = Vec::new();
    let mut t = Table::new(&["threads", "batch", "dense", "csr", "structured", "condensed"]);
    for &th in &threads {
        for &b in &batches {
            let med: Vec<f64> = bundle
                .kernels()
                .iter()
                .map(|k| time_kernel(*k, b, th, runs).median_s())
                .collect();
            t.row(vec![
                th.to_string(),
                b.to_string(),
                fmt_time(med[0]),
                fmt_time(med[1]),
                fmt_time(med[2]),
                fmt_time(med[3]),
            ]);
            recs.push(obj(vec![
                ("threads", num(th as f64)),
                ("batch", num(b as f64)),
                ("dense_s", num(med[0])),
                ("csr_s", num(med[1])),
                ("structured_s", num(med[2])),
                ("condensed_s", num(med[3])),
            ]));
        }
    }
    t.print();
    record("fig18", obj(vec![("sparsity", num(sparsity)), ("rows", arr(recs))]))
}

/// Fig. 21: batched inference at batch {1, 256, 2048} (GPU substitute).
pub fn fig21(args: &Args) -> Result<()> {
    let sparsities: Vec<f64> = args.list_or("sparsities", &[0.8, 0.9, 0.95, 0.99])?;
    let batches: Vec<usize> = args.list_or("batches", &[1usize, 256, 2048])?;
    let runs: usize = args.parse_or("runs", 5)?;
    println!("Fig. 21 — batch sweep (paper: Titan V CUDA; here: native engine, DESIGN.md §4)");
    let mut recs = Vec::new();
    let mut t = Table::new(&["batch", "sparsity", "dense", "csr", "structured", "condensed", "cond/csr"]);
    for &b in &batches {
        for &sp in &sparsities {
            let bundle = LayerBundle::synth(VIT_FF_N, VIT_FF_D, sp, ablated_frac_for(sp), 42);
            let med: Vec<f64> = bundle
                .kernels()
                .iter()
                .map(|k| time_kernel(*k, b, 1, runs).median_s())
                .collect();
            t.row(vec![
                b.to_string(),
                format!("{:.0}%", sp * 100.0),
                fmt_time(med[0]),
                fmt_time(med[1]),
                fmt_time(med[2]),
                fmt_time(med[3]),
                format!("{:.2}x", med[1] / med[3]),
            ]);
            recs.push(obj(vec![
                ("batch", num(b as f64)),
                ("sparsity", num(sp)),
                ("dense_s", num(med[0])),
                ("csr_s", num(med[1])),
                ("structured_s", num(med[2])),
                ("condensed_s", num(med[3])),
            ]));
        }
    }
    t.print();
    record("fig21", obj(vec![("rows", arr(recs))]))
}

/// Fig. 22 / App. K: condensed vs the engineered unstructured baseline
/// (our CSR at 4 threads stands in for DeepSparse — DESIGN.md §4),
/// measured end-to-end through the online-inference server.
pub fn fig22(args: &Args) -> Result<()> {
    let sparsities: Vec<f64> = args.list_or("sparsities", &[0.8, 0.9, 0.95, 0.99])?;
    let n_requests: usize = args.parse_or("requests", 200)?;
    println!("Fig. 22 — online-inference server latency (batch-1 Poisson stream)");
    let mut recs = Vec::new();
    let mut t = Table::new(&[
        "sparsity", "repr", "p50 (us)", "p99 (us)", "throughput (req/s)",
    ]);
    for &sp in &sparsities {
        let bundle = LayerBundle::synth(VIT_FF_N, VIT_FF_D, sp, ablated_frac_for(sp), 42);
        for (kernel, threads) in [
            (&bundle.condensed as &dyn LinearKernel, 1usize),
            (&bundle.csr as &dyn LinearKernel, 4usize), // "engine" baseline
        ] {
            let stats = serve(
                kernel,
                &EngineBuilder::online().threads(threads),
                &ServeConfig { n_requests, mean_interarrival: Duration::ZERO, seed: 3 },
            );
            t.row(vec![
                format!("{:.0}%", sp * 100.0),
                format!("{}@{}t", kernel.name(), threads),
                format!("{:.1}", stats.p50_us),
                format!("{:.1}", stats.p99_us),
                format!("{:.0}", stats.throughput_rps),
            ]);
            recs.push(obj(vec![
                ("sparsity", num(sp)),
                ("repr", js(kernel.name())),
                ("threads", num(threads as f64)),
                ("p50_us", num(stats.p50_us)),
                ("p99_us", num(stats.p99_us)),
                ("rps", num(stats.throughput_rps)),
            ]));
        }
    }
    t.print();
    println!("\nPaper finding: SRigL-condensed matches the engineered unstructured engine\nwith lower variance; here compare condensed@1t vs csr@4t rows.");
    record("fig22", obj(vec![("rows", arr(recs))]))
}
