//! Experiment registry: one harness per paper table/figure (DESIGN.md §5).
//!
//! Every harness prints the paper-style rows plus, where meaningful, the
//! paper's own numbers for shape comparison, and appends a JSON record to
//! `results/<id>.json`. All are scaled to this testbed (see DESIGN.md §4);
//! `--steps`, `--seeds`, etc. rescale them.

pub mod tables;
pub mod timings;
pub mod training;
pub mod variance_fig;

use anyhow::{bail, Result};

use crate::util::cli::Args;

pub struct ExpInfo {
    pub id: &'static str,
    pub paper: &'static str,
    pub what: &'static str,
}

pub const EXPERIMENTS: &[ExpInfo] = &[
    ExpInfo { id: "fig1b", paper: "Fig. 1b", what: "output-norm variance: theory vs Monte-Carlo" },
    ExpInfo { id: "table1", paper: "Tab. 1 / Fig. 3a", what: "ResNet-50 proxy: accuracy vs sparsity, RigL vs SRigL" },
    ExpInfo { id: "fig3b", paper: "Fig. 3b", what: "% active neurons after training, RigL vs SRigL" },
    ExpInfo { id: "table2", paper: "Tab. 2", what: "ResNet-18/CIFAR proxy: 5 seeds, mean±95% CI" },
    ExpInfo { id: "table3", paper: "Tab. 3", what: "DST method comparison (Static/SET/RigL/SRigL)" },
    ExpInfo { id: "table4", paper: "Tab. 4", what: "ViT proxy: ablation on/off at 80/90%" },
    ExpInfo { id: "table5", paper: "Tab. 5", what: "training/inference FLOPs vs sparsity" },
    ExpInfo { id: "fig4", paper: "Fig. 4", what: "layer timings: dense/CSR/structured/condensed" },
    ExpInfo { id: "table9", paper: "Tab. 9 / Fig. 5", what: "Wide-ResNet proxy across sparsities" },
    ExpInfo { id: "fig8", paper: "Fig. 8", what: "gamma_sal sweep (CNN proxy)" },
    ExpInfo { id: "fig9", paper: "Fig. 9a", what: "gamma_sal sweep (ViT proxy)" },
    ExpInfo { id: "fig10", paper: "Fig. 10", what: "min salient weights per neuron, per layer" },
    ExpInfo { id: "fig11", paper: "Fig. 11", what: "layer widths at 99% sparsity vs gamma_sal" },
    ExpInfo { id: "fig12", paper: "Fig. 12", what: "RigL fan-in variance (transformer)" },
    ExpInfo { id: "fig13", paper: "Fig. 13", what: "normalized training FLOPs vs sparsity" },
    ExpInfo { id: "itop", paper: "Figs. 14-17", what: "in-time overparameterization rates" },
    ExpInfo { id: "fig18", paper: "Figs. 18-20", what: "CPU thread x batch timing sweep" },
    ExpInfo { id: "fig21", paper: "Fig. 21", what: "batched-inference timing sweep (GPU substitute)" },
    ExpInfo { id: "fig22", paper: "Fig. 22", what: "condensed vs engineered-CSR online latency" },
    ExpInfo { id: "table10", paper: "Tab. 10", what: "structured pruning + fine-tune vs SRigL" },
];

pub fn list() {
    println!("{:<9} {:<18} {}", "id", "paper", "description");
    for e in EXPERIMENTS {
        println!("{:<9} {:<18} {}", e.id, e.paper, e.what);
    }
}

pub fn run(id: &str, args: &Args) -> Result<()> {
    match id {
        "fig1b" => variance_fig::fig1b(args),
        "table1" => training::table1(args),
        "fig3b" => training::fig3b(args),
        "table2" => training::table2(args),
        "table3" => training::table3(args),
        "table4" => training::table4(args),
        "table5" => tables::table5(args),
        "fig4" => timings::fig4(args),
        "table9" => training::table9(args),
        "fig8" => training::fig8(args),
        "fig9" => training::fig9(args),
        "fig10" => tables::fig10(args),
        "fig11" => training::fig11(args),
        "fig12" => training::fig12(args),
        "fig13" => tables::fig13(args),
        "itop" => training::itop(args),
        "fig18" => timings::fig18(args),
        "fig21" => timings::fig21(args),
        "fig22" => timings::fig22(args),
        "table10" => training::table10(args),
        "all" => {
            for e in EXPERIMENTS {
                println!("\n################ {} ({}) ################", e.id, e.paper);
                run(e.id, args)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?}; `srigl exp --list`"),
    }
}

/// Write a JSON record under results/.
pub fn record(id: &str, payload: crate::util::json::Json) -> Result<()> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{id}.json");
    std::fs::write(&path, payload.to_string())?;
    println!("[recorded -> {path}]");
    Ok(())
}

/// Simple fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate().take(ncol) {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        for r in &self.rows {
            line(r);
        }
    }
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_unique_and_dispatchable() {
        let mut seen = std::collections::HashSet::new();
        for e in EXPERIMENTS {
            assert!(seen.insert(e.id), "duplicate id {}", e.id);
        }
        // unknown id errors
        let args = Args::default();
        assert!(run("nope", &args).is_err());
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
        assert_eq!(pct(0.5), "50.0%");
    }
}
