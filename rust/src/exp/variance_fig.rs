//! Fig. 1b: output-norm variance — theory (Appendix B, Eqs. 14/21/25) vs
//! Monte-Carlo simulation, for each sparsity structure.

use anyhow::Result;

use super::{record, Table};
use crate::stats::variance::{simulate_var, SparsityType};
use crate::util::cli::Args;
use crate::util::json::{arr, num, obj, s, Json};

pub fn fig1b(args: &Args) -> Result<()> {
    let n: usize = args.parse_or("n", 256)?;
    let trials: usize = args.parse_or("trials", 3000)?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let ks: Vec<usize> = args.list_or("ks", &[2usize, 4, 8, 16, 32, 64, 128])?;

    println!("Fig. 1b — Var(||z_{{l+1}}||^2 / ||z_l||^2) at n={n}, {trials} MC trials");
    println!("(theory per Appendix B; note the main-text 18k/n vs appendix 18n/k typo — see DESIGN.md)");
    let mut t = Table::new(&[
        "k", "bern(theory)", "bern(sim)", "cpl(theory)", "cpl(sim)", "cfi(theory)", "cfi(sim)",
        "cfi smallest?",
    ]);
    let mut recs = Vec::new();
    for &k in &ks {
        if k >= n {
            continue;
        }
        let types = [SparsityType::Bernoulli, SparsityType::ConstPerLayer, SparsityType::ConstFanIn];
        let mut theory = Vec::new();
        let mut sim = Vec::new();
        for (i, ty) in types.iter().enumerate() {
            theory.push(ty.theory(n, k));
            sim.push(simulate_var(*ty, n, k, trials, seed + (k as u64) * 10 + i as u64));
        }
        let smallest = theory[2] < theory[0] && theory[2] < theory[1];
        t.row(vec![
            k.to_string(),
            format!("{:.5}", theory[0]),
            format!("{:.5}", sim[0]),
            format!("{:.5}", theory[1]),
            format!("{:.5}", sim[1]),
            format!("{:.5}", theory[2]),
            format!("{:.5}", sim[2]),
            if smallest { "yes".into() } else { "NO".into() },
        ]);
        recs.push(obj(vec![
            ("k", num(k as f64)),
            ("bern_theory", num(theory[0])),
            ("bern_sim", num(sim[0])),
            ("cpl_theory", num(theory[1])),
            ("cpl_sim", num(sim[1])),
            ("cfi_theory", num(theory[2])),
            ("cfi_sim", num(sim[2])),
        ]));
    }
    t.print();
    println!("\nPaper claim: constant fan-in variance is consistently the smallest, with the\ngap growing as k << n — matches the 'cfi smallest?' column.");
    record(
        "fig1b",
        obj(vec![("n", num(n as f64)), ("trials", num(trials as f64)), ("rows", arr(recs)), ("note", s("theory uses appendix 18n/k form"))]),
    )
}
