//! Learning-rate schedules used across the paper's recipes (App. D):
//! step decay (ResNets), linear warm-up + cosine (ViT), constant.

#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    Const(f32),
    /// Multiply by `factor` at each step in `drops`.
    StepDecay { base: f32, drops: Vec<usize>, factor: f32 },
    /// Linear warm-up to `max` over `warmup` steps, then cosine to ~0.
    WarmupCosine { max: f32, warmup: usize },
}

impl LrSchedule {
    pub fn step_decay(base: f32, drops: &[usize], factor: f32) -> LrSchedule {
        LrSchedule::StepDecay { base, drops: drops.to_vec(), factor }
    }

    pub fn at(&self, step: usize, total: usize) -> f32 {
        match self {
            LrSchedule::Const(v) => *v,
            LrSchedule::StepDecay { base, drops, factor } => {
                let n = drops.iter().filter(|&&d| step >= d).count();
                base * factor.powi(n as i32)
            }
            LrSchedule::WarmupCosine { max, warmup } => {
                if step < *warmup {
                    max * (step + 1) as f32 / *warmup as f32
                } else {
                    let t = (step - warmup) as f32 / (total.saturating_sub(*warmup)).max(1) as f32;
                    max * 0.5 * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos())
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_decay_drops() {
        let s = LrSchedule::step_decay(0.1, &[100, 200], 0.1);
        assert!((s.at(0, 300) - 0.1).abs() < 1e-9);
        assert!((s.at(150, 300) - 0.01).abs() < 1e-9);
        assert!((s.at(250, 300) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = LrSchedule::WarmupCosine { max: 0.003, warmup: 10 };
        assert!(s.at(0, 100) < s.at(9, 100));
        assert!((s.at(9, 100) - 0.003).abs() < 1e-3 * 0.4);
        assert!(s.at(99, 100) < 0.0005);
        // monotone decreasing after warmup
        let mut prev = f32::INFINITY;
        for t in 10..100 {
            let v = s.at(t, 100);
            assert!(v <= prev + 1e-9);
            prev = v;
        }
    }

    #[test]
    fn const_is_const() {
        let s = LrSchedule::Const(0.5);
        assert_eq!(s.at(0, 10), 0.5);
        assert_eq!(s.at(9, 10), 0.5);
    }
}
