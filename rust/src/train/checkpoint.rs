//! Checkpointing: save/restore full training state (params, momenta,
//! masks, fan-in constraints, step counter) so long runs survive
//! restarts and trained models can be shipped to the inference engine.
//!
//! Format: a directory with `state.json` (metadata + mask/param index)
//! and `tensors.bin` (little-endian f32 blobs, offsets in the JSON).
//! No serde available offline — the JSON side uses util::json.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

use crate::sparsity::Mask;
use crate::tensor::Tensor;
use crate::util::json::{arr, num, obj, s, Json};

pub struct Checkpoint {
    pub model: String,
    pub step: usize,
    pub params: Vec<Tensor>,
    pub momenta: Vec<Tensor>,
    pub masks: Vec<Mask>,
    pub ks: Vec<usize>,
}

impl Checkpoint {
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut bin: Vec<u8> = Vec::new();
        let mut entries = Vec::new();
        let mut push_tensor = |kind: &str, i: usize, t: &Tensor, bin: &mut Vec<u8>| {
            let offset = bin.len();
            for v in &t.data {
                bin.extend_from_slice(&v.to_le_bytes());
            }
            entries.push(obj(vec![
                ("kind", s(kind)),
                ("index", num(i as f64)),
                ("shape", arr(t.shape.iter().map(|&d| num(d as f64)))),
                ("offset", num(offset as f64)),
                ("len", num(t.data.len() as f64)),
            ]));
        };
        for (i, t) in self.params.iter().enumerate() {
            push_tensor("param", i, t, &mut bin);
        }
        for (i, t) in self.momenta.iter().enumerate() {
            push_tensor("momentum", i, t, &mut bin);
        }
        for (i, m) in self.masks.iter().enumerate() {
            push_tensor("mask", i, &m.t, &mut bin);
        }
        let meta = obj(vec![
            ("version", num(1.0)),
            ("model", s(&self.model)),
            ("step", num(self.step as f64)),
            ("ks", arr(self.ks.iter().map(|&k| num(k as f64)))),
            ("tensors", Json::Arr(entries)),
        ]);
        std::fs::File::create(dir.join("tensors.bin"))?.write_all(&bin)?;
        std::fs::write(dir.join("state.json"), meta.to_string())?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<Checkpoint> {
        let meta_src = std::fs::read_to_string(dir.join("state.json"))
            .with_context(|| format!("reading {dir:?}/state.json"))?;
        let meta = Json::parse(&meta_src)?;
        if meta.get("version")?.as_usize()? != 1 {
            bail!("unsupported checkpoint version");
        }
        let mut bin = Vec::new();
        std::fs::File::open(dir.join("tensors.bin"))?.read_to_end(&mut bin)?;

        let mut params = Vec::new();
        let mut momenta = Vec::new();
        let mut masks = Vec::new();
        for e in meta.get("tensors")?.as_arr()? {
            let shape: Vec<usize> =
                e.get("shape")?.as_arr()?.iter().map(|v| v.as_usize()).collect::<Result<_>>()?;
            let offset = e.get("offset")?.as_usize()?;
            let len = e.get("len")?.as_usize()?;
            let end = offset + len * 4;
            if end > bin.len() {
                bail!("tensor blob out of range");
            }
            let mut data = Vec::with_capacity(len);
            for c in bin[offset..end].chunks_exact(4) {
                data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            let t = Tensor::from_vec(&shape, data);
            match e.get("kind")?.as_str()? {
                "param" => params.push(t),
                "momentum" => momenta.push(t),
                "mask" => masks.push(Mask::from_tensor(t)),
                other => bail!("unknown tensor kind {other:?}"),
            }
        }
        Ok(Checkpoint {
            model: meta.get("model")?.as_str()?.to_string(),
            step: meta.get("step")?.as_usize()?,
            params,
            momenta,
            masks,
            ks: meta.get("ks")?.as_arr()?.iter().map(|v| v.as_usize()).collect::<Result<_>>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("srigl_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(0);
        let ck = Checkpoint {
            model: "mlp_tiny".into(),
            step: 123,
            params: vec![Tensor::normal(&[4, 8], 1.0, &mut rng), Tensor::normal(&[4], 1.0, &mut rng)],
            momenta: vec![Tensor::zeros(&[4, 8]), Tensor::zeros(&[4])],
            masks: vec![Mask::random_constant_fan_in(&[4, 8], 3, &mut rng)],
            ks: vec![3],
        };
        let dir = tmpdir("roundtrip");
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.model, "mlp_tiny");
        assert_eq!(back.step, 123);
        assert_eq!(back.params.len(), 2);
        assert_eq!(back.params[0].data, ck.params[0].data);
        assert_eq!(back.params[0].shape, vec![4, 8]);
        assert_eq!(back.masks[0].t.data, ck.masks[0].t.data);
        assert_eq!(back.ks, vec![3]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_missing_fails() {
        assert!(Checkpoint::load(Path::new("/nonexistent/ckpt")).is_err());
    }
}
