//! JSON experiment-config files: the launcher-facing config system.
//!
//! ```json
//! {
//!   "model": "cnn_proxy", "method": "srigl", "sparsity": 0.9,
//!   "gamma_sal": 0.3, "ablation": true, "distribution": "erk",
//!   "steps": 600, "delta_t": 40, "alpha": 0.3,
//!   "lr": {"kind": "step", "base": 0.05, "drops": [300, 450], "factor": 0.2},
//!   "grad_accum": 1, "seed": 0, "eval_batches": 8,
//!   "dense_first_layer": false
//! }
//! ```
//!
//! `srigl train --config path.json` loads one of these; missing keys fall
//! back to the defaults below, so minimal configs stay minimal.

use anyhow::{bail, Result};
use std::path::Path;

use super::{LrSchedule, Method, TrainConfig};
use crate::sparsity::Distribution;
use crate::util::json::Json;

pub fn load(path: &Path) -> Result<TrainConfig> {
    let src = std::fs::read_to_string(path)?;
    parse(&src)
}

pub fn parse(src: &str) -> Result<TrainConfig> {
    let j = Json::parse(src)?;
    let get_f = |k: &str, d: f64| -> Result<f64> {
        Ok(match j.opt(k) {
            Some(v) => v.as_f64()?,
            None => d,
        })
    };
    let get_u = |k: &str, d: usize| -> Result<usize> {
        Ok(match j.opt(k) {
            Some(v) => v.as_usize()?,
            None => d,
        })
    };
    let get_b = |k: &str, d: bool| -> Result<bool> {
        Ok(match j.opt(k) {
            Some(v) => v.as_bool()?,
            None => d,
        })
    };
    let steps = get_u("steps", 300)?;
    let method = Method::parse(
        j.opt("method").map(|v| v.as_str()).transpose()?.unwrap_or("srigl"),
        get_b("ablation", true)?,
        get_f("gamma_sal", 0.3)?,
    )?;
    let dist: Distribution = j
        .opt("distribution")
        .map(|v| v.as_str())
        .transpose()?
        .unwrap_or("erk")
        .parse()?;
    let lr = match j.opt("lr") {
        None => LrSchedule::step_decay(0.05, &[steps / 2, 3 * steps / 4], 0.2),
        Some(Json::Num(v)) => LrSchedule::Const(*v as f32),
        Some(spec) => {
            let kind = spec.get("kind")?.as_str()?;
            match kind {
                "const" => LrSchedule::Const(spec.get("base")?.as_f64()? as f32),
                "step" => {
                    let drops: Vec<usize> = spec
                        .get("drops")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_usize())
                        .collect::<Result<_>>()?;
                    LrSchedule::StepDecay {
                        base: spec.get("base")?.as_f64()? as f32,
                        drops,
                        factor: spec.get("factor")?.as_f64()? as f32,
                    }
                }
                "warmup_cosine" => LrSchedule::WarmupCosine {
                    max: spec.get("max")?.as_f64()? as f32,
                    warmup: spec.get("warmup")?.as_usize()?,
                },
                other => bail!("unknown lr kind {other:?}"),
            }
        }
    };
    Ok(TrainConfig {
        model: j
            .opt("model")
            .map(|v| v.as_str())
            .transpose()?
            .unwrap_or("cnn_proxy")
            .to_string(),
        method,
        sparsity: get_f("sparsity", 0.9)?,
        distribution: dist,
        total_steps: steps,
        delta_t: get_u("delta_t", (steps / 15).max(5))?,
        alpha: get_f("alpha", 0.3)?,
        lr,
        grad_accum: get_u("grad_accum", 1)?,
        seed: get_u("seed", 0)? as u64,
        eval_batches: get_u("eval_batches", 8)?,
        dense_first_layer: get_b("dense_first_layer", false)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_config_defaults() {
        let c = parse(r#"{"model": "mlp_tiny"}"#).unwrap();
        assert_eq!(c.model, "mlp_tiny");
        assert_eq!(c.total_steps, 300);
        assert!(matches!(c.method, Method::SRigL { ablation: true, .. }));
        assert!(matches!(c.lr, LrSchedule::StepDecay { .. }));
    }

    #[test]
    fn full_config() {
        let c = parse(
            r#"{
              "model": "vit_proxy", "method": "rigl", "sparsity": 0.95,
              "distribution": "uniform", "steps": 100, "delta_t": 10,
              "alpha": 0.2, "lr": {"kind": "warmup_cosine", "max": 0.003, "warmup": 16},
              "grad_accum": 8, "seed": 7, "dense_first_layer": true
            }"#,
        )
        .unwrap();
        assert_eq!(c.model, "vit_proxy");
        assert!(matches!(c.method, Method::RigL));
        assert_eq!(c.sparsity, 0.95);
        assert_eq!(c.grad_accum, 8);
        assert!(c.dense_first_layer);
        assert!(matches!(c.lr, LrSchedule::WarmupCosine { warmup: 16, .. }));
    }

    #[test]
    fn scalar_lr_is_const() {
        let c = parse(r#"{"lr": 0.01}"#).unwrap();
        assert_eq!(c.lr, LrSchedule::Const(0.01));
    }

    #[test]
    fn bad_method_rejected() {
        assert!(parse(r#"{"method": "magic"}"#).is_err());
    }
}
