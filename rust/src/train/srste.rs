//! SR-STE (Zhou et al. 2021) — the dense-to-sparse N:M baseline of paper
//! Table 3. Unlike the sparse-to-sparse DST methods, SR-STE keeps *dense*
//! shadow weights and re-projects them to the top-N:M mask every step,
//! propagating gradients through the projection with a straight-through
//! estimator plus the sparse-refined regularizer on pruned weights:
//!
//!   mask_t   = topNM(|w_t|)
//!   g_dense  = dL/d(w ⊙ mask)              (STE: passes straight to w)
//!   w_{t+1}  = w_t - lr (g_dense + λ_w (1 - mask) ⊙ w_t)
//!
//! The coordinator owns the dense weights and the SGD update host-side;
//! the AOT `dense_grad` and `loss_eval`/`eval_logits` programs supply the
//! gradients and evaluation — no extra artifacts needed. This honestly
//! reproduces the paper's complaint about SR-STE: every step costs a
//! dense gradient (compare the throughput this reports to the sparse
//! methods').

use anyhow::Result;

use super::{Session, TrainReport};
use crate::data;
use crate::runtime::{i32s_to_lit, lit_to_f32, lit_to_tensor, tensor_to_lit};
use crate::sparsity::nm::nm_mask;
use crate::sparsity::Mask;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SrSteConfig {
    pub model: String,
    /// N:M pattern, e.g. (2, 4) for Ampere-style 50%, (1, 4) for 75%.
    pub n: usize,
    pub m: usize,
    pub steps: usize,
    pub lr: f32,
    /// Sparse-refined regularization coefficient λ_w (2e-4 in the paper).
    pub lambda_w: f32,
    pub momentum: f32,
    pub seed: u64,
    pub eval_batches: usize,
}

pub fn train_srste(sess: &Session, cfg: &SrSteConfig) -> Result<TrainReport> {
    let entry = sess.man.model(&cfg.model)?.clone();
    let programs = sess.programs(&cfg.model)?;
    let mut rng = Rng::new(cfg.seed);
    let sparse_idx = entry.sparse_indices();

    // dense init for every param
    let mut params: Vec<Tensor> = Vec::new();
    let mut momenta: Vec<Tensor> = Vec::new();
    for p in &entry.params {
        let t = match p.init.as_str() {
            "zeros" => Tensor::zeros(&p.shape),
            "ones" => Tensor::ones(&p.shape),
            "he" => Tensor::he_sparse(&p.shape, p.fan_in, &mut rng),
            s if s.starts_with("normal:") => {
                Tensor::normal(&p.shape, s["normal:".len()..].parse().unwrap_or(0.02), &mut rng)
            }
            other => anyhow::bail!("unknown init {other:?}"),
        };
        momenta.push(Tensor::zeros(&p.shape));
        params.push(t);
    }

    let project = |params: &[Tensor]| -> Vec<Mask> {
        sparse_idx
            .iter()
            .map(|&pi| {
                let p = &params[pi];
                let (n_rows, f) = p.neuron_view();
                let flat = Tensor::from_vec(&[n_rows, f], p.data.clone());
                // fall back to per-row top-k when fan-in isn't M-divisible
                let m_eff = if f % cfg.m == 0 { cfg.m } else { f };
                let n_eff = if f % cfg.m == 0 {
                    cfg.n
                } else {
                    ((cfg.n * f) / cfg.m).max(1)
                };
                let mask2 = nm_mask(&flat, n_eff, m_eff);
                Mask::from_tensor(Tensor::from_vec(&p.shape, mask2.t.data))
            })
            .collect()
    };

    let dataset = data::for_model(&entry, cfg.seed ^ 0xda7a);
    let t0 = std::time::Instant::now();
    let mut losses = Vec::with_capacity(cfg.steps);

    for _step in 0..cfg.steps {
        let masks = project(&params);
        let batch = dataset.sample(&mut rng);
        // inputs: params, masks, x, y — note params enter *dense*; the HLO
        // multiplies by the mask, giving dL/d(w⊙m).
        let mut inputs = Vec::new();
        for p in &params {
            inputs.push(tensor_to_lit(p)?);
        }
        for m in &masks {
            inputs.push(tensor_to_lit(&m.t)?);
        }
        match &batch.x {
            data::XData::F32(v) => inputs.push(crate::runtime::f32s_to_lit(&entry.x.shape, v)?),
            data::XData::I32(v) => inputs.push(i32s_to_lit(&entry.x.shape, v)?),
        }
        inputs.push(i32s_to_lit(&entry.y.shape, &batch.y)?);

        let grads_out = programs.dense_grad.run(&inputs)?;
        // loss for the curve (separate call; SR-STE is expensive, faithfully)
        let loss_out = programs.loss_eval.run(&inputs)?;
        losses.push(lit_to_f32(&loss_out[0])?);

        // host-side SGD with momentum; STE: dense grads apply to all
        // sparse weights, plus λ_w decay on the pruned ones. Non-sparse
        // params get no gradient here (dense_grad returns sparse only), so
        // SR-STE at this scale trains sparse tensors only — biases/LN stay
        // at init, which is the dominant-term approximation.
        for (si, &pi) in sparse_idx.iter().enumerate() {
            let g = lit_to_tensor(&grads_out[si], &entry.params[pi].shape)?;
            let mask = &masks[si];
            for i in 0..params[pi].data.len() {
                let pruned = 1.0 - mask.t.data[i];
                let reg = cfg.lambda_w * pruned * params[pi].data[i];
                let v = cfg.momentum * momenta[pi].data[i] + g.data[i] + reg;
                momenta[pi].data[i] = v;
                params[pi].data[i] -= cfg.lr * v;
            }
        }
    }

    // final projection + eval with masked weights
    let masks = project(&params);
    let mut eval_rng = Rng::new(cfg.seed ^ 0xe7a1);
    let classes = entry.num_classes;
    let b = entry.batch;
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut lm_loss = 0f64;
    for _ in 0..cfg.eval_batches.max(1) {
        let batch = dataset.sample(&mut eval_rng);
        let mut inputs = Vec::new();
        for p in &params {
            inputs.push(tensor_to_lit(p)?);
        }
        for m in &masks {
            inputs.push(tensor_to_lit(&m.t)?);
        }
        match &batch.x {
            data::XData::F32(v) => inputs.push(crate::runtime::f32s_to_lit(&entry.x.shape, v)?),
            data::XData::I32(v) => inputs.push(i32s_to_lit(&entry.x.shape, v)?),
        }
        if entry.task == "lm" {
            inputs.push(i32s_to_lit(&entry.y.shape, &batch.y)?);
            lm_loss += lit_to_f32(&programs.loss_eval.run(&inputs)?[0])? as f64;
        } else {
            let logits = programs.eval_logits.run(&inputs)?[0].to_vec::<f32>()?;
            for i in 0..b {
                let row = &logits[i * classes..(i + 1) * classes];
                let pred =
                    row.iter().enumerate().max_by(|a, c| a.1.total_cmp(c.1)).unwrap().0;
                if pred == batch.y[i] as usize {
                    correct += 1;
                }
                seen += 1;
            }
        }
    }
    let (eval_metric, eval_kind) = if entry.task == "lm" {
        (lm_loss / cfg.eval_batches.max(1) as f64, "loss")
    } else {
        (correct as f64 / seen.max(1) as f64, "accuracy")
    };

    let total: usize = sparse_idx.iter().map(|&i| entry.params[i].numel()).sum();
    let nnz: usize = masks.iter().map(|m| m.nnz()).sum();
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(TrainReport {
        config_label: format!("{}/sr-ste {}:{}", entry.name, cfg.n, cfg.m),
        losses,
        eval_metric,
        eval_kind,
        updates: vec![],
        final_sparsity: 1.0 - nnz as f64 / total.max(1) as f64,
        itop_rate: 1.0, // dense shadow weights: the whole space is "explored"
        wall_s,
        throughput: cfg.steps as f64 / wall_s.max(1e-9),
    })
}

#[cfg(test)]
mod tests {
    // SR-STE is exercised end-to-end in rust/tests/integration_train.rs
    // (needs artifacts); the N:M projection itself is tested in
    // sparsity::nm.
}
