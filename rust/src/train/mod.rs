//! The training-loop driver: owns all state (params / momenta / masks) on
//! the host, executes the AOT `train_step` each step, and every ΔT steps
//! pulls dense gradients (`dense_grad`) and runs the configured topology
//! updater — exactly the loop of paper Section 3.1 / App. D.
//!
//! Python is never invoked here; the HLO artifacts are the only compute.

pub mod checkpoint;
pub mod config_file;
pub mod lr;
pub mod srste;

pub use checkpoint::Checkpoint;
pub use lr::LrSchedule;
pub use srste::{train_srste, SrSteConfig};

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::data::{self, Batch, Dataset, XData};
use crate::dst::{
    schedule::UpdateSchedule, LayerView, RigL, SRigL, Set, StaticSparse, TopologyUpdater,
    UpdateStats,
};
use crate::runtime::{
    self, i32s_to_lit, lit_to_f32, lit_to_tensor, scalar_f32, tensor_to_lit, Manifest, ModelEntry,
    Program, Runtime,
};
use crate::sparsity::{
    distribution::{fan_in_targets, layer_densities, Distribution, LayerShape},
    Condensed, Mask,
};
use crate::stats::itop::ItopTracker;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Which DST method drives topology (paper Table 3 rows).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    Dense,
    Static { structured: bool },
    Set,
    RigL,
    SRigL { ablation: bool, gamma_sal: f64 },
}

impl Method {
    pub fn parse(name: &str, ablation: bool, gamma_sal: f64) -> Result<Method> {
        Ok(match name {
            "dense" => Method::Dense,
            "static" => Method::Static { structured: false },
            "static_cfi" => Method::Static { structured: true },
            "set" => Method::Set,
            "rigl" => Method::RigL,
            "srigl" => Method::SRigL { ablation, gamma_sal },
            other => anyhow::bail!("unknown method {other:?}"),
        })
    }

    pub fn label(&self) -> String {
        match self {
            Method::Dense => "dense".into(),
            Method::Static { structured: false } => "static".into(),
            Method::Static { structured: true } => "static_cfi".into(),
            Method::Set => "set".into(),
            Method::RigL => "rigl".into(),
            Method::SRigL { ablation: true, .. } => "srigl".into(),
            Method::SRigL { ablation: false, .. } => "srigl_noabl".into(),
        }
    }

    fn updater(&self) -> Box<dyn TopologyUpdater> {
        match *self {
            Method::Dense => Box::new(StaticSparse { structured: false }),
            Method::Static { structured } => Box::new(StaticSparse { structured }),
            Method::Set => Box::new(Set),
            Method::RigL => Box::new(RigL),
            Method::SRigL { ablation, gamma_sal } => Box::new(SRigL { ablation, gamma_sal }),
        }
    }

    fn structured(&self) -> bool {
        matches!(self, Method::SRigL { .. } | Method::Static { structured: true })
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    pub method: Method,
    /// Global sparsity over the sparse params (0 for dense training).
    pub sparsity: f64,
    pub distribution: Distribution,
    pub total_steps: usize,
    pub delta_t: usize,
    pub alpha: f64,
    pub lr: LrSchedule,
    /// Mini-batches averaged for the dense-gradient saliency signal
    /// (the paper uses 8 for ResNet-50, App. D.2).
    pub grad_accum: usize,
    pub seed: u64,
    pub eval_batches: usize,
    /// Keep the first sparse layer dense (RigL's 99%-sparsity trick).
    pub dense_first_layer: bool,
}

impl TrainConfig {
    pub fn quick(model: &str, method: Method, sparsity: f64, steps: usize, seed: u64) -> Self {
        TrainConfig {
            model: model.into(),
            method,
            sparsity,
            distribution: Distribution::Erk,
            total_steps: steps,
            delta_t: (steps / 20).max(10),
            alpha: 0.3,
            lr: LrSchedule::step_decay(0.1, &[steps / 2, 3 * steps / 4], 0.2),
            grad_accum: 1,
            seed,
            eval_batches: 8,
            dense_first_layer: false,
        }
    }
}

/// Per-update-step record (drives Figs. 3b, 11, 12, 14-17 harnesses).
#[derive(Clone, Debug)]
pub struct UpdateLog {
    pub step: usize,
    pub drop_fraction: f64,
    pub per_layer: Vec<UpdateStats>,
}

/// Full training result: loss curve, final eval, topology history.
#[derive(Debug)]
pub struct TrainReport {
    pub config_label: String,
    pub losses: Vec<f32>,
    pub eval_metric: f64,
    /// "accuracy" for classifiers, "loss" (lower better) for LMs.
    pub eval_kind: &'static str,
    pub updates: Vec<UpdateLog>,
    pub final_sparsity: f64,
    pub itop_rate: f64,
    pub wall_s: f64,
    /// steps/s over the whole run.
    pub throughput: f64,
}

/// Compiled program set for one model, shareable across trainers.
#[derive(Clone)]
pub struct ProgramSet {
    pub train_step: Rc<Program>,
    pub dense_grad: Rc<Program>,
    pub eval_logits: Rc<Program>,
    pub loss_eval: Rc<Program>,
}

/// A session: one PJRT client + manifest + per-model compile cache. Use
/// this when running many configs (the exp harnesses) so each model's
/// programs compile once per process.
pub struct Session {
    pub rt: Runtime,
    pub man: Manifest,
    cache: RefCell<BTreeMap<String, ProgramSet>>,
}

impl Session {
    pub fn open() -> Result<Session> {
        let man = Manifest::load_default().context("loading manifest")?;
        let rt = Runtime::cpu()?;
        Ok(Session { rt, man, cache: RefCell::new(BTreeMap::new()) })
    }

    pub fn programs(&self, model: &str) -> Result<ProgramSet> {
        if let Some(p) = self.cache.borrow().get(model) {
            return Ok(p.clone());
        }
        let entry = self.man.model(model)?;
        let set = ProgramSet {
            train_step: Rc::new(self.rt.load_program(&self.man, entry, "train_step")?),
            dense_grad: Rc::new(self.rt.load_program(&self.man, entry, "dense_grad")?),
            eval_logits: Rc::new(self.rt.load_program(&self.man, entry, "eval_logits")?),
            loss_eval: Rc::new(self.rt.load_program(&self.man, entry, "loss_eval")?),
        };
        self.cache.borrow_mut().insert(model.to_string(), set.clone());
        Ok(set)
    }

    pub fn trainer(&self, cfg: TrainConfig) -> Result<Trainer> {
        let programs = self.programs(&cfg.model)?;
        let entry = self.man.model(&cfg.model)?.clone();
        Trainer::with_programs(entry, programs, cfg)
    }
}

/// The trainer: all state host-side, all compute via PJRT programs.
pub struct Trainer {
    pub entry: ModelEntry,
    pub cfg: TrainConfig,
    train_step: Rc<Program>,
    dense_grad: Rc<Program>,
    eval_logits: Rc<Program>,
    loss_eval: Rc<Program>,
    pub params: Vec<Tensor>,
    pub momenta: Vec<Tensor>,
    /// Parallel to `sparse_idx`.
    pub masks: Vec<Mask>,
    pub ks: Vec<usize>,
    pub budgets: Vec<usize>,
    pub sparse_idx: Vec<usize>,
    dataset: Box<dyn Dataset>,
    schedule: UpdateSchedule,
    rng: Rng,
    itop: ItopTracker,
    /// Mask literals change only at topology updates (every ΔT steps);
    /// caching them avoids re-marshalling every step (§Perf iteration 4).
    mask_lits: RefCell<Option<Rc<Vec<xla::Literal>>>>,
}

impl Trainer {
    pub fn new(rt: &Runtime, man: &Manifest, cfg: TrainConfig) -> Result<Trainer> {
        let entry = man.model(&cfg.model)?.clone();
        let programs = ProgramSet {
            train_step: Rc::new(rt.load_program(man, &entry, "train_step")?),
            dense_grad: Rc::new(rt.load_program(man, &entry, "dense_grad")?),
            eval_logits: Rc::new(rt.load_program(man, &entry, "eval_logits")?),
            loss_eval: Rc::new(rt.load_program(man, &entry, "loss_eval")?),
        };
        Trainer::with_programs(entry, programs, cfg)
    }

    pub fn with_programs(entry: ModelEntry, programs: ProgramSet, cfg: TrainConfig) -> Result<Trainer> {
        let ProgramSet { train_step, dense_grad, eval_logits, loss_eval } = programs;
        let mut rng = Rng::new(cfg.seed);
        let sparse_idx = entry.sparse_indices();

        // Per-layer densities + constant fan-in targets over sparse params.
        let shapes: Vec<LayerShape> = sparse_idx
            .iter()
            .map(|&i| LayerShape {
                name: entry.params[i].name.clone(),
                dims: entry.params[i].shape.clone(),
            })
            .collect();
        let sparsity = if cfg.method == Method::Dense { 0.0 } else { cfg.sparsity };
        let densities = if sparsity == 0.0 {
            vec![1.0; shapes.len()]
        } else {
            layer_densities(cfg.distribution, &shapes, sparsity)
        };
        let mut ks = fan_in_targets(&shapes, &densities);
        if cfg.dense_first_layer && !ks.is_empty() {
            ks[0] = shapes[0].fan_in();
        }

        // Masks: constant fan-in for structured methods, per-layer uniform
        // for unstructured ones (RigL/SET/static) — same nnz budget.
        let structured = cfg.method.structured() || sparsity == 0.0;
        let mut masks = Vec::new();
        let mut budgets = Vec::new();
        for (li, shape) in shapes.iter().enumerate() {
            let k = ks[li];
            let nnz = shape.neurons() * k;
            budgets.push(nnz);
            let m = if structured || k == shape.fan_in() {
                Mask::random_constant_fan_in(&shape.dims, k, &mut rng)
            } else {
                Mask::random_per_layer(&shape.dims, nnz, &mut rng)
            };
            masks.push(m);
        }

        // Parameter init (sparse weights scaled by sparse fan-in — Evci
        // et al. 2022; see Tensor::he_sparse).
        let mut params = Vec::new();
        let mut momenta = Vec::new();
        let mut mask_cursor = 0usize;
        for (i, p) in entry.params.iter().enumerate() {
            let t = match p.init.as_str() {
                "zeros" => Tensor::zeros(&p.shape),
                "ones" => Tensor::ones(&p.shape),
                "he" => {
                    let fan = if p.sparse { ks[mask_cursor] } else { p.fan_in };
                    Tensor::he_sparse(&p.shape, fan, &mut rng)
                }
                s if s.starts_with("normal:") => {
                    let sigma: f64 = s["normal:".len()..].parse().unwrap_or(0.02);
                    Tensor::normal(&p.shape, sigma, &mut rng)
                }
                other => anyhow::bail!("unknown init {other:?}"),
            };
            let mut t = t;
            if p.sparse {
                t.mul_assign(&masks[mask_cursor].t);
                mask_cursor += 1;
            }
            momenta.push(Tensor::zeros(&p.shape));
            params.push(t);
            let _ = i;
        }

        let dataset = data::for_model(&entry, cfg.seed ^ 0xda7a);
        let schedule = UpdateSchedule {
            delta_t: cfg.delta_t,
            alpha: cfg.alpha,
            t_end_frac: 0.75,
            total_steps: cfg.total_steps,
        };
        let itop = ItopTracker::new(&masks);

        Ok(Trainer {
            entry,
            cfg,
            train_step,
            dense_grad,
            eval_logits,
            loss_eval,
            params,
            momenta,
            masks,
            ks,
            budgets,
            sparse_idx,
            dataset,
            schedule,
            rng,
            itop,
            mask_lits: RefCell::new(None),
        })
    }

    fn x_lit(&self, b: &Batch) -> Result<xla::Literal> {
        match &b.x {
            XData::F32(v) => runtime::f32s_to_lit(&self.entry.x.shape, v),
            XData::I32(v) => i32s_to_lit(&self.entry.x.shape, v),
        }
    }

    fn y_lit(&self, b: &Batch) -> Result<xla::Literal> {
        i32s_to_lit(&self.entry.y.shape, &b.y)
    }

    /// Fresh literals for params (and optionally momenta) — these change
    /// every step so they are always re-marshalled.
    fn state_lits(&self, with_momenta: bool) -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::new();
        for p in &self.params {
            lits.push(tensor_to_lit(p)?);
        }
        if with_momenta {
            for v in &self.momenta {
                lits.push(tensor_to_lit(v)?);
            }
        }
        Ok(lits)
    }

    /// Cached mask literals, rebuilt only after topology updates.
    fn mask_lits(&self) -> Result<Rc<Vec<xla::Literal>>> {
        if self.mask_lits.borrow().is_none() {
            let mls: Vec<xla::Literal> = self
                .masks
                .iter()
                .map(|m| tensor_to_lit(&m.t))
                .collect::<Result<_>>()?;
            *self.mask_lits.borrow_mut() = Some(Rc::new(mls));
        }
        Ok(self.mask_lits.borrow().as_ref().unwrap().clone())
    }

    fn invalidate_mask_cache(&self) {
        *self.mask_lits.borrow_mut() = None;
    }

    /// One optimizer step; returns the loss.
    pub fn step(&mut self, step_idx: usize) -> Result<f32> {
        let batch = self.dataset.sample(&mut self.rng);
        let mut fresh = self.state_lits(true)?;
        fresh.push(self.x_lit(&batch)?);
        fresh.push(self.y_lit(&batch)?);
        fresh.push(scalar_f32(self.cfg.lr.at(step_idx, self.cfg.total_steps)));
        let masks = self.mask_lits()?;
        let n_state = self.params.len() * 2;
        let inputs: Vec<&xla::Literal> = fresh[..n_state]
            .iter()
            .chain(masks.iter())
            .chain(fresh[n_state..].iter())
            .collect();
        let out = self.train_step.run(&inputs)?;
        let n = self.params.len();
        for i in 0..n {
            self.params[i] = lit_to_tensor(&out[i], &self.entry.params[i].shape)?;
            self.momenta[i] = lit_to_tensor(&out[n + i], &self.entry.params[i].shape)?;
        }
        lit_to_f32(&out[2 * n])
    }

    /// Dense gradients dL/d(w.*m) for all sparse params, averaged over
    /// `grad_accum` fresh batches.
    pub fn dense_grads(&mut self) -> Result<Vec<Tensor>> {
        let ns = self.sparse_idx.len();
        let mut acc: Vec<Tensor> = self
            .sparse_idx
            .iter()
            .map(|&i| Tensor::zeros(&self.entry.params[i].shape))
            .collect();
        let reps = self.cfg.grad_accum.max(1);
        for _ in 0..reps {
            let batch = self.dataset.sample(&mut self.rng);
            let mut fresh = self.state_lits(false)?;
            fresh.push(self.x_lit(&batch)?);
            fresh.push(self.y_lit(&batch)?);
            let masks = self.mask_lits()?;
            let n_state = self.params.len();
            let inputs: Vec<&xla::Literal> = fresh[..n_state]
                .iter()
                .chain(masks.iter())
                .chain(fresh[n_state..].iter())
                .collect();
            let out = self.dense_grad.run(&inputs)?;
            for j in 0..ns {
                let g = lit_to_tensor(&out[j], &acc[j].shape)?;
                acc[j].add_scaled(&g, 1.0 / reps as f32);
            }
        }
        Ok(acc)
    }

    /// One topology update across all sparse layers.
    pub fn update_topology(&mut self, step_idx: usize) -> Result<UpdateLog> {
        let frac = self.schedule.drop_fraction(step_idx);
        let grads = self.dense_grads()?;
        let updater = self.cfg.method.updater();
        let mut per_layer = Vec::new();
        for (li, &pi) in self.sparse_idx.iter().enumerate() {
            // dense_first_layer: layer 0 stays dense-static.
            if self.cfg.dense_first_layer && li == 0 {
                per_layer.push(UpdateStats {
                    active_neurons: self.masks[li].active_neurons(),
                    k: self.ks[li],
                    ..Default::default()
                });
                continue;
            }
            let mut view = LayerView {
                w: &mut self.params[pi],
                v: &mut self.momenta[pi],
                mask: &mut self.masks[li],
                grad: &grads[li],
                k: &mut self.ks[li],
                budget: self.budgets[li],
            };
            per_layer.push(updater.update(&mut view, frac, &mut self.rng));
        }
        self.itop.ingest(&self.masks);
        self.invalidate_mask_cache();
        Ok(UpdateLog { step: step_idx, drop_fraction: frac, per_layer })
    }

    /// Evaluate: classification accuracy or LM loss over fresh batches.
    pub fn evaluate(&mut self) -> Result<(f64, &'static str)> {
        let n_batches = self.cfg.eval_batches.max(1);
        // decorrelated eval stream
        let mut eval_rng = Rng::new(self.cfg.seed ^ 0xe7a1);
        let masks = self.mask_lits()?;
        let n_state = self.params.len();
        if self.entry.task == "lm" {
            let mut total = 0f64;
            for _ in 0..n_batches {
                let batch = self.dataset.sample(&mut eval_rng);
                let mut fresh = self.state_lits(false)?;
                fresh.push(self.x_lit(&batch)?);
                fresh.push(self.y_lit(&batch)?);
                let inputs: Vec<&xla::Literal> = fresh[..n_state]
                    .iter()
                    .chain(masks.iter())
                    .chain(fresh[n_state..].iter())
                    .collect();
                let out = self.loss_eval.run(&inputs)?;
                total += lit_to_f32(&out[0])? as f64;
            }
            Ok((total / n_batches as f64, "loss"))
        } else {
            let classes = self.entry.num_classes;
            let b = self.entry.batch;
            let mut correct = 0usize;
            let mut seen = 0usize;
            for _ in 0..n_batches {
                let batch = self.dataset.sample(&mut eval_rng);
                let mut fresh = self.state_lits(false)?;
                fresh.push(self.x_lit(&batch)?);
                let inputs: Vec<&xla::Literal> = fresh[..n_state]
                    .iter()
                    .chain(masks.iter())
                    .chain(fresh[n_state..].iter())
                    .collect();
                let out = self.eval_logits.run(&inputs)?;
                let logits = out[0].to_vec::<f32>()?;
                for i in 0..b {
                    let row = &logits[i * classes..(i + 1) * classes];
                    let pred = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .unwrap()
                        .0;
                    if pred == batch.y[i] as usize {
                        correct += 1;
                    }
                    seen += 1;
                }
            }
            Ok((correct as f64 / seen as f64, "accuracy"))
        }
    }

    /// Achieved sparsity over the sparse params right now.
    pub fn current_sparsity(&self) -> f64 {
        let total: usize = self.sparse_idx.iter().map(|&i| self.entry.params[i].numel()).sum();
        let nnz: usize = self.masks.iter().map(|m| m.nnz()).sum();
        if total == 0 {
            0.0
        } else {
            1.0 - nnz as f64 / total as f64
        }
    }

    /// Whether `step` is a scheduled topology-update step (and the method
    /// has a topology to update) — exposed so external step-loops
    /// (`srigl train --serve` streaming snapshots into a live front-end)
    /// can mirror [`Trainer::run`]'s update cadence exactly.
    pub fn is_update_step(&self, step: usize) -> bool {
        self.cfg.method != Method::Dense && self.schedule.is_update_step(step)
    }

    /// Full run: steps + scheduled topology updates + final eval.
    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let mut losses = Vec::with_capacity(self.cfg.total_steps);
        let mut updates = Vec::new();
        for step in 0..self.cfg.total_steps {
            losses.push(self.step(step)?);
            if self.cfg.method != Method::Dense && self.schedule.is_update_step(step) {
                updates.push(self.update_topology(step)?);
            }
        }
        let (eval_metric, eval_kind) = self.evaluate()?;
        let wall_s = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            config_label: format!(
                "{}/{}/s{:.0}%",
                self.entry.name,
                self.cfg.method.label(),
                self.cfg.sparsity * 100.0
            ),
            losses,
            eval_metric,
            eval_kind,
            updates,
            final_sparsity: self.current_sparsity(),
            itop_rate: self.itop.rate(),
            wall_s,
            throughput: self.cfg.total_steps as f64 / wall_s.max(1e-9),
        })
    }

    /// Export one trained sparse layer in the condensed representation.
    /// Fails with the typed [`crate::sparsity::CondensedError`] (through
    /// `anyhow`) when the layer's mask does not have constant fan-in —
    /// i.e. when a non-structured method trained it.
    pub fn export_condensed(&self, layer: usize) -> Result<Condensed> {
        let pi = self.sparse_idx[layer];
        // flatten to (n, fan_in) view
        let p = &self.params[pi];
        let (n, f) = p.neuron_view();
        let w2 = Tensor::from_vec(&[n, f], p.data.clone());
        let m2 = Mask::from_tensor(Tensor::from_vec(&[n, f], self.masks[layer].t.data.clone()));
        Ok(Condensed::from_masked(&w2, &m2)?)
    }

    /// Export the trained sparse stack as a serving
    /// [`SparseModel`](crate::inference::SparseModel) in the requested
    /// representation (MLP-shaped models: each sparse layer's fan-in must
    /// equal the previous layer's width). Bias params are matched by the
    /// manifest naming convention `X.w` -> `X.b`; layers without one get
    /// zero bias.
    pub fn export_model(
        &self,
        repr: crate::inference::Repr,
    ) -> Result<crate::inference::SparseModel> {
        let mut triples = Vec::new();
        for (li, &pi) in self.sparse_idx.iter().enumerate() {
            let p = &self.params[pi];
            let (n, f) = p.neuron_view();
            let w = Tensor::from_vec(&[n, f], p.data.clone());
            let m = Mask::from_tensor(Tensor::from_vec(&[n, f], self.masks[li].t.data.clone()));
            let wname = &self.entry.params[pi].name;
            let bias = match wname.strip_suffix(".w").and_then(|stem| {
                let bname = format!("{stem}.b");
                self.entry.params.iter().position(|q| q.name == bname)
            }) {
                Some(bi) => {
                    let b = &self.params[bi].data;
                    anyhow::ensure!(
                        b.len() == n,
                        "bias {} has {} entries but {} has {} neurons",
                        self.entry.params[bi].name,
                        b.len(),
                        wname,
                        n
                    );
                    b.clone()
                }
                None => vec![0.0; n],
            };
            triples.push((w, m, bias));
        }
        crate::inference::SparseModel::from_trained(&triples, repr)
    }

    /// Mask statistics snapshot, per sparse layer: (name, fan-in counts).
    pub fn mask_stats(&self) -> Vec<(String, Vec<usize>)> {
        self.sparse_idx
            .iter()
            .zip(&self.masks)
            .map(|(&pi, m)| (self.entry.params[pi].name.clone(), m.fan_in_counts()))
            .collect()
    }

    pub fn itop_rate(&self) -> f64 {
        self.itop.rate()
    }

    /// Snapshot the full training state for [`Checkpoint::save`].
    pub fn checkpoint(&self, step: usize) -> Checkpoint {
        Checkpoint {
            model: self.entry.name.clone(),
            step,
            params: self.params.clone(),
            momenta: self.momenta.clone(),
            masks: self.masks.clone(),
            ks: self.ks.clone(),
        }
    }

    /// Restore state from a checkpoint (shapes must match the model).
    pub fn restore(&mut self, ck: Checkpoint) -> Result<()> {
        anyhow::ensure!(ck.model == self.entry.name, "checkpoint is for {}", ck.model);
        anyhow::ensure!(ck.params.len() == self.params.len(), "param count mismatch");
        anyhow::ensure!(ck.masks.len() == self.masks.len(), "mask count mismatch");
        for (cur, new) in self.params.iter().zip(&ck.params) {
            anyhow::ensure!(cur.shape == new.shape, "param shape mismatch");
        }
        self.params = ck.params;
        self.momenta = ck.momenta;
        self.masks = ck.masks;
        self.ks = ck.ks;
        self.invalidate_mask_cache();
        Ok(())
    }
}

/// Convenience: build runtime+manifest once and train one config.
pub fn train_once(cfg: TrainConfig) -> Result<TrainReport> {
    let man = Manifest::load_default().context("loading manifest")?;
    let rt = Runtime::cpu()?;
    let mut t = Trainer::new(&rt, &man, cfg)?;
    t.run()
}
