//! Hand-rolled plaintext `GET /metrics` endpoint (zero dependencies).
//!
//! One dedicated listener thread serves HTTP/1.1 requests serially:
//! scrapes are rare (seconds apart), tiny (one rendered string), and must
//! never compete with the serving data path for threads or locks — the
//! responder only takes the registry mutex long enough to snapshot.
//! Anything that is not `GET /metrics` gets a 404; malformed or stalled
//! peers are bounded by a read timeout and an 8 KiB header cap.
//!
//! [`scrape`] is the matching minimal client, used by wire-mode arena
//! replay (persisting live snapshots into `BENCH_*.json`) and the socket
//! tests.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::Registry;

/// Maximum request-head bytes read before answering; a scraper's GET line
/// plus headers fits in a fraction of this.
const MAX_HEAD_BYTES: usize = 8 * 1024;

/// Running metrics endpoint. Stop it explicitly with
/// [`MetricsServer::stop`] or let Drop do the same.
pub struct MetricsServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

/// Bind `addr` (e.g. `"127.0.0.1:0"`) and serve `registry.render()` at
/// `/metrics` until stopped.
pub fn serve(addr: &str, registry: Arc<Registry>) -> Result<MetricsServer> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding metrics endpoint {addr}"))?;
    let bound = listener.local_addr().context("resolving metrics address")?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&shutdown);
    let join = std::thread::Builder::new()
        .name("srigl-metrics".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if flag.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(mut stream) = stream else { continue };
                let _ = respond(&mut stream, &registry);
            }
        })
        .context("spawning metrics thread")?;
    Ok(MetricsServer { addr: bound, shutdown, join: Some(join) })
}

fn respond(stream: &mut TcpStream, registry: &Registry) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && head.len() < MAX_HEAD_BYTES {
        let n = stream.read(&mut buf)?;
        if n == 0 {
            break;
        }
        head.extend_from_slice(&buf[..n]);
    }
    let line = String::from_utf8_lossy(&head);
    let line = line.lines().next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let (status, ctype, body) = if method == "GET" && path == "/metrics" {
        ("200 OK", "text/plain; version=0.0.4; charset=utf-8", registry.render())
    } else {
        ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string())
    };
    let header = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

impl MetricsServer {
    /// The bound address (resolves port 0 to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener thread and join it. Idempotent.
    pub fn stop(&mut self) {
        let Some(join) = self.join.take() else { return };
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        let _ = join.join();
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Minimal scrape client: `GET /metrics`, return the body. Fails on any
/// non-200 status.
pub fn scrape(addr: SocketAddr) -> Result<String> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to metrics at {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream
        .write_all(b"GET /metrics HTTP/1.1\r\nHost: srigl\r\nConnection: close\r\n\r\n")
        .context("sending scrape request")?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("reading scrape response")?;
    let text = String::from_utf8_lossy(&raw);
    let Some((head, body)) = text.split_once("\r\n\r\n") else {
        bail!("malformed scrape response (no header terminator)");
    };
    let status = head.lines().next().unwrap_or("");
    if !status.contains(" 200 ") {
        bail!("scrape failed: {status}");
    }
    Ok(body.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_serves_render_and_404s_elsewhere() {
        let registry = Arc::new(Registry::new());
        let c = registry.counter("srigl_test_total", "Test counter.");
        c.add(9);
        let mut srv = serve("127.0.0.1:0", Arc::clone(&registry)).unwrap();

        // live values, scraped twice (values move between scrapes)
        let body = scrape(srv.addr()).unwrap();
        assert!(body.contains("srigl_test_total 9"), "{body}");
        c.add(1);
        let body = scrape(srv.addr()).unwrap();
        assert!(body.contains("srigl_test_total 10"), "{body}");

        // non-/metrics path → 404
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.write_all(b"GET /other HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
        let mut resp = Vec::new();
        s.read_to_end(&mut resp).unwrap();
        assert!(String::from_utf8_lossy(&resp).starts_with("HTTP/1.1 404"), "{resp:?}");

        srv.stop();
        srv.stop(); // idempotent
        assert!(scrape(srv.addr()).is_err(), "listener gone after stop");
    }
}
