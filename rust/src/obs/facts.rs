//! Startup engine facts exported as labeled gauges: the process-wide
//! microkernel selection, model storage footprint, and — per layer — the
//! representation, shape, stored weights, and a quick measured GFLOP/s
//! estimate at the serving batch cap. Same accounting as the
//! `serve-model` startup banner (2 FLOPs per stored weight per example;
//! ablated neurons store nothing, so compact forms are credited only for
//! work they actually do): a few milliseconds per layer at registration
//! buys a scrape that shows *which* kernel a deployment is actually
//! running and how fast each layer moves.

use std::time::Duration;

use super::Registry;
use crate::bench::bench;
use crate::inference::SparseModel;

/// Register the model/kernel fact gauges on `registry`. Called once at
/// spawn when a metrics endpoint is enabled (the GFLOP/s probe costs a
/// few ms per layer, which metric-less test spawns must not pay).
pub fn register_model_facts(registry: &Registry, model: &SparseModel, batch: usize, threads: usize) {
    registry.const_gauge(
        "srigl_kernel_info",
        "Process-wide microkernel selection; the value is always 1 (facts ride the labels).",
        &[("selection", &crate::kernels::describe_selection())],
        1.0,
    );
    registry.const_gauge(
        "srigl_engine_storage_bytes",
        "Bytes the model's layer representations occupy (weights + indices + biases).",
        &[],
        model.storage_bytes() as f64,
    );
    let batch = batch.max(1);
    for (i, layer) in model.layers().iter().enumerate() {
        let k = layer.kernel();
        let stored: usize = layer.row_weights().iter().sum();
        let flops = 2.0 * stored as f64 * batch as f64;
        let x = vec![0.1f32; batch * k.in_width()];
        let mut out = vec![0f32; batch * k.out_width()];
        let m = bench("layer", 3, Duration::from_millis(2), || {
            k.forward(&x, batch, &mut out, threads);
        });
        let layer_label = i.to_string();
        let labels: &[(&str, &str)] = &[("layer", &layer_label), ("repr", k.name())];
        registry.const_gauge(
            "srigl_layer_stored_weights",
            "Stored weights per layer (ablated neurons store nothing in compact forms).",
            labels,
            stored as f64,
        );
        registry.const_gauge(
            "srigl_layer_est_gflops",
            "Measured GFLOP/s per layer at the serving batch cap (quick startup probe).",
            labels,
            flops / m.median_s().max(1e-12) / 1e9,
        );
        registry.const_gauge(
            "srigl_layer_out_width",
            "Output width per layer (active neurons for compact representations).",
            labels,
            k.out_width() as f64,
        );
        registry.const_gauge(
            "srigl_layer_storage_bytes",
            "Bytes this layer's representation occupies — representation-aware (int8 \
             quantized layers store 4-byte records where f32 condensed stores 8), not \
             an assumed 4 bytes per weight.",
            labels,
            k.storage_bytes() as f64,
        );
    }
}

/// The fact families [`register_model_facts`] owns — retracted wholesale
/// on republication so a scrape never mixes layers of two epochs.
const FACT_FAMILIES: [&str; 6] = [
    "srigl_kernel_info",
    "srigl_engine_storage_bytes",
    "srigl_layer_stored_weights",
    "srigl_layer_est_gflops",
    "srigl_layer_out_width",
    "srigl_layer_storage_bytes",
];

/// Replace the fact gauges with ones describing `model` — called after a
/// live model swap so `stored_weights`/`est_gflops` never describe a dead
/// epoch. Retract-then-register is atomic enough for scrapes: the
/// registry mutex serializes each retraction against `render`, and the
/// brief window where a family is absent only under-reports (it can never
/// show stale values as current).
pub fn republish_model_facts(
    registry: &Registry,
    model: &SparseModel,
    batch: usize,
    threads: usize,
) {
    for family in FACT_FAMILIES {
        registry.retract_family(family);
    }
    register_model_facts(registry, model, batch, threads);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::timings::ablated_frac_for;
    use crate::inference::{Activation, LayerSpec, Repr};

    #[test]
    fn model_facts_register_per_layer_gauges() {
        let spec = |n, act| LayerSpec {
            n,
            repr: Repr::Condensed,
            sparsity: 0.9,
            ablated_frac: ablated_frac_for(0.9),
            activation: act,
        };
        let model = SparseModel::synth(
            32,
            &[spec(24, Activation::Relu), spec(8, Activation::Identity)],
            3,
        )
        .unwrap();
        let r = Registry::new();
        register_model_facts(&r, &model, 4, 1);
        let text = r.render();
        assert!(text.contains("srigl_kernel_info{selection=\"kernel="), "{text}");
        assert!(text.contains("srigl_engine_storage_bytes "), "{text}");
        for layer in ["0", "1"] {
            let needle = format!("srigl_layer_stored_weights{{layer=\"{layer}\",repr=\"condensed\"}}");
            assert!(text.contains(&needle), "missing {needle} in:\n{text}");
        }
        // GFLOP/s is measured, so only its presence and positivity are
        // asserted
        let j = crate::obs::parse_exposition(&text);
        let g = j
            .get("srigl_layer_est_gflops{layer=\"0\",repr=\"condensed\"}")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(g > 0.0, "gflops must be positive, got {g}");
        // per-layer storage is representation-aware: the int8 twin of the
        // same stack must report strictly fewer bytes per layer
        let f32_bytes = j
            .get("srigl_layer_storage_bytes{layer=\"0\",repr=\"condensed\"}")
            .unwrap()
            .as_f64()
            .unwrap();
        let quant = model.quantized(false).unwrap();
        let rq = Registry::new();
        register_model_facts(&rq, &quant, 4, 1);
        let jq = crate::obs::parse_exposition(&rq.render());
        let int8_bytes = jq
            .get("srigl_layer_storage_bytes{layer=\"0\",repr=\"quantized\"}")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(
            int8_bytes < f32_bytes,
            "int8 layer must report fewer bytes: {int8_bytes} vs {f32_bytes}"
        );
    }

    #[test]
    fn republish_replaces_stale_layer_facts() {
        let spec = |n, act| LayerSpec {
            n,
            repr: Repr::Condensed,
            sparsity: 0.9,
            ablated_frac: 0.0,
            activation: act,
        };
        let three = SparseModel::synth(
            32,
            &[spec(24, Activation::Relu), spec(16, Activation::Relu), spec(8, Activation::Identity)],
            3,
        )
        .unwrap();
        let two = SparseModel::synth(
            32,
            &[spec(24, Activation::Relu), spec(8, Activation::Identity)],
            5,
        )
        .unwrap();
        let r = Registry::new();
        register_model_facts(&r, &three, 4, 1);
        assert!(r.render().contains("layer=\"2\""), "three-layer epoch shows layer 2");
        republish_model_facts(&r, &two, 4, 1);
        let text = r.render();
        assert!(!text.contains("layer=\"2\""), "dead epoch's layer 2 must vanish:\n{text}");
        assert!(text.contains("layer=\"1\""), "{text}");
        let j = crate::obs::parse_exposition(&text);
        let bytes = j.get("srigl_engine_storage_bytes").unwrap().as_f64().unwrap();
        assert_eq!(bytes, two.storage_bytes() as f64, "storage describes the live epoch");
    }
}
