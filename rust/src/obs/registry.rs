//! Metric registry + Prometheus text exposition renderer.
//!
//! Registration hands back `Arc` handles ([`super::Counter`] /
//! [`super::Gauge`] / [`super::Histogram`]) that the hot path bumps with
//! relaxed atomics; the registry's mutex is touched only at registration
//! and scrape time, never per request. Registering the same histogram
//! family name + label set more than once is the intended idiom for
//! per-worker instances: each worker records into its own allocation and
//! the renderer merges the snapshots into one series at scrape.
//!
//! Output is the Prometheus text format (version 0.0.4): `# HELP` /
//! `# TYPE` once per family, series in registration order, `le` buckets
//! cumulative with a closing `+Inf`. Ordering is deterministic so the
//! golden test below can assert the exact bytes.

use std::fmt::Write as _;
use std::sync::{Arc, Mutex};

use super::{Counter, Gauge, Histogram, HistogramSnapshot, BUCKET_BOUNDS_US};
use crate::util::json::{num, Json};

enum Value {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    /// Fixed at registration (per-layer facts, kernel info).
    Const(f64),
    Histogram(Arc<Histogram>),
}

struct Metric {
    name: String,
    help: String,
    labels: Vec<(String, String)>,
    value: Value,
}

/// See the module docs. Cheap to share (`Arc<Registry>`); all methods
/// take `&self`.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Vec<Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn push(&self, name: &str, help: &str, labels: &[(&str, &str)], value: Value) {
        self.inner.lock().unwrap().push(Metric {
            name: name.to_string(),
            help: help.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            value,
        });
    }

    /// Register an unlabeled counter and return its live handle.
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        self.counter_with(name, help, &[])
    }

    pub fn counter_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let c = Arc::new(Counter::new());
        self.push(name, help, labels, Value::Counter(Arc::clone(&c)));
        c
    }

    /// Register an unlabeled gauge and return its live handle.
    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        self.gauge_with(name, help, &[])
    }

    pub fn gauge_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let g = Arc::new(Gauge::new());
        self.push(name, help, labels, Value::Gauge(Arc::clone(&g)));
        g
    }

    /// Register a gauge whose value is fixed at registration time
    /// (startup facts: stored weights, measured GFLOP/s, kernel info).
    pub fn const_gauge(&self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        self.push(name, help, labels, Value::Const(value));
    }

    /// Register a histogram instance. Same name + labels may be
    /// registered many times (one per worker); scrapes merge them.
    pub fn histogram_with(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> Arc<Histogram> {
        let h = Arc::new(Histogram::new());
        self.push(name, help, labels, Value::Histogram(Arc::clone(&h)));
        h
    }

    /// Remove every metric registered under `name` (all label sets).
    /// Live handles returned at registration keep working — they just no
    /// longer render. Used to refresh per-epoch engine facts on a live
    /// model swap: retract the family, then re-register it from the new
    /// stack (`docs/RELOAD.md`).
    pub fn retract_family(&self, name: &str) {
        self.inner.lock().unwrap().retain(|m| m.name != name);
    }

    /// Remove the metrics matching `name` + exact `labels`. Used for
    /// per-connection series (e.g. egress-depth gauges) that must leave
    /// the scrape when their connection closes, or the registry would
    /// grow without bound under connection churn.
    pub fn retract(&self, name: &str, labels: &[(&str, &str)]) {
        self.inner.lock().unwrap().retain(|m| {
            m.name != name
                || m.labels.len() != labels.len()
                || !m
                    .labels
                    .iter()
                    .zip(labels)
                    .all(|((k, v), (lk, lv))| k == lk && v == lv)
        });
    }

    /// Render the full exposition text. Families appear in first-
    /// registration order; histogram instances sharing name + labels are
    /// merged into one series.
    pub fn render(&self) -> String {
        enum Snap {
            Scalar(f64),
            Hist(HistogramSnapshot),
        }
        struct Series {
            name: String,
            labels: Vec<(String, String)>,
            snap: Snap,
        }

        let metrics = self.inner.lock().unwrap();
        // Snapshot pass: merge same-(name, labels) histogram instances,
        // preserving first-occurrence order for everything.
        let mut series: Vec<Series> = Vec::with_capacity(metrics.len());
        let mut families: Vec<(String, String, &'static str)> = Vec::new(); // (name, help, type)
        for m in metrics.iter() {
            let ty = match m.value {
                Value::Counter(_) => "counter",
                Value::Gauge(_) | Value::Const(_) => "gauge",
                Value::Histogram(_) => "histogram",
            };
            if !families.iter().any(|(n, _, _)| *n == m.name) {
                families.push((m.name.clone(), m.help.clone(), ty));
            }
            match &m.value {
                Value::Counter(c) => series.push(Series {
                    name: m.name.clone(),
                    labels: m.labels.clone(),
                    snap: Snap::Scalar(c.get() as f64),
                }),
                Value::Gauge(g) => series.push(Series {
                    name: m.name.clone(),
                    labels: m.labels.clone(),
                    snap: Snap::Scalar(g.get() as f64),
                }),
                Value::Const(v) => series.push(Series {
                    name: m.name.clone(),
                    labels: m.labels.clone(),
                    snap: Snap::Scalar(*v),
                }),
                Value::Histogram(h) => {
                    let snap = h.snapshot();
                    match series.iter_mut().find(|s| {
                        s.name == m.name
                            && s.labels == m.labels
                            && matches!(s.snap, Snap::Hist(_))
                    }) {
                        Some(Series { snap: Snap::Hist(acc), .. }) => acc.merge(&snap),
                        _ => series.push(Series {
                            name: m.name.clone(),
                            labels: m.labels.clone(),
                            snap: Snap::Hist(snap),
                        }),
                    }
                }
            }
        }
        drop(metrics);

        let mut out = String::new();
        for (name, help, ty) in &families {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {ty}");
            for s in series.iter().filter(|s| s.name == *name) {
                match &s.snap {
                    Snap::Scalar(v) => {
                        let _ =
                            writeln!(out, "{name}{} {}", labels_text(&s.labels, &[]), fmt_num(*v));
                    }
                    Snap::Hist(h) => {
                        let mut cum = 0u64;
                        for (i, &c) in h.counts.iter().enumerate() {
                            cum += c;
                            let le = if i < BUCKET_BOUNDS_US.len() {
                                fmt_num(BUCKET_BOUNDS_US[i])
                            } else {
                                "+Inf".to_string()
                            };
                            let _ = writeln!(
                                out,
                                "{name}_bucket{} {cum}",
                                labels_text(&s.labels, &[("le", &le)])
                            );
                        }
                        let _ = writeln!(
                            out,
                            "{name}_sum{} {}",
                            labels_text(&s.labels, &[]),
                            fmt_num(h.sum_us)
                        );
                        let _ =
                            writeln!(out, "{name}_count{} {cum}", labels_text(&s.labels, &[]));
                    }
                }
            }
        }
        out
    }
}

/// `{k="v",...}` (empty string when there are no labels), with `extra`
/// pairs appended — used for the histogram `le` label.
fn labels_text(labels: &[(String, String)], extra: &[(&str, &str)]) -> String {
    if labels.is_empty() && extra.is_empty() {
        return String::new();
    }
    let mut s = String::from("{");
    let mut first = true;
    for (k, v) in labels
        .iter()
        .map(|(k, v)| (k.as_str(), v.as_str()))
        .chain(extra.iter().copied())
    {
        if !first {
            s.push(',');
        }
        first = false;
        let _ = write!(s, "{k}=\"{}\"", escape_label(v));
    }
    s.push('}');
    s
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Integral values print without a fraction (`le="500"`, `served 12`),
/// everything else via f64 Display.
fn fmt_num(v: f64) -> String {
    if v.is_finite() && v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Parse exposition text back into a flat JSON object mapping each series
/// line (`name{labels}` exactly as rendered) to its numeric value —
/// what wire-mode arena rounds persist into `BENCH_*.json` so trajectory
/// records and live scrapes share one namespace. Comment and malformed
/// lines are skipped.
pub fn parse_exposition(text: &str) -> Json {
    let mut m = std::collections::BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((key, val)) = line.rsplit_once(' ') else { continue };
        let Ok(v) = val.parse::<f64>() else { continue };
        m.insert(key.to_string(), num(v));
    }
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_exposition_format() {
        // the exact bytes are the contract: metric names, label order,
        // cumulative le buckets, +Inf, _sum/_count — stable across runs
        let r = Registry::new();
        let c = r.counter("srigl_requests_served_total", "Requests answered by the pool.");
        c.add(3);
        let g = r.gauge_with(
            "srigl_connections_active",
            "Live connections.",
            &[("proto", "tcp")],
        );
        g.set(2);
        r.const_gauge("srigl_layer_stored_weights", "Stored weights.", &[("layer", "0")], 128.0);
        let h = r.histogram_with(
            "srigl_stage_latency_us",
            "Per-stage latency.",
            &[("stage", "forward")],
        );
        h.record_us(1.5); // le=2
        h.record_us(40.0); // le=50
        h.record_us(40.0); // le=50

        let text = r.render();
        let expected = "\
# HELP srigl_requests_served_total Requests answered by the pool.
# TYPE srigl_requests_served_total counter
srigl_requests_served_total 3
# HELP srigl_connections_active Live connections.
# TYPE srigl_connections_active gauge
srigl_connections_active{proto=\"tcp\"} 2
# HELP srigl_layer_stored_weights Stored weights.
# TYPE srigl_layer_stored_weights gauge
srigl_layer_stored_weights{layer=\"0\"} 128
# HELP srigl_stage_latency_us Per-stage latency.
# TYPE srigl_stage_latency_us histogram
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"1\"} 0
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"2\"} 1
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"5\"} 1
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"10\"} 1
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"20\"} 1
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"50\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"100\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"200\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"500\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"1000\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"2000\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"5000\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"10000\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"20000\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"50000\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"100000\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"200000\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"500000\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"1000000\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"2000000\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"5000000\"} 3
srigl_stage_latency_us_bucket{stage=\"forward\",le=\"+Inf\"} 3
srigl_stage_latency_us_sum{stage=\"forward\"} 81.5
srigl_stage_latency_us_count{stage=\"forward\"} 3
";
        assert_eq!(text, expected);
    }

    #[test]
    fn same_family_histograms_merge_per_label_set() {
        // per-worker idiom: two instances under one (name, labels) merge;
        // a different label set stays its own series under one family
        // header
        let r = Registry::new();
        let w0 = r.histogram_with("h_us", "h", &[("stage", "total")]);
        let w1 = r.histogram_with("h_us", "h", &[("stage", "total")]);
        let q = r.histogram_with("h_us", "h", &[("stage", "queue")]);
        w0.record_us(1.0);
        w1.record_us(1.0);
        q.record_us(3.0);
        let text = r.render();
        assert_eq!(text.matches("# TYPE h_us histogram").count(), 1);
        assert!(text.contains("h_us_count{stage=\"total\"} 2"), "merged: {text}");
        assert!(text.contains("h_us_count{stage=\"queue\"} 1"), "separate: {text}");
    }

    #[test]
    fn retract_family_and_labeled_series() {
        let r = Registry::new();
        let g0 = r.gauge_with("srigl_egress_depth", "d", &[("conn", "0")]);
        let g1 = r.gauge_with("srigl_egress_depth", "d", &[("conn", "1")]);
        r.const_gauge("srigl_layer_stored_weights", "w", &[("layer", "0")], 7.0);
        g0.set(3);
        g1.set(5);
        // exact-label retraction drops one series, keeps the sibling
        r.retract("srigl_egress_depth", &[("conn", "0")]);
        let text = r.render();
        assert!(!text.contains("conn=\"0\""), "{text}");
        assert!(text.contains("srigl_egress_depth{conn=\"1\"} 5"), "{text}");
        // the live handle of a retracted series keeps working (no panic)
        g0.set(9);
        // family retraction clears every label set; re-registration renders
        r.retract_family("srigl_layer_stored_weights");
        assert!(!r.render().contains("srigl_layer_stored_weights"), "family gone");
        r.const_gauge("srigl_layer_stored_weights", "w", &[("layer", "0")], 9.0);
        assert!(r.render().contains("srigl_layer_stored_weights{layer=\"0\"} 9"));
    }

    #[test]
    fn label_values_escape_quotes_and_backslashes() {
        let r = Registry::new();
        r.const_gauge("g", "g", &[("k", "a\"b\\c")], 1.0);
        assert!(r.render().contains("g{k=\"a\\\"b\\\\c\"} 1"));
    }

    #[test]
    fn parse_exposition_round_trips_series_lines() {
        let r = Registry::new();
        let c = r.counter("srigl_x_total", "x");
        c.add(7);
        r.const_gauge("srigl_y", "y", &[("layer", "1")], 2.5);
        let j = parse_exposition(&r.render());
        assert_eq!(j.get("srigl_x_total").unwrap().as_f64().unwrap(), 7.0);
        assert_eq!(j.get("srigl_y{layer=\"1\"}").unwrap().as_f64().unwrap(), 2.5);
    }
}
