//! Live observability: lock-light metric primitives, a registry with a
//! Prometheus-text renderer, and a hand-rolled `GET /metrics` endpoint.
//!
//! The serving stack ([`crate::inference::frontend`]) historically merged
//! all its counters at `stop()` — a live server under load was a black
//! box. This module closes that gap without adding dependencies or hot-
//! path locks:
//!
//! * [`Counter`] / [`Gauge`] — one relaxed `AtomicU64` each. The frontend
//!   holds `Arc` handles and bumps them inline; the scrape path reads the
//!   same atomics, so the endpoint and the end-of-run
//!   `FrontendStats` can never disagree.
//! * [`Histogram`] — fixed log-scale (1-2-5) microsecond buckets,
//!   allocation-free `record` (one array scan + two relaxed adds), with
//!   mergeable [`HistogramSnapshot`]s so per-worker instances aggregate at
//!   scrape time instead of contending at record time.
//! * [`Registry`] ([`registry`]) — owns metric metadata (name, help,
//!   labels) and renders the Prometheus text exposition format
//!   deterministically (registration order, `BTreeMap`-free hot path).
//! * [`MetricsServer`] ([`http`]) — a zero-dependency HTTP/1.1 responder
//!   on its own listener thread, wired into `frontend::spawn_engine` and
//!   `serve-model --metrics ADDR`; [`scrape`] is the matching client used
//!   by the arena so perf-trajectory records and production deployments
//!   share one metric namespace (docs/METRICS.md).
//! * [`facts`] — per-layer engine gauges (repr/kernel, stored weights,
//!   measured GFLOP/s) registered from the model at spawn.

pub mod facts;
pub mod http;
pub mod registry;

pub use http::{scrape, MetricsServer};
pub use registry::{parse_exposition, Registry};

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Monotonic counter. Relaxed ordering: metric reads need no
/// happens-before edge with the work they count.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Up/down gauge (e.g. live connections). `dec` saturates at zero so a
/// teardown race can never wrap to u64::MAX in a scrape.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
    }

    /// Raise the gauge to `v` if larger (a live running-max, e.g. the
    /// biggest packed forward seen).
    pub fn record_max(&self, v: u64) {
        let _ = self.0.fetch_max(v, Ordering::Relaxed);
    }

    /// Lower the gauge to `v` if smaller, treating 0 as "no data yet" (a
    /// live running-min over values that are never legitimately zero,
    /// e.g. packed forward rows, which are always >= 1).
    pub fn record_min_nonzero(&self, v: u64) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
            if cur == 0 || v < cur {
                Some(v)
            } else {
                None
            }
        });
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Upper bounds (inclusive, microseconds) of the finite histogram
/// buckets: a 1-2-5 log scale from 1us to 5s. One extra overflow bucket
/// catches everything above. ~21 buckets keep the record-path scan inside
/// one cache line pair while still resolving percentiles to better than
/// 2.5x anywhere in the range.
pub const BUCKET_BOUNDS_US: [f64; 21] = [
    1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1e3, 2e3, 5e3, 1e4, 2e4, 5e4, 1e5,
    2e5, 5e5, 1e6, 2e6, 5e6,
];

/// Total bucket count including the +Inf overflow bucket.
pub const BUCKETS: usize = BUCKET_BOUNDS_US.len() + 1;

/// Fixed-bucket latency histogram. `record_us` is allocation-free and
/// lock-free: a linear scan over [`BUCKET_BOUNDS_US`] plus two relaxed
/// atomic adds, cheap enough for the per-request serve path.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    /// Sum kept in integer nanoseconds so it can live in one AtomicU64
    /// (f64 sums would need a CAS loop); rendered back as microseconds.
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
        }
    }

    /// Record one observation in microseconds. Non-finite values are
    /// dropped (a NaN must not poison the sum); negatives clamp to 0.
    pub fn record_us(&self, us: f64) {
        if !us.is_finite() {
            return;
        }
        let us = us.max(0.0);
        let idx = BUCKET_BOUNDS_US
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(BUCKET_BOUNDS_US.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add((us * 1e3).round() as u64, Ordering::Relaxed);
    }

    /// Record one observed duration.
    pub fn record(&self, d: Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    /// Point-in-time copy; cheap (22 relaxed loads). Not atomic across
    /// buckets — a scrape racing a record may be off by the in-flight
    /// sample, which monotonicity tests must (and do) tolerate.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            counts: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            sum_us: self.sum_ns.load(Ordering::Relaxed) as f64 / 1e3,
        }
    }
}

/// Owned, mergeable histogram state — what the scrape path aggregates
/// across per-worker [`Histogram`] instances.
#[derive(Clone, Debug, PartialEq)]
pub struct HistogramSnapshot {
    /// Per-bucket (non-cumulative) counts; the last entry is the overflow
    /// bucket above the largest finite bound.
    pub counts: [u64; BUCKETS],
    /// Sum of all observations, microseconds.
    pub sum_us: f64,
}

impl Default for HistogramSnapshot {
    fn default() -> HistogramSnapshot {
        HistogramSnapshot { counts: [0; BUCKETS], sum_us: 0.0 }
    }
}

impl HistogramSnapshot {
    /// Total observation count.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Fold another snapshot in (per-worker aggregation at scrape).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum_us += other.sum_us;
    }

    /// Estimate the p-th percentile (0..=100) in microseconds by linear
    /// interpolation inside the winning bucket. Uses the same rank
    /// convention as `inference::server`'s exact percentile
    /// (`rank = p/100 * (n-1)`), so against the same samples the two
    /// agree to within one bucket's width. NaN when empty; observations
    /// in the overflow bucket report the largest finite bound.
    pub fn percentile(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
        let mut before = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if rank < (before + c) as f64 {
                let lo = if i == 0 { 0.0 } else { BUCKET_BOUNDS_US[i - 1] };
                let hi = BUCKET_BOUNDS_US[i.min(BUCKET_BOUNDS_US.len() - 1)];
                let frac = ((rank + 1.0 - before as f64) / c as f64).clamp(0.0, 1.0);
                return lo + (hi - lo) * frac;
            }
            before += c;
        }
        // rank <= n-1 < n guarantees the loop returned; unreachable with
        // a consistent snapshot, but a racing copy should not panic.
        BUCKET_BOUNDS_US[BUCKET_BOUNDS_US.len() - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);

        let g = Gauge::new();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.dec();
        g.dec(); // saturates, never wraps
        assert_eq!(g.get(), 0);
        g.set(42);
        assert_eq!(g.get(), 42);

        let mx = Gauge::new();
        mx.record_max(3);
        mx.record_max(1);
        assert_eq!(mx.get(), 3);
        let mn = Gauge::new();
        mn.record_min_nonzero(5); // 0 means "no data", so 5 replaces it
        mn.record_min_nonzero(7);
        mn.record_min_nonzero(2);
        assert_eq!(mn.get(), 2);
    }

    #[test]
    fn histogram_bucket_boundaries_are_le_inclusive() {
        let h = Histogram::new();
        // a value exactly on a bound lands IN that bound's bucket
        // (Prometheus `le` semantics), just past it in the next
        h.record_us(10.0);
        h.record_us(10.000001);
        let s = h.snapshot();
        let i10 = BUCKET_BOUNDS_US.iter().position(|&b| b == 10.0).unwrap();
        assert_eq!(s.counts[i10], 1, "10.0 belongs to le=10");
        assert_eq!(s.counts[i10 + 1], 1, "10.000001 belongs to le=20");
    }

    #[test]
    fn histogram_edges_zero_overflow_nan() {
        let h = Histogram::new();
        h.record_us(0.0); // first bucket
        h.record_us(-3.0); // clamps to first bucket
        h.record_us(9e99); // overflow bucket
        h.record_us(f64::NAN); // dropped
        h.record_us(f64::INFINITY); // dropped
        let s = h.snapshot();
        assert_eq!(s.counts[0], 2);
        assert_eq!(s.counts[BUCKETS - 1], 1);
        assert_eq!(s.count(), 3);
    }

    #[test]
    fn snapshot_merge_equals_recording_into_one() {
        // property: record a seeded stream split across two histograms;
        // merging their snapshots must equal recording it all into one
        let mut rng = Rng::new(977);
        let (a, b, all) = (Histogram::new(), Histogram::new(), Histogram::new());
        for i in 0..4000 {
            // span the full bucket range: ~1e-1 .. ~1e7 us
            let us = 10f64.powf(rng.uniform() * 8.0 - 1.0);
            if i % 2 == 0 { &a } else { &b }.record_us(us);
            all.record_us(us);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged, all.snapshot());
        assert_eq!(merged.count(), 4000);
    }

    #[test]
    fn percentile_tracks_exact_within_bucket_resolution() {
        // the acceptance bound for the serving integration: histogram
        // percentiles vs the exact sorted-sample percentile, within the
        // winning bucket's width
        let mut rng = Rng::new(31);
        let h = Histogram::new();
        let mut xs: Vec<f64> = Vec::new();
        for _ in 0..5000 {
            let us = 10f64.powf(rng.uniform() * 4.0); // 1us .. 10ms
            h.record_us(us);
            xs.push(us);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let s = h.snapshot();
        for p in [50.0, 95.0, 99.0] {
            let rank = p / 100.0 * (xs.len() - 1) as f64;
            let exact = xs[rank.floor() as usize]
                + (xs[rank.ceil() as usize] - xs[rank.floor() as usize]) * rank.fract();
            let est = s.percentile(p);
            // the bucket containing the exact value: [lo, hi]
            let i = BUCKET_BOUNDS_US.iter().position(|&b| exact <= b).unwrap();
            let lo = if i == 0 { 0.0 } else { BUCKET_BOUNDS_US[i - 1] };
            let hi = BUCKET_BOUNDS_US[i];
            assert!(
                est >= lo - 1e-9 && est <= hi + 1e-9,
                "p{p}: est {est} outside bucket [{lo}, {hi}] of exact {exact}"
            );
        }
    }

    #[test]
    fn percentile_empty_and_single() {
        assert!(HistogramSnapshot::default().percentile(50.0).is_nan());
        let h = Histogram::new();
        h.record_us(30.0);
        let p = h.snapshot().percentile(99.0);
        assert!((20.0..=50.0).contains(&p), "single sample stays in its bucket, got {p}");
    }
}
