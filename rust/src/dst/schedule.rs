//! Topology-update schedule: cosine-annealed drop fraction (Dettmers &
//! Zettlemoyer 2019), as used by RigL and SRigL (paper App. D.1):
//! alpha = 0.3, updates every ΔT steps, mask frozen after 75% of training.

#[derive(Clone, Copy, Debug)]
pub struct UpdateSchedule {
    /// Mini-batch steps between connectivity updates (ΔT; 100 for
    /// CIFAR-scale, 800 for the ImageNet runs in the paper).
    pub delta_t: usize,
    /// Initial drop fraction alpha (0.3 in the paper).
    pub alpha: f64,
    /// Fraction of training after which the mask is frozen (0.75).
    pub t_end_frac: f64,
    pub total_steps: usize,
}

impl UpdateSchedule {
    pub fn rigl_default(total_steps: usize, delta_t: usize) -> Self {
        UpdateSchedule { delta_t, alpha: 0.3, t_end_frac: 0.75, total_steps }
    }

    pub fn t_end(&self) -> usize {
        (self.t_end_frac * self.total_steps as f64).floor() as usize
    }

    /// Fraction of active weights to prune+regrow at `step` (cosine decay
    /// from alpha to 0 at t_end; 0 afterwards).
    pub fn drop_fraction(&self, step: usize) -> f64 {
        let t_end = self.t_end();
        if step >= t_end || t_end == 0 {
            return 0.0;
        }
        self.alpha / 2.0 * (1.0 + (std::f64::consts::PI * step as f64 / t_end as f64).cos())
    }

    /// True iff a connectivity update runs after this step.
    pub fn is_update_step(&self, step: usize) -> bool {
        step > 0 && step % self.delta_t == 0 && step < self.t_end()
    }

    /// Number of updates over the whole run (for progress reporting).
    pub fn num_updates(&self) -> usize {
        if self.delta_t == 0 {
            return 0;
        }
        (1..self.t_end()).filter(|s| s % self.delta_t == 0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_decays_to_zero() {
        let s = UpdateSchedule::rigl_default(1000, 100);
        assert!((s.drop_fraction(0) - 0.3).abs() < 1e-12);
        let mid = s.drop_fraction(375); // halfway to t_end=750
        assert!((mid - 0.15).abs() < 1e-9, "{mid}");
        assert_eq!(s.drop_fraction(750), 0.0);
        assert_eq!(s.drop_fraction(999), 0.0);
        // monotone non-increasing
        let mut prev = f64::INFINITY;
        for t in (0..750).step_by(10) {
            let f = s.drop_fraction(t);
            assert!(f <= prev + 1e-12);
            prev = f;
        }
    }

    #[test]
    fn update_steps_respect_freeze() {
        let s = UpdateSchedule::rigl_default(1000, 100);
        assert!(!s.is_update_step(0));
        assert!(s.is_update_step(100));
        assert!(s.is_update_step(700));
        assert!(!s.is_update_step(750));
        assert!(!s.is_update_step(800));
        assert!(!s.is_update_step(101));
        assert_eq!(s.num_updates(), 7);
    }
}
