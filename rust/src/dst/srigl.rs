//! SRigL (paper Section 3.1): RigL with a constant fan-in constraint and
//! dynamic neuron ablation.
//!
//! Per layer, each update performs the paper's steps 1–7:
//!  1. saliency criteria: |w| of active weights, |g| of pruned weights;
//!  2. K = round(drop_fraction * active) weights to prune & regrow;
//!  3. per-neuron salient count — a weight is salient if it survives the
//!     layer-wide prune (top active-K by |w|) or is a layer-wide regrowth
//!     candidate (top K by |g| among pruned positions);
//!  4. ablate neurons with salient < max(1, gamma_sal * k): prune all
//!     their weights and redistribute them to the surviving neurons;
//!  5. recompute the constant fan-in k' = budget / n_active;
//!  6. prune the K smallest-|w| weights layer-wide;
//!  7. per active neuron, regrow by decreasing |g| until fan-in == k'.
//!
//! Invariants maintained (checked by property tests in rust/tests/):
//!  * every active neuron has exactly k' active weights;
//!  * ablated neurons have zero fan-in, zero weights, zero momentum;
//!  * layer nnz == n_active * k' <= budget (never exceeds);
//!  * ablation is monotone: an ablated neuron never revives.

use super::saliency::{bottom_k_by, top_k_by};
use super::{LayerView, TopologyUpdater, UpdateStats};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct SRigL {
    /// Enable dynamic neuron ablation (paper's "w/ ablation").
    pub ablation: bool,
    /// Minimum fraction of salient weights per neuron, gamma_sal
    /// (0.3 for CNNs, 0.95 for ViT in the paper).
    pub gamma_sal: f64,
}

impl Default for SRigL {
    fn default() -> Self {
        SRigL { ablation: true, gamma_sal: 0.3 }
    }
}

impl SRigL {
    pub fn without_ablation() -> Self {
        SRigL { ablation: false, gamma_sal: 0.0 }
    }
}

impl TopologyUpdater for SRigL {
    fn name(&self) -> &'static str {
        "srigl"
    }

    fn structured(&self) -> bool {
        true
    }

    fn update(&self, layer: &mut LayerView, frac: f64, _rng: &mut Rng) -> UpdateStats {
        let (n, f) = (layer.mask.neurons, layer.mask.fan_in);
        let k_cur = *layer.k;
        let counts = layer.mask.fan_in_counts();
        let alive: Vec<usize> = (0..n).filter(|&r| counts[r] > 0).collect();
        let n_alive = alive.len();
        if n_alive == 0 || k_cur == 0 {
            return UpdateStats::default();
        }
        let total_active: usize = counts.iter().sum();

        // Step 2: prune/grow quota.
        let quota = (frac * total_active as f64).round() as usize;
        if quota == 0 && !self.ablation {
            return UpdateStats {
                active_neurons: n_alive,
                k: k_cur,
                ..Default::default()
            };
        }

        let abs_w: Vec<f32> = layer.w.data.iter().map(|v| v.abs()).collect();
        let abs_g: Vec<f32> = layer.grad.data.iter().map(|v| v.abs()).collect();
        let mask_data_snapshot = layer.mask.t.data.clone();
        let is_active = |i: usize| mask_data_snapshot[i] != 0.0;
        // Growth candidates live only in non-ablated neurons (step 7 says
        // "for each active neuron"); ablated rows never revive.
        let alive_row = {
            let mut v = vec![false; n];
            for &r in &alive {
                v[r] = true;
            }
            v
        };

        // Step 6's prune set, computed up-front because step 3's salient
        // counts need it: K smallest |w| among active weights.
        let active_positions = (0..n * f).filter(|&i| is_active(i));
        let prune_set = bottom_k_by(active_positions, &abs_w, quota);
        let mut in_prune = vec![false; n * f];
        for &i in &prune_set {
            in_prune[i] = true;
        }

        // Layer-wide regrowth candidates: K largest |g| among pruned
        // positions of alive neurons.
        let inactive_positions =
            (0..n * f).filter(|&i| !is_active(i) && alive_row[i / f]);
        let grow_set = top_k_by(inactive_positions, &abs_g, quota);
        let mut in_grow = vec![false; n * f];
        for &i in &grow_set {
            in_grow[i] = true;
        }

        // Step 3: salient weights per neuron = survivors + grow candidates.
        let mut salient = vec![0usize; n];
        for r in &alive {
            let r = *r;
            for j in 0..f {
                let i = r * f + j;
                if (is_active(i) && !in_prune[i]) || in_grow[i] {
                    salient[r] += 1;
                }
            }
        }

        // Step 4: ablation. Threshold clamps to a minimum of one salient
        // weight (App. E) so gamma_sal * k < 1 never ablates everything.
        let mut ablated_now = 0usize;
        let mut survivors: Vec<usize> = alive.clone();
        if self.ablation {
            let tau = (self.gamma_sal * k_cur as f64).max(1.0);
            survivors = alive.iter().copied().filter(|&r| salient[r] as f64 >= tau).collect();
            // Layer-collapse guard: keep the most salient neuron alive.
            if survivors.is_empty() {
                let best = alive
                    .iter()
                    .copied()
                    .max_by_key(|&r| salient[r])
                    .expect("alive nonempty");
                survivors.push(best);
            }
            ablated_now = n_alive - survivors.len();
            let keep: Vec<bool> = {
                let mut v = vec![false; n];
                for &r in &survivors {
                    v[r] = true;
                }
                v
            };
            for &r in &alive {
                if !keep[r] {
                    for j in 0..f {
                        let i = r * f + j;
                        layer.mask.t.data[i] = 0.0;
                        layer.w.data[i] = 0.0;
                        layer.v.data[i] = 0.0;
                    }
                }
            }
        }

        // Step 5: new constant fan-in from the fixed layer budget.
        let k_new = (layer.budget / survivors.len()).clamp(1, f);

        // Step 6: apply the layer-wide magnitude prune (positions in
        // ablated rows are already gone).
        for &i in &prune_set {
            layer.mask.t.data[i] = 0.0;
            layer.w.data[i] = 0.0;
            layer.v.data[i] = 0.0;
        }

        // Step 7: per-neuron adjust to exactly k_new. Regrow by decreasing
        // |g| (preferring positions not just pruned); over-full neurons
        // (possible when k_new < k_cur after rounding) prune smallest |w|.
        let mut pruned_total = prune_set.len();
        let mut grown_total = 0usize;
        for &r in &survivors {
            let row = r * f..(r + 1) * f;
            let cur: usize = layer.mask.t.data[row.clone()].iter().filter(|v| **v != 0.0).count();
            if cur < k_new {
                let need = k_new - cur;
                // candidates: inactive now, not just pruned (fall back to
                // just-pruned if the row lacks candidates). Membership via
                // a boolean row mark, not Vec::contains (§Perf iteration 3).
                let fresh: Vec<usize> = row
                    .clone()
                    .filter(|&i| layer.mask.t.data[i] == 0.0 && !in_prune[i])
                    .collect();
                let mut chosen = top_k_by(fresh.iter().copied(), &abs_g, need);
                if chosen.len() < need {
                    let mut taken = vec![false; f];
                    for &i in &chosen {
                        taken[i - r * f] = true;
                    }
                    let extra: Vec<usize> = row
                        .clone()
                        .filter(|&i| layer.mask.t.data[i] == 0.0 && !taken[i - r * f])
                        .collect();
                    let more = top_k_by(extra.into_iter(), &abs_g, need - chosen.len());
                    chosen.extend(more);
                }
                for i in chosen {
                    layer.mask.t.data[i] = 1.0;
                    layer.w.data[i] = 0.0;
                    layer.v.data[i] = 0.0;
                    grown_total += 1;
                }
            } else if cur > k_new {
                let excess = cur - k_new;
                let active_in_row: Vec<usize> =
                    row.clone().filter(|&i| layer.mask.t.data[i] != 0.0).collect();
                for i in bottom_k_by(active_in_row.into_iter(), &abs_w, excess) {
                    layer.mask.t.data[i] = 0.0;
                    layer.w.data[i] = 0.0;
                    layer.v.data[i] = 0.0;
                    pruned_total += 1;
                }
            }
        }

        *layer.k = k_new;
        debug_assert!(layer.mask.is_constant_fan_in(k_new));
        UpdateStats {
            pruned: pruned_total,
            grown: grown_total,
            ablated: ablated_now,
            active_neurons: survivors.len(),
            k: k_new,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::TestLayer;
    use super::*;

    #[test]
    fn maintains_constant_fan_in() {
        let mut l = TestLayer::new(16, 32, 8, true, 0);
        let mut rng = Rng::new(1);
        for step in 0..10 {
            let frac = 0.3 * (1.0 - step as f64 / 10.0);
            let stats = SRigL::default().update(&mut l.view(), frac, &mut rng);
            assert!(l.mask.is_constant_fan_in(stats.k), "step {step}");
            assert!(l.mask.nnz() <= l.budget);
            l.assert_consistent();
        }
    }

    #[test]
    fn no_ablation_keeps_all_neurons_and_k() {
        let mut l = TestLayer::new(12, 24, 6, true, 2);
        let mut rng = Rng::new(3);
        for _ in 0..5 {
            let stats = SRigL::without_ablation().update(&mut l.view(), 0.3, &mut rng);
            assert_eq!(stats.active_neurons, 12);
            assert_eq!(stats.k, 6);
            assert_eq!(l.mask.nnz(), 72);
            assert!(l.mask.is_constant_fan_in(6));
        }
    }

    #[test]
    fn high_gamma_ablates_and_raises_k() {
        // gamma_sal = 0.99 with a small drop fraction makes most neurons
        // fail the salient threshold -> heavy ablation, larger k'.
        let mut l = TestLayer::new(32, 64, 4, true, 4);
        let mut rng = Rng::new(5);
        let stats = SRigL { ablation: true, gamma_sal: 0.99 }.update(&mut l.view(), 0.3, &mut rng);
        assert!(stats.ablated > 0, "{stats:?}");
        assert!(stats.k >= 4, "{stats:?}");
        assert!(l.mask.is_constant_fan_in(stats.k));
        assert_eq!(l.mask.active_neurons(), stats.active_neurons);
    }

    #[test]
    fn ablation_monotone() {
        let mut l = TestLayer::new(24, 48, 3, true, 6);
        let mut rng = Rng::new(7);
        let upd = SRigL { ablation: true, gamma_sal: 0.7 };
        let mut prev_dead: Vec<usize> = vec![];
        for _ in 0..8 {
            upd.update(&mut l.view(), 0.2, &mut rng);
            let counts = l.mask.fan_in_counts();
            let dead: Vec<usize> =
                (0..24).filter(|&r| counts[r] == 0).collect();
            for d in &prev_dead {
                assert!(dead.contains(d), "neuron {d} revived");
            }
            prev_dead = dead;
        }
    }

    #[test]
    fn layer_collapse_guard() {
        // gamma so high nothing is salient enough -> one neuron survives.
        let mut l = TestLayer::new(8, 16, 2, true, 8);
        let mut rng = Rng::new(9);
        let stats =
            SRigL { ablation: true, gamma_sal: 100.0 }.update(&mut l.view(), 0.3, &mut rng);
        assert_eq!(stats.active_neurons, 1);
        assert!(l.mask.nnz() >= 1);
        assert!(l.mask.is_constant_fan_in(stats.k));
    }

    #[test]
    fn budget_never_exceeded() {
        for seed in 0..5 {
            let mut l = TestLayer::new(20, 40, 5, true, seed);
            let mut rng = Rng::new(seed + 100);
            for _ in 0..6 {
                SRigL { ablation: true, gamma_sal: 0.5 }.update(&mut l.view(), 0.25, &mut rng);
                assert!(l.mask.nnz() <= l.budget, "seed {seed}");
            }
        }
    }

    #[test]
    fn grown_weights_start_zero() {
        let mut l = TestLayer::new(10, 20, 4, true, 11);
        let before = l.mask.t.data.clone();
        let mut rng = Rng::new(12);
        SRigL::default().update(&mut l.view(), 0.3, &mut rng);
        for i in 0..before.len() {
            if before[i] == 0.0 && l.mask.t.data[i] == 1.0 {
                assert_eq!(l.w.data[i], 0.0);
                assert_eq!(l.v.data[i], 0.0);
            }
        }
    }
}
