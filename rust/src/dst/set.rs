//! SET (Mocanu et al. 2018): prune smallest-magnitude weights, regrow
//! *random* inactive positions. Baseline row in Table 3.

use super::saliency::bottom_k_by;
use super::{apply_prune_grow, prune_quota, LayerView, TopologyUpdater, UpdateStats};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, Default)]
pub struct Set;

impl TopologyUpdater for Set {
    fn name(&self) -> &'static str {
        "set"
    }

    fn structured(&self) -> bool {
        false
    }

    fn update(&self, layer: &mut LayerView, frac: f64, rng: &mut Rng) -> UpdateStats {
        let mask = &layer.mask.t.data;
        let n_total = mask.len();
        let inactive: Vec<usize> = (0..n_total).filter(|&i| mask[i] == 0.0).collect();
        let quota = prune_quota(layer.mask, frac).min(inactive.len());
        if quota == 0 {
            return UpdateStats {
                active_neurons: layer.mask.active_neurons(),
                ..Default::default()
            };
        }

        let abs_w: Vec<f32> = layer.w.data.iter().map(|v| v.abs()).collect();
        let active = (0..n_total).filter(|&i| mask[i] != 0.0);
        let pruned = bottom_k_by(active, &abs_w, quota);

        // Random regrowth among previously-inactive positions.
        let picks = rng.choose_k(inactive.len(), quota);
        let grown: Vec<usize> = picks.into_iter().map(|p| inactive[p]).collect();

        apply_prune_grow(layer, &pruned, &grown);
        UpdateStats {
            pruned: pruned.len(),
            grown: grown.len(),
            ablated: 0,
            active_neurons: layer.mask.active_neurons(),
            k: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::TestLayer;
    use super::*;

    #[test]
    fn preserves_nnz_and_consistency() {
        let mut l = TestLayer::new(10, 20, 5, false, 0);
        let nnz = l.mask.nnz();
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            Set.update(&mut l.view(), 0.3, &mut rng);
            assert_eq!(l.mask.nnz(), nnz);
            l.assert_consistent();
        }
    }

    #[test]
    fn regrowth_is_random_not_gradient() {
        // Two different rngs should (overwhelmingly) grow different sets.
        let mut l1 = TestLayer::new(16, 64, 4, false, 2);
        let mut l2 = TestLayer::new(16, 64, 4, false, 2);
        Set.update(&mut l1.view(), 0.3, &mut Rng::new(10));
        Set.update(&mut l2.view(), 0.3, &mut Rng::new(20));
        assert_ne!(l1.mask.t.data, l2.mask.t.data);
    }
}
