//! Static sparse training: the topology fixed at initialization never
//! changes ("Static" row in Table 3).

use super::{LayerView, TopologyUpdater, UpdateStats};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, Default)]
pub struct StaticSparse {
    /// Report the constant fan-in structure if initialized that way.
    pub structured: bool,
}

impl TopologyUpdater for StaticSparse {
    fn name(&self) -> &'static str {
        "static"
    }

    fn structured(&self) -> bool {
        self.structured
    }

    fn update(&self, layer: &mut LayerView, _frac: f64, _rng: &mut Rng) -> UpdateStats {
        UpdateStats {
            pruned: 0,
            grown: 0,
            ablated: 0,
            active_neurons: layer.mask.active_neurons(),
            k: *layer.k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::TestLayer;
    use super::*;

    #[test]
    fn never_changes_mask() {
        let mut l = TestLayer::new(8, 16, 4, true, 0);
        let before = l.mask.t.data.clone();
        let mut rng = Rng::new(1);
        for _ in 0..5 {
            StaticSparse { structured: true }.update(&mut l.view(), 0.3, &mut rng);
        }
        assert_eq!(l.mask.t.data, before);
    }
}
