//! Structured (neuron-level) magnitude pruning — the classic
//! prune-then-fine-tune baseline family of Table 10. Whole neurons are
//! removed by L2 norm of their incoming weights; the result is then
//! fine-tuned with `StaticSparse`.

use crate::sparsity::mask::Mask;
use crate::tensor::Tensor;

/// Build a mask that keeps the `keep` neurons with the largest incoming
/// L2 norm and ablates the rest entirely. Kept neurons stay dense
/// (structured pruning does not thin surviving neurons).
pub fn structured_prune_mask(w: &Tensor, keep: usize) -> Mask {
    let (n, f) = w.neuron_view();
    let keep = keep.clamp(1, n);
    let mut norms: Vec<(usize, f64)> = (0..n)
        .map(|r| {
            let s: f64 = w.data[r * f..(r + 1) * f]
                .iter()
                .map(|v| (*v as f64) * (*v as f64))
                .sum();
            (r, s)
        })
        .collect();
    norms.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
    let mut m = Mask::from_tensor(Tensor::zeros(&w.shape));
    for &(r, _) in norms.iter().take(keep) {
        for j in 0..f {
            m.set(r, j, true);
        }
    }
    m
}

/// Uniform-magnitude unstructured prune to a target density (the
/// "Uniform" baseline row of Table 10 at the layer level).
pub fn magnitude_prune_mask(w: &Tensor, density: f64) -> Mask {
    let nnz = ((w.numel() as f64 * density).round() as usize).clamp(1, w.numel());
    let mut order: Vec<usize> = (0..w.numel()).collect();
    order.sort_by(|&a, &b| {
        w.data[b]
            .abs()
            .partial_cmp(&w.data[a].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut m = Mask::from_tensor(Tensor::zeros(&w.shape));
    for &i in order.iter().take(nnz) {
        m.t.data[i] = 1.0;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn keeps_high_norm_neurons() {
        let mut w = Tensor::zeros(&[4, 3]);
        // neuron norms: 0 -> small, 1 -> big, 2 -> medium, 3 -> tiny
        w.data = vec![0.1, 0.1, 0.1, 5.0, 5.0, 5.0, 1.0, 1.0, 1.0, 0.01, 0.0, 0.0];
        let m = structured_prune_mask(&w, 2);
        let counts = m.fan_in_counts();
        assert_eq!(counts, vec![0, 3, 3, 0]);
        assert_eq!(m.active_neurons(), 2);
    }

    #[test]
    fn magnitude_prune_density() {
        let mut rng = Rng::new(0);
        let w = Tensor::normal(&[16, 16], 1.0, &mut rng);
        let m = magnitude_prune_mask(&w, 0.25);
        assert_eq!(m.nnz(), 64);
        // kept weights dominate dropped ones in magnitude
        let kept_min = w
            .data
            .iter()
            .zip(&m.t.data)
            .filter(|(_, m)| **m != 0.0)
            .map(|(w, _)| w.abs())
            .fold(f32::INFINITY, f32::min);
        let dropped_max = w
            .data
            .iter()
            .zip(&m.t.data)
            .filter(|(_, m)| **m == 0.0)
            .map(|(w, _)| w.abs())
            .fold(0.0f32, f32::max);
        assert!(kept_min >= dropped_max);
    }
}
