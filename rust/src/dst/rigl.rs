//! RigL (Evci et al. 2021): prune smallest-magnitude weights, regrow the
//! inactive positions with the largest gradient magnitude — unstructured,
//! layer-wise. This is the baseline SRigL is built from and compared to.

use super::saliency::{bottom_k_by, top_k_by};
use super::{apply_prune_grow, prune_quota, LayerView, TopologyUpdater, UpdateStats};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, Default)]
pub struct RigL;

impl TopologyUpdater for RigL {
    fn name(&self) -> &'static str {
        "rigl"
    }

    fn structured(&self) -> bool {
        false
    }

    fn update(&self, layer: &mut LayerView, frac: f64, _rng: &mut Rng) -> UpdateStats {
        let mask = &layer.mask.t.data;
        let n_total = mask.len();
        let mut quota = prune_quota(layer.mask, frac);
        let inactive: Vec<usize> = (0..n_total).filter(|&i| mask[i] == 0.0).collect();
        quota = quota.min(inactive.len());
        if quota == 0 {
            return UpdateStats {
                active_neurons: layer.mask.active_neurons(),
                k: 0,
                ..Default::default()
            };
        }

        // Prune: K smallest |w| among active.
        let abs_w: Vec<f32> = layer.w.data.iter().map(|v| v.abs()).collect();
        let active = (0..n_total).filter(|&i| mask[i] != 0.0);
        let pruned = bottom_k_by(active, &abs_w, quota);

        // Grow: K largest |g| among positions inactive *before* the update
        // (just-pruned positions are excluded, as in the reference impl).
        let abs_g: Vec<f32> = layer.grad.data.iter().map(|v| v.abs()).collect();
        let grown = top_k_by(inactive.into_iter(), &abs_g, quota);
        debug_assert_eq!(pruned.len(), grown.len());

        apply_prune_grow(layer, &pruned, &grown);
        UpdateStats {
            pruned: pruned.len(),
            grown: grown.len(),
            ablated: 0,
            active_neurons: layer.mask.active_neurons(),
            k: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::TestLayer;
    use super::*;

    #[test]
    fn preserves_nnz() {
        let mut l = TestLayer::new(16, 32, 8, false, 0);
        let before = l.mask.nnz();
        let stats = RigL.update(&mut l.view(), 0.3, &mut Rng::new(1));
        assert_eq!(l.mask.nnz(), before);
        assert_eq!(stats.pruned, stats.grown);
        assert_eq!(stats.pruned, (0.3f64 * before as f64).round() as usize);
        l.assert_consistent();
    }

    #[test]
    fn prunes_smallest_weights() {
        let mut l = TestLayer::new(4, 8, 4, false, 2);
        // Find the single smallest active |w|; with frac small enough only
        // it should be pruned.
        let active_min = l
            .w
            .data
            .iter()
            .enumerate()
            .filter(|(i, _)| l.mask.t.data[*i] != 0.0)
            .min_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        let frac = 1.0 / l.mask.nnz() as f64;
        RigL.update(&mut l.view(), frac, &mut Rng::new(3));
        assert_eq!(l.mask.t.data[active_min], 0.0, "smallest weight not pruned");
    }

    #[test]
    fn grows_largest_gradients() {
        let mut l = TestLayer::new(4, 8, 2, false, 4);
        let inactive_max = l
            .grad
            .data
            .iter()
            .enumerate()
            .filter(|(i, _)| l.mask.t.data[*i] == 0.0)
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        let frac = 1.0 / l.mask.nnz() as f64;
        RigL.update(&mut l.view(), frac, &mut Rng::new(5));
        assert_eq!(l.mask.t.data[inactive_max], 1.0, "largest-grad position not grown");
        assert_eq!(l.w.data[inactive_max], 0.0, "grown weight must start at 0");
    }

    #[test]
    fn zero_frac_noop() {
        let mut l = TestLayer::new(8, 8, 4, false, 6);
        let mask_before = l.mask.t.data.clone();
        let stats = RigL.update(&mut l.view(), 0.0, &mut Rng::new(7));
        assert_eq!(l.mask.t.data, mask_before);
        assert_eq!(stats.pruned, 0);
    }

    #[test]
    fn repeated_updates_hold_budget() {
        let mut l = TestLayer::new(12, 24, 6, false, 8);
        let budget = l.mask.nnz();
        let mut rng = Rng::new(9);
        for step in 0..20 {
            let frac = 0.3 * (1.0 - step as f64 / 20.0);
            RigL.update(&mut l.view(), frac, &mut rng);
            assert_eq!(l.mask.nnz(), budget, "step {step}");
            l.assert_consistent();
        }
    }
}
