//! Dynamic Sparse Training topology updaters — the paper's algorithmic
//! core. `SRigL` implements Section 3.1 (constant fan-in + dynamic neuron
//! ablation); `RigL`, `SET`, and `StaticSparse` are the baselines the
//! paper compares against (Table 3); `struct_prune` is the structured
//! pruning baseline of Table 10.

pub mod rigl;
pub mod saliency;
pub mod schedule;
pub mod set;
pub mod srigl;
pub mod static_sparse;
pub mod struct_prune;

pub use rigl::RigL;
pub use schedule::UpdateSchedule;
pub use set::Set;
pub use srigl::SRigL;
pub use static_sparse::StaticSparse;

use crate::sparsity::mask::Mask;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Mutable view of one sparse layer during a connectivity update.
pub struct LayerView<'a> {
    /// Weights (masked: pruned entries are exactly 0).
    pub w: &'a mut Tensor,
    /// SGD momentum buffer; reset to 0 at newly-grown positions (RigL).
    pub v: &'a mut Tensor,
    pub mask: &'a mut Mask,
    /// Dense gradient dL/d(w .* m) from the AOT `dense_grad` program.
    pub grad: &'a Tensor,
    /// Current constant fan-in k (SRigL updates this on ablation).
    pub k: &'a mut usize,
    /// Fixed non-zero budget for this layer (set at initialization).
    pub budget: usize,
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct UpdateStats {
    pub pruned: usize,
    pub grown: usize,
    /// Neurons ablated *by this update*.
    pub ablated: usize,
    /// Active (non-ablated) neurons after the update.
    pub active_neurons: usize,
    /// Constant fan-in after the update (0 for unstructured methods).
    pub k: usize,
}

/// A sparse-to-sparse DST topology updater.
pub trait TopologyUpdater {
    fn name(&self) -> &'static str;

    /// True if this method maintains the constant fan-in structure (and
    /// should therefore be initialized with constant fan-in masks).
    fn structured(&self) -> bool;

    /// Run one connectivity update on a layer. `frac` is the cosine-
    /// annealed drop fraction from `UpdateSchedule::drop_fraction`.
    fn update(&self, layer: &mut LayerView, frac: f64, rng: &mut Rng) -> UpdateStats;
}

/// Shared post-edit fixups: zero weights+momentum at pruned positions,
/// zero momentum (and weight) at grown positions. `grown` positions start
/// at w=0 exactly as in RigL.
pub(crate) fn apply_prune_grow(
    layer: &mut LayerView,
    pruned: &[usize],
    grown: &[usize],
) {
    for &i in pruned {
        layer.mask.t.data[i] = 0.0;
        layer.w.data[i] = 0.0;
        layer.v.data[i] = 0.0;
    }
    for &i in grown {
        layer.mask.t.data[i] = 1.0;
        layer.w.data[i] = 0.0;
        layer.v.data[i] = 0.0;
    }
}

/// Active-weight count helper.
pub(crate) fn active_count(mask: &Mask) -> usize {
    mask.nnz()
}

/// Number of prune/grow slots for this update: round(frac * active).
pub(crate) fn prune_quota(mask: &Mask, frac: f64) -> usize {
    (frac * active_count(mask) as f64).round() as usize
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Build a random layer (weights, momentum, mask, grads) for updater
    /// tests. `constant_k` picks constant-fan-in vs per-layer topology.
    pub struct TestLayer {
        pub w: Tensor,
        pub v: Tensor,
        pub mask: Mask,
        pub grad: Tensor,
        pub k: usize,
        pub budget: usize,
    }

    impl TestLayer {
        pub fn new(n: usize, f: usize, k: usize, constant: bool, seed: u64) -> TestLayer {
            let mut rng = Rng::new(seed);
            let mask = if constant {
                Mask::random_constant_fan_in(&[n, f], k, &mut rng)
            } else {
                Mask::random_per_layer(&[n, f], n * k, &mut rng)
            };
            let mut w = Tensor::normal(&[n, f], 1.0, &mut rng);
            w.mul_assign(&mask.t);
            let v = Tensor::zeros(&[n, f]);
            let grad = Tensor::normal(&[n, f], 1.0, &mut rng);
            TestLayer { w, v, mask, grad, k, budget: n * k }
        }

        pub fn view(&mut self) -> LayerView<'_> {
            LayerView {
                w: &mut self.w,
                v: &mut self.v,
                mask: &mut self.mask,
                grad: &self.grad,
                k: &mut self.k,
                budget: self.budget,
            }
        }

        /// Weights at pruned positions must be exactly zero.
        pub fn assert_consistent(&self) {
            for (i, &m) in self.mask.t.data.iter().enumerate() {
                if m == 0.0 {
                    assert_eq!(self.w.data[i], 0.0, "weight alive at pruned idx {i}");
                    assert_eq!(self.v.data[i], 0.0, "momentum alive at pruned idx {i}");
                }
            }
        }
    }
}
