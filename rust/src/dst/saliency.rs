//! Saliency selection helpers shared by the topology updaters: top-k /
//! bottom-k index selection by score over arbitrary candidate subsets.

/// Indices of the `k` largest `scores[i]` among `candidates`.
/// O(n log n) via sort — layer sizes here are <=10^6 and updates are
/// amortized over ΔT steps (the paper ignores mask-update FLOPs for the
/// same reason, App. G).
pub fn top_k_by(candidates: impl Iterator<Item = usize>, scores: &[f32], k: usize) -> Vec<usize> {
    let mut v: Vec<usize> = candidates.collect();
    if k == 0 {
        return Vec::new();
    }
    if v.len() > k {
        v.select_nth_unstable_by(k - 1, |&a, &b| {
            scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        v.truncate(k);
    }
    v.sort_unstable_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    v
}

/// Indices of the `k` smallest `scores[i]` among `candidates`.
pub fn bottom_k_by(candidates: impl Iterator<Item = usize>, scores: &[f32], k: usize) -> Vec<usize> {
    let mut v: Vec<usize> = candidates.collect();
    if k == 0 {
        return Vec::new();
    }
    if v.len() > k {
        v.select_nth_unstable_by(k - 1, |&a, &b| {
            scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal)
        });
        v.truncate(k);
    }
    v.sort_unstable_by(|&a, &b| {
        scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal)
    });
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_basic() {
        let scores = [1.0f32, 5.0, 3.0, 2.0, 4.0];
        assert_eq!(top_k_by(0..5, &scores, 2), vec![1, 4]);
        assert_eq!(bottom_k_by(0..5, &scores, 2), vec![0, 3]);
    }

    #[test]
    fn k_larger_than_candidates() {
        let scores = [1.0f32, 2.0];
        let v = top_k_by(0..2, &scores, 10);
        assert_eq!(v, vec![1, 0]);
    }

    #[test]
    fn subset_candidates() {
        let scores = [9.0f32, 1.0, 8.0, 2.0, 7.0];
        let v = top_k_by([1, 3, 4].into_iter(), &scores, 2);
        assert_eq!(v, vec![4, 3]);
    }

    #[test]
    fn zero_k() {
        let scores = [1.0f32];
        assert!(top_k_by(0..1, &scores, 0).is_empty());
        assert!(bottom_k_by(0..1, &scores, 0).is_empty());
    }
}
