//! FLOPs accounting (paper Table 5, Fig. 13, App. G): inference and
//! training FLOPs for sparse models, following the paper's methodology —
//! only conv/linear layers and their activations are counted; add/pool
//! ops and the amortized mask updates are ignored.
//!
//! Training FLOPs per step (Evci et al. 2021 convention): forward (1x) +
//! input grads (1x) + weight grads (1x) ≈ 3x forward, with RigL/SRigL's
//! periodic dense-gradient pass amortized over ΔT: the paper folds it in
//! as (2·s_fwd + s_dense)/ΔT corrections; we expose both terms.

/// One accounted layer: a linear or conv with an activation count.
#[derive(Clone, Debug)]
pub struct LayerFlops {
    pub name: String,
    /// Dense multiply-accumulates per example (counted as 2 FLOPs each).
    pub dense_macs: u64,
    /// Fraction of weights active (1 - layer sparsity).
    pub density: f64,
}

impl LayerFlops {
    pub fn linear(name: &str, in_f: usize, out_f: usize, density: f64) -> LayerFlops {
        LayerFlops { name: name.into(), dense_macs: (in_f * out_f) as u64, density }
    }

    /// Conv with SAME padding: macs = out_h*out_w*kh*kw*in_c*out_c.
    pub fn conv(
        name: &str,
        in_c: usize,
        out_c: usize,
        kh: usize,
        kw: usize,
        out_h: usize,
        out_w: usize,
        density: f64,
    ) -> LayerFlops {
        LayerFlops {
            name: name.into(),
            dense_macs: (out_h * out_w * kh * kw * in_c * out_c) as u64,
            density,
        }
    }

    pub fn sparse_flops(&self) -> f64 {
        2.0 * self.dense_macs as f64 * self.density
    }

    pub fn dense_flops(&self) -> f64 {
        2.0 * self.dense_macs as f64
    }
}

#[derive(Clone, Debug)]
pub struct ModelFlops {
    pub layers: Vec<LayerFlops>,
}

impl ModelFlops {
    /// Inference FLOPs per example.
    pub fn inference(&self) -> f64 {
        self.layers.iter().map(|l| l.sparse_flops()).sum()
    }

    pub fn inference_dense(&self) -> f64 {
        self.layers.iter().map(|l| l.dense_flops()).sum()
    }

    /// Training FLOPs per example per step: 3x sparse forward plus the
    /// amortized dense-gradient saliency pass every `delta_t` steps
    /// (RigL Appendix; the dense backward-for-weights is ~1x dense fwd).
    pub fn train_step(&self, delta_t: usize) -> f64 {
        let sparse = self.inference();
        let dense = self.inference_dense();
        3.0 * sparse + if delta_t > 0 { dense / delta_t as f64 } else { 0.0 }
    }

    /// Total training FLOPs for `steps` steps at `batch` examples.
    pub fn train_total(&self, steps: usize, batch: usize, delta_t: usize) -> f64 {
        self.train_step(delta_t) * steps as f64 * batch as f64
    }

    /// Normalized against the dense model (paper Fig. 13 y-axis).
    pub fn train_fraction_of_dense(&self, delta_t: usize) -> f64 {
        let dense3 = 3.0 * self.inference_dense();
        self.train_step(delta_t) / dense3
    }
}

/// The paper's ResNet-50 reference numbers (Table 5) for shape checking:
/// dense inference = 8.2 GFLOPs; we verify our *ratios* against theirs.
pub const RESNET50_DENSE_INFERENCE_GFLOPS: f64 = 8.2;

/// Table 5 ratios from the paper: sparsity -> (train e18, inference e9),
/// dense train = 3.15e18.
pub fn paper_table5() -> Vec<(f64, f64, f64)> {
    vec![
        (0.80, 1.13, 3.40),
        (0.90, 0.77, 1.99),
        (0.95, 0.40, 1.01),
        (0.99, 0.09, 0.21),
        (0.00, 3.15, 8.20),
    ]
}

/// Build the FLOPs model of our cnn_proxy (3x16x16 input, SAME convs,
/// pool/2 after stages 0 and 1, GAP, fc) with per-layer densities.
pub fn cnn_proxy_flops(channels: &[usize], image: usize, classes: usize, densities: &[f64]) -> ModelFlops {
    let mut layers = Vec::new();
    let mut h = image;
    let mut prev = 3usize;
    for (i, &c) in channels.iter().enumerate() {
        layers.push(LayerFlops::conv(
            &format!("conv{i}"),
            prev,
            c,
            3,
            3,
            h,
            h,
            densities.get(i).copied().unwrap_or(1.0),
        ));
        if i < channels.len() - 1 {
            h /= 2;
        }
        prev = c;
    }
    layers.push(LayerFlops::linear(
        "fc",
        prev,
        classes,
        densities.get(channels.len()).copied().unwrap_or(1.0),
    ));
    ModelFlops { layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_flops() {
        let l = LayerFlops::linear("fc", 3072, 768, 0.1);
        assert_eq!(l.dense_flops(), 2.0 * 3072.0 * 768.0);
        assert!((l.sparse_flops() / l.dense_flops() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn conv_flops() {
        let l = LayerFlops::conv("c", 3, 16, 3, 3, 16, 16, 1.0);
        assert_eq!(l.dense_macs, 16 * 16 * 9 * 3 * 16);
    }

    #[test]
    fn train_includes_amortized_dense_pass() {
        let m = ModelFlops { layers: vec![LayerFlops::linear("l", 100, 100, 0.1)] };
        let with = m.train_step(100);
        let without = 3.0 * m.inference();
        assert!(with > without);
        assert!((with - without - m.inference_dense() / 100.0).abs() < 1e-9);
    }

    #[test]
    fn sparsity_scales_training_flops_like_paper() {
        // Paper Table 5: 90% sparse training = 0.77/3.15 ≈ 24% of dense.
        // With uniform density the ratio is ~(3*0.1 + 1/dt)/3; at dt=800
        // that's ~10%; ERK + dense-ish small layers lift the real model to
        // ~24%. Here we just check monotonicity + the dense limit.
        let mk = |d: f64| ModelFlops { layers: vec![LayerFlops::linear("l", 512, 512, d)] };
        let f90 = mk(0.1).train_fraction_of_dense(100);
        let f80 = mk(0.2).train_fraction_of_dense(100);
        let f0 = mk(1.0).train_fraction_of_dense(100);
        assert!(f90 < f80 && f80 < f0);
        assert!(f0 > 1.0 && f0 < 1.01); // dense + tiny amortized term
    }

    #[test]
    fn cnn_proxy_structure() {
        let m = cnn_proxy_flops(&[16, 32, 64], 16, 10, &[1.0; 4]);
        assert_eq!(m.layers.len(), 4);
        // first conv at 16x16, second at 8x8, third at 4x4
        assert_eq!(m.layers[0].dense_macs, 16 * 16 * 9 * 3 * 16);
        assert_eq!(m.layers[1].dense_macs, 8 * 8 * 9 * 16 * 32);
        assert_eq!(m.layers[2].dense_macs, 4 * 4 * 9 * 32 * 64);
    }
}
