//! srigl — CLI entrypoint for the SRigL reproduction (L3 coordinator).
//!
//! Subcommands:
//!   exp `<id>` [flags]   run a paper table/figure harness (exp --list)
//!   train [flags]        train one configuration and report
//!   serve [flags]        run the online-inference server benchmark
//!   serve-model [flags]  serve a multi-layer sparse model via the worker pool
//!   arena [flags]        duel two serving configs on shared traffic; --history
//!   check                verify artifacts load and execute
//!   list                 list models in the artifact manifest
//!   lint [--root DIR]    repo-specific static checks (docs/ANALYSIS.md)

use std::sync::Arc;

use anyhow::Result;

use srigl::data;
use srigl::exp;
use srigl::inference::server::{serve, serve_model, ServeConfig};
use srigl::inference::{frontend, Activation, EngineBuilder, LayerBundle, LayerSpec, Repr, SparseModel};
use srigl::runtime::manifest::ServeKnobs;
use srigl::runtime::{Manifest, Runtime};
use srigl::sparsity::Distribution;
use srigl::train::{LrSchedule, Method, Session, TrainConfig};
use srigl::util::cli::Args;
use srigl::util::log;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    println!(
        "srigl — Dynamic Sparse Training with Structured Sparsity (ICLR 2024 reproduction)

USAGE:
  srigl exp <id> [--steps N] [--seeds N] [--sparsities a,b] [--gamma G] ...
  srigl exp --list
  srigl train --model cnn_proxy --method srigl --sparsity 0.9 [--steps N]
              [--gamma 0.3] [--no-ablation] [--dist erk|uniform] [--seed S]
              [--serve ADDR] [--publish-every N] [--serve-repr R]
              (--serve streams checkpoints into a live front-end as epochs)
  srigl serve [--sparsity 0.9] [--requests N] [--batched MAX]
  srigl serve-model [--dims 3072,768,768,256]
              [--repr condensed|condensed-tiled|dense|csr|structured|mixed]
              [--sparsity 0.9] [--workers 4] [--max-batch 8] [--requests N]
              [--threads T] [--gap-us G] [--stack NAME] [--adaptive]
              [--shards S] [--listen ADDR] [--queue-cap N] [--cache-cap N]
              [--egress-cap N] [--retry-ms M] [--fixed-batch]
              [--metrics ADDR] [--max-conns N] [--reload]
              (--reload: SIGHUP or a wire control frame re-reads the model
               source and swaps it in as a new epoch; docs/RELOAD.md)
  srigl arena [--scenario poisson|bursty|diurnal|heavytail|adversarial]
              [--a SPEC] [--b SPEC]   (SPEC: workers=4,adaptive=8,shards=2,...)
              [--requests N] [--rounds R] [--gap-us G] [--max-rows M]
              [--pool P] [--seed S] [--wire] [--clients C] [--max-retries K]
              [--dims 256,256,128,64] [--sparsity 0.9] [--repr condensed]
              [--label L] [--no-persist]
  srigl arena --history     (render persisted BENCH_*.json trajectory)
  srigl check
  srigl list
  srigl lint [--root DIR]   (SAFETY comments, serve-path unwraps, print
              macros, wire-constant drift; blocking in CI — docs/ANALYSIS.md)"
    );
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("exp") => {
            if args.has("list") || args.positional.len() < 2 {
                exp::list();
                return Ok(());
            }
            exp::run(&args.positional[1], &args)
        }
        Some("train") => cmd_train(&args),
        Some("srste") => cmd_srste(&args),
        Some("serve") => cmd_serve(&args),
        Some("serve-model") => cmd_serve_model(&args),
        Some("arena") => cmd_arena(&args),
        Some("check") => cmd_check(),
        Some("list") => cmd_list(),
        Some("lint") => srigl::lint::cmd(std::path::Path::new(&args.get_or("root", "."))),
        _ => {
            usage();
            Ok(())
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    // --config file.json loads the full config; CLI flags are ignored then.
    let cfg = if let Some(path) = args.get("config") {
        srigl::train::config_file::load(std::path::Path::new(path))?
    } else {
        let model = args.get_or("model", "cnn_proxy");
        let steps: usize = args.parse_or("steps", 300)?;
        let gamma: f64 = args.parse_or("gamma", 0.3)?;
        let method =
            Method::parse(&args.get_or("method", "srigl"), !args.has("no-ablation"), gamma)?;
        let dist: Distribution = args.get_or("dist", "erk").parse()?;
        TrainConfig {
            model,
            method,
            sparsity: args.parse_or("sparsity", 0.9)?,
            distribution: dist,
            total_steps: steps,
            delta_t: args.parse_or("delta-t", (steps / 15).max(5))?,
            alpha: args.parse_or("alpha", 0.3)?,
            lr: LrSchedule::step_decay(
                args.parse_or("lr", 0.1)?,
                &[steps / 2, 3 * steps / 4],
                0.2,
            ),
            grad_accum: args.parse_or("grad-accum", 1)?,
            seed: args.parse_or("seed", 0)?,
            eval_batches: args.parse_or("eval-batches", 8)?,
            dense_first_layer: args.has("dense-first-layer"),
        }
    };
    let (model, method, sparsity, steps) =
        (cfg.model.clone(), cfg.method, cfg.sparsity, cfg.total_steps);
    let sess = Session::open()?;
    let mut tr = sess.trainer(cfg)?;
    if let Some(dir) = args.get("load") {
        let ck = srigl::train::Checkpoint::load(std::path::Path::new(dir))?;
        println!("restored checkpoint from {dir} (step {})", ck.step);
        tr.restore(ck)?;
    }
    println!(
        "training {model} / {} @ {:.0}% sparsity for {steps} steps ({} params)",
        method.label(),
        sparsity * 100.0,
        tr.entry.param_count
    );
    if let Some(addr) = args.get("serve") {
        return train_and_serve(args, tr, addr, steps);
    }
    let rep = tr.run()?;
    if let Some(dir) = args.get("save") {
        tr.checkpoint(steps).save(std::path::Path::new(dir))?;
        println!("checkpoint saved to {dir}");
    }
    let n = rep.losses.len();
    println!(
        "loss: first={:.4} mid={:.4} last={:.4}",
        rep.losses.first().unwrap_or(&f32::NAN),
        rep.losses.get(n / 2).unwrap_or(&f32::NAN),
        rep.losses.last().unwrap_or(&f32::NAN)
    );
    println!("eval {} = {:.4}", rep.eval_kind, rep.eval_metric);
    println!(
        "final sparsity = {:.2}% | ITOP = {:.3} | {:.1}s ({:.2} steps/s)",
        rep.final_sparsity * 100.0,
        rep.itop_rate,
        rep.wall_s,
        rep.throughput
    );
    for (name, counts) in tr.mask_stats() {
        let top = srigl::stats::LayerTopology::from_counts(&name, &counts);
        println!(
            "  {name}: {}/{} neurons active, fan-in mean {:.1} (max {})",
            top.active_neurons, top.neurons, top.fan_in_mean, top.fan_in_max
        );
    }
    Ok(())
}

/// `srigl train --serve ADDR`: run the training loop on the main thread
/// while a swappable front-end serves the stack; every `--publish-every`
/// steps the current weights are exported and published as a new epoch,
/// so traffic moves to fresher snapshots without a restart or a dropped
/// request. Exits (and stops serving) when training completes.
fn train_and_serve(args: &Args, mut tr: srigl::train::Trainer, addr: &str, steps: usize) -> Result<()> {
    let repr = Repr::parse(&args.get_or("serve-repr", "condensed"))?;
    let every: usize = args.parse_or("publish-every", (steps / 4).max(1))?;
    anyhow::ensure!(every >= 1, "--publish-every must be >= 1");
    let builder = EngineBuilder::new()
        .workers(args.parse_or("serve-workers", 2)?)
        .adaptive(args.parse_or("max-batch", 8)?);
    let first = Arc::new(tr.export_model(repr)?);
    let handle = frontend::spawn_swappable(first, addr, &builder, args.get("metrics"), None)?;
    log::info(
        "train",
        &format!("serving snapshots on {} (publish every {every} steps)", handle.addr()),
    );
    for step in 0..steps {
        let loss = tr.step(step)?;
        if tr.is_update_step(step) {
            let _ = tr.update_topology(step)?;
        }
        if (step + 1) % every == 0 || step + 1 == steps {
            let epoch = handle.publish_model(Arc::new(tr.export_model(repr)?))?;
            log::info(
                "train",
                &format!("step {}: loss {loss:.4} -> published epoch {epoch}", step + 1),
            );
        }
    }
    let stats = handle.stop();
    println!(
        "trained {steps} steps; front-end served {} requests ({} cache hits) across live epochs",
        stats.served, stats.cache_hits
    );
    Ok(())
}

/// SR-STE baseline (Zhou et al. 2021): dense-to-sparse N:M training.
fn cmd_srste(args: &Args) -> Result<()> {
    let cfg = srigl::train::SrSteConfig {
        model: args.get_or("model", "mlp_proxy"),
        n: args.parse_or("n", 1)?,
        m: args.parse_or("m", 4)?,
        steps: args.parse_or("steps", 300)?,
        lr: args.parse_or("lr", 0.05)?,
        lambda_w: args.parse_or("lambda", 2e-4)?,
        momentum: 0.9,
        seed: args.parse_or("seed", 0)?,
        eval_batches: args.parse_or("eval-batches", 8)?,
    };
    let sess = Session::open()?;
    println!("SR-STE {}:{} on {} ({} steps; dense shadow weights)", cfg.n, cfg.m, cfg.model, cfg.steps);
    let rep = srigl::train::train_srste(&sess, &cfg)?;
    println!(
        "loss {:.3} -> {:.3} | eval {} = {:.4} | sparsity {:.1}% | {:.2} steps/s (compare `srigl train`)",
        rep.losses.first().unwrap_or(&f32::NAN),
        rep.losses.last().unwrap_or(&f32::NAN),
        rep.eval_kind,
        rep.eval_metric,
        rep.final_sparsity * 100.0,
        rep.throughput
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let sparsity: f64 = args.parse_or("sparsity", 0.9)?;
    let n_requests: usize = args.parse_or("requests", 500)?;
    let threads: usize = args.parse_or("threads", 1)?;
    let builder = match args.get("batched") {
        Some(v) => EngineBuilder::new().workers(1).fixed_batch(v.parse()?),
        None => EngineBuilder::online(),
    }
    .threads(threads);
    let bundle = LayerBundle::synth(
        exp::timings::VIT_FF_N,
        exp::timings::VIT_FF_D,
        sparsity,
        exp::timings::ablated_frac_for(sparsity),
        42,
    );
    println!(
        "online-inference server: ViT FF layer @ {:.0}% sparsity, {n_requests} requests",
        sparsity * 100.0
    );
    for kernel in bundle.kernels() {
        let stats = serve(
            kernel,
            &builder,
            &ServeConfig {
                n_requests,
                mean_interarrival: std::time::Duration::from_micros(args.parse_or("gap-us", 0u64)?),
                seed: 1,
            },
        );
        println!(
            "  {:<11} p50={:>8.1}us p99={:>8.1}us mean_batch={:.1} throughput={:.0} req/s",
            kernel.name(),
            stats.p50_us,
            stats.p99_us,
            stats.mean_batch,
            stats.throughput_rps
        );
    }
    Ok(())
}

/// Serve a multi-layer sparse model: by default through the in-process
/// Poisson benchmark (reporting workers=1 vs workers=N so the pool speedup
/// is visible); with `--listen ADDR`, through the network front-end until
/// the process is killed.
fn cmd_serve_model(args: &Args) -> Result<()> {
    let n_requests: usize = args.parse_or("requests", 2000)?;
    let workers: usize = args.parse_or("workers", 4)?;
    let threads: usize = args.parse_or("threads", 1)?;
    let gap = std::time::Duration::from_micros(args.parse_or("gap-us", 0u64)?);

    // The model's origin is kept as a re-loadable source (not just a
    // one-shot construction) so `--listen --reload` can re-read it — the
    // manifest entry may have been retrained/republished in place — and
    // swap the result in as a new epoch without dropping a request.
    let source = if let Some(name) = args.get("stack") {
        ModelSource::Stack(name.to_string())
    } else {
        let dims: Vec<usize> = args.list_or("dims", &[3072usize, 768, 768, 256])?;
        anyhow::ensure!(dims.len() >= 2, "--dims needs an input width plus >=1 layer widths");
        let sparsity: f64 = args.parse_or("sparsity", 0.9)?;
        let repr_flag = args.get_or("repr", "condensed");
        let n_layers = dims.len() - 1;
        let mut specs = Vec::with_capacity(n_layers);
        for (i, &n) in dims[1..].iter().enumerate() {
            let repr = if repr_flag == "mixed" {
                Repr::ALL[i % Repr::ALL.len()]
            } else {
                Repr::parse(&repr_flag)?
            };
            specs.push(LayerSpec {
                n,
                repr,
                sparsity,
                ablated_frac: exp::timings::ablated_frac_for(sparsity),
                activation: if i + 1 == n_layers { Activation::Identity } else { Activation::Relu },
            });
        }
        ModelSource::Synth { d_in: dims[0], specs }
    };
    let (knobs, stack_metrics) = match &source {
        ModelSource::Stack(name) => {
            let man = Manifest::load_default()?;
            let entry = man.stack(name)?;
            (entry.serve, entry.metrics.clone())
        }
        ModelSource::Synth { .. } => (ServeKnobs::default(), None),
    };
    let model = source.load()?;
    let max_batch: usize = args.parse_or("max-batch", knobs.max_batch)?;
    // In-process benches only go adaptive on an explicit flag (the PR-1
    // Poisson path stays byte-identical by default); the listen path
    // defaults to the stack's serve knobs, `--fixed-batch` overriding.
    let adaptive = args.has("adaptive");
    let shards: usize = args.parse_or("shards", knobs.shards)?;

    // Startup kernel report: which microkernel dispatch selected, and a
    // quick per-layer throughput estimate at the serving batch cap — so
    // bench logs can attribute serving numbers to the kernel that ran.
    report_kernel_selection(&model, max_batch, threads);

    // One construction path for every serving surface: the stack's serve
    // knobs seed the builder, CLI flags override.
    let builder = EngineBuilder::from_knobs(&knobs)
        .workers(workers)
        .threads(threads)
        .shards(shards)
        .queue_capacity(args.parse_or("queue-cap", knobs.queue_capacity)?)
        .cache_capacity(args.parse_or("cache-cap", knobs.cache_capacity)?)
        .egress_capacity(args.parse_or("egress-cap", knobs.egress_capacity)?)
        .retry_after_ms(args.parse_or("retry-ms", 2)?)
        .max_connections(args.parse_or("max-conns", knobs.max_connections)?);

    if let Some(addr) = args.get("listen") {
        let adaptive = adaptive || (knobs.adaptive && !args.has("fixed-batch"));
        let builder = if adaptive {
            builder.adaptive(max_batch)
        } else {
            builder.fixed_batch(max_batch)
        };
        // CLI --metrics wins; else the stack's "serve": {"metrics": ...}.
        let metrics = args.get("metrics").map(str::to_string).or(stack_metrics);
        let reload: Option<frontend::ReloadSource> = args
            .has("reload")
            .then(move || Box::new(move || Ok(Arc::new(source.load()?))) as frontend::ReloadSource);
        return serve_listen(model, addr, &builder, metrics.as_deref(), reload);
    }

    if shards > 1 {
        // replicated pool at the same core budget vs the shard team, so
        // the tensor-parallel tradeoff is visible in one run
        if adaptive || args.get("workers").is_some() {
            println!(
                "note: --shards comparison pins the replicated baseline to workers={shards} \
                 with fixed batching; --workers/--adaptive are ignored here (use --listen for \
                 a sharded front-end with those knobs)"
            );
        }
        println!("serving model: {} ({shards} shards)", model.describe());
        println!(
            "{} layers, {} KiB total, {n_requests} requests, cap={max_batch}, {threads} intra-shard thread(s)",
            model.depth(),
            model.storage_bytes() / 1024,
        );
        for (label, b) in [
            ("replicated", builder.workers(shards).fixed_batch(max_batch).shards(1)),
            ("sharded", builder.workers(1).fixed_batch(max_batch).shards(shards)),
        ] {
            let stats = serve_model(
                &model,
                &b,
                &ServeConfig { n_requests, mean_interarrival: gap, seed: 1 },
            )?;
            println!(
                "  {label:<10} p50={:>8.1}us p99={:>8.1}us mean_batch={:.1} throughput={:.0} req/s",
                stats.p50_us, stats.p99_us, stats.mean_batch, stats.throughput_rps
            );
        }
        return Ok(());
    }

    println!("serving model: {}", model.describe());
    println!(
        "{} layers, {} KiB total, {n_requests} requests, max_batch={max_batch}{}, {threads} intra-op thread(s)",
        model.depth(),
        model.storage_bytes() / 1024,
        if adaptive { " (adaptive)" } else { "" }
    );
    let mut worker_counts = vec![1usize];
    if workers > 1 {
        worker_counts.push(workers);
    }
    let mut base_rps = 0.0;
    for &w in &worker_counts {
        let b = if adaptive {
            builder.workers(w).adaptive(max_batch)
        } else {
            builder.workers(w).fixed_batch(max_batch)
        };
        let stats =
            serve_model(&model, &b, &ServeConfig { n_requests, mean_interarrival: gap, seed: 1 })?;
        let speedup = if base_rps > 0.0 {
            format!("  ({:.2}x vs 1 worker)", stats.throughput_rps / base_rps)
        } else {
            base_rps = stats.throughput_rps;
            String::new()
        };
        println!(
            "  workers={w:<2} p50={:>8.1}us p99={:>8.1}us mean_batch={:.1} throughput={:.0} req/s{speedup}",
            stats.p50_us, stats.p99_us, stats.mean_batch, stats.throughput_rps
        );
    }
    Ok(())
}

/// `srigl arena`: duel two engine specs on one shared synthetic trace and
/// persist the scored result; `--history` renders the accumulated
/// `BENCH_*.json` trajectory instead of running anything.
fn cmd_arena(args: &Args) -> Result<()> {
    use srigl::arena::{self, DuelConfig, Scenario, Trace, TraceSpec};

    if args.has("history") {
        let dir = arena::persist::bench_dir();
        let records = arena::load_history(&dir)?;
        print!("{}", arena::render_history(&records));
        return Ok(());
    }

    let scenario = Scenario::parse(&args.get_or("scenario", "poisson"))?;
    let spec = TraceSpec {
        scenario,
        n_requests: args.parse_or("requests", 400)?,
        mean_gap_us: args.parse_or("gap-us", 200.0)?,
        max_rows: args.parse_or("max-rows", 4)?,
        pool: args.parse_or("pool", 64)?,
        seed: args.parse_or("seed", 1)?,
    };
    let trace = Trace::generate(&spec);

    // Same synth path as serve-model: --dims widths, uniform sparsity,
    // one representation, Identity on the last layer.
    let dims: Vec<usize> = args.list_or("dims", &[256usize, 256, 128, 64])?;
    anyhow::ensure!(dims.len() >= 2, "--dims needs an input width plus >=1 layer widths");
    let sparsity: f64 = args.parse_or("sparsity", 0.9)?;
    let repr = Repr::parse(&args.get_or("repr", "condensed"))?;
    let n_layers = dims.len() - 1;
    let specs: Vec<LayerSpec> = dims[1..]
        .iter()
        .enumerate()
        .map(|(i, &n)| LayerSpec {
            n,
            repr,
            sparsity,
            ablated_frac: exp::timings::ablated_frac_for(sparsity),
            activation: if i + 1 == n_layers { Activation::Identity } else { Activation::Relu },
        })
        .collect();
    let model = std::sync::Arc::new(SparseModel::synth(dims[0], &specs, 42)?);

    let a_spec = args.get_or("a", "workers=4,batch=8");
    let b_spec = args.get_or("b", "workers=4,adaptive=8");
    let a = arena::parse_engine_spec(&a_spec)?;
    let b = arena::parse_engine_spec(&b_spec)?;
    let cfg = DuelConfig {
        rounds: args.parse_or("rounds", 3)?,
        wire: args.has("wire"),
        clients: args.parse_or("clients", 4)?,
        max_retries: args.parse_or("max-retries", 8)?,
    };

    println!("model: {}", model.describe());
    println!(
        "trace: {} | {} requests | digest {:016x}{}",
        scenario.name(),
        trace.events.len(),
        trace.digest(),
        if cfg.wire { " | wire mode (loopback front-end)" } else { "" }
    );
    let summary =
        arena::run_duel(&model, (&a_spec, &a), (&b_spec, &b), &trace, &cfg, |line| {
            println!("  {line}")
        })?;
    print!("{}", summary.render());

    if !args.has("no-persist") {
        let name = format!("arena-{}", scenario.name());
        let path = arena::persist::persist_record(
            "arena",
            &name,
            &summary.headline(),
            summary.to_json(),
            args.get("label"),
        )?;
        println!("record -> {}", path.display());
    }
    Ok(())
}

/// Where `serve-model` got its model from, kept so `--reload` can get it
/// again: a manifest stack is re-read from disk (picking up a retrain
/// that republished the entry in place); a synth spec re-derives the same
/// deterministic stack (epoch bumps, bits identical — still useful for
/// exercising the swap path end to end).
enum ModelSource {
    Stack(String),
    Synth { d_in: usize, specs: Vec<LayerSpec> },
}

impl ModelSource {
    fn load(&self) -> Result<SparseModel> {
        match self {
            ModelSource::Stack(name) => {
                let man = Manifest::load_default()?;
                SparseModel::from_stack(man.stack(name)?)
            }
            ModelSource::Synth { d_in, specs } => SparseModel::synth(*d_in, specs, 42),
        }
    }
}

/// SIGHUP-to-flag bridge for `serve-model --listen --reload`. A signal
/// handler may only do async-signal-safe work, so it sets one atomic; the
/// serve loop polls it and runs the actual (allocating, locking) reload.
#[cfg(unix)]
mod sighup {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static PENDING: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_hup(_sig: i32) {
        PENDING.store(true, Ordering::SeqCst);
    }

    /// Install the handler (raw libc `signal` — no new dependency).
    pub fn install() {
        const SIGHUP: i32 = 1;
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        // SAFETY: `signal` is async-signal-safe to install at startup;
        // `on_hup` is `extern "C"`, matches the handler ABI, and only
        // touches a lock-free static AtomicBool, which is the entire set
        // of operations POSIX permits inside a signal handler.
        unsafe {
            signal(SIGHUP, on_hup as usize);
        }
    }

    pub fn take() -> bool {
        PENDING.swap(false, Ordering::SeqCst)
    }
}

/// Print the process-wide microkernel selection and, per layer, the
/// representation, shape, stored weights, and a quick measured GFLOP/s
/// estimate at the serving batch cap (2 FLOPs per stored weight per
/// example; ablated neurons store nothing, so compact forms are credited
/// only for work they actually do). A few milliseconds per layer at
/// startup buys bench JSON lines that can track kernel selection and
/// per-layer throughput across machines.
fn report_kernel_selection(model: &SparseModel, batch: usize, threads: usize) {
    use srigl::bench::bench;
    if !log::enabled(log::Level::Info) {
        return; // quieted: skip the per-layer probe entirely
    }
    log::info(
        "kernel",
        &format!(
            "dispatch: {} (SRIGL_KERNEL=scalar|portable|avx2 overrides)",
            srigl::kernels::describe_selection()
        ),
    );
    let batch = batch.max(1);
    for (i, layer) in model.layers().iter().enumerate() {
        let k = layer.kernel();
        let stored: usize = layer.row_weights().iter().sum();
        // MAC count is representation-independent; *bytes per stored
        // weight* is not (f32 condensed: 8 = value + index; int8
        // quantized: 4 = one packed record) — take real storage from the
        // kernel instead of assuming 4-byte weights, so the probe
        // attributes int8 speedups to the halved weight stream.
        let flops = 2.0 * stored as f64 * batch as f64;
        let bytes = k.storage_bytes();
        let x = vec![0.1f32; batch * k.in_width()];
        let mut out = vec![0f32; batch * k.out_width()];
        let m = bench("layer", 5, std::time::Duration::from_millis(4), || {
            k.forward(&x, batch, &mut out, threads);
        });
        log::info(
            "kernel",
            &format!(
                "layer {i}: {:<15} {:>5}x{:<5} {:>9} stored weights ({:>6} KiB, {:.1} B/wt), \
                 est {:>7.2} GFLOP/s @ batch {batch}",
                k.name(),
                k.out_width(),
                k.in_width(),
                stored,
                bytes / 1024,
                bytes as f64 / stored.max(1) as f64,
                flops / m.median_s().max(1e-12) / 1e9
            ),
        );
    }
}

/// `serve-model --listen ADDR`: run the socket front-end until killed.
/// The builder (manifest knobs + CLI overrides) is the single source of
/// serving configuration.
fn serve_listen(
    model: SparseModel,
    addr: &str,
    builder: &EngineBuilder,
    metrics: Option<&str>,
    reload: Option<frontend::ReloadSource>,
) -> Result<()> {
    log::info("serve", &format!("serving model: {}", model.describe()));
    let reloadable = reload.is_some();
    let handle = if reloadable {
        frontend::spawn_swappable(Arc::new(model), addr, builder, metrics, reload)?
    } else {
        frontend::spawn_with_metrics(Arc::new(model), addr, builder, metrics)?
    };
    log::info(
        "serve",
        &format!(
            "listening on {} — {} workers, {} batching (cap {}), queue cap {}, cache {} entries, \
             egress cap {}{}",
            handle.addr(),
            builder.workers,
            match builder.batching {
                srigl::inference::server::Batching::Adaptive { .. } => "adaptive",
                srigl::inference::server::Batching::Fixed(_) => "fixed",
            },
            builder.max_batch(),
            builder.queue_capacity,
            builder.cache_capacity,
            builder.egress_capacity,
            if builder.is_sharded() {
                format!(", {} shards/forward (persistent team)", builder.shards)
            } else {
                String::new()
            }
        ),
    );
    if let Some(m) = handle.metrics_addr() {
        log::info("serve", &format!("metrics: http://{m}/metrics (Prometheus text; docs/METRICS.md)"));
    }
    if builder.max_connections > 0 {
        log::info(
            "serve",
            &format!("connection cap: {} (over-cap connects get Busy)", builder.max_connections),
        );
    }
    log::info("serve", "wire format: docs/WIRE.md; stop with Ctrl-C");
    if !reloadable {
        handle.run_forever();
        return Ok(());
    }
    #[cfg(not(unix))]
    {
        log::info("serve", "reload enabled via wire control frame (no SIGHUP on this platform)");
        handle.run_forever();
        return Ok(());
    }
    #[cfg(unix)]
    {
        sighup::install();
        log::info("serve", "reload enabled: SIGHUP or a wire control frame swaps in a new epoch");
        // Poll the signal flag on the main thread (the acceptor runs on its
        // own thread); the handle stays here so reload_now can use it.
        loop {
            std::thread::sleep(std::time::Duration::from_millis(200));
            if sighup::take() {
                match handle.reload_now() {
                    Ok(epoch) => log::info("serve", &format!("SIGHUP reload -> epoch {epoch}")),
                    Err(e) => log::warn("serve", &format!("SIGHUP reload failed: {e:#}")),
                }
            }
        }
    }
}

fn cmd_check() -> Result<()> {
    let man = Manifest::load_default()?;
    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());
    for (name, entry) in &man.models {
        for prog in entry.programs.keys() {
            let p = man.program_path(entry, prog)?;
            rt.load(&p)?;
        }
        println!("  model {name}: {} programs compile OK", entry.programs.len());
    }
    for (name, c) in &man.condensed {
        rt.load(&man.dir.join(&c.file))?;
        println!("  condensed {name}: compiles OK");
    }
    println!("artifacts check passed");
    Ok(())
}

fn cmd_list() -> Result<()> {
    let man = Manifest::load_default()?;
    println!("{:<12} {:>12} {:>7} {:>6}  task", "model", "params", "sparse", "batch");
    for (name, e) in &man.models {
        let ns = e.sparse_indices().len();
        println!("{:<12} {:>12} {:>7} {:>6}  {}", name, e.param_count, ns, e.batch, e.task);
    }
    for (name, c) in &man.condensed {
        println!("condensed {name}: ({}x{}) k={} batch={}", c.n, c.d, c.k, c.batch);
    }
    if let Some(e) = man.models.values().next() {
        let ds = data::for_model(e, 0);
        println!("dataset for {}: {}", e.name, ds.name());
    }
    Ok(())
}
