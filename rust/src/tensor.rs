//! Host-side tensor: a flat `Vec<f32>` plus shape. The L3 coordinator owns
//! all training state (params/momenta/masks) in this form and marshals it
//! to/from PJRT literals at each step (cheap memcpy on the CPU client).

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Leading-axis size (the neuron axis for all our sparse layouts) and
    /// the per-neuron fan-in (product of the remaining axes).
    pub fn neuron_view(&self) -> (usize, usize) {
        let n = *self.shape.first().unwrap_or(&1);
        let fan_in = if n == 0 { 0 } else { self.numel() / n };
        (n, fan_in)
    }

    /// He-normal init scaled by the *sparse* fan-in (Evci et al. 2022):
    /// sigma = sqrt(2 / k) where k is the per-neuron active connection
    /// count under the initial mask.
    pub fn he_sparse(shape: &[usize], sparse_fan_in: usize, rng: &mut Rng) -> Tensor {
        let sigma = (2.0 / sparse_fan_in.max(1) as f64).sqrt();
        let mut t = Tensor::zeros(shape);
        for v in t.data.iter_mut() {
            *v = (rng.normal() * sigma) as f32;
        }
        t
    }

    pub fn normal(shape: &[usize], sigma: f64, rng: &mut Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        for v in t.data.iter_mut() {
            *v = (rng.normal() * sigma) as f32;
        }
        t
    }

    pub fn scale(&mut self, s: f32) {
        for v in self.data.iter_mut() {
            *v *= s;
        }
    }

    /// Elementwise multiply (used to re-mask params after topology edits).
    pub fn mul_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a *= b;
        }
    }

    pub fn add_scaled(&mut self, other: &Tensor, s: f32) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    pub fn count_nonzero(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_views() {
        let t = Tensor::zeros(&[8, 3, 3, 2]);
        assert_eq!(t.numel(), 144);
        assert_eq!(t.neuron_view(), (8, 18));
    }

    #[test]
    fn he_sparse_scale() {
        let mut rng = Rng::new(0);
        let t = Tensor::he_sparse(&[64, 256], 16, &mut rng);
        let var: f64 =
            t.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>() / t.numel() as f64;
        let expect = 2.0 / 16.0;
        assert!((var - expect).abs() < 0.02 * expect * 10.0, "var={var} expect={expect}");
    }

    #[test]
    fn elementwise_ops() {
        let mut a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let m = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 1.0, 0.0]);
        a.mul_assign(&m);
        assert_eq!(a.data, vec![1.0, 0.0, 3.0, 0.0]);
        assert_eq!(a.count_nonzero(), 2);
        a.add_scaled(&m, 0.5);
        assert_eq!(a.data, vec![1.5, 0.0, 3.5, 0.0]);
        assert_eq!(a.abs_max(), 3.5);
    }
}
