//! Wall-clock benchmark harness — in-tree replacement for `criterion`
//! (offline environment).
//!
//! Matches the paper's reporting protocol: "the median over a minimum of
//! 5 runs is shown, while the error bars show the std. dev." (Fig. 4).

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Per-run wall-clock seconds (each run is `iters` inner iterations,
    /// already divided out).
    pub runs: Vec<f64>,
    pub iters: usize,
}

impl Measurement {
    pub fn median_s(&self) -> f64 {
        let mut v = self.runs.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        if n == 0 {
            return f64::NAN;
        }
        if n % 2 == 1 {
            v[n / 2]
        } else {
            0.5 * (v[n / 2 - 1] + v[n / 2])
        }
    }

    pub fn mean_s(&self) -> f64 {
        self.runs.iter().sum::<f64>() / self.runs.len().max(1) as f64
    }

    pub fn stddev_s(&self) -> f64 {
        let m = self.mean_s();
        let n = self.runs.len();
        if n < 2 {
            return 0.0;
        }
        (self.runs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn median_us(&self) -> f64 {
        self.median_s() * 1e6
    }
}

/// Benchmark `f`, auto-calibrating the inner iteration count so one run
/// takes ~`target_run` wall-clock, then timing `runs` runs after one
/// warmup. Returns per-run seconds normalized per iteration.
pub fn bench<F: FnMut()>(name: &str, runs: usize, target_run: Duration, mut f: F) -> Measurement {
    // calibrate
    let mut iters = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed();
        if dt >= target_run || iters >= 1 << 20 {
            break;
        }
        if dt < target_run / 16 {
            iters = iters.saturating_mul(8);
        } else {
            let scale = target_run.as_secs_f64() / dt.as_secs_f64().max(1e-9);
            iters = ((iters as f64 * scale).ceil() as usize).max(iters + 1);
        }
    }
    // warmup
    for _ in 0..iters {
        f();
    }
    // measure
    let mut out = Vec::with_capacity(runs);
    for _ in 0..runs.max(5) {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        out.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    Measurement { name: name.to_string(), runs: out, iters }
}

/// Convenience wrapper with the paper's protocol: >=5 runs, short target.
pub fn bench5<F: FnMut()>(name: &str, f: F) -> Measurement {
    bench(name, 5, Duration::from_millis(50), f)
}

/// Render a table of measurements with a speedup column vs a baseline row.
pub fn print_table(title: &str, rows: &[Measurement], baseline: Option<&str>) {
    println!("\n== {title} ==");
    let base = baseline
        .and_then(|b| rows.iter().find(|m| m.name == b))
        .map(|m| m.median_s());
    println!("{:<42} {:>12} {:>12} {:>9}", "case", "median", "stddev", "speedup");
    for m in rows {
        let med = m.median_s();
        let speed = base.map(|b| b / med);
        println!(
            "{:<42} {:>12} {:>12} {:>9}",
            m.name,
            fmt_time(med),
            fmt_time(m.stddev_s()),
            speed.map(|s| format!("{s:.2}x")).unwrap_or_else(|| "-".into()),
        );
    }
}

pub fn fmt_time(s: f64) -> String {
    if !s.is_finite() {
        "n/a".into()
    } else if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.2} us", s * 1e6)
    }
}

/// Guard against the optimizer deleting benchmark bodies.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_stddev() {
        let m = Measurement { name: "x".into(), runs: vec![3.0, 1.0, 2.0], iters: 1 };
        assert_eq!(m.median_s(), 2.0);
        assert!((m.stddev_s() - 1.0).abs() < 1e-12);
        let e = Measurement { name: "e".into(), runs: vec![1.0, 2.0], iters: 1 };
        assert_eq!(e.median_s(), 1.5);
    }

    #[test]
    fn bench_runs_at_least_five() {
        let m = bench("t", 5, Duration::from_micros(100), || {
            black_box(1 + 1);
        });
        assert!(m.runs.len() >= 5);
        assert!(m.median_s() >= 0.0);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
    }
}
