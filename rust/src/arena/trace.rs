//! Deterministic request-trace generators — the scenario library of the
//! traffic arena.
//!
//! A [`Trace`] is a seeded, fully materialized request schedule: for each
//! request, an absolute submit time (µs from trace start), a row count,
//! and a payload-pool index. Both sides of an arena duel replay the
//! *same* trace, which is what makes their per-request latency and
//! per-round throughput differences paired observations
//! ([`crate::stats::compare`]).
//!
//! Scenarios (all driven by one xoshiro [`Rng`] stream with a fixed
//! per-event draw order, so the same seed reproduces the same schedule
//! byte-for-byte):
//!
//! * [`Scenario::Poisson`] — the baseline open-loop load: exponential
//!   inter-arrival gaps at the configured mean (same distribution and 10x
//!   clamp as [`crate::inference::server::poisson_gap`]).
//! * [`Scenario::Bursty`] — flash crowds: Poisson background punctuated by
//!   bursts (geometric start, uniform 64..=128 events long) during which
//!   gaps shrink 50x. Most events sit inside a burst, so the gap
//!   distribution is far overdispersed vs Poisson (CV ≈ 2.6 vs 1).
//! * [`Scenario::Diurnal`] — a day-curve ramp: the arrival rate follows a
//!   half-sine from 25% (trace edges) to 100% (mid-trace), so a run sweeps
//!   trough -> peak -> trough loads in one replay.
//! * [`Scenario::HeavyTail`] — heavy-tailed batch sizes: rows per request
//!   are Pareto(α=1.2) clamped to `[1, max_rows]` — mostly single-row
//!   requests with rare near-cap monsters that stress packing.
//! * [`Scenario::Adversarial`] — cache-adversarial: every request gets a
//!   unique payload, so a result cache never hits and the full compute
//!   path is measured (the pool scenarios re-draw from `pool` payloads and
//!   measure cache-friendly traffic instead).

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Probability a burst starts at a non-burst event (expected ~32 quiet
/// events between bursts).
const BURST_START_P: f64 = 1.0 / 32.0;
/// Burst length is uniform in `BURST_LEN_MIN..=BURST_LEN_MAX` events.
const BURST_LEN_MIN: usize = 64;
const BURST_LEN_MAX: usize = 128;
/// Inside a burst the mean gap shrinks by this factor.
const BURST_SPEEDUP: f64 = 50.0;
/// Diurnal trough rate as a fraction of the peak rate.
const DIURNAL_TROUGH: f64 = 0.25;
/// Pareto shape for heavy-tailed row counts (α ≤ 2: infinite variance
/// before the clamp — genuinely heavy).
const HEAVY_TAIL_ALPHA: f64 = 1.2;

/// A load scenario the generator can materialize.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    Poisson,
    Bursty,
    Diurnal,
    HeavyTail,
    Adversarial,
}

impl Scenario {
    pub const ALL: [Scenario; 5] = [
        Scenario::Poisson,
        Scenario::Bursty,
        Scenario::Diurnal,
        Scenario::HeavyTail,
        Scenario::Adversarial,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Scenario::Poisson => "poisson",
            Scenario::Bursty => "bursty",
            Scenario::Diurnal => "diurnal",
            Scenario::HeavyTail => "heavytail",
            Scenario::Adversarial => "adversarial",
        }
    }

    pub fn parse(s: &str) -> Result<Scenario> {
        match s {
            "poisson" => Ok(Scenario::Poisson),
            "bursty" => Ok(Scenario::Bursty),
            "diurnal" => Ok(Scenario::Diurnal),
            "heavytail" => Ok(Scenario::HeavyTail),
            "adversarial" => Ok(Scenario::Adversarial),
            other => bail!(
                "unknown scenario {other:?} (known: poisson, bursty, diurnal, heavytail, adversarial)"
            ),
        }
    }
}

/// Everything that determines a trace. Same spec -> same [`Trace`],
/// bit-for-bit.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSpec {
    pub scenario: Scenario,
    pub n_requests: usize,
    /// Mean inter-arrival gap in µs (the Poisson/background mean; bursty
    /// and diurnal modulate around it). `0` floods.
    pub mean_gap_us: f64,
    /// Rows per request are drawn in `[1, max_rows]` (uniformly, except
    /// [`Scenario::HeavyTail`]'s Pareto draw). Must be ≤ both duel
    /// configs' batching caps ([`super::replay::validate`]).
    pub max_rows: usize,
    /// Distinct payloads requests draw from (cache-hit traffic);
    /// [`Scenario::Adversarial`] ignores this and gives every request a
    /// unique payload.
    pub pool: usize,
    pub seed: u64,
}

/// One scheduled request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// Absolute submit time, µs from trace start.
    pub at_us: u64,
    /// Feature rows this request carries.
    pub rows: u32,
    /// Index into the payload pool ([`Trace::payloads`]).
    pub payload: u32,
}

/// A materialized request schedule (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    pub spec: TraceSpec,
    pub events: Vec<TraceEvent>,
}

/// One exponential gap draw with the same 10x-mean clamp as
/// [`crate::inference::server::poisson_gap`] (one extreme tail draw must
/// not stall a replay for unbounded time). Always consumes exactly one
/// uniform draw so the generator's stream position is scenario-shape
/// independent of the configured mean.
fn exp_gap_us(mean_us: f64, rng: &mut Rng) -> f64 {
    let u = rng.uniform().max(1e-12);
    if mean_us <= 0.0 {
        return 0.0;
    }
    (mean_us * -u.ln()).min(10.0 * mean_us)
}

/// Pareto(α) row count clamped to `[1, max_rows]`: `floor(u^(-1/α))`.
fn pareto_rows(max_rows: usize, rng: &mut Rng) -> usize {
    let u = rng.uniform().max(1e-12);
    let r = (1.0 / u).powf(1.0 / HEAVY_TAIL_ALPHA).floor() as usize;
    r.clamp(1, max_rows)
}

impl Trace {
    /// Materialize the schedule for `spec`. Deterministic: one
    /// [`Rng`] stream, fixed per-event draw order (burst state, gap, rows,
    /// payload), accumulation in f64 µs rounded once per event.
    pub fn generate(spec: &TraceSpec) -> Trace {
        let mut rng = Rng::new(spec.seed);
        let n = spec.n_requests;
        let mean = spec.mean_gap_us.max(0.0);
        let max_rows = spec.max_rows.max(1);
        let pool = spec.pool.max(1);
        let mut events = Vec::with_capacity(n);
        let mut t_us = 0.0f64;
        let mut burst_left = 0usize;
        for i in 0..n {
            let gap = match spec.scenario {
                Scenario::Poisson | Scenario::HeavyTail | Scenario::Adversarial => {
                    exp_gap_us(mean, &mut rng)
                }
                Scenario::Bursty => {
                    if burst_left == 0 && rng.uniform() < BURST_START_P {
                        burst_left =
                            BURST_LEN_MIN + rng.below(BURST_LEN_MAX - BURST_LEN_MIN + 1);
                    }
                    if burst_left > 0 {
                        burst_left -= 1;
                        exp_gap_us(mean / BURST_SPEEDUP, &mut rng)
                    } else {
                        exp_gap_us(mean, &mut rng)
                    }
                }
                Scenario::Diurnal => {
                    // rate factor follows a half-sine over the trace:
                    // trough at the edges, peak mid-trace; a slower rate
                    // means a proportionally longer gap
                    let x = if n > 1 { i as f64 / (n - 1) as f64 } else { 0.5 };
                    let r =
                        DIURNAL_TROUGH + (1.0 - DIURNAL_TROUGH) * (std::f64::consts::PI * x).sin();
                    exp_gap_us(mean, &mut rng) / r
                }
            };
            t_us += gap;
            let rows = match spec.scenario {
                Scenario::HeavyTail => pareto_rows(max_rows, &mut rng),
                _ => 1 + rng.below(max_rows),
            } as u32;
            let payload = match spec.scenario {
                Scenario::Adversarial => i as u32, // unique: every request misses the cache
                _ => rng.below(pool) as u32,
            };
            events.push(TraceEvent { at_us: t_us.round() as u64, rows, payload });
        }
        Trace { spec: spec.clone(), events }
    }

    /// Largest row count any event carries (1 for an empty trace) — what
    /// a replaying engine's batching cap must cover.
    pub fn max_event_rows(&self) -> usize {
        self.events.iter().map(|e| e.rows as usize).max().unwrap_or(1)
    }

    /// FNV-1a over the packed event stream — a cheap schedule fingerprint
    /// for summaries and determinism tests (two traces with equal digests
    /// replayed the same load).
    pub fn digest(&self) -> u64 {
        let mut bytes = Vec::with_capacity(self.events.len() * 16);
        for e in &self.events {
            bytes.extend_from_slice(&e.at_us.to_le_bytes());
            bytes.extend_from_slice(&e.rows.to_le_bytes());
            bytes.extend_from_slice(&e.payload.to_le_bytes());
        }
        crate::net::fnv1a(&bytes)
    }

    /// Materialize the payload pool for input width `d`: entry `p` holds
    /// `max_rows_referencing(p) * d` standard-normal f32s, so any event
    /// can slice its `rows * d` prefix. Drawn from a seed-derived stream
    /// decoupled from the schedule draws (deterministic per spec).
    pub fn payloads(&self, d: usize) -> Vec<Vec<f32>> {
        let pool_n = self.events.iter().map(|e| e.payload as usize + 1).max().unwrap_or(0);
        let mut rows_need = vec![1usize; pool_n];
        for e in &self.events {
            let p = e.payload as usize;
            rows_need[p] = rows_need[p].max(e.rows as usize);
        }
        let mut rng = Rng::new(self.spec.seed ^ 0x5EED_F00D_D00F_DEE5);
        rows_need
            .iter()
            .map(|&r| (0..r * d).map(|_| rng.normal_f32()).collect())
            .collect()
    }

    /// Inter-arrival gaps in µs (`events[i].at_us - events[i-1].at_us`;
    /// the first gap is from t=0) — the raw material of the shape tests.
    pub fn gaps_us(&self) -> Vec<f64> {
        let mut prev = 0u64;
        self.events
            .iter()
            .map(|e| {
                let g = e.at_us.saturating_sub(prev) as f64;
                prev = e.at_us;
                g
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(scenario: Scenario, seed: u64) -> TraceSpec {
        TraceSpec { scenario, n_requests: 500, mean_gap_us: 100.0, max_rows: 8, pool: 16, seed }
    }

    #[test]
    fn generation_is_deterministic() {
        for sc in Scenario::ALL {
            let a = Trace::generate(&spec(sc, 42));
            let b = Trace::generate(&spec(sc, 42));
            assert_eq!(a, b, "{sc:?}: same spec, same trace");
            assert_eq!(a.digest(), b.digest());
            let c = Trace::generate(&spec(sc, 43));
            assert_ne!(a.digest(), c.digest(), "{sc:?}: different seed, different schedule");
        }
    }

    #[test]
    fn scenarios_produce_distinct_schedules() {
        let digests: Vec<u64> =
            Scenario::ALL.iter().map(|&sc| Trace::generate(&spec(sc, 7)).digest()).collect();
        for i in 0..digests.len() {
            for j in (i + 1)..digests.len() {
                assert_ne!(digests[i], digests[j], "{:?} vs {:?}", Scenario::ALL[i], Scenario::ALL[j]);
            }
        }
    }

    #[test]
    fn events_are_ordered_and_bounded() {
        for sc in Scenario::ALL {
            let t = Trace::generate(&spec(sc, 3));
            assert_eq!(t.events.len(), 500);
            let mut prev = 0u64;
            for e in &t.events {
                assert!(e.at_us >= prev, "{sc:?}: submit times must be non-decreasing");
                prev = e.at_us;
                assert!((1..=8).contains(&(e.rows as usize)), "{sc:?}: rows in [1, max_rows]");
            }
            assert!(t.max_event_rows() <= 8);
        }
    }

    #[test]
    fn adversarial_payloads_are_unique() {
        let t = Trace::generate(&spec(Scenario::Adversarial, 9));
        let mut seen = std::collections::HashSet::new();
        for e in &t.events {
            assert!(seen.insert(e.payload), "payload {} repeats — cache would hit", e.payload);
        }
        // pool-based scenarios reuse payloads (that's the cache-hit traffic)
        let p = Trace::generate(&spec(Scenario::Poisson, 9));
        let distinct: std::collections::HashSet<u32> =
            p.events.iter().map(|e| e.payload).collect();
        assert!(distinct.len() <= 16, "pool bound respected");
        assert!(distinct.len() > 1, "pool actually sampled");
    }

    #[test]
    fn payload_pool_covers_every_event() {
        for sc in Scenario::ALL {
            let t = Trace::generate(&spec(sc, 5));
            let d = 3;
            let pool = t.payloads(d);
            for e in &t.events {
                let p = &pool[e.payload as usize];
                assert!(p.len() >= e.rows as usize * d, "{sc:?}: payload too small for rows");
            }
            // deterministic
            assert_eq!(pool, t.payloads(d));
        }
    }

    #[test]
    fn parse_roundtrips_names() {
        for sc in Scenario::ALL {
            assert_eq!(Scenario::parse(sc.name()).unwrap(), sc);
        }
        assert!(Scenario::parse("flood").is_err());
    }

    #[test]
    fn zero_mean_floods() {
        let mut s = spec(Scenario::Poisson, 1);
        s.mean_gap_us = 0.0;
        let t = Trace::generate(&s);
        assert!(t.events.iter().all(|e| e.at_us == 0), "zero mean gap = flood");
    }

    #[test]
    fn gaps_reconstruct_times() {
        let t = Trace::generate(&spec(Scenario::Bursty, 11));
        let gaps = t.gaps_us();
        let mut acc = 0.0;
        for (g, e) in gaps.iter().zip(&t.events) {
            acc += g;
            assert_eq!(acc as u64, e.at_us);
        }
    }
}
