//! The traffic arena: head-to-head engine duels on shared synthetic
//! traffic, scored with paired statistics, persisted as a performance
//! trajectory (`srigl arena`).
//!
//! The serving stack had accumulated knobs — worker count, fixed vs
//! adaptive batching, shard count, queue/cache/egress capacities — whose
//! comparisons lived in one-off bench runs under steady Poisson load, the
//! friendliest possible traffic. The arena makes comparisons *fair*,
//! *adversarial*, and *durable*:
//!
//! * **Fair** — both configs replay the *same* deterministic trace
//!   ([`trace`]): identical arrival times, batch sizes, and payloads,
//!   checked by a digest. Deltas are paired per round and per request, so
//!   the shared load pattern cancels ([`summary`], backed by
//!   [`crate::stats::compare`]).
//! * **Adversarial** — five scenarios ([`Scenario`]): Poisson baseline,
//!   bursty flash-crowds, a diurnal ramp, heavy-tailed batch sizes, and a
//!   cache-adversarial stream of never-repeating payloads.
//! * **Durable** — results persist as schema-versioned `BENCH_*.json`
//!   records ([`persist`]); `srigl arena --history` renders the
//!   trajectory across commits.
//!
//! [`replay`] drives the traffic either in-process (the serving pool
//! without sockets) or over loopback TCP through the real front-end and
//! retrying client — the mode where the cache, backpressure, and backoff
//! fixes are actually on the field.

pub mod persist;
pub mod replay;
pub mod summary;
pub mod trace;

use std::sync::Arc;

use anyhow::{bail, Context, Result};

pub use persist::{load_history, persist_bench_summary, render_history, HistoryRecord, SCHEMA_VERSION};
pub use replay::{replay, replay_wire, ReplayOutcome};
pub use summary::{summarize, DuelSummary};
pub use trace::{Scenario, Trace, TraceEvent, TraceSpec};

use crate::inference::engine::{EngineBuilder, QuantMode};
use crate::inference::SparseModel;
use crate::kernels::KernelKind;

/// Parse an engine-spec string like `"workers=4,adaptive=8,shards=2"`
/// into an [`EngineBuilder`]. Keys: `workers`, `batch` (fixed), `adaptive`
/// (cap), `shards`, `threads`, `queue`, `cache`, `egress`, `retry` (ms),
/// `conns` (live-connection cap; 0 = unlimited), plus two string-valued
/// model-transform keys: `quant` (off|rows|tiled — int8-quantize the
/// stack for this side) and `kernel` (scalar|portable|avx2 — force the
/// microkernel kind), which is what lets one arena process duel f32
/// against int8, or avx2 against scalar, on identical traffic.
/// Unknown keys error with the known list — a typo must not silently
/// bench the defaults.
pub fn parse_engine_spec(spec: &str) -> Result<EngineBuilder> {
    let mut b = EngineBuilder::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (key, val) = part
            .split_once('=')
            .with_context(|| format!("engine spec {part:?}: expected key=value"))?;
        let (key, val) = (key.trim(), val.trim());
        // string-valued keys first; everything else takes an integer
        match key {
            "quant" => {
                b = b.quant(QuantMode::parse(val).with_context(|| format!("engine spec {part:?}"))?);
                continue;
            }
            "kernel" => {
                let kind = KernelKind::parse(val).with_context(|| {
                    format!("engine spec {part:?}: unknown kernel (scalar|portable|avx2)")
                })?;
                b = b.kernel(Some(kind));
                continue;
            }
            _ => {}
        }
        let n: usize = val
            .parse()
            .with_context(|| format!("engine spec {part:?}: value must be an integer"))?;
        b = match key {
            "workers" => b.workers(n),
            "batch" => b.fixed_batch(n),
            "adaptive" => b.adaptive(n),
            "shards" => b.shards(n),
            "threads" => b.threads(n),
            "queue" => b.queue_capacity(n),
            "cache" => b.cache_capacity(n),
            "egress" => b.egress_capacity(n),
            "retry" => b.retry_after_ms(n as u32),
            "conns" => b.max_connections(n),
            other => bail!(
                "engine spec: unknown key {other:?} (known: workers, batch, adaptive, \
                 shards, threads, queue, cache, egress, retry, conns, quant, kernel)"
            ),
        };
    }
    Ok(b)
}

/// How a duel runs.
#[derive(Clone, Copy, Debug)]
pub struct DuelConfig {
    /// Paired replays per side (floored at 1). More rounds tighten the
    /// throughput interval.
    pub rounds: usize,
    /// Replay over loopback TCP through the real front-end instead of
    /// in-process (engages cache, backpressure, egress, client backoff).
    pub wire: bool,
    /// Client connections in wire mode (clamped to 1..=64).
    pub clients: usize,
    /// `Client::infer_retrying` retry budget in wire mode.
    pub max_retries: usize,
}

impl Default for DuelConfig {
    fn default() -> DuelConfig {
        DuelConfig { rounds: 3, wire: false, clients: 4, max_retries: 8 }
    }
}

/// Run a full duel: replay `trace` under specs `a` and `b` for
/// `cfg.rounds` paired rounds and score the result. Execution order
/// alternates each round (A,B then B,A) so slow machine drift — thermal
/// ramps, background load — cancels in the per-round pairing instead of
/// biasing whichever side always ran second. `log` receives one progress
/// line per round.
pub fn run_duel(
    model: &Arc<SparseModel>,
    a: (&str, &EngineBuilder),
    b: (&str, &EngineBuilder),
    trace: &Trace,
    cfg: &DuelConfig,
    mut log: impl FnMut(String),
) -> Result<DuelSummary> {
    replay::validate(trace, a.1).context("side A")?;
    replay::validate(trace, b.1).context("side B")?;
    // Each side's model transforms (quant=/kernel=) apply once up front,
    // not per round — quantization is a build-time cost in production too,
    // and the duel should score serving, not calibration.
    let a_model = a.1.prepare_model(model).context("side A")?;
    let b_model = b.1.prepare_model(model).context("side B")?;
    let rounds = cfg.rounds.max(1);
    let mut a_out = Vec::with_capacity(rounds);
    let mut b_out = Vec::with_capacity(rounds);
    let mut run_side = |m: &Arc<SparseModel>, builder: &EngineBuilder| -> Result<ReplayOutcome> {
        if cfg.wire {
            replay_wire(m, builder, trace, cfg.clients, cfg.max_retries)
        } else {
            replay(m, builder, trace)
        }
    };
    for round in 0..rounds {
        let (ra, rb) = if round % 2 == 0 {
            let ra = run_side(&a_model, a.1).with_context(|| format!("round {round}, side A"))?;
            let rb = run_side(&b_model, b.1).with_context(|| format!("round {round}, side B"))?;
            (ra, rb)
        } else {
            let rb = run_side(&b_model, b.1).with_context(|| format!("round {round}, side B"))?;
            let ra = run_side(&a_model, a.1).with_context(|| format!("round {round}, side A"))?;
            (ra, rb)
        };
        log(format!(
            "round {}/{rounds}: A {:.1} rps ({} served) | B {:.1} rps ({} served)",
            round + 1,
            ra.rps(),
            ra.served(),
            rb.rps(),
            rb.served()
        ));
        a_out.push(ra);
        b_out.push(rb);
    }
    summarize(trace, a.0, b.0, &a_out, &b_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::server::Batching;

    #[test]
    fn engine_spec_parses_every_key() {
        let b = parse_engine_spec(
            "workers=2,adaptive=16,shards=3,threads=2,queue=99,cache=0,egress=7,retry=5,conns=32",
        )
        .unwrap();
        assert_eq!(b.workers, 2);
        assert_eq!(b.batching, Batching::Adaptive { cap: 16 });
        assert_eq!(b.shards, 3);
        assert_eq!(b.threads, 2);
        assert_eq!(b.queue_capacity, 99);
        assert_eq!(b.cache_capacity, 0);
        assert_eq!(b.egress_capacity, 7);
        assert_eq!(b.retry_after_ms, 5);
        assert_eq!(b.max_connections, 32);

        let fixed = parse_engine_spec("batch=4").unwrap();
        assert_eq!(fixed.batching, Batching::Fixed(4));
        // later keys override earlier ones
        let last = parse_engine_spec("batch=4,adaptive=8").unwrap();
        assert_eq!(last.batching, Batching::Adaptive { cap: 8 });
        // empty spec is the defaults
        assert_eq!(parse_engine_spec("").unwrap(), EngineBuilder::new());
    }

    #[test]
    fn engine_spec_parses_model_transform_keys() {
        let b = parse_engine_spec("quant=tiled,kernel=scalar,workers=2").unwrap();
        assert_eq!(b.quant, QuantMode::Tiled);
        assert_eq!(b.kernel, Some(KernelKind::Scalar));
        assert_eq!(b.workers, 2);
        assert_eq!(parse_engine_spec("quant=rows").unwrap().quant, QuantMode::Rows);
        assert_eq!(parse_engine_spec("quant=off").unwrap().quant, QuantMode::Off);
        assert_eq!(parse_engine_spec("").unwrap().kernel, None, "default: auto selection");
    }

    #[test]
    fn engine_spec_rejects_garbage() {
        for bad in
            ["wrkers=2", "workers", "workers=x", "batch=4,boop=1", "quant=fp4", "kernel=sse"]
        {
            let err = parse_engine_spec(bad).unwrap_err();
            assert!(!format!("{err:#}").is_empty(), "{bad}");
        }
        assert!(format!("{:#}", parse_engine_spec("boop=1").unwrap_err()).contains("known:"));
    }
}
