//! Duel scoring: fold two sides' per-round [`ReplayOutcome`]s into a
//! [`DuelSummary`] — paired deltas with confidence intervals and a
//! verdict.
//!
//! Two paired metrics, two interval flavours (see [`crate::stats::compare`]
//! for why):
//!
//! * **Throughput** — per-round (B − A) answered-requests-per-second
//!   deltas, [`t_ci`] over the handful of replicates. Rounds alternate
//!   execution order (A-first, then B-first), so slow machine drift
//!   cancels in the pairing.
//! * **Latency** — per-request (A − B) µs diffs at matched trace
//!   positions (both sides replay the *same* events), pooled across
//!   rounds, [`bootstrap_mean_ci`] because latency diffs are skewed and
//!   plentiful. Positive mean ⇒ B answered faster. Positions either side
//!   failed to answer (NaN) are skipped — a pair needs both observations.
//!
//! The verdict is throughput-first: latency only decides when the
//! throughput interval straddles zero. Bootstrap resampling is seeded from
//! the trace digest, so re-scoring the same measurements reproduces the
//! same interval bit-for-bit.

use anyhow::Result;

use super::replay::ReplayOutcome;
use super::trace::Trace;
use crate::stats::compare::{bootstrap_mean_ci, t_ci, MeanCi, Verdict};
use crate::util::json::{arr, num, obj, s, Json};

/// Bootstrap resamples for the pooled latency-delta interval.
const BOOTSTRAP_RESAMPLES: usize = 1000;
/// Confidence level for both intervals.
const CONFIDENCE: f64 = 0.95;

/// The scored outcome of one A-vs-B duel over a shared trace.
#[derive(Clone, Debug)]
pub struct DuelSummary {
    /// Scenario name (e.g. "bursty").
    pub scenario: String,
    /// FNV-1a digest of the replayed trace — proof both sides saw the
    /// same schedule, and the bootstrap seed.
    pub digest: u64,
    pub n_requests: usize,
    pub mean_gap_us: f64,
    pub max_rows: usize,
    pub seed: u64,
    /// Engine-spec strings as the user wrote them.
    pub a_spec: String,
    pub b_spec: String,
    /// Per-round throughput observations (answered req/s).
    pub a_rps: Vec<f64>,
    pub b_rps: Vec<f64>,
    /// Per-round outcome records (rps, wall, latency block, frontend
    /// counters in wire mode) for the persisted JSON.
    pub a_rounds: Vec<Json>,
    pub b_rounds: Vec<Json>,
    /// t-interval over per-round (B − A) rps deltas; positive ⇒ B faster.
    pub rps_delta: MeanCi,
    /// Bootstrap interval over pooled per-request (A − B) latency diffs in
    /// µs; positive ⇒ B answers sooner.
    pub lat_saved_us: MeanCi,
    /// Matched request pairs behind `lat_saved_us`.
    pub paired: usize,
    pub verdict: Verdict,
    /// Which metric decided: "throughput", "latency", or "none".
    pub decided_by: &'static str,
}

/// Score a finished duel: `a_rounds`/`b_rounds` are the per-round
/// outcomes of replaying `trace` under each spec (equal length ≥ 1).
pub fn summarize(
    trace: &Trace,
    a_spec: &str,
    b_spec: &str,
    a_rounds: &[ReplayOutcome],
    b_rounds: &[ReplayOutcome],
) -> Result<DuelSummary> {
    anyhow::ensure!(
        !a_rounds.is_empty() && a_rounds.len() == b_rounds.len(),
        "duel needs matching non-empty round lists (got {} vs {})",
        a_rounds.len(),
        b_rounds.len()
    );
    let a_rps: Vec<f64> = a_rounds.iter().map(ReplayOutcome::rps).collect();
    let b_rps: Vec<f64> = b_rounds.iter().map(ReplayOutcome::rps).collect();
    let rps_deltas: Vec<f64> = a_rps.iter().zip(&b_rps).map(|(a, b)| b - a).collect();
    let rps_delta = t_ci(&rps_deltas);

    // Pool per-request paired diffs across rounds; a pair exists only
    // where BOTH sides answered that trace position.
    let mut diffs: Vec<f64> = Vec::new();
    for (ra, rb) in a_rounds.iter().zip(b_rounds) {
        for (la, lb) in ra.latencies_us.iter().zip(&rb.latencies_us) {
            if la.is_finite() && lb.is_finite() {
                diffs.push(la - lb);
            }
        }
    }
    let boot_seed = trace.digest() ^ trace.spec.seed;
    let lat_saved_us = bootstrap_mean_ci(&diffs, BOOTSTRAP_RESAMPLES, CONFIDENCE, boot_seed);

    let (verdict, decided_by) = match Verdict::from_ci(&rps_delta) {
        Verdict::Inconclusive => match Verdict::from_ci(&lat_saved_us) {
            Verdict::Inconclusive => (Verdict::Inconclusive, "none"),
            v => (v, "latency"),
        },
        v => (v, "throughput"),
    };

    Ok(DuelSummary {
        scenario: trace.spec.scenario.name().to_string(),
        digest: trace.digest(),
        n_requests: trace.spec.n_requests,
        mean_gap_us: trace.spec.mean_gap_us,
        max_rows: trace.spec.max_rows,
        seed: trace.spec.seed,
        a_spec: a_spec.to_string(),
        b_spec: b_spec.to_string(),
        a_rps,
        b_rps,
        a_rounds: a_rounds.iter().map(ReplayOutcome::to_json).collect(),
        b_rounds: b_rounds.iter().map(ReplayOutcome::to_json).collect(),
        rps_delta,
        lat_saved_us,
        paired: diffs.len(),
        verdict,
        decided_by,
    })
}

fn ci_json(ci: &MeanCi) -> Json {
    let fnum = |v: f64| if v.is_finite() { num(v) } else { Json::Null };
    obj(vec![("mean", fnum(ci.mean)), ("lo", fnum(ci.lo)), ("hi", fnum(ci.hi))])
}

impl DuelSummary {
    /// One-line result for the persisted record's `headline` field and the
    /// `--history` listing.
    pub fn headline(&self) -> String {
        format!(
            "{}: {} (rps B-A {:+.1} [{:+.1}, {:+.1}], n={} rounds)",
            self.scenario,
            self.verdict.label(),
            self.rps_delta.mean,
            self.rps_delta.lo,
            self.rps_delta.hi,
            self.a_rps.len(),
        )
    }

    /// Full record. Keys `scenario`/`digest`/`n_requests`/`gap_us`/
    /// `max_rows`/`seed`/`rounds` plus each side's `spec` are functions of
    /// the inputs alone — the determinism tests fingerprint on them. The
    /// `rounds`/`delta`/`verdict` blocks carry wall-clock measurements.
    pub fn to_json(&self) -> Json {
        let side = |spec: &str, rps: &[f64], rounds: &[Json]| {
            obj(vec![
                ("spec", s(spec)),
                ("rps", arr(rps.iter().map(|&v| num(v)))),
                ("rounds", arr(rounds.to_vec())),
            ])
        };
        obj(vec![
            ("scenario", s(&self.scenario)),
            ("digest", s(&format!("{:016x}", self.digest))),
            ("n_requests", num(self.n_requests as f64)),
            ("gap_us", num(self.mean_gap_us)),
            ("max_rows", num(self.max_rows as f64)),
            ("seed", num(self.seed as f64)),
            ("rounds", num(self.a_rps.len() as f64)),
            ("a", side(&self.a_spec, &self.a_rps, &self.a_rounds)),
            ("b", side(&self.b_spec, &self.b_rps, &self.b_rounds)),
            (
                "delta",
                obj(vec![
                    ("rps_b_minus_a", ci_json(&self.rps_delta)),
                    ("lat_saved_us_a_minus_b", ci_json(&self.lat_saved_us)),
                    ("paired", num(self.paired as f64)),
                ]),
            ),
            (
                "verdict",
                obj(vec![
                    ("result", s(self.verdict.label())),
                    ("decided_by", s(self.decided_by)),
                ]),
            ),
        ])
    }

    /// Human-readable block for the CLI.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let ci = |c: &MeanCi| format!("{:+.2} [{:+.2}, {:+.2}]", c.mean, c.lo, c.hi);
        out.push_str(&format!(
            "arena: scenario {} | {} requests | trace {:016x} | {} round(s)\n",
            self.scenario,
            self.n_requests,
            self.digest,
            self.a_rps.len()
        ));
        out.push_str(&format!("  A: {}\n     rps per round: {:?}\n", self.a_spec, rounded(&self.a_rps)));
        out.push_str(&format!("  B: {}\n     rps per round: {:?}\n", self.b_spec, rounded(&self.b_rps)));
        out.push_str(&format!("  throughput delta (B-A, rps): {}\n", ci(&self.rps_delta)));
        out.push_str(&format!(
            "  latency saved by B (A-B, us over {} pairs): {}\n",
            self.paired,
            ci(&self.lat_saved_us)
        ));
        out.push_str(&format!(
            "  verdict: {} (decided by {})\n",
            self.verdict.label(),
            self.decided_by
        ));
        out
    }
}

fn rounded(xs: &[f64]) -> Vec<f64> {
    xs.iter().map(|v| (v * 10.0).round() / 10.0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arena::trace::{Scenario, TraceSpec};
    use crate::inference::server::LatencyStats;

    fn trace() -> Trace {
        Trace::generate(&TraceSpec {
            scenario: Scenario::Poisson,
            n_requests: 6,
            mean_gap_us: 0.0,
            max_rows: 2,
            pool: 4,
            seed: 3,
        })
    }

    fn outcome(lat: Vec<f64>, wall_s: f64) -> ReplayOutcome {
        ReplayOutcome {
            stats: LatencyStats::from_workers(&[], wall_s),
            latencies_us: lat,
            wall_s,
            frontend: None,
            metrics: None,
        }
    }

    #[test]
    fn clear_winner_on_throughput() {
        let t = trace();
        // B consistently ~2x the throughput of A across 3 rounds
        let a: Vec<_> = (0..3).map(|i| outcome(vec![100.0; 6], 2.0 + 0.01 * i as f64)).collect();
        let b: Vec<_> = (0..3).map(|i| outcome(vec![50.0; 6], 1.0 + 0.01 * i as f64)).collect();
        let s = summarize(&t, "slow", "fast", &a, &b).unwrap();
        assert_eq!(s.verdict, Verdict::BWins);
        assert_eq!(s.decided_by, "throughput");
        assert_eq!(s.paired, 18);
        assert!(s.rps_delta.mean > 0.0 && s.rps_delta.excludes_zero());
        assert!(s.lat_saved_us.mean > 0.0, "B also saves latency");
        assert!(s.headline().contains("B wins"));
    }

    #[test]
    fn latency_decides_when_throughput_ties() {
        let t = trace();
        // identical wall-clock (rps deltas all zero -> zero-width interval
        // at 0 -> inconclusive) but B answers 40us sooner per request
        let a: Vec<_> = (0..3).map(|_| outcome(vec![100.0; 6], 1.0)).collect();
        let b: Vec<_> = (0..3).map(|_| outcome(vec![60.0; 6], 1.0)).collect();
        let s = summarize(&t, "a", "b", &a, &b).unwrap();
        assert_eq!(s.decided_by, "latency");
        assert_eq!(s.verdict, Verdict::BWins);
        assert!((s.lat_saved_us.mean - 40.0).abs() < 1e-9);
    }

    #[test]
    fn nan_positions_drop_out_of_pairing() {
        let t = trace();
        let mut la = vec![100.0; 6];
        la[2] = f64::NAN; // A never answered event 2
        let mut lb = vec![100.0; 6];
        lb[4] = f64::NAN; // B never answered event 4
        let s = summarize(&t, "a", "b", &[outcome(la, 1.0)], &[outcome(lb, 1.0)]).unwrap();
        assert_eq!(s.paired, 4, "6 positions minus one NaN on each side");
        // single round: rps interval infinitely wide, latency diffs all 0
        assert_eq!(s.verdict, Verdict::Inconclusive);
        assert_eq!(s.decided_by, "none");
    }

    #[test]
    fn json_roundtrips_and_fingerprint_is_deterministic() {
        let t = trace();
        let a = [outcome(vec![10.0; 6], 1.0), outcome(vec![11.0; 6], 1.1)];
        let b = [outcome(vec![9.0; 6], 0.9), outcome(vec![8.0; 6], 1.0)];
        let s1 = summarize(&t, "sa", "sb", &a, &b).unwrap();
        let s2 = summarize(&t, "sa", "sb", &a, &b).unwrap();
        let j1 = Json::parse(&s1.to_json().to_string()).unwrap();
        let j2 = Json::parse(&s2.to_json().to_string()).unwrap();
        for key in ["scenario", "digest", "n_requests", "gap_us", "max_rows", "seed", "rounds"] {
            assert_eq!(
                j1.get(key).unwrap().to_string(),
                j2.get(key).unwrap().to_string(),
                "deterministic fingerprint key {key}"
            );
        }
        assert_eq!(j1.get("digest").unwrap().as_str().unwrap(), format!("{:016x}", t.digest()));
        assert_eq!(j1.get("a").unwrap().get("spec").unwrap().as_str().unwrap(), "sa");
        // same measurements -> same seeded bootstrap -> identical deltas
        assert_eq!(
            j1.get("delta").unwrap().to_string(),
            j2.get("delta").unwrap().to_string()
        );
        assert!(!s1.render().is_empty());
    }

    #[test]
    fn mismatched_rounds_error() {
        let t = trace();
        assert!(summarize(&t, "a", "b", &[outcome(vec![1.0; 6], 1.0)], &[]).is_err());
    }
}
