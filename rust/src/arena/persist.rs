//! Persisted performance trajectory: every arena duel and JSON-emitting
//! bench can drop a schema-versioned `BENCH_<name>_<label>.json` record at
//! the repo root (or `SRIGL_BENCH_DIR`), and `srigl arena --history`
//! renders the accumulated trajectory — performance over commits, not
//! just one run's console scroll.
//!
//! Envelope (schema 1):
//!
//! ```json
//! {
//!   "schema": 1,
//!   "kind": "arena" | "bench",
//!   "name": "arena-bursty",
//!   "label": "1a2b3c4d5e6f",
//!   "created_unix": 1754600000,
//!   "headline": "bursty: B wins (...)",
//!   "payload": { ... }
//! }
//! ```
//!
//! The label defaults to the current git commit (short sha, read straight
//! from `.git` — no subprocess), overridable with `--label` or
//! `SRIGL_BENCH_LABEL`, so CI can stamp records with run ids. Loading
//! *fails* on an unknown `schema` — that is the CI drift gate: a change to
//! the envelope must bump [`SCHEMA_VERSION`] and teach [`load_history`]
//! about the old one, or the bench-trajectory job goes red.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{num, obj, s, Json};

/// Envelope schema written by [`persist_record_in`] and required by
/// [`load_history`].
pub const SCHEMA_VERSION: u64 = 1;

/// Environment override for where records are written/read (default: the
/// current directory, i.e. the repo root when run from it).
pub const ENV_BENCH_DIR: &str = "SRIGL_BENCH_DIR";

/// Environment override for the record label (default: git short sha).
pub const ENV_BENCH_LABEL: &str = "SRIGL_BENCH_LABEL";

/// Directory bench records live in: `SRIGL_BENCH_DIR` or `.`.
pub fn bench_dir() -> PathBuf {
    std::env::var_os(ENV_BENCH_DIR).map(PathBuf::from).unwrap_or_else(|| PathBuf::from("."))
}

/// Keep labels filename- and JSON-safe: anything outside `[A-Za-z0-9._-]`
/// becomes `-`.
fn sanitize(label: &str) -> String {
    let cleaned: String = label
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') { c } else { '-' })
        .collect();
    if cleaned.is_empty() { "unlabeled".to_string() } else { cleaned }
}

/// The label to stamp on new records: `SRIGL_BENCH_LABEL`, else the git
/// short sha of `HEAD` (found by walking ancestors of the current
/// directory), else `"unlabeled"`.
pub fn label() -> String {
    if let Some(l) = std::env::var_os(ENV_BENCH_LABEL) {
        return sanitize(&l.to_string_lossy());
    }
    sanitize(&git_label().unwrap_or_else(|| "unlabeled".to_string()))
}

/// Resolve HEAD to a 12-char short sha without shelling out: find the
/// `.git` directory, parse `HEAD` (`ref: refs/...` or a detached sha),
/// then the ref file or `packed-refs`.
fn git_label() -> Option<String> {
    let mut dir = std::env::current_dir().ok()?;
    let git = loop {
        let candidate = dir.join(".git");
        if candidate.is_dir() {
            break candidate;
        }
        if !dir.pop() {
            return None;
        }
    };
    let head = fs::read_to_string(git.join("HEAD")).ok()?;
    let head = head.trim();
    let sha = if let Some(refname) = head.strip_prefix("ref: ") {
        let refname = refname.trim();
        match fs::read_to_string(git.join(refname)) {
            Ok(sha) => sha.trim().to_string(),
            // ref not loose: scan packed-refs for "<sha> <refname>"
            Err(_) => fs::read_to_string(git.join("packed-refs"))
                .ok()?
                .lines()
                .filter(|l| !l.starts_with('#') && !l.starts_with('^'))
                .find_map(|l| {
                    let (sha, name) = l.split_once(' ')?;
                    (name.trim() == refname).then(|| sha.to_string())
                })?,
        }
    } else {
        head.to_string()
    };
    let sha: String = sha.chars().take_while(char::is_ascii_hexdigit).collect();
    if sha.len() < 7 {
        return None;
    }
    Some(sha.chars().take(12).collect())
}

fn now_unix() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Write one record into `dir` as `BENCH_<name>_<label>.json`; returns the
/// path written. `label_override` skips the env/git lookup.
pub fn persist_record_in(
    dir: &Path,
    kind: &str,
    name: &str,
    headline: &str,
    payload: Json,
    label_override: Option<&str>,
) -> Result<PathBuf> {
    let label = match label_override {
        Some(l) => sanitize(l),
        None => label(),
    };
    let name = sanitize(name);
    let record = obj(vec![
        ("schema", num(SCHEMA_VERSION as f64)),
        ("kind", s(kind)),
        ("name", s(&name)),
        ("label", s(&label)),
        ("created_unix", num(now_unix() as f64)),
        ("headline", s(headline)),
        ("payload", payload),
    ]);
    let path = dir.join(format!("BENCH_{name}_{label}.json"));
    fs::write(&path, record.to_string()).with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

/// [`persist_record_in`] targeting [`bench_dir`].
pub fn persist_record(
    kind: &str,
    name: &str,
    headline: &str,
    payload: Json,
    label_override: Option<&str>,
) -> Result<PathBuf> {
    persist_record_in(&bench_dir(), kind, name, headline, payload, label_override)
}

/// Best-effort persistence for the cargo benches: never fails the bench,
/// just reports where the record went (or why it didn't).
pub fn persist_bench_summary(name: &str, summary: &Json) {
    match persist_record("bench", name, &format!("bench {name}"), summary.clone(), None) {
        Ok(path) => crate::util::log::info("bench", &format!("bench record -> {}", path.display())),
        Err(e) => {
            crate::util::log::warn("bench", &format!("bench record for {name} not persisted: {e:#}"))
        }
    }
}

/// One loaded `BENCH_*.json` record.
#[derive(Clone, Debug)]
pub struct HistoryRecord {
    pub path: PathBuf,
    pub kind: String,
    pub name: String,
    pub label: String,
    pub created_unix: u64,
    pub headline: String,
    pub payload: Json,
}

/// Load every `BENCH_*.json` in `dir`, sorted by (name, created_unix,
/// label). Errors on unreadable/unparsable records and on any schema
/// other than [`SCHEMA_VERSION`] — schema drift must be handled here, not
/// silently skipped.
pub fn load_history(dir: &Path) -> Result<Vec<HistoryRecord>> {
    let mut records = Vec::new();
    let entries =
        fs::read_dir(dir).with_context(|| format!("reading bench dir {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        let fname = match path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n,
            None => continue,
        };
        if !fname.starts_with("BENCH_") || !fname.ends_with(".json") {
            continue;
        }
        let text =
            fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
        let json =
            Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let schema = json.get("schema")?.as_usize()? as u64;
        if schema != SCHEMA_VERSION {
            bail!(
                "{}: schema {schema} but this build reads schema {SCHEMA_VERSION} — \
                 bump SCHEMA_VERSION handling in arena::persist",
                path.display()
            );
        }
        records.push(HistoryRecord {
            kind: json.get("kind")?.as_str()?.to_string(),
            name: json.get("name")?.as_str()?.to_string(),
            label: json.get("label")?.as_str()?.to_string(),
            created_unix: json.get("created_unix")?.as_usize()? as u64,
            headline: json.get("headline")?.as_str()?.to_string(),
            payload: json.get("payload")?.clone(),
            path,
        });
    }
    records.sort_by(|a, b| {
        (&a.name, a.created_unix, &a.label).cmp(&(&b.name, b.created_unix, &b.label))
    });
    Ok(records)
}

/// The `srigl arena --history` listing: records grouped by name in time
/// order — the perf trajectory.
pub fn render_history(records: &[HistoryRecord]) -> String {
    if records.is_empty() {
        return "no BENCH_*.json records found\n".to_string();
    }
    let mut out = String::new();
    let mut current = "";
    for r in records {
        if r.name != current {
            current = &r.name;
            out.push_str(&format!("{} ({}):\n", r.name, r.kind));
        }
        out.push_str(&format!("  [{}] {} — {}\n", r.created_unix, r.label, r.headline));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("srigl-arena-persist-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn sanitize_labels() {
        assert_eq!(sanitize("abc123.def-g_h"), "abc123.def-g_h");
        assert_eq!(sanitize("feat/odd name"), "feat-odd-name");
        assert_eq!(sanitize(""), "unlabeled");
    }

    #[test]
    fn record_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let payload = obj(vec![("x", num(3.5))]);
        let p1 = persist_record_in(&dir, "arena", "arena-poisson", "h1", payload.clone(), Some("lbl-a"))
            .unwrap();
        let p2 =
            persist_record_in(&dir, "bench", "model_serve", "h2", payload, Some("lbl-b")).unwrap();
        assert!(p1.file_name().unwrap().to_str().unwrap() == "BENCH_arena-poisson_lbl-a.json");
        let hist = load_history(&dir).unwrap();
        assert_eq!(hist.len(), 2);
        // sorted by name: arena-poisson before model_serve
        assert_eq!(hist[0].name, "arena-poisson");
        assert_eq!(hist[0].kind, "arena");
        assert_eq!(hist[0].label, "lbl-a");
        assert_eq!(hist[0].headline, "h1");
        assert_eq!(hist[0].payload.get("x").unwrap().as_f64().unwrap(), 3.5);
        assert_eq!(hist[1].path, p2);
        let listing = render_history(&hist);
        assert!(listing.contains("arena-poisson") && listing.contains("lbl-b"), "{listing}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rewriting_same_name_and_label_overwrites() {
        let dir = tmp_dir("overwrite");
        let pay = |v| obj(vec![("v", num(v))]);
        persist_record_in(&dir, "arena", "a", "old", pay(1.0), Some("l")).unwrap();
        persist_record_in(&dir, "arena", "a", "new", pay(2.0), Some("l")).unwrap();
        let hist = load_history(&dir).unwrap();
        assert_eq!(hist.len(), 1, "same (name, label) -> one file");
        assert_eq!(hist[0].headline, "new");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_schema_fails_loudly() {
        let dir = tmp_dir("schema");
        let record = obj(vec![
            ("schema", num(999.0)),
            ("kind", s("arena")),
            ("name", s("x")),
            ("label", s("l")),
            ("created_unix", num(0.0)),
            ("headline", s("h")),
            ("payload", obj(vec![])),
        ]);
        fs::write(dir.join("BENCH_x_l.json"), record.to_string()).unwrap();
        let err = load_history(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("schema 999"), "{err:#}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn non_bench_files_are_ignored() {
        let dir = tmp_dir("ignore");
        fs::write(dir.join("notes.txt"), "hi").unwrap();
        fs::write(dir.join("BENCH_broken.notjson"), "{").unwrap();
        assert!(load_history(&dir).unwrap().is_empty());
        assert!(render_history(&[]).contains("no BENCH"));
        let _ = fs::remove_dir_all(&dir);
    }
}
