//! Trace replay: drive one [`Trace`] through a serving configuration and
//! record per-request latencies, aligned by event index so two replays of
//! the same trace can be compared pairwise.
//!
//! Two drivers share the outcome type:
//!
//! * [`replay`] — in-process: the same submitter/queue/worker machinery as
//!   [`crate::inference::server::serve_target`] (Injector, adaptive or
//!   fixed batching, greedy row-packing, per-worker typed scratch), except
//!   the submitter paces to the trace's absolute schedule instead of
//!   drawing fresh Poisson gaps, payloads come from the trace's pool, and
//!   each request keeps its event index so latencies land in a
//!   position-aligned vector.
//! * [`replay_wire`] — through the real socket front-end: spawns
//!   [`crate::inference::frontend`] on a loopback port and fans the trace
//!   out over a small set of [`Client`] connections using the retrying
//!   (backoff-scheduled) request path. This is the mode where the result
//!   cache, backpressure, and egress machinery participate — and where
//!   [`Scenario::Adversarial`](super::trace::Scenario) vs pool traffic
//!   actually differ.
//!
//! A request the wire driver could not get answered (retries exhausted)
//! records a NaN latency; [`LatencyStats`] counts-and-excludes NaN
//! (`nan_samples`), and the paired summary skips unpaired positions.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use super::trace::Trace;
use crate::inference::engine::{Engine, EngineBuilder};
use crate::inference::frontend::{self, FrontendStats};
use crate::inference::server::{AdaptiveBatcher, Batching, LatencyStats, WorkerStats};
use crate::inference::SparseModel;
use crate::net::Client;
use crate::util::json::{num, obj, Json};
use crate::util::threadpool::Injector;

/// One replay's measurements.
#[derive(Clone, Debug)]
pub struct ReplayOutcome {
    /// Per-request latency in µs, indexed by trace event position; NaN
    /// where the request was never answered (wire mode, retries
    /// exhausted). Position alignment is what makes two outcomes of the
    /// same trace pairwise comparable.
    pub latencies_us: Vec<f64>,
    /// Merged engine-side statistics (in-process: worker records; wire:
    /// the front-end's queue-served latency block).
    pub stats: LatencyStats,
    /// Wall-clock of the whole replay (submission start to last answer).
    pub wall_s: f64,
    /// Wire-mode extras (cache hits, rejections, drops); `None` for
    /// in-process replays.
    pub frontend: Option<FrontendStats>,
    /// Wire-mode scrape of the live `/metrics` endpoint taken just before
    /// shutdown, parsed into a series -> value object
    /// ([`crate::obs::parse_exposition`]); `None` in-process.
    pub metrics: Option<Json>,
}

impl ReplayOutcome {
    /// Requests that received an answer (finite latency).
    pub fn served(&self) -> usize {
        self.latencies_us.iter().filter(|v| v.is_finite()).count()
    }

    /// Answered requests per wall-clock second — the round's throughput
    /// observation.
    pub fn rps(&self) -> f64 {
        self.served() as f64 / self.wall_s.max(1e-9)
    }

    /// Round record for the persisted summary.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("rps", num(self.rps())),
            ("served", num(self.served() as f64)),
            ("wall_s", num(self.wall_s)),
            ("latency", self.stats.to_json()),
        ];
        if let Some(f) = &self.frontend {
            fields.push(("frontend", f.to_json()));
        }
        if let Some(m) = &self.metrics {
            fields.push(("metrics", m.clone()));
        }
        obj(fields)
    }
}

/// Check a trace is replayable under `builder`: every request must fit one
/// forward, i.e. the trace's largest row count ≤ the batching cap.
pub fn validate(trace: &Trace, builder: &EngineBuilder) -> Result<()> {
    let max_rows = trace.max_event_rows();
    let cap = builder.batching.cap();
    ensure!(
        max_rows <= cap,
        "trace carries requests up to {max_rows} rows but the engine's batching cap is {cap}; \
         raise batch=/adaptive= in the engine spec or lower --max-rows"
    );
    Ok(())
}

/// Replay `trace` against the engine `builder` selects for `model`
/// (replicated pool, or persistent shard team when `shards > 1`) —
/// in-process, no sockets.
pub fn replay(model: &SparseModel, builder: &EngineBuilder, trace: &Trace) -> Result<ReplayOutcome> {
    validate(trace, builder)?;
    if builder.is_sharded() {
        let team = builder.build_persistent_sharded(model).context("building shard team")?;
        Ok(replay_engine(&team, builder, trace))
    } else {
        Ok(replay_engine(model, builder, trace))
    }
}

/// The in-process replay loop over any prebuilt [`Engine`]. Callers should
/// [`validate`] first; an oversized request here would panic the packing
/// invariant instead of erroring.
pub fn replay_engine<E: Engine>(engine: &E, builder: &EngineBuilder, trace: &Trace) -> ReplayOutcome {
    struct Req<'a> {
        idx: usize,
        rows: usize,
        x: &'a [f32],
        t_submit: Instant,
    }

    let workers = builder.workers.max(1);
    let batching = builder.batching;
    let cap = batching.cap();
    let batcher = AdaptiveBatcher::new(cap);
    let d = engine.in_width();
    let threads = builder.threads;
    let pool = trace.payloads(d);
    let n = trace.events.len();
    let injector: Injector<Req> = Injector::new();

    let t_start = Instant::now();
    let per_worker: Vec<(WorkerStats, Vec<(usize, f64)>)> = std::thread::scope(|s| {
        let inj = &injector;
        let pool = &pool;
        let events = &trace.events;

        // Submitter: pace to the trace's absolute schedule (open-loop —
        // a slow engine does not slow arrivals, it grows the queue).
        s.spawn(move || {
            let t0 = Instant::now();
            for (i, ev) in events.iter().enumerate() {
                let target = t0 + Duration::from_micros(ev.at_us);
                let now = Instant::now();
                if target > now {
                    std::thread::sleep(target - now);
                }
                let rows = ev.rows as usize;
                let x = &pool[ev.payload as usize][..rows * d];
                inj.push(Req { idx: i, rows, x, t_submit: Instant::now() });
            }
            inj.close();
        });

        // Workers: adaptive/fixed pop, greedy row-packing (the same loop
        // shape as the front-end's worker_loop), latencies tagged with the
        // originating event index.
        let batcher = &batcher;
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(move || {
                    let mut scratch = engine.scratch(cap);
                    let mut xbuf = vec![0f32; cap * d];
                    let mut jobs: Vec<Req> = Vec::with_capacity(cap);
                    let mut ws = WorkerStats::default();
                    let mut lat: Vec<(usize, f64)> = Vec::new();
                    loop {
                        jobs.clear();
                        let want = match batching {
                            Batching::Fixed(n) => n.max(1),
                            Batching::Adaptive { .. } => batcher.next_batch(inj.len()),
                        };
                        if inj.pop_batch(want, &mut jobs) == 0 {
                            break;
                        }
                        while !jobs.is_empty() {
                            // pack leading jobs while their rows fit one
                            // forward (validate() guarantees take >= 1)
                            let mut rows = 0usize;
                            let mut take = 0usize;
                            while take < jobs.len() && rows + jobs[take].rows <= cap {
                                rows += jobs[take].rows;
                                take += 1;
                            }
                            let mut off = 0usize;
                            for j in &jobs[..take] {
                                xbuf[off * d..(off + j.rows) * d].copy_from_slice(j.x);
                                off += j.rows;
                            }
                            let _ = engine.forward(&xbuf[..rows * d], rows, &mut scratch, threads);
                            let t_done = Instant::now();
                            ws.batches += 1;
                            ws.served += take;
                            for j in jobs.drain(..take) {
                                let us =
                                    t_done.duration_since(j.t_submit).as_secs_f64() * 1e6;
                                ws.latencies_us.push(us);
                                lat.push((j.idx, us));
                            }
                        }
                    }
                    (ws, lat)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("replay worker panicked")).collect()
    });
    let wall_s = t_start.elapsed().as_secs_f64();

    let mut latencies = vec![f64::NAN; n];
    let mut worker_stats = Vec::with_capacity(per_worker.len());
    for (ws, lat) in per_worker {
        for (i, us) in lat {
            latencies[i] = us;
        }
        worker_stats.push(ws);
    }
    ReplayOutcome {
        latencies_us: latencies,
        stats: LatencyStats::from_workers(&worker_stats, wall_s),
        wall_s,
        frontend: None,
        metrics: None,
    }
}

/// Replay `trace` through the real socket front-end: spawn it on a
/// loopback port, fan events over `clients` connections (event `i` goes to
/// connection `i % clients`, each pacing to the shared schedule), request
/// via [`Client::infer_retrying`] with up to `max_retries` backoff-spaced
/// retries. Latency is measured client-side around the whole retry loop —
/// the latency a backpressured caller actually experiences.
pub fn replay_wire(
    model: &Arc<SparseModel>,
    builder: &EngineBuilder,
    trace: &Trace,
    clients: usize,
    max_retries: usize,
) -> Result<ReplayOutcome> {
    validate(trace, builder)?;
    let d = model.in_width();
    let pool = trace.payloads(d);
    let n = trace.events.len();
    let clients = clients.clamp(1, 64);

    // A live metrics endpoint rides along on every wire replay: the round
    // record persists a scrape of it, so the BENCH trajectory carries the
    // same counters an operator would see in production.
    let handle =
        frontend::spawn_with_metrics(Arc::clone(model), "127.0.0.1:0", builder, Some("127.0.0.1:0"))
            .context("spawning arena front-end")?;
    let addr = handle.addr();
    // connect everyone before the clock starts so connection setup is not
    // billed to the first requests
    let mut conns = Vec::with_capacity(clients);
    for _ in 0..clients {
        conns.push(Client::connect(addr).context("connecting arena client")?);
    }

    let t_start = Instant::now();
    let lat_chunks: Vec<Vec<(usize, f64)>> = std::thread::scope(|s| {
        let pool = &pool;
        let events = &trace.events;
        let handles: Vec<_> = conns
            .into_iter()
            .enumerate()
            .map(|(c, mut client)| {
                s.spawn(move || {
                    let mut out = Vec::new();
                    for (i, ev) in events.iter().enumerate() {
                        if i % clients != c {
                            continue;
                        }
                        let target = t_start + Duration::from_micros(ev.at_us);
                        let now = Instant::now();
                        if target > now {
                            std::thread::sleep(target - now);
                        }
                        let rows = ev.rows as usize;
                        let x = &pool[ev.payload as usize][..rows * d];
                        let t_submit = Instant::now();
                        match client.infer_retrying(rows, x, max_retries) {
                            Ok(_) => out
                                .push((i, t_submit.elapsed().as_secs_f64() * 1e6)),
                            // retries exhausted or transport error: the
                            // position stays NaN (counted, excluded)
                            Err(_) => out.push((i, f64::NAN)),
                        }
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("arena client panicked")).collect()
    });
    let wall_s = t_start.elapsed().as_secs_f64();
    // scrape while the endpoint is still up (stop() tears it down)
    let metrics = handle
        .metrics_addr()
        .and_then(|a| crate::obs::scrape(a).ok())
        .map(|text| crate::obs::parse_exposition(&text));
    let fstats = handle.stop();

    let mut latencies = vec![f64::NAN; n];
    for chunk in lat_chunks {
        for (i, us) in chunk {
            latencies[i] = us;
        }
    }
    Ok(ReplayOutcome {
        latencies_us: latencies,
        stats: fstats.latency.clone(),
        wall_s,
        frontend: Some(fstats),
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arena::trace::{Scenario, TraceSpec};
    use crate::inference::model::{Activation, LayerSpec, Repr};

    fn tiny_model() -> SparseModel {
        let spec = |n, act| LayerSpec {
            n,
            repr: Repr::Condensed,
            sparsity: 0.8,
            ablated_frac: 0.2,
            activation: act,
        };
        SparseModel::synth(32, &[spec(24, Activation::Relu), spec(8, Activation::Identity)], 5)
            .unwrap()
    }

    fn flood(n: usize, max_rows: usize, seed: u64) -> Trace {
        Trace::generate(&TraceSpec {
            scenario: Scenario::Poisson,
            n_requests: n,
            mean_gap_us: 0.0,
            max_rows,
            pool: 8,
            seed,
        })
    }

    #[test]
    fn validate_rejects_oversized_rows() {
        let t = flood(50, 16, 1);
        let err = validate(&t, &EngineBuilder::new().fixed_batch(8)).unwrap_err();
        assert!(format!("{err:#}").contains("cap is 8"), "{err:#}");
        assert!(validate(&t, &EngineBuilder::new().fixed_batch(16)).is_ok());
    }

    #[test]
    fn replay_answers_every_request_once() {
        let m = tiny_model();
        let t = flood(120, 4, 2);
        let out = replay(&m, &EngineBuilder::new().workers(2).fixed_batch(8), &t).unwrap();
        assert_eq!(out.latencies_us.len(), 120);
        assert_eq!(out.served(), 120, "every event answered exactly once");
        assert_eq!(out.stats.n, 120);
        assert_eq!(out.stats.nan_samples, 0);
        assert!(out.rps() > 0.0);
        assert!(out.frontend.is_none());
        // round record is valid JSON
        let j = Json::parse(&out.to_json().to_string()).unwrap();
        assert_eq!(j.get("served").unwrap().as_usize().unwrap(), 120);
    }

    #[test]
    fn replay_adaptive_and_sharded_serve_all() {
        let m = tiny_model();
        let t = flood(80, 4, 3);
        for b in [
            EngineBuilder::new().workers(2).adaptive(8),
            EngineBuilder::new().workers(1).fixed_batch(4).shards(2),
        ] {
            let out = replay(&m, &b, &t).unwrap();
            assert_eq!(out.served(), 80, "{b:?}");
        }
    }

    #[test]
    fn replay_respects_trace_pacing() {
        // 30 requests at 4 ms mean gaps: the replay must take roughly the
        // trace's span (open-loop pacing), not finish instantly
        let m = tiny_model();
        let t = Trace::generate(&TraceSpec {
            scenario: Scenario::Poisson,
            n_requests: 30,
            mean_gap_us: 4000.0,
            max_rows: 1,
            pool: 4,
            seed: 8,
        });
        let span_s = t.events.last().unwrap().at_us as f64 / 1e6;
        let out = replay(&m, &EngineBuilder::new().workers(1).fixed_batch(4), &t).unwrap();
        assert!(out.wall_s >= span_s * 0.9, "wall {} vs span {span_s}", out.wall_s);
        assert_eq!(out.served(), 30);
    }
}
