//! artifacts/manifest.json — the contract between the python AOT compiler
//! (L2) and the rust runtime (L3): every program's file name plus the
//! canonical argument ordering (params, momenta, masks, x, y, lr) and the
//! per-parameter metadata (shape, sparse flag, fan-in, init spec).

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub sparse: bool,
    pub fan_in: usize,
    pub init: String,
}

impl ParamInfo {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

#[derive(Clone, Debug)]
pub struct Hyper {
    pub momentum: f64,
    pub weight_decay: f64,
    pub label_smoothing: f64,
}

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub batch: usize,
    pub task: String, // "classify" | "lm"
    pub num_classes: usize,
    pub x: IoSpec,
    pub y: IoSpec,
    pub params: Vec<ParamInfo>,
    pub hyper: Hyper,
    pub param_count: usize,
    /// program name -> artifact file name
    pub programs: BTreeMap<String, String>,
}

impl ModelEntry {
    pub fn sparse_indices(&self) -> Vec<usize> {
        self.params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.sparse)
            .map(|(i, _)| i)
            .collect()
    }
}

#[derive(Clone, Debug)]
pub struct CondensedEntry {
    pub name: String,
    pub file: String,
    pub batch: usize,
    pub d: usize,
    pub n: usize,
    pub k: usize,
}

/// One layer of a serving-stack description (see [`StackEntry`]).
#[derive(Clone, Debug)]
pub struct StackLayerSpec {
    pub n: usize,
    /// Representation name: dense | csr | structured | condensed.
    pub repr: String,
    pub sparsity: f64,
    pub ablated_frac: f64,
    /// Activation name: relu | identity.
    pub activation: String,
}

/// Serving knobs a stack can carry in its optional `"serve"` object —
/// defaults for the front-end's queue bound, result cache, and batching
/// (`serve-model --listen` reads these; CLI flags override).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeKnobs {
    /// Bounded `Injector` capacity (requests).
    pub queue_capacity: usize,
    /// LRU result-cache entries; 0 disables the cache.
    pub cache_capacity: usize,
    /// Per-connection egress-queue capacity (response frames): how many
    /// computed responses a slow client may leave unread before overflow
    /// converts further ones to Busy (docs/WIRE.md).
    pub egress_capacity: usize,
    /// true: adaptive (EWMA-of-depth) batching up to `max_batch`;
    /// false: fixed `max_batch` per pop.
    pub adaptive: bool,
    pub max_batch: usize,
    /// Tensor-parallel shards per forward; `1` (default) = replicated
    /// workers (a persistent shard team is engaged when > 1).
    pub shards: usize,
    /// Live-connection cap for the accept loop; `0` (default) = unlimited.
    pub max_connections: usize,
}

impl Default for ServeKnobs {
    fn default() -> ServeKnobs {
        ServeKnobs {
            queue_capacity: 1024,
            cache_capacity: 1024,
            egress_capacity: 64,
            adaptive: true,
            max_batch: 8,
            shards: 1,
            max_connections: 0,
        }
    }
}

/// A multi-layer serving model described in the manifest's optional
/// `"stacks"` section — shapes/sparsities only (no weight data); the
/// inference engine synthesizes weights from `seed`. Consumed by
/// `inference::SparseModel::from_stack` and the `serve-model` subcommand.
#[derive(Clone, Debug)]
pub struct StackEntry {
    pub name: String,
    pub d_in: usize,
    pub seed: u64,
    pub layers: Vec<StackLayerSpec>,
    /// Front-end defaults for this stack (absent section -> defaults).
    pub serve: ServeKnobs,
    /// Optional metrics-endpoint bind address (`"serve": {"metrics": ...}`);
    /// `serve-model --metrics` overrides.
    pub metrics: Option<String>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
    pub condensed: BTreeMap<String, CondensedEntry>,
    pub stacks: BTreeMap<String, StackEntry>,
}

impl Manifest {
    /// Default artifacts directory: $SRIGL_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("SRIGL_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn load_default() -> Result<Manifest> {
        Manifest::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&src).with_context(|| format!("parsing {path:?}"))?;

        let mut models = BTreeMap::new();
        for (name, m) in root.get("models")?.as_obj()? {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        let mut condensed = BTreeMap::new();
        for (name, c) in root.get("condensed")?.as_obj()? {
            condensed.insert(
                name.clone(),
                CondensedEntry {
                    name: name.clone(),
                    file: c.get("file")?.as_str()?.to_string(),
                    batch: c.get("batch")?.as_usize()?,
                    d: c.get("d")?.as_usize()?,
                    n: c.get("n")?.as_usize()?,
                    k: c.get("k")?.as_usize()?,
                },
            );
        }
        // optional section: older manifests have no serving stacks
        let mut stacks = BTreeMap::new();
        if let Some(sj) = root.opt("stacks") {
            for (name, s) in sj.as_obj()? {
                stacks.insert(name.clone(), parse_stack(name, s)?);
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), models, condensed, stacks })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model {name:?} not in manifest ({:?})", self.models.keys()))
    }

    pub fn stack(&self, name: &str) -> Result<&StackEntry> {
        self.stacks
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("stack {name:?} not in manifest ({:?})", self.stacks.keys()))
    }

    pub fn program_path(&self, entry: &ModelEntry, program: &str) -> Result<PathBuf> {
        let file = entry
            .programs
            .get(program)
            .ok_or_else(|| anyhow::anyhow!("program {program:?} missing for {}", entry.name))?;
        Ok(self.dir.join(file))
    }
}

fn parse_stack(name: &str, s: &Json) -> Result<StackEntry> {
    let mut layers = Vec::new();
    for l in s.get("layers")?.as_arr()? {
        layers.push(StackLayerSpec {
            n: l.get("n")?.as_usize()?,
            repr: l.get("repr")?.as_str()?.to_string(),
            sparsity: l.get("sparsity")?.as_f64()?,
            ablated_frac: l.opt("ablated_frac").map(|v| v.as_f64()).transpose()?.unwrap_or(0.0),
            activation: l
                .opt("activation")
                .map(|v| v.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_else(|| "relu".to_string()),
        });
    }
    let mut serve = ServeKnobs::default();
    let mut metrics = None;
    if let Some(k) = s.opt("serve") {
        metrics = k.opt("metrics").map(|v| v.as_str().map(str::to_string)).transpose()?;
        serve = ServeKnobs {
            queue_capacity: k
                .opt("queue_capacity")
                .map(|v| v.as_usize())
                .transpose()?
                .unwrap_or(serve.queue_capacity),
            cache_capacity: k
                .opt("cache_capacity")
                .map(|v| v.as_usize())
                .transpose()?
                .unwrap_or(serve.cache_capacity),
            egress_capacity: k
                .opt("egress_capacity")
                .map(|v| v.as_usize())
                .transpose()?
                .unwrap_or(serve.egress_capacity),
            adaptive: k.opt("adaptive").map(|v| v.as_bool()).transpose()?.unwrap_or(serve.adaptive),
            max_batch: k
                .opt("max_batch")
                .map(|v| v.as_usize())
                .transpose()?
                .unwrap_or(serve.max_batch),
            shards: k.opt("shards").map(|v| v.as_usize()).transpose()?.unwrap_or(serve.shards),
            max_connections: k
                .opt("max_connections")
                .map(|v| v.as_usize())
                .transpose()?
                .unwrap_or(serve.max_connections),
        };
    }
    Ok(StackEntry {
        name: name.to_string(),
        d_in: s.get("d_in")?.as_usize()?,
        seed: s.opt("seed").map(|v| v.as_usize()).transpose()?.unwrap_or(0) as u64,
        layers,
        serve,
        metrics,
    })
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        shape: j.get("shape")?.as_arr()?.iter().map(|v| v.as_usize()).collect::<Result<_>>()?,
        dtype: j.get("dtype")?.as_str()?.to_string(),
    })
}

fn parse_model(name: &str, m: &Json) -> Result<ModelEntry> {
    let mut params = Vec::new();
    for p in m.get("params")?.as_arr()? {
        params.push(ParamInfo {
            name: p.get("name")?.as_str()?.to_string(),
            shape: p.get("shape")?.as_arr()?.iter().map(|v| v.as_usize()).collect::<Result<_>>()?,
            sparse: p.get("sparse")?.as_bool()?,
            fan_in: p.get("fan_in")?.as_usize()?,
            init: p.get("init")?.as_str()?.to_string(),
        });
    }
    let h = m.get("hyper")?;
    let mut programs = BTreeMap::new();
    for (k, v) in m.get("programs")?.as_obj()? {
        programs.insert(k.clone(), v.as_str()?.to_string());
    }
    Ok(ModelEntry {
        name: name.to_string(),
        batch: m.get("batch")?.as_usize()?,
        task: m.get("task")?.as_str()?.to_string(),
        num_classes: m.get("num_classes")?.as_usize()?,
        x: parse_io(m.get("x")?)?,
        y: parse_io(m.get("y")?)?,
        params,
        hyper: Hyper {
            momentum: h.get("momentum")?.as_f64()?,
            weight_decay: h.get("weight_decay")?.as_f64()?,
            label_smoothing: h.get("label_smoothing")?.as_f64()?,
        },
        param_count: m.get("param_count")?.as_usize()?,
        programs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_stack_description() {
        let src = r#"{
            "d_in": 3072, "seed": 7,
            "layers": [
                {"n": 768, "repr": "condensed", "sparsity": 0.9, "ablated_frac": 0.35},
                {"n": 768, "repr": "csr", "sparsity": 0.9},
                {"n": 256, "repr": "dense", "sparsity": 0.0, "activation": "identity"}
            ]
        }"#;
        let e = parse_stack("vit_ff_stack", &Json::parse(src).unwrap()).unwrap();
        assert_eq!(e.name, "vit_ff_stack");
        assert_eq!(e.d_in, 3072);
        assert_eq!(e.seed, 7);
        assert_eq!(e.layers.len(), 3);
        assert_eq!(e.layers[0].repr, "condensed");
        assert_eq!(e.layers[0].ablated_frac, 0.35);
        assert_eq!(e.layers[1].ablated_frac, 0.0, "ablated_frac defaults to 0");
        assert_eq!(e.layers[1].activation, "relu", "activation defaults to relu");
        assert_eq!(e.layers[2].activation, "identity");
        assert_eq!(e.serve, ServeKnobs::default(), "no serve section -> defaults");
        assert_eq!(e.metrics, None, "no serve section -> no metrics endpoint");
    }

    #[test]
    fn parses_serve_knobs() {
        let src = r#"{
            "d_in": 16,
            "layers": [{"n": 8, "repr": "dense", "sparsity": 0.5}],
            "serve": {"queue_capacity": 64, "cache_capacity": 0, "egress_capacity": 16,
                      "adaptive": false, "max_batch": 4, "shards": 4,
                      "max_connections": 128, "metrics": "127.0.0.1:9900"}
        }"#;
        let e = parse_stack("s", &Json::parse(src).unwrap()).unwrap();
        assert_eq!(
            e.serve,
            ServeKnobs {
                queue_capacity: 64,
                cache_capacity: 0,
                egress_capacity: 16,
                adaptive: false,
                max_batch: 4,
                shards: 4,
                max_connections: 128
            }
        );
        assert_eq!(e.metrics.as_deref(), Some("127.0.0.1:9900"));
    }

    #[test]
    fn partial_serve_knobs_keep_defaults() {
        let src = r#"{
            "d_in": 16,
            "layers": [{"n": 8, "repr": "dense", "sparsity": 0.5}],
            "serve": {"max_batch": 32}
        }"#;
        let e = parse_stack("s", &Json::parse(src).unwrap()).unwrap();
        assert_eq!(e.serve.max_batch, 32);
        let d = ServeKnobs::default();
        assert_eq!(e.serve.queue_capacity, d.queue_capacity);
        assert_eq!(e.serve.cache_capacity, d.cache_capacity);
        assert_eq!(e.serve.egress_capacity, d.egress_capacity, "absent egress knob -> default");
        assert_eq!(e.serve.adaptive, d.adaptive);
        assert_eq!(e.serve.shards, 1, "absent shards knob means replicated");
        assert_eq!(e.serve.max_connections, 0, "absent cap means unlimited");
        assert_eq!(e.metrics, None);
    }

    #[test]
    fn stack_missing_fields_error() {
        let src = r#"{"layers": [{"n": 4, "repr": "dense", "sparsity": 0.5}]}"#;
        assert!(parse_stack("x", &Json::parse(src).unwrap()).is_err(), "d_in is required");
    }

    #[test]
    fn parses_real_manifest_when_present() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let e = m.model("mlp_tiny").unwrap();
        assert_eq!(e.batch, 32);
        assert!(e.params.iter().any(|p| p.sparse));
        assert!(e.programs.contains_key("train_step"));
        assert!(m.condensed.contains_key("cond_tiny"));
        // param_count is consistent with shapes
        let total: usize = e.params.iter().map(|p| p.numel()).sum();
        assert_eq!(total, e.param_count);
    }
}
