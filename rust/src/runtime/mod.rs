//! PJRT runtime: loads the AOT-compiled HLO-text artifacts and executes
//! them on the XLA CPU client. This is the only place the `xla` crate is
//! touched; everything above works with host [`Tensor`]s.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute`.
//! Programs are compiled once and cached per process.

pub mod manifest;

pub use manifest::{CondensedEntry, Manifest, ModelEntry, ParamInfo};

use anyhow::{Context, Result};
use std::path::Path;

use crate::tensor::Tensor;

/// Shared PJRT CPU client. Creating a client is expensive (~100ms) and the
/// underlying library dislikes multiple clients per process, so hold one.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Program> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {path:?}"))?;
        Ok(Program { exe, name: path.file_name().unwrap().to_string_lossy().into_owned() })
    }

    /// Load a model program by manifest entry + program name.
    pub fn load_program(&self, man: &Manifest, entry: &ModelEntry, program: &str) -> Result<Program> {
        self.load(&man.program_path(entry, program)?)
    }
}

/// A compiled executable. All our programs return a tuple (the AOT side
/// lowers with `return_tuple=True`), so `run` always yields a Vec.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Program {
    /// Execute with owned or borrowed literals (borrowed lets callers
    /// reuse cached input literals without a deep copy — §Perf iter. 4).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = self.exe.execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

// ---------------------------------------------------------------------------
// Tensor <-> Literal marshalling
// ---------------------------------------------------------------------------

pub fn tensor_to_lit(t: &Tensor) -> Result<xla::Literal> {
    if t.shape.is_empty() {
        return Ok(xla::Literal::scalar(t.data[0]));
    }
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
}

pub fn f32s_to_lit(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn i32s_to_lit(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let data = lit.to_vec::<f32>()?;
    anyhow::ensure!(
        data.len() == shape.iter().product::<usize>(),
        "literal size {} != shape {:?}",
        data.len(),
        shape
    );
    Ok(Tensor::from_vec(shape, data))
}

pub fn lit_to_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.to_vec::<f32>()?[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> Option<Manifest> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).unwrap())
        } else {
            eprintln!("skipping: run `make artifacts`");
            None
        }
    }

    #[test]
    fn condensed_kernel_roundtrip_through_pjrt() {
        // Execute the AOT'd Pallas condensed kernel (L1) from rust (L3) and
        // check the numerics against a host-side reference — the full
        // three-layer stack in one test.
        let Some(man) = artifacts_ready() else { return };
        let rt = Runtime::cpu().unwrap();
        let e = &man.condensed["cond_tiny"];
        let prog = rt.load(&man.dir.join(&e.file)).unwrap();

        let mut rng = crate::util::rng::Rng::new(0);
        let x = Tensor::normal(&[e.batch, e.d], 1.0, &mut rng);
        let w = Tensor::normal(&[e.n, e.k], 1.0, &mut rng);
        let mut idx = vec![0i32; e.n * e.k];
        for r in 0..e.n {
            for (c, j) in rng.choose_k(e.d, e.k).into_iter().enumerate() {
                idx[r * e.k + c] = j as i32;
            }
        }

        let out = prog
            .run(&[
                tensor_to_lit(&x).unwrap(),
                tensor_to_lit(&w).unwrap(),
                i32s_to_lit(&[e.n, e.k], &idx).unwrap(),
            ])
            .unwrap();
        let got = lit_to_tensor(&out[0], &[e.batch, e.n]).unwrap();

        // host reference: out[b, r] = sum_c x[b, idx[r,c]] * w[r, c]
        for b in 0..e.batch {
            for r in 0..e.n {
                let mut acc = 0f32;
                for c in 0..e.k {
                    acc += x.data[b * e.d + idx[r * e.k + c] as usize] * w.data[r * e.k + c];
                }
                let gotv = got.data[b * e.n + r];
                assert!((acc - gotv).abs() < 1e-4 * acc.abs().max(1.0), "({b},{r}): {acc} vs {gotv}");
            }
        }
    }

    #[test]
    fn scalar_and_reshape_marshalling() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = tensor_to_lit(&t).unwrap();
        let back = lit_to_tensor(&lit, &[2, 3]).unwrap();
        assert_eq!(back.data, t.data);
        let s = Tensor::from_vec(&[], vec![7.5]);
        let lit = tensor_to_lit(&s).unwrap();
        assert_eq!(lit_to_f32(&lit).unwrap(), 7.5);
    }
}
