//! `srigl lint` — repo-specific static checks for the unsafe serving core.
//!
//! A zero-dependency source scanner (no syn, no rustc plumbing — the
//! offline build can't take either) that enforces four rules the generic
//! toolchain can't express, over every `.rs` file under `rust/`:
//!
//! * **safety-comment** — every `unsafe` token (blocks, fns, impls, in
//!   tests too) must be justified by a `// SAFETY:` comment on the same
//!   line or in the contiguous comment/attribute block directly above it
//!   (a `/// # Safety` doc section also counts, for `unsafe fn`
//!   signatures).
//! * **serve-unwrap** — no `.unwrap()` / `.expect(` on the serving paths
//!   (`inference/frontend.rs`, `net/mod.rs`) outside `#[cfg(test)]`: a
//!   panic there kills a connection thread and poisons shared locks. A
//!   site that is genuinely infallible or startup-only carries a trailing
//!   `// lint:allow-unwrap <reason>` marker — the reason is mandatory
//!   prose for the reviewer, the marker is what the scanner honors.
//! * **print-macro** — no bare `println!`/`eprintln!`/`print!`/`eprint!`
//!   outside `#[cfg(test)]`, except in the CLI surface (`main.rs`), the
//!   leveled logger itself (`util/log.rs`), harness/bench output
//!   (`exp/`, `bench/`), integration-test binaries (`rust/tests/`, which
//!   have no `#[cfg(test)]` regions to mask), and this reporter. Library
//!   code logs through `util::log` so `SRIGL_LOG` filtering works.
//! * **wire-consts** — the protocol constants in `net/mod.rs` must match
//!   the byte-level spec in `docs/WIRE.md` (status bytes, frame cap,
//!   control sentinel, reload opcode), so the document can't silently
//!   drift from the implementation.
//!
//! The scanner lexes each file just enough to be trustworthy: string and
//! char literals are blanked (including raw strings like the `r#"..."#`
//! fixtures in `util/json.rs`) and comments are separated from code, so
//! an `unsafe` inside a string or a `println!` inside a doc comment never
//! trips a rule. See docs/ANALYSIS.md for the full rationale and the CI
//! wiring (`lint` is a blocking job).

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One rule violation at a source location.
#[derive(Debug)]
pub struct Violation {
    pub file: PathBuf,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file.display(), self.line, self.rule, self.msg)
    }
}

/// Files (relative to the repo root, `/`-separated) where `.unwrap()` /
/// `.expect(` need justification: the request-serving paths.
const SERVE_PATHS: &[&str] = &["rust/src/inference/frontend.rs", "rust/src/net/mod.rs"];

/// Marker that exempts one line from the serve-unwrap rule; must be
/// followed by a reason in the same comment.
const ALLOW_UNWRAP: &str = "lint:allow-unwrap";

/// Files/dirs (relative, `/`-separated) whose job is terminal output and
/// may therefore use print macros directly.
const PRINT_ALLOWED: &[&str] = &[
    "rust/src/main.rs",     // CLI surface
    "rust/src/util/log.rs", // the logger's own sink
    "rust/src/lint.rs",     // this reporter
    "rust/src/exp/",        // paper-table harness output
    "rust/src/bench/",      // bench banners
    "rust/tests/",          // integration binaries print skip notices; no #[cfg(test)] to mask
];

/// Run every rule over the repo rooted at `root`; violations are sorted
/// by file then line.
pub fn run(root: &Path) -> Result<Vec<Violation>> {
    let rust_dir = root.join("rust");
    if !rust_dir.is_dir() {
        bail!("{} has no rust/ directory (pass --root REPO)", root.display());
    }
    let mut files = Vec::new();
    collect_rs(&rust_dir, &mut files)?;
    files.sort();

    let mut out = Vec::new();
    for path in &files {
        let src = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = rel_slashed(path, root);
        let sc = scrub(&src);
        let in_test = test_mask(&sc.code);
        check_safety_comments(path, &sc, &mut out);
        if SERVE_PATHS.contains(&rel.as_str()) {
            check_serve_unwraps(path, &sc, &in_test, &mut out);
        }
        if !PRINT_ALLOWED.iter().any(|p| rel == *p || (p.ends_with('/') && rel.starts_with(p))) {
            check_print_macros(path, &sc, &in_test, &mut out);
        }
    }
    check_wire_consts(root, &mut out)?;
    out.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(out)
}

/// CLI entry for `srigl lint`: print a report, fail if anything fired.
pub fn cmd(root: &Path) -> Result<()> {
    let violations = run(root)?;
    if violations.is_empty() {
        println!("lint: clean ({})", rules_summary());
        return Ok(());
    }
    for v in &violations {
        println!("{v}");
    }
    bail!("lint: {} violation(s)", violations.len());
}

fn rules_summary() -> &'static str {
    "safety-comment, serve-unwrap, print-macro, wire-consts"
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_slashed(path: &Path, root: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

// ---------------------------------------------------------------------------
// Lexing: split each line into code (literals blanked) and comment text
// ---------------------------------------------------------------------------

struct Scrubbed {
    /// Per-line code with comments removed and string/char contents
    /// blanked (delimiters kept, so brace counting still works).
    code: Vec<String>,
    /// Per-line comment text (line + block + doc comments, concatenated).
    comment: Vec<String>,
}

fn scrub(src: &str) -> Scrubbed {
    enum Mode {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(usize),
    }
    let b: Vec<char> = src.chars().collect();
    let mut code = Vec::new();
    let mut comment = Vec::new();
    let mut cl = String::new();
    let mut cm = String::new();
    let mut mode = Mode::Code;
    let mut prev_ident = false; // last emitted code char was ident-ish (an `r` after one can't open a raw string)
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            code.push(std::mem::take(&mut cl));
            comment.push(std::mem::take(&mut cm));
            if matches!(mode, Mode::LineComment) {
                mode = Mode::Code;
            }
            prev_ident = false;
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment(1);
                    i += 2;
                } else if !prev_ident && (c == 'r' || (c == 'b' && next == Some('r'))) && {
                    // raw (byte) string: r"..."  r#"..."#  br#"..."#
                    let mut j = i + if c == 'b' { 2 } else { 1 };
                    while b.get(j) == Some(&'#') {
                        j += 1;
                    }
                    b.get(j) == Some(&'"')
                } {
                    let start = i + if c == 'b' { 2 } else { 1 };
                    let mut j = start;
                    while b.get(j) == Some(&'#') {
                        j += 1;
                    }
                    cl.push('"');
                    mode = Mode::RawStr(j - start);
                    i = j + 1;
                } else if c == '"' {
                    cl.push('"');
                    mode = Mode::Str;
                    i += 1;
                } else if c == '\'' {
                    // char literal vs lifetime
                    if next == Some('\\') {
                        cl.push_str("''");
                        i += 2; // consume '\ then skip to the closing quote
                        while i < b.len() && b[i] != '\'' {
                            i += 1;
                        }
                        i += 1;
                    } else if b.get(i + 2) == Some(&'\'') {
                        cl.push_str("''");
                        i += 3;
                    } else {
                        cl.push('\''); // lifetime marker
                        i += 1;
                    }
                    prev_ident = false;
                } else {
                    prev_ident = c.is_alphanumeric() || c == '_';
                    cl.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                cm.push(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    mode = if depth == 1 { Mode::Code } else { Mode::BlockComment(depth - 1) };
                    i += 2;
                } else {
                    cm.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    // keep line accounting for escaped-newline continuations
                    i += if b.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '"' {
                    cl.push('"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' && (1..=hashes).all(|k| b.get(i + k) == Some(&'#')) {
                    cl.push('"');
                    mode = Mode::Code;
                    i += 1 + hashes;
                } else {
                    i += 1;
                }
            }
        }
    }
    code.push(cl);
    comment.push(cm);
    Scrubbed { code, comment }
}

/// Standalone-token match: `tok` in `line` with non-ident chars (or line
/// edges) on both sides — `unsafe` matches, `unsafe_op_in_unsafe_fn`
/// doesn't.
fn has_token(line: &str, tok: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = line[from..].find(tok) {
        let at = from + pos;
        let before_ok = at == 0
            || !line[..at].chars().next_back().is_some_and(|c| c.is_alphanumeric() || c == '_');
        let after = at + tok.len();
        let after_ok = after >= line.len()
            || !line[after..].chars().next().is_some_and(|c| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return true;
        }
        from = at + tok.len();
    }
    false
}

/// Per-line mask: true where the line sits inside a `#[cfg(test)]` item
/// (the attribute line itself through the item's closing brace).
fn test_mask(code: &[String]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    let mut i = 0;
    while i < code.len() {
        if !code[i].contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth = 0i64;
        let mut started = false;
        let mut j = i;
        while j < code.len() {
            mask[j] = true;
            for ch in code[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if started && depth <= 0 {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

fn is_safety_comment(comment: &str) -> bool {
    comment.contains("SAFETY") || comment.contains("# Safety")
}

fn check_safety_comments(path: &Path, sc: &Scrubbed, out: &mut Vec<Violation>) {
    for (i, line) in sc.code.iter().enumerate() {
        if !has_token(line, "unsafe") {
            continue;
        }
        if is_safety_comment(&sc.comment[i]) {
            continue;
        }
        // Walk up through the contiguous comment/attribute/blank block.
        // Lines that themselves contain `unsafe` are part of the same
        // cluster (e.g. four raw-pointer derefs in a row) and share one
        // justification.
        let mut ok = false;
        let mut j = i;
        while j > 0 {
            j -= 1;
            if is_safety_comment(&sc.comment[j]) {
                ok = true;
                break;
            }
            let c = sc.code[j].trim();
            if !(c.is_empty() || c.starts_with('#') || has_token(c, "unsafe")) {
                break; // hit real code without finding a justification
            }
        }
        if !ok {
            out.push(Violation {
                file: path.to_path_buf(),
                line: i + 1,
                rule: "safety-comment",
                msg: "`unsafe` without a `// SAFETY:` justification on this line or \
                      directly above it"
                    .into(),
            });
        }
    }
}

fn check_serve_unwraps(path: &Path, sc: &Scrubbed, in_test: &[bool], out: &mut Vec<Violation>) {
    for (i, line) in sc.code.iter().enumerate() {
        if in_test[i] {
            continue;
        }
        let hit = if line.contains(".unwrap()") {
            Some(".unwrap()")
        } else if line.contains(".expect(") {
            Some(".expect(...)")
        } else {
            None
        };
        let Some(what) = hit else { continue };
        if sc.comment[i].contains(ALLOW_UNWRAP) {
            continue;
        }
        out.push(Violation {
            file: path.to_path_buf(),
            line: i + 1,
            rule: "serve-unwrap",
            msg: format!(
                "{what} on a serving path: handle the error (util::log + degrade) or mark \
                 the line `// {ALLOW_UNWRAP} <reason>` if it is provably infallible"
            ),
        });
    }
}

fn check_print_macros(path: &Path, sc: &Scrubbed, in_test: &[bool], out: &mut Vec<Violation>) {
    const MACROS: &[&str] = &["println!", "eprintln!", "print!", "eprint!"];
    for (i, line) in sc.code.iter().enumerate() {
        if in_test[i] {
            continue;
        }
        for m in MACROS {
            if has_token(line, &m[..m.len() - 1]) && line.contains(m) {
                out.push(Violation {
                    file: path.to_path_buf(),
                    line: i + 1,
                    rule: "print-macro",
                    msg: format!("bare `{m}` in library code: use `util::log` so `SRIGL_LOG` \
                                  level filtering applies"),
                });
                break;
            }
        }
    }
}

// --- wire-consts -----------------------------------------------------------

/// `pub const NAME: _ = EXPR;` in `src` → (value, 1-based line).
fn const_value(src: &str, name: &str) -> Option<(u64, usize)> {
    for (i, raw) in src.lines().enumerate() {
        let t = raw.trim();
        let Some(rest) = t.strip_prefix("pub const ") else { continue };
        let Some((decl, val)) = rest.split_once('=') else { continue };
        if decl.split(':').next().map(str::trim) != Some(name) {
            continue;
        }
        return eval_const(val.trim().trim_end_matches(';')).map(|v| (v, i + 1));
    }
    None
}

/// Evaluate the tiny const-expression language the wire module uses:
/// integer literals (decimal/hex, `_` separators), `u32::MAX`, `A << B`.
fn eval_const(expr: &str) -> Option<u64> {
    let e = expr.trim();
    if e == "u32::MAX" {
        return Some(u64::from(u32::MAX));
    }
    if let Some((a, b)) = e.split_once("<<") {
        return parse_int(a)?.checked_shl(parse_int(b)? as u32);
    }
    parse_int(e)
}

fn parse_int(s: &str) -> Option<u64> {
    let s = s.trim().replace('_', "");
    match s.strip_prefix("0x") {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => s.parse().ok(),
    }
}

/// First `<number> MiB` mention in the doc, as bytes.
fn doc_mib_cap(doc: &str) -> Option<u64> {
    let at = doc.find(" MiB")?;
    let digits: String = doc[..at]
        .chars()
        .rev()
        .take_while(|c| c.is_ascii_digit())
        .collect::<Vec<_>>()
        .into_iter()
        .rev()
        .collect();
    parse_int(&digits)?.checked_shl(20)
}

/// Single digit `d` such that `doc` contains `pat(d)`.
fn doc_digit(doc: &str, pat: impl Fn(u64) -> String) -> Option<u64> {
    (0..=9).find(|&d| doc.contains(&pat(d)))
}

fn check_wire_consts(root: &Path, out: &mut Vec<Violation>) -> Result<()> {
    let net_path = root.join("rust/src/net/mod.rs");
    let doc_path = root.join("docs/WIRE.md");
    let net = fs::read_to_string(&net_path)
        .with_context(|| format!("reading {}", net_path.display()))?;
    let doc = fs::read_to_string(&doc_path)
        .with_context(|| format!("reading {}", doc_path.display()))?;

    let mut expect = |name: &str, documented: Option<u64>, doc_desc: &str| {
        let Some(want) = documented else {
            out.push(Violation {
                file: doc_path.clone(),
                line: 1,
                rule: "wire-consts",
                msg: format!("docs/WIRE.md no longer documents {doc_desc} (expected for `{name}`)"),
            });
            return;
        };
        match const_value(&net, name) {
            Some((got, _)) if got == want => {}
            Some((got, line)) => out.push(Violation {
                file: net_path.clone(),
                line,
                rule: "wire-consts",
                msg: format!("`{name}` = {got} but docs/WIRE.md documents {doc_desc} = {want}"),
            }),
            None => out.push(Violation {
                file: net_path.clone(),
                line: 1,
                rule: "wire-consts",
                msg: format!("`pub const {name}` not found but docs/WIRE.md documents {doc_desc}"),
            }),
        }
    };

    expect("MAX_FRAME_BYTES", doc_mib_cap(&doc), "the frame cap");
    expect("STATUS_OK", doc_digit(&doc, |d| format!("`{d}` Ok")), "status Ok");
    expect("STATUS_BUSY", doc_digit(&doc, |d| format!("`{d}` Busy")), "status Busy");
    expect("STATUS_ERROR", doc_digit(&doc, |d| format!("`{d}` Error")), "status Error");
    expect("STATUS_EPOCH", doc_digit(&doc, |d| format!("`{d}` Epoch")), "status Epoch");
    expect(
        "CONTROL_OP_RELOAD",
        doc_digit(&doc, |d| format!("opcode {d} (reload)")),
        "the reload opcode",
    );
    expect(
        "CONTROL_SENTINEL",
        doc.contains("rows == u32::MAX").then(|| u64::from(u32::MAX)),
        "the control sentinel (`rows == u32::MAX`)",
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines(src: &str) -> Scrubbed {
        scrub(src)
    }

    #[test]
    fn scrub_separates_comments_and_blanks_literals() {
        let sc = lines("let x = \"unsafe println!\"; // SAFETY: not really\nlet y = 'u';\n");
        assert!(!sc.code[0].contains("unsafe"), "string contents blanked: {}", sc.code[0]);
        assert!(sc.comment[0].contains("SAFETY"));
        assert_eq!(sc.code[1], "let y = '';");
    }

    #[test]
    fn scrub_handles_raw_strings_and_lifetimes() {
        let sc = lines("let j = r#\"{\"k\": \"unsafe\"}\"#;\nfn f<'a>(x: &'a str) {}\n");
        assert!(!sc.code[0].contains("unsafe"));
        assert!(sc.code[0].ends_with(';'), "raw string closed: {}", sc.code[0]);
        assert!(sc.code[1].contains("<'a>"), "lifetimes survive: {}", sc.code[1]);
    }

    #[test]
    fn scrub_tracks_multiline_and_nested_comments() {
        let sc = lines("/* outer /* inner */ still comment */ code();\n// tail\n");
        assert_eq!(sc.code[0].trim(), "code();");
        assert!(sc.comment[1].contains("tail"));
    }

    #[test]
    fn scrub_survives_escaped_newline_in_string() {
        let sc = lines("let s = \"a \\\n   b\";\nafter();\n");
        assert_eq!(sc.code.len(), 3, "line accounting preserved");
        assert_eq!(sc.code[2].trim(), "after();");
    }

    #[test]
    fn token_matching_respects_word_boundaries() {
        assert!(has_token("unsafe impl Send for X {}", "unsafe"));
        assert!(!has_token("#![deny(unsafe_op_in_unsafe_fn)]", "unsafe"));
        assert!(has_token("eprintln!(\"x\")", "eprintln"));
        assert!(!has_token("writeln!(f)", "println"));
    }

    #[test]
    fn safety_rule_accepts_adjacent_and_trailing_justifications() {
        let ok = "// SAFETY: bounds checked above\nunsafe { go() };\n\
                  let x = unsafe { f() }; // SAFETY: f is pure\n\
                  /// docs\n/// # Safety\n/// caller promises\npub unsafe fn g() {}\n";
        let sc = lines(ok);
        let mut out = Vec::new();
        check_safety_comments(Path::new("x.rs"), &sc, &mut out);
        assert!(out.is_empty(), "{out:?}");

        let bad = "let first = 1;\nunsafe { go() };\n";
        let mut out = Vec::new();
        check_safety_comments(Path::new("x.rs"), &lines(bad), &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 2);
    }

    #[test]
    fn serve_unwrap_rule_honors_tests_and_markers() {
        let src = "fn f() {\n    a.lock().unwrap();\n    b.expect(\"up\"); // lint:allow-unwrap startup only\n}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        let sc = lines(src);
        let mask = test_mask(&sc.code);
        let mut out = Vec::new();
        check_serve_unwraps(Path::new("x.rs"), &sc, &mask, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].line, 2);
    }

    #[test]
    fn print_rule_skips_tests_and_doc_comments() {
        let src = "/// println! in docs is fine\nfn f() { crate::util::log::info(\"a\", \"b\"); }\nfn g() { println!(\"no\"); }\n#[cfg(test)]\nmod tests { fn t() { println!(\"ok\"); } }\n";
        let sc = lines(src);
        let mask = test_mask(&sc.code);
        let mut out = Vec::new();
        check_print_macros(Path::new("x.rs"), &sc, &mask, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].line, 3);
    }

    #[test]
    fn const_mini_evaluator() {
        assert_eq!(eval_const("64 << 20"), Some(64 << 20));
        assert_eq!(eval_const("u32::MAX"), Some(u64::from(u32::MAX)));
        assert_eq!(eval_const("0xFF"), Some(255));
        assert_eq!(eval_const("1_000"), Some(1000));
        let src = "pub const MAX_FRAME_BYTES: usize = 64 << 20;\n";
        assert_eq!(const_value(src, "MAX_FRAME_BYTES"), Some((64 << 20, 1)));
    }

    /// The rules hold over this repo itself — the in-process equivalent
    /// of the CI `lint` job, so `cargo test` alone catches a regression.
    #[test]
    fn repo_is_lint_clean() {
        // CARGO_MANIFEST_DIR is the repo root (the crate lives at the top
        // level with sources under rust/).
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let violations = run(&root).expect("lint run");
        assert!(
            violations.is_empty(),
            "lint violations:\n{}",
            violations.iter().map(|v| format!("  {v}\n")).collect::<String>()
        );
    }
}
