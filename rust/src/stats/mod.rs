//! Analysis substrates for the paper's evaluation: output-norm variance
//! theory + simulation (Fig. 1b), topology analytics (Figs. 3b, 10-12),
//! and ITOP-rate tracking (Figs. 14-17).

pub mod ablation;
pub mod compare;
pub mod itop;
pub mod variance;

pub use ablation::{active_neuron_fraction, LayerTopology};
pub use compare::{bootstrap_mean_ci, mean_var, t_ci, MeanCi, Verdict};
pub use itop::ItopTracker;
pub use variance::{simulate_var, var_bernoulli, var_const_fan_in, var_const_per_layer, SparsityType};

/// Mean and the half-width of a 95% confidence interval (t≈1.96 normal
/// approximation) — the format of paper Tables 2 and 9.
pub fn mean_ci95(xs: &[f64]) -> (f64, f64) {
    let n = xs.len();
    if n == 0 {
        return (f64::NAN, f64::NAN);
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    if n == 1 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
    (mean, 1.96 * (var / n as f64).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ci95_basic() {
        let (m, ci) = mean_ci95(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!(ci > 0.0 && ci < 2.0);
        let (m1, ci1) = mean_ci95(&[5.0]);
        assert_eq!((m1, ci1), (5.0, 0.0));
    }
}
