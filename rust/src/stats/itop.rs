//! In-Time Over-Parameterization rate (Liu et al. 2021c): the fraction of
//! all prunable weights that have been active at *some* point during
//! training. Reproduces paper Figs. 14-17.

use crate::sparsity::Mask;
use crate::tensor::Tensor;

#[derive(Debug)]
pub struct ItopTracker {
    /// Union of every mask seen so far, per layer.
    acc: Vec<Tensor>,
    total: usize,
    /// (step-index series, rate) history appended at each ingest.
    pub history: Vec<f64>,
}

impl ItopTracker {
    pub fn new(masks: &[Mask]) -> ItopTracker {
        let mut acc = Vec::new();
        let mut total = 0;
        for m in masks {
            total += m.t.numel();
            let mut a = Tensor::zeros(&m.t.shape);
            m.or_into(&mut a);
            acc.push(a);
        }
        ItopTracker { acc, total, history: Vec::new() }
    }

    /// Fold in the current topology (call after every mask update).
    pub fn ingest(&mut self, masks: &[Mask]) {
        for (a, m) in self.acc.iter_mut().zip(masks) {
            m.or_into(a);
        }
        self.history.push(self.rate());
    }

    /// Fraction of prunable parameter positions ever activated.
    pub fn rate(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let explored: usize = self.acc.iter().map(|a| a.count_nonzero()).sum();
        explored as f64 / self.total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rate_starts_at_density_and_grows() {
        let mut rng = Rng::new(0);
        let m0 = Mask::random_constant_fan_in(&[16, 32], 4, &mut rng);
        let mut tr = ItopTracker::new(std::slice::from_ref(&m0));
        let r0 = tr.rate();
        assert!((r0 - 4.0 / 32.0).abs() < 1e-12);
        // new random topology explores new positions
        let m1 = Mask::random_constant_fan_in(&[16, 32], 4, &mut rng);
        tr.ingest(std::slice::from_ref(&m1));
        assert!(tr.rate() >= r0);
        assert_eq!(tr.history.len(), 1);
    }

    #[test]
    fn static_topology_flat_rate() {
        let mut rng = Rng::new(1);
        let m = Mask::random_constant_fan_in(&[8, 8], 2, &mut rng);
        let mut tr = ItopTracker::new(std::slice::from_ref(&m));
        let r = tr.rate();
        for _ in 0..5 {
            tr.ingest(std::slice::from_ref(&m));
        }
        assert!(tr.history.iter().all(|&h| (h - r).abs() < 1e-12));
    }
}
