//! Output-norm variance theory (paper Appendix A/B) and the Monte-Carlo
//! simulation that validates it (Fig. 1b).
//!
//! For a ReLU layer z = sqrt(2/k) (W ⊙ I)(ξ ⊙ u) with n neurons and mean
//! fan-in k, the variance of ||z||² depends on the sparsity *structure*:
//!
//!   Bernoulli (Eq. 14):        (5n - 8 + 18 n/k) / (n (n+2))
//!   Const-per-layer (Eq. 21):  ((n²+7n-8) C_{n,k} + 18 n/k - n² - 2n) / (n(n+2))
//!                              with C_{n,k} = (n - 1/k) / (n - 1/n)
//!   Const-fan-in (Eq. 25):     Bernoulli - 3(n-k) / (k n (n+2))
//!
//! NOTE: the paper's *main-text* Eqs. 1-3 print the Bernoulli term as
//! `18 k/n`; re-deriving the four-case sum of Appendix B (Tables 6-8)
//! gives `18 n/k`, which matches Prop. B.4 (Eq. 14) and our Monte-Carlo
//! simulation to ~2% — we therefore implement the appendix version and
//! treat the main-text exponent flip as a typo (recorded in
//! EXPERIMENTS.md fig1b notes).
//!
//! Constant fan-in is *always* the smallest — the theoretical motivation
//! for SRigL's structural constraint.

use crate::util::rng::Rng;

/// Prop. B.4 (Eq. 14) — independent Bernoulli(k/n) connectivity.
pub fn var_bernoulli(n: usize, k: usize) -> f64 {
    let (n, k) = (n as f64, k as f64);
    (5.0 * n - 8.0 + 18.0 * n / k) / (n * (n + 2.0))
}

/// Prop. B.5 (Eq. 21) — exactly k·n connections placed uniformly.
pub fn var_const_per_layer(n: usize, k: usize) -> f64 {
    let (nf, kf) = (n as f64, k as f64);
    let c = (nf - 1.0 / kf) / (nf - 1.0 / nf);
    ((nf * nf + 7.0 * nf - 8.0) * c + 18.0 * nf / kf - nf * nf - 2.0 * nf) / (nf * (nf + 2.0))
}

/// Prop. B.6 (Eq. 25) — exactly k connections per neuron (constant fan-in).
pub fn var_const_fan_in(n: usize, k: usize) -> f64 {
    let (nf, kf) = (n as f64, k as f64);
    var_bernoulli(n, k) - 3.0 * (nf - kf) / (kf * nf * (nf + 2.0))
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparsityType {
    Bernoulli,
    ConstPerLayer,
    ConstFanIn,
}

impl SparsityType {
    pub fn theory(&self, n: usize, k: usize) -> f64 {
        match self {
            SparsityType::Bernoulli => var_bernoulli(n, k),
            SparsityType::ConstPerLayer => var_const_per_layer(n, k),
            SparsityType::ConstFanIn => var_const_fan_in(n, k),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SparsityType::Bernoulli => "bernoulli",
            SparsityType::ConstPerLayer => "const-per-layer",
            SparsityType::ConstFanIn => "const-fan-in",
        }
    }
}

/// Monte-Carlo estimate of Var(||z||²) for the given sparsity type,
/// following Definition B.1: W ~ N(0,1), ξ ~ Ber(1/2) (the ReLU-sign
/// proxy), u uniform on the sphere, z = sqrt(2/k) (W ⊙ I)(ξ ⊙ u).
pub fn simulate_var(ty: SparsityType, n: usize, k: usize, trials: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut s1 = 0f64;
    let mut s2 = 0f64;
    let mut u = vec![0f64; n];
    let mut xi_u = vec![0f64; n];
    for _ in 0..trials {
        // u uniform on the unit sphere
        let mut norm = 0f64;
        for v in u.iter_mut() {
            *v = rng.normal();
            norm += *v * *v;
        }
        let inv = 1.0 / norm.sqrt().max(1e-300);
        for (xu, v) in xi_u.iter_mut().zip(&u) {
            let xi = if rng.uniform() < 0.5 { 1.0 } else { 0.0 };
            *xu = xi * v * inv;
        }

        // ||z||^2 = (2/k) Σ_i ( Σ_j W_ij I_ij (ξ⊙u)_j )² ; we synthesize
        // row sums directly. Var(z_i | I) = Σ_j I_ij (ξ⊙u)_j², so each
        // z_i = g_i · sqrt(Σ_j I_ij (ξu)_j²) (Prop. B.2) — this lets the
        // simulation draw per-row gathers instead of full matrices.
        let mut norm_z = 0f64;
        match ty {
            SparsityType::ConstFanIn => {
                for _ in 0..n {
                    let mut row = 0f64;
                    for j in rng.choose_k(n, k) {
                        row += xi_u[j] * xi_u[j];
                    }
                    let g = rng.normal();
                    norm_z += g * g * row;
                }
            }
            SparsityType::Bernoulli => {
                let p = k as f64 / n as f64;
                for _ in 0..n {
                    let mut row = 0f64;
                    for xu in &xi_u {
                        if rng.uniform() < p {
                            row += xu * xu;
                        }
                    }
                    let g = rng.normal();
                    norm_z += g * g * row;
                }
            }
            SparsityType::ConstPerLayer => {
                // exactly k*n ones over the n×n grid
                let mut rows = vec![0f64; n];
                for idx in rng.choose_k(n * n, k * n) {
                    let (i, j) = (idx / n, idx % n);
                    rows[i] += xi_u[j] * xi_u[j];
                }
                for row in rows {
                    let g = rng.normal();
                    norm_z += g * g * row;
                }
            }
        }
        let z2 = 2.0 / k as f64 * norm_z;
        s1 += z2;
        s2 += z2 * z2;
    }
    let mean = s1 / trials as f64;
    s2 / trials as f64 - mean * mean
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_fan_in_always_smallest() {
        for &n in &[64usize, 256, 1000] {
            for &k in &[2usize, 8, 32] {
                if k >= n {
                    continue;
                }
                let b = var_bernoulli(n, k);
                let c = var_const_fan_in(n, k);
                let p = var_const_per_layer(n, k);
                assert!(c < b, "n={n} k={k}: cfi {c} !< bern {b}");
                assert!(c < p, "n={n} k={k}: cfi {c} !< cpl {p}");
            }
        }
    }

    #[test]
    fn gap_grows_as_k_shrinks() {
        let n = 512;
        let gap_small_k = var_bernoulli(n, 2) - var_const_fan_in(n, 2);
        let gap_big_k = var_bernoulli(n, 128) - var_const_fan_in(n, 128);
        assert!(gap_small_k > gap_big_k);
    }

    #[test]
    fn cpl_close_to_bernoulli_for_large_n() {
        // C_{n,k} -> 1 as n >> 1 (paper remark after Eq. 2).
        let n = 2000;
        let k = 16;
        let rel = (var_const_per_layer(n, k) - var_bernoulli(n, k)).abs() / var_bernoulli(n, k);
        assert!(rel < 0.05, "rel={rel}");
    }

    #[test]
    fn simulation_matches_theory_const_fan_in() {
        let (n, k) = (128, 8);
        let sim = simulate_var(SparsityType::ConstFanIn, n, k, 4000, 42);
        let th = var_const_fan_in(n, k);
        let rel = (sim - th).abs() / th;
        assert!(rel < 0.15, "sim={sim} theory={th} rel={rel}");
    }

    #[test]
    fn simulation_matches_theory_bernoulli() {
        let (n, k) = (128, 8);
        let sim = simulate_var(SparsityType::Bernoulli, n, k, 4000, 43);
        let th = var_bernoulli(n, k);
        let rel = (sim - th).abs() / th;
        assert!(rel < 0.15, "sim={sim} theory={th} rel={rel}");
    }

    #[test]
    fn mean_is_one() {
        // E(||z||²) = 1 for all types (Prop. B.4-B.6): check via simulation
        // by reusing simulate_var internals indirectly — mean within noise.
        let (n, k) = (64, 4);
        let mut rng = Rng::new(7);
        let trials = 3000;
        let mut s1 = 0f64;
        for _ in 0..trials {
            let mut u = vec![0f64; n];
            let mut norm = 0f64;
            for v in u.iter_mut() {
                *v = rng.normal();
                norm += *v * *v;
            }
            let inv = 1.0 / norm.sqrt();
            let mut z2 = 0f64;
            for _ in 0..n {
                let mut row = 0f64;
                for j in rng.choose_k(n, k) {
                    let xi = if rng.uniform() < 0.5 { 1.0 } else { 0.0 };
                    let xu = xi * u[j] * inv;
                    row += xu * xu;
                }
                let g = rng.normal();
                z2 += g * g * row;
            }
            s1 += 2.0 / k as f64 * z2;
        }
        let mean = s1 / trials as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
    }
}
