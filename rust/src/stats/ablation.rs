//! Topology analytics over trained masks: active-neuron fractions
//! (Fig. 3b), per-layer widths (Fig. 11), fan-in variance (Fig. 12), and
//! the minimum-salient-weights clamp report (Fig. 10).

/// Summary of one layer's topology.
#[derive(Clone, Debug)]
pub struct LayerTopology {
    pub name: String,
    pub neurons: usize,
    pub active_neurons: usize,
    pub fan_in_mean: f64,
    pub fan_in_var: f64,
    pub fan_in_max: usize,
    pub nnz: usize,
}

impl LayerTopology {
    pub fn from_counts(name: &str, counts: &[usize]) -> LayerTopology {
        let neurons = counts.len();
        let alive: Vec<usize> = counts.iter().copied().filter(|&c| c > 0).collect();
        let nnz: usize = counts.iter().sum();
        let mean = if alive.is_empty() {
            0.0
        } else {
            alive.iter().sum::<usize>() as f64 / alive.len() as f64
        };
        let var = if alive.len() < 2 {
            0.0
        } else {
            alive.iter().map(|&c| (c as f64 - mean).powi(2)).sum::<f64>() / alive.len() as f64
        };
        LayerTopology {
            name: name.to_string(),
            neurons,
            active_neurons: alive.len(),
            fan_in_mean: mean,
            fan_in_var: var,
            fan_in_max: alive.iter().copied().max().unwrap_or(0),
            nnz,
        }
    }

    /// Fraction of neurons still active — the Fig. 3b y-axis.
    pub fn active_fraction(&self) -> f64 {
        if self.neurons == 0 {
            0.0
        } else {
            self.active_neurons as f64 / self.neurons as f64
        }
    }
}

/// Model-wide active-neuron percentage (Fig. 3b series point).
pub fn active_neuron_fraction(per_layer: &[LayerTopology]) -> f64 {
    let total: usize = per_layer.iter().map(|l| l.neurons).sum();
    let active: usize = per_layer.iter().map(|l| l.active_neurons).sum();
    if total == 0 {
        0.0
    } else {
        active as f64 / total as f64
    }
}

/// Fig. 10: the per-layer minimum-salient-weights threshold
/// max(1, gamma_sal * k) the SRigL update applies.
pub fn min_salient_per_neuron(gamma_sal: f64, k: usize) -> f64 {
    (gamma_sal * k as f64).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_from_counts() {
        let t = LayerTopology::from_counts("l", &[3, 3, 0, 3, 0]);
        assert_eq!(t.neurons, 5);
        assert_eq!(t.active_neurons, 3);
        assert_eq!(t.nnz, 9);
        assert!((t.active_fraction() - 0.6).abs() < 1e-12);
        assert_eq!(t.fan_in_mean, 3.0);
        assert_eq!(t.fan_in_var, 0.0);
        assert_eq!(t.fan_in_max, 3);
    }

    #[test]
    fn variance_detects_unbalanced_fan_in() {
        let uniform = LayerTopology::from_counts("u", &[4; 16]);
        let skewed = LayerTopology::from_counts("s", &[1, 1, 1, 1, 28]);
        assert_eq!(uniform.fan_in_var, 0.0);
        assert!(skewed.fan_in_var > 50.0);
        assert_eq!(skewed.fan_in_max, 28);
    }

    #[test]
    fn model_fraction() {
        let layers = vec![
            LayerTopology::from_counts("a", &[1, 1, 0, 0]),
            LayerTopology::from_counts("b", &[2, 2, 2, 2]),
        ];
        assert!((active_neuron_fraction(&layers) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn min_salient_clamps_to_one() {
        assert_eq!(min_salient_per_neuron(0.3, 2), 1.0);
        assert_eq!(min_salient_per_neuron(0.3, 10), 3.0);
        assert_eq!(min_salient_per_neuron(0.95, 100), 95.0);
    }
}
