//! Paired-comparison statistics for head-to-head measurements — the
//! inference substrate behind the traffic arena's winner declaration
//! (`srigl arena`, [`crate::arena`]).
//!
//! Two flavours of confidence interval, both deterministic:
//!
//! * [`t_ci`] — normal/t approximation for the mean of a small sample
//!   (per-round paired throughput deltas: a handful of replicates). Uses
//!   two-sided 95% t quantiles for df <= 30, 1.96 beyond.
//! * [`bootstrap_mean_ci`] — percentile bootstrap for the mean of a large
//!   sample (per-request paired latency deltas: thousands of diffs whose
//!   distribution is skewed and heavy-tailed, where the normal
//!   approximation is least trustworthy). Resampling is driven by the
//!   crate's xoshiro [`Rng`], so the same seed reproduces the interval
//!   bit-for-bit.
//!
//! The paired design is what gives the arena statistical teeth: both
//! engine configs replay the *same* trace, so per-request and per-round
//! differences cancel the shared load pattern and the interval speaks
//! only to the config change.

use crate::util::rng::Rng;

/// A mean with a two-sided confidence interval.
#[derive(Clone, Copy, Debug)]
pub struct MeanCi {
    pub mean: f64,
    pub lo: f64,
    pub hi: f64,
}

impl MeanCi {
    /// True when the interval excludes zero — the paired delta is
    /// distinguishable from "no difference" at the interval's level.
    pub fn excludes_zero(&self) -> bool {
        (self.lo > 0.0 && self.hi > 0.0) || (self.lo < 0.0 && self.hi < 0.0)
    }
}

/// Sample mean and *unbiased* (n-1) variance; (mean, 0.0) for n < 2.
pub fn mean_var(xs: &[f64]) -> (f64, f64) {
    let n = xs.len();
    if n == 0 {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    if n < 2 {
        return (mean, 0.0);
    }
    let ss: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum();
    (mean, ss / (n - 1) as f64)
}

/// Two-sided 95% t quantile for `df` degrees of freedom (1.96 beyond 30 —
/// within 2% of the exact value there).
fn t95(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, // 1..=10
        2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, // 11..=20
        2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042, // 21..=30
    ];
    match df {
        0 => f64::INFINITY,
        1..=30 => TABLE[df - 1],
        _ => 1.96,
    }
}

/// 95% confidence interval for the mean of `xs` under the t
/// approximation: `mean ± t_{df} * s / sqrt(n)`. With n < 2 the interval
/// is infinitely wide (mean ± ∞) — one replicate proves nothing, and the
/// caller's verdict correctly degrades to "inconclusive".
pub fn t_ci(xs: &[f64]) -> MeanCi {
    let (mean, var) = mean_var(xs);
    let n = xs.len();
    if n < 2 {
        return MeanCi { mean, lo: f64::NEG_INFINITY, hi: f64::INFINITY };
    }
    let half = t95(n - 1) * (var / n as f64).sqrt();
    MeanCi { mean, lo: mean - half, hi: mean + half }
}

/// Percentile-bootstrap confidence interval for the mean of `xs` at level
/// `conf` (e.g. 0.95): resample n-out-of-n with replacement `resamples`
/// times, take the (α/2, 1-α/2) empirical quantiles of the resampled
/// means. Deterministic for a given `seed`. Degenerate inputs (n < 2, or
/// zero resamples) fall back to [`t_ci`]'s behavior at the edges.
pub fn bootstrap_mean_ci(xs: &[f64], resamples: usize, conf: f64, seed: u64) -> MeanCi {
    let n = xs.len();
    let (mean, _) = mean_var(xs);
    if n < 2 || resamples == 0 {
        return MeanCi { mean, lo: f64::NEG_INFINITY, hi: f64::INFINITY };
    }
    let mut rng = Rng::new(seed);
    let mut means = Vec::with_capacity(resamples);
    for _ in 0..resamples {
        let mut s = 0.0f64;
        for _ in 0..n {
            s += xs[rng.below(n)];
        }
        means.push(s / n as f64);
    }
    means.sort_by(f64::total_cmp);
    let alpha = (1.0 - conf.clamp(0.0, 1.0)) / 2.0;
    let q = |p: f64| {
        // nearest-rank on the resampled means (they are dense enough that
        // interpolation would change nothing material)
        let idx = ((p * (resamples - 1) as f64).round() as usize).min(resamples - 1);
        means[idx]
    };
    MeanCi { mean, lo: q(alpha), hi: q(1.0 - alpha) }
}

/// Outcome of one paired metric comparison. "Positive delta" means side B
/// beat side A on this metric (the caller orients the sign).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// The interval excludes zero in B's favour.
    BWins,
    /// The interval excludes zero in A's favour.
    AWins,
    /// The interval straddles zero: no significant difference.
    Inconclusive,
}

impl Verdict {
    /// Classify an interval over (B - A) deltas where larger is better.
    pub fn from_ci(ci: &MeanCi) -> Verdict {
        if !ci.excludes_zero() {
            Verdict::Inconclusive
        } else if ci.mean > 0.0 {
            Verdict::BWins
        } else {
            Verdict::AWins
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Verdict::AWins => "A wins",
            Verdict::BWins => "B wins",
            Verdict::Inconclusive => "no significant difference",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var_basics() {
        assert_eq!(mean_var(&[]), (0.0, 0.0));
        assert_eq!(mean_var(&[3.0]), (3.0, 0.0));
        let (m, v) = mean_var(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m, 2.5);
        assert!((v - 5.0 / 3.0).abs() < 1e-12, "unbiased variance, got {v}");
    }

    #[test]
    fn t_ci_covers_and_shrinks() {
        // constant sample: zero-width interval at the mean
        let ci = t_ci(&[5.0, 5.0, 5.0, 5.0]);
        assert_eq!((ci.mean, ci.lo, ci.hi), (5.0, 5.0, 5.0));
        // single sample: infinitely wide, never "significant"
        let ci1 = t_ci(&[5.0]);
        assert!(!ci1.excludes_zero());
        assert!(ci1.lo.is_infinite() && ci1.hi.is_infinite());
        // n=2 of {4,6}: mean 5, s=sqrt(2), half = t(1)*sqrt(2/2) = 12.706
        let ci2 = t_ci(&[4.0, 6.0]);
        assert_eq!(ci2.mean, 5.0);
        assert!((ci2.hi - ci2.mean - 12.706).abs() < 1e-9, "t(1)=12.706 at n=2");
        // more replicates with the same spread tighten the interval
        let ci8 = t_ci(&[4.0, 6.0, 4.0, 6.0, 4.0, 6.0, 4.0, 6.0]);
        assert!(ci8.hi - ci8.lo < ci2.hi - ci2.lo);
        assert!(ci8.excludes_zero(), "clearly positive mean with 8 replicates");
    }

    #[test]
    fn t95_table_shape() {
        assert_eq!(t95(0), f64::INFINITY);
        assert!((t95(1) - 12.706).abs() < 1e-9);
        assert!(t95(5) > t95(10), "quantile shrinks with df");
        assert_eq!(t95(31), 1.96);
        assert_eq!(t95(1000), 1.96);
    }

    #[test]
    fn bootstrap_is_deterministic_and_sane() {
        let xs: Vec<f64> = (0..200).map(|i| (i % 17) as f64 - 8.0 + 3.0).collect();
        let a = bootstrap_mean_ci(&xs, 500, 0.95, 42);
        let b = bootstrap_mean_ci(&xs, 500, 0.95, 42);
        assert_eq!((a.lo, a.hi), (b.lo, b.hi), "same seed, same interval");
        let c = bootstrap_mean_ci(&xs, 500, 0.95, 43);
        assert!((a.lo, a.hi) != (c.lo, c.hi), "different seed resamples differently");
        assert!(a.lo <= a.mean && a.mean <= a.hi, "interval brackets the sample mean");
        assert!(a.excludes_zero(), "mean 3 with tight spread excludes zero");
        // constant data: the interval collapses onto the constant
        let k = bootstrap_mean_ci(&[7.0; 50], 200, 0.95, 1);
        assert_eq!((k.mean, k.lo, k.hi), (7.0, 7.0, 7.0));
    }

    #[test]
    fn bootstrap_degenerate_inputs_are_inconclusive() {
        assert!(!bootstrap_mean_ci(&[], 100, 0.95, 1).excludes_zero());
        assert!(!bootstrap_mean_ci(&[3.0], 100, 0.95, 1).excludes_zero());
        assert!(!bootstrap_mean_ci(&[1.0, 2.0], 0, 0.95, 1).excludes_zero());
    }

    #[test]
    fn verdict_orientation() {
        assert_eq!(Verdict::from_ci(&MeanCi { mean: 5.0, lo: 2.0, hi: 8.0 }), Verdict::BWins);
        assert_eq!(Verdict::from_ci(&MeanCi { mean: -5.0, lo: -8.0, hi: -2.0 }), Verdict::AWins);
        assert_eq!(
            Verdict::from_ci(&MeanCi { mean: 1.0, lo: -1.0, hi: 3.0 }),
            Verdict::Inconclusive
        );
        assert_eq!(
            Verdict::from_ci(&MeanCi { mean: 0.0, lo: f64::NEG_INFINITY, hi: f64::INFINITY }),
            Verdict::Inconclusive
        );
    }
}
