//! Engine conformance suite: every execution strategy behind the
//! [`Engine`] trait must compute **bit-for-bit** the same outputs —
//! replicated ([`SparseModel`] directly), scoped-sharded
//! ([`ShardedModel`], the per-forward `thread::scope` reference
//! implementation), and persistent-sharded
//! ([`PersistentShardedEngine`], the long-lived mailbox/condvar team).
//! Not even f32 re-association may differ: the sharded paths run the
//! identical `shard_pass` layer walk, and slices copy weight rows
//! verbatim. Pinned across:
//!
//! * all representations (incl. the batch-tiled condensed form — batch
//!   256 exercises its full-tile path, 7 its remainder — and the int8
//!   quantized pair, whose exact i32 accumulation makes even the
//!   row-vs-tiled driver pair bit-identical), uniform and mixed per
//!   layer;
//! * shard counts {1, 2, 3};
//! * batch sizes {1, 7, 256};
//! * intra-shard thread counts {1, 4};
//! * heavy ablation (zero-cost neuron runs in the plan).
//!
//! Plus the lifecycle guarantees of the persistent team: the same S
//! long-lived threads execute every forward (no per-request spawning —
//! Rust never reuses a `ThreadId`, so scoped spawning would mint fresh
//! ids every call), and a team drops cleanly.

use srigl::inference::model::{Activation, LayerSpec, Repr, SparseModel};
use srigl::inference::shard::{ShardPlan, ShardPlanError, ShardedModel};
use srigl::inference::{Engine, PersistentShardedEngine};
use srigl::util::rng::Rng;

const BATCHES: [usize; 3] = [1, 7, 256];
const SHARDS: [usize; 3] = [1, 2, 3];

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{ctx}: idx {i}: {g} vs {w} (must be bit-for-bit)");
    }
}

fn stack(reprs: &[Repr], ablated: f64, seed: u64) -> SparseModel {
    let n_layers = reprs.len();
    let widths = [48usize, 32, 16];
    let specs: Vec<LayerSpec> = reprs
        .iter()
        .enumerate()
        .map(|(i, &repr)| LayerSpec {
            n: widths[i % widths.len()],
            repr,
            sparsity: 0.9,
            ablated_frac: ablated,
            activation: if i + 1 == n_layers { Activation::Identity } else { Activation::Relu },
        })
        .collect();
    SparseModel::synth(64, &specs, seed).unwrap()
}

/// Drive any engine through the generic trait surface (typed scratch).
fn run_engine<E: Engine>(engine: &E, x: &[f32], batch: usize, threads: usize) -> Vec<f32> {
    let mut scratch = engine.scratch(batch);
    engine.forward(x, batch, &mut scratch, threads).to_vec()
}

/// The conformance core: replicated vs scoped-sharded vs
/// persistent-sharded on identical weights and inputs, across batch sizes
/// and intra-shard thread counts.
fn check_all_engines(model: &SparseModel, shards: usize, ctx: &str) {
    let scoped = ShardedModel::from_model(model, shards).unwrap();
    let team = PersistentShardedEngine::from_model(model, shards).unwrap();
    assert_eq!(team.team_size(), shards, "{ctx}: one long-lived thread per shard");
    for &batch in &BATCHES {
        let mut rng = Rng::new(0xE0 ^ batch as u64);
        let x: Vec<f32> = (0..batch * model.in_width()).map(|_| rng.normal_f32()).collect();
        let want = run_engine(model, &x, batch, 1); // replicated reference
        for threads in [1usize, 4] {
            let scoped_out = run_engine(&scoped, &x, batch, threads);
            let team_out = run_engine(&team, &x, batch, threads);
            assert_bits_eq(
                &scoped_out,
                &want,
                &format!("{ctx} b{batch} t{threads} scoped-vs-replicated"),
            );
            assert_bits_eq(
                &team_out,
                &want,
                &format!("{ctx} b{batch} t{threads} persistent-vs-replicated"),
            );
        }
    }
}

#[test]
fn engines_agree_all_reprs() {
    for repr in Repr::ALL {
        let model = stack(&[repr; 3], 0.25, 7);
        for &shards in &SHARDS {
            check_all_engines(&model, shards, &format!("{} s{shards}", repr.name()));
        }
    }
}

#[test]
fn engines_agree_mixed_stack() {
    let model = stack(
        &[
            Repr::Condensed,
            Repr::CondensedTiled,
            Repr::Csr,
            Repr::Structured,
            Repr::Dense,
            Repr::Quantized,
            Repr::QuantizedTiled,
        ],
        0.3,
        21,
    );
    for &shards in &SHARDS {
        check_all_engines(&model, shards, &format!("mixed s{shards}"));
    }
}

#[test]
fn engines_agree_with_heavy_ablation() {
    // over half the neurons ablated: plans must absorb long zero-cost runs
    for repr in [
        Repr::Condensed,
        Repr::CondensedTiled,
        Repr::Structured,
        Repr::Quantized,
        Repr::QuantizedTiled,
    ] {
        let model = stack(&[repr; 3], 0.6, 33);
        for &shards in &SHARDS {
            check_all_engines(&model, shards, &format!("{} ablated s{shards}", repr.name()));
        }
    }
}

/// Unique among repr pairs: the int8 row-gather and batch-tiled drivers
/// compute **identical bits** (i32 accumulation is exact, both paths
/// quantize inputs per row and share one finalize), so a whole stack built
/// with `quantized` must equal the same stack built with
/// `quantized-tiled` — across every engine, shard count, and batch size.
#[test]
fn quantized_row_and_tiled_drivers_agree_bitwise() {
    let row = stack(&[Repr::Quantized; 3], 0.25, 7);
    let tiled = stack(&[Repr::QuantizedTiled; 3], 0.25, 7);
    for &shards in &SHARDS {
        let scoped = ShardedModel::from_model(&tiled, shards).unwrap();
        for &batch in &BATCHES {
            let mut rng = Rng::new(0xE0 ^ batch as u64);
            let x: Vec<f32> = (0..batch * row.in_width()).map(|_| rng.normal_f32()).collect();
            let want = run_engine(&row, &x, batch, 1);
            assert_bits_eq(
                &run_engine(&tiled, &x, batch, 1),
                &want,
                &format!("quant row-vs-tiled b{batch}"),
            );
            assert_bits_eq(
                &run_engine(&scoped, &x, batch, 2),
                &want,
                &format!("quant row-vs-tiled-sharded s{shards} b{batch}"),
            );
        }
    }
}

/// Every engine's `describe` reports the process-wide kernel selection —
/// how bench JSON lines track which kernel actually ran on a machine.
#[test]
fn describe_reports_kernel_selection() {
    let sel = srigl::kernels::describe_selection();
    assert!(sel.contains(srigl::kernels::selected().name()));
    let model = stack(&[Repr::CondensedTiled; 3], 0.25, 5);
    assert!(Engine::describe(&model).contains(&sel), "{}", Engine::describe(&model));
    let scoped = ShardedModel::from_model(&model, 2).unwrap();
    assert!(Engine::describe(&scoped).contains(&sel), "{}", Engine::describe(&scoped));
    let team = PersistentShardedEngine::from_model(&model, 2).unwrap();
    assert!(Engine::describe(&team).contains(&sel), "{}", Engine::describe(&team));
}

/// The persistent team's whole point: 100 forwards reuse the same S
/// threads. `ThreadId`s are guaranteed unique for the life of a process
/// (never reused), so if the engine spawned per request we would observe
/// 100*S distinct ids here instead of S.
#[test]
fn persistent_team_thread_count_constant_across_100_forwards() {
    let shards = 3;
    let model = stack(&[Repr::Condensed; 3], 0.25, 13);
    let team = PersistentShardedEngine::from_model(&model, shards).unwrap();
    let mut scratch = team.scratch(4);
    let mut rng = Rng::new(42);
    let mut seen = std::collections::HashSet::new();
    for i in 0..100usize {
        let batch = 1 + i % 4;
        let x: Vec<f32> = (0..batch * 64).map(|_| rng.normal_f32()).collect();
        let _ = team.forward(&x, batch, &mut scratch, 1);
        assert_eq!(team.team_size(), shards, "team never grows or shrinks");
        for tid in team.last_shard_threads() {
            seen.insert(tid.expect("every shard ran this forward"));
        }
        assert_eq!(
            seen.len(),
            shards,
            "forward {i}: the same {shards} long-lived threads must serve every request"
        );
    }
    assert!(
        !seen.contains(&std::thread::current().id()),
        "shard work runs on the team, not the caller"
    );
}

#[test]
fn balanced_plan_ranges_cover_each_layer() {
    let model = stack(&[Repr::Condensed; 3], 0.4, 9);
    for &shards in &[2usize, 3, 7] {
        let plan = ShardPlan::balanced(&model, shards).unwrap();
        assert_eq!(plan.shards(), shards);
        assert_eq!(plan.layers(), model.depth());
        for (li, layer) in model.layers().iter().enumerate() {
            let mut covered = 0usize;
            let mut prev_end = 0usize;
            for s in 0..shards {
                let r = plan.range(li, s);
                assert_eq!(r.start, prev_end, "contiguous");
                covered += r.len();
                prev_end = r.end;
            }
            assert_eq!(covered, layer.out_full_width(), "layer {li} fully covered");
            // balanced within one neuron's worth of stored weights of
            // ideal is not guaranteed by the greedy, but gross imbalance
            // (> 1.75x ideal) would mean the plan ignored the costs
            assert!(
                plan.imbalance(&model, li) < 1.75,
                "layer {li} imbalance {}",
                plan.imbalance(&model, li)
            );
        }
    }
}

/// `balanced` refuses shard counts the narrowest layer cannot fill — a
/// typed error, not a silent clamp (and not a panic downstream).
#[test]
fn oversized_shard_count_is_a_typed_error() {
    let model = stack(&[Repr::Condensed; 3], 0.25, 3); // narrowest layer: 16
    match ShardPlan::balanced(&model, 17) {
        Err(ShardPlanError::ShardsExceedWidth { shards, layer, width }) => {
            assert_eq!((shards, layer, width), (17, 2, 16));
        }
        other => panic!("expected ShardsExceedWidth, got {other:?}"),
    }
    assert_eq!(ShardPlan::balanced(&model, 0), Err(ShardPlanError::ZeroShards));
    // both sharded constructors propagate it
    assert!(ShardedModel::from_model(&model, 17).is_err());
    let err = PersistentShardedEngine::from_model(&model, 17).unwrap_err();
    assert!(format!("{err:#}").contains("17 shards"), "{err:#}");
}
